"""Table substrate edge cases: construction validation and schema
metadata lookups (serving-tier correctness satellites)."""

import jax
import numpy as np
import pytest

from repro.tables.table import ColumnMeta, RelSchema, Table

jax.config.update("jax_platform_name", "cpu")


def test_from_numpy_rejects_capacity_below_data():
    """Regression: capacity < n used to compute a negative pad and die
    inside jnp.concatenate with a confusing shape error."""
    data = {"a": np.arange(10, dtype=np.int32)}
    with pytest.raises(ValueError, match="below data length"):
        Table.from_numpy(data, capacity=5)


def test_from_numpy_capacity_pads_with_dead_rows():
    data = {"a": np.arange(4, dtype=np.int32)}
    tab = Table.from_numpy(data, capacity=8)
    assert tab.capacity == 8
    assert int(tab.live_count()) == 4
    np.testing.assert_array_equal(np.asarray(tab.freq),
                                  [1, 1, 1, 1, 0, 0, 0, 0])
    # capacity == n is the no-pad fast path
    assert Table.from_numpy(data, capacity=4).capacity == 4


def test_is_unique_raises_on_unknown_column():
    """Regression: a typo in FK/PK metadata used to be skipped silently,
    flipping §4.3 pre-grouping decisions without any error."""
    rel = RelSchema("part", (ColumnMeta("p_partkey", unique=True),
                             ColumnMeta("p_price")))
    assert rel.is_unique(["p_partkey"])
    assert rel.is_unique(["p_price", "p_partkey"])
    assert not rel.is_unique(["p_price"])
    with pytest.raises(KeyError, match="p_partkye"):
        rel.is_unique(["p_partkye"])        # typo'd name must raise
