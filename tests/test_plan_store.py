"""Plan persistence: serialisation round-trip (property test), the
on-disk store's corruption tolerance and version skew handling,
cross-process warm starts, and write-failure degradation.

The round-trip test mirrors ``test_graph_ir_differential``'s harness: a
hypothesis property test when hypothesis is installed, else a seeded sweep
over the same randomised case builder (visible, not silent, degradation).
The property pinned: ``plan_from_payload(plan_to_payload(plan))`` — with a
JSON round trip in between, exactly what the store does — preserves
``graph_key()``, ``subplan_keys()``, the topological op list, and bitwise
execution results across every plan class (ref / opt / opt_plus / oma).
"""

import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import (
    Executor,
    parse_sql,
    plan_from_payload,
    plan_query,
    plan_to_payload,
)
from repro.core.plan import PlanNotSerialisable, ScanOp
from repro.core.query import Agg, AggQuery, Atom, selection_from_spec
from repro.data import make_tpch_db
from repro.service import (
    PlanStore,
    QueryService,
    canonicalize,
    schema_fingerprint,
    store_fingerprint,
)
from repro.service.plan_store import FORMAT_VERSION
from repro.tables.table import ColumnMeta, RelSchema, Schema, Table

try:  # property tests degrade to a seeded sweep without hypothesis
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

jax.config.update("jax_platform_name", "cpu")

FIG1 = """
SELECT MIN(s.s_acctbal), MAX(s.s_acctbal)
FROM region r, nation n, supplier s, partsupp ps, part p
WHERE r.r_regionkey = n.n_regionkey AND n.n_nationkey = s.s_nationkey
  AND s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
  AND r.r_name IN (2, 3) AND p.p_price > 1200.0
"""
COSTLY_PARTS = """
SELECT SUM(ps.ps_supplycost), COUNT(*)
FROM partsupp ps, part p
WHERE ps.ps_partkey = p.p_partkey AND p.p_price > 1500.0
"""

# ---------------------------------------------------------------------------
# randomised case builder (same pattern as test_graph_ir_differential)
# ---------------------------------------------------------------------------
_N_IDS = 12
SCHEMA = Schema(relations={
    "node": RelSchema("node", (
        ColumnMeta("id", domain=_N_IDS),
        ColumnMeta("grp", domain=5),
        ColumnMeta("score"),
    )),
    "edge": RelSchema("edge", (
        ColumnMeta("src", domain=_N_IDS),
        ColumnMeta("dst", domain=_N_IDS),
    )),
})


def _make_db(rng):
    n_nodes = int(rng.integers(4, 24))
    n_edges = int(rng.integers(4, 40))
    node = {
        "id": rng.integers(0, _N_IDS, n_nodes).astype(np.int32),
        "grp": rng.integers(0, 5, n_nodes).astype(np.int32),
        "score": rng.integers(0, 50, n_nodes).astype(np.float32),
    }
    edge = {
        "src": rng.integers(0, _N_IDS, n_edges).astype(np.int32),
        "dst": rng.integers(0, _N_IDS, n_edges).astype(np.int32),
    }
    return {"node": Table.from_numpy(node), "edge": Table.from_numpy(edge)}


_AGG_POOL = (("min", "sc"), ("max", "sc"), ("sum", "sc"), ("avg", "sc"),
             ("median", "sc"), ("count", None))


def _make_query(rng):
    chain_len = int(rng.integers(0, 3))
    star = bool(rng.integers(0, 2)) and chain_len > 0
    atoms = [Atom("node", "n0", ("v0", "g", "sc"))]
    if chain_len >= 1:
        atoms.append(Atom("edge", "e1", ("v0", "x1")))
    if chain_len >= 2:
        atoms.append(Atom("edge", "e2", ("x1", "x2")))
    if star:
        atoms.append(Atom("edge", "e3", ("v0", "y1")))
    n_aggs = int(rng.integers(1, 3))
    picks = rng.choice(len(_AGG_POOL), size=n_aggs, replace=False)
    aggs = tuple(Agg(_AGG_POOL[i][0], _AGG_POOL[i][1]) for i in picks)
    group_by = ("g",) if rng.integers(0, 2) else ()
    selections, specs = {}, {}
    if rng.integers(0, 2):
        lit = int(rng.integers(1, 5))
        selections["n0"] = lambda c, lit=lit: c["grp"] < lit
        specs["n0"] = (("<", "grp", lit),)
    if chain_len >= 1 and rng.integers(0, 2):
        # same selection shape as the differential test (">" keeps rows
        # live for the ref baseline's grouped aggregates); the "in" op's
        # round trip is pinned deterministically by the FIG1 store tests
        lit = int(rng.integers(1, _N_IDS))
        specs["e1"] = ((">", "dst", lit),)
        selections["e1"] = selection_from_spec(specs["e1"])
    return AggQuery(atoms=tuple(atoms), aggregates=aggs, group_by=group_by,
                    selections=selections, selection_specs=specs)


def _assert_bitwise(a: dict, b: dict, ctx: str = ""):
    keys_a = {k for k in a if k != "__stats__"}
    keys_b = {k for k in b if k != "__stats__"}
    assert keys_a == keys_b, ctx
    for k in keys_a:
        va, vb = a[k], b[k]
        if k == "groups":
            assert set(va) == set(vb), ctx
            for c in va:
                xa, xb = np.asarray(va[c]), np.asarray(vb[c])
                assert xa.dtype == xb.dtype and xa.shape == xb.shape, \
                    (ctx, c)
                assert xa.tobytes() == xb.tobytes(), (ctx, c)
        else:
            xa, xb = np.asarray(va), np.asarray(vb)
            assert xa.dtype == xb.dtype and xa.shape == xb.shape, (ctx, k)
            assert xa.tobytes() == xb.tobytes(), (ctx, k)


def _ops_modulo_selection(plan):
    """The topological op list with rebuilt-by-spec selection callables
    normalised away (they compare by identity; the spec is the stable
    content)."""
    return [dataclasses.replace(op, selection=None)
            if isinstance(op, ScanOp) else op for op in plan.ops]


def _check_roundtrip(seed: int):
    rng = np.random.default_rng(seed)
    db = _make_db(rng)
    query = _make_query(rng)
    ex = Executor(db, SCHEMA)
    for mode in ("ref", "opt", "opt_plus", "oma"):
        try:
            plan = plan_query(query, SCHEMA, mode=mode)
        except ValueError:
            continue  # mode not applicable (not 0MA, say) — by design
        # through actual JSON text, exactly as the store writes it
        payload = json.loads(json.dumps(plan_to_payload(plan)))
        plan2 = plan_from_payload(payload)
        assert plan2.mode == plan.mode
        assert plan2.graph_key() == plan.graph_key(), mode
        assert plan2.subplan_keys() == plan.subplan_keys(), mode
        assert _ops_modulo_selection(plan2) == _ops_modulo_selection(plan)
        assert plan2.tree == plan.tree and plan2.var_cols == plan.var_cols
        _assert_bitwise(ex.execute(plan), ex.execute(plan2),
                        ctx=f"eager/{mode}")
        if mode in ("opt_plus", "oma"):
            _assert_bitwise(dict(ex.compile(plan)(db)),
                            dict(ex.compile(plan2)(db)),
                            ctx=f"compiled/{mode}")


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=12, deadline=None)
    def test_plan_serialisation_roundtrip(seed):
        _check_roundtrip(seed)
else:
    @pytest.mark.parametrize("seed", range(8))
    def test_plan_serialisation_roundtrip(seed):
        _check_roundtrip(seed)


def test_opaque_selections_are_not_serialisable(tmp_path):
    q = AggQuery(
        atoms=(Atom("node", "n0", ("v0", "g", "sc")),),
        aggregates=(Agg("count"),),
        selections={"n0": lambda c: c["grp"] > 1})   # no declarative spec
    plan = plan_query(q, SCHEMA)
    with pytest.raises(PlanNotSerialisable, match="opaque"):
        plan_to_payload(plan)
    store = PlanStore(tmp_path, schema_fingerprint(SCHEMA))
    assert store.save("f" * 64, plan) is False   # swallowed, not raised
    assert store.metrics()["persist_entries"] == 0


# ---------------------------------------------------------------------------
# the on-disk store
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tpch():
    db, schema = make_tpch_db(scale=30, seed=3)
    return db, schema


def test_plan_store_roundtrip_across_instances(tmp_path, tpch):
    """A second PlanStore over the same directory (a fresh process, in
    effect) serves the plan the first one persisted."""
    db, schema = tpch
    canon = canonicalize(parse_sql(FIG1, schema))
    plan = plan_query(canon.query, schema)
    store = PlanStore(tmp_path, schema_fingerprint(schema))
    assert store.save(canon.fingerprint, plan)
    assert store.metrics()["persist_writes"] == 1
    assert store.metrics()["persist_entries"] == 1

    fresh = PlanStore(tmp_path, schema_fingerprint(schema))
    loaded = fresh.load(canon.fingerprint)
    assert loaded is not None
    assert loaded.graph_key() == plan.graph_key()
    assert loaded.subplan_keys() == plan.subplan_keys()
    _assert_bitwise(Executor(db, schema).execute(plan),
                    Executor(db, schema).execute(loaded))
    assert fresh.load("0" * 64) is None
    m = fresh.metrics()
    assert m["persist_hits"] == 1 and m["persist_misses"] == 1
    assert m["persist_corrupt_skipped"] == 0


def _single_entry(store: PlanStore):
    paths = list(store.plans_dir.glob("*.json"))
    assert len(paths) == 1
    return paths[0]


@pytest.mark.parametrize("damage", ["truncated", "flipped", "version",
                                    "schema"])
def test_corrupt_and_skewed_entries_skipped_and_evicted(
        tmp_path, tpch, damage):
    """A damaged entry — truncated file, flipped payload byte, wrong
    format version, foreign schema fingerprint — is skipped with
    ``persist_corrupt_skipped`` incremented and evicted; the query is
    still served correctly via re-plan (and re-persisted)."""
    db, schema = tpch
    want = QueryService(db, schema).submit(FIG1)

    svc = QueryService(db, schema, cache_dir=tmp_path)
    svc.submit(FIG1)
    path = _single_entry(svc.plan_store)
    raw = path.read_bytes()
    if damage == "truncated":
        path.write_bytes(raw[:len(raw) // 2])
    elif damage == "flipped":
        doc = json.loads(raw)
        doc["payload"]["mode"] = "omx"          # checksum now mismatches
        path.write_text(json.dumps(doc))
    elif damage == "version":
        doc = json.loads(raw)
        doc["format_version"] = FORMAT_VERSION + 99
        path.write_text(json.dumps(doc))
    else:
        doc = json.loads(raw)
        doc["schema_fingerprint"] = "f" * 64
        path.write_text(json.dumps(doc))

    svc2 = QueryService(db, schema, cache_dir=tmp_path)
    res = svc2.submit(FIG1)
    assert res.error is None
    np.testing.assert_array_equal(
        np.asarray(res.values["min(s.s_acctbal)"]),
        np.asarray(want.values["min(s.s_acctbal)"]))
    m = svc2.metrics()
    assert m["persist_corrupt_skipped"] == 1
    assert m["persist_hits"] == 0
    assert m["plan_builds"] == 1                 # served via re-plan
    assert m["persist_writes"] == 1              # ...and re-persisted
    # the damaged file was evicted (then replaced by the fresh write)
    assert json.loads(_single_entry(svc2.plan_store).read_text())[
        "format_version"] == FORMAT_VERSION


def test_store_warm_start_in_process(tmp_path, tpch):
    """cache_dir warm start: a second service over the same directory
    replans nothing and answers bitwise-identically."""
    db, schema = tpch
    svc = QueryService(db, schema, cache_dir=tmp_path)
    cold = [svc.submit(FIG1), svc.submit(COSTLY_PARTS)]
    m = svc.metrics()
    assert m["plan_builds"] == 2 and m["persist_writes"] == 2

    warm_svc = QueryService(db, schema, cache_dir=tmp_path)
    warm = [warm_svc.submit(FIG1), warm_svc.submit(COSTLY_PARTS)]
    m2 = warm_svc.metrics()
    assert m2["plan_builds"] == 0
    assert m2["persist_hits"] == 2 and m2["persist_misses"] == 0
    for a, b in zip(cold, warm):
        _assert_bitwise(a.values, b.values)


@pytest.mark.persistence
def test_cross_process_warm_start(tmp_path, tpch):
    """A subprocess builds and persists the plans; a fresh in-test
    QueryService over the same cache_dir serves the same queries with
    persist hits, zero re-plans, and bitwise-equal answers."""
    db, schema = tpch
    child = f"""
import json
import jax
jax.config.update("jax_platform_name", "cpu")
import numpy as np
from repro.data import make_tpch_db
from repro.service import QueryService

db, schema = make_tpch_db(scale=30, seed=3)
svc = QueryService(db, schema, cache_dir={str(tmp_path)!r})
out = {{}}
for name, sql in (("fig1", {FIG1!r}), ("costly", {COSTLY_PARTS!r})):
    r = svc.submit(sql)
    out[name] = {{k: np.asarray(v).tobytes().hex()
                 for k, v in r.values.items()}}
m = svc.metrics()
print(json.dumps({{"answers": out, "plan_builds": m["plan_builds"],
                   "persist_writes": m["persist_writes"]}}))
"""
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    proc = subprocess.run([sys.executable, "-c", child],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    report = json.loads(proc.stdout.strip().splitlines()[-1])
    assert report["plan_builds"] == 2 and report["persist_writes"] == 2

    svc = QueryService(db, schema, cache_dir=tmp_path)
    got = {"fig1": svc.submit(FIG1), "costly": svc.submit(COSTLY_PARTS)}
    m = svc.metrics()
    assert m["plan_builds"] == 0                  # zero re-plans
    assert m["persist_hits"] == 2
    for name, res in got.items():
        assert res.error is None
        want = report["answers"][name]
        assert {k: np.asarray(v).tobytes().hex()
                for k, v in res.values.items()} == want


def test_failed_write_degrades_to_memory_only(tmp_path, tpch):
    """Regression (composes with PR 4's fault isolation): a failing disk
    write attaches NO error to the request and the service degrades to
    memory-only caching."""
    db, schema = tpch
    svc = QueryService(db, schema, cache_dir=tmp_path / "store")
    # sabotage the store after init: replace the plans directory with a
    # regular file, so every write (even as root, where chmod is decor)
    # fails with NotADirectoryError
    plans_dir = svc.plan_store.plans_dir
    plans_dir.rmdir()
    plans_dir.write_text("not a directory")

    res = svc.submit(FIG1)
    assert res.error is None and res.values
    batch = svc.submit_many([FIG1, COSTLY_PARTS])
    assert all(r.error is None for r in batch)
    m = svc.metrics()
    assert m["persist_write_errors"] >= 1
    assert m["persist_writes"] == 0
    # memory-only caching still works: the repeat was a plan-cache hit
    assert m["plan_hits"] >= 1 and m["plan_builds"] == 2


def test_unwritable_cache_dir_never_crashes_construction(tmp_path, tpch):
    """cache_dir pointing under a regular file: construction, serving,
    and metrics all work; persistence is simply off."""
    db, schema = tpch
    blocker = tmp_path / "blocker"
    blocker.write_text("file, not dir")
    svc = QueryService(db, schema, cache_dir=blocker / "nested")
    res = svc.submit(COSTLY_PARTS)
    assert res.error is None and res.values
    m = svc.metrics()
    assert m["persist_hits"] == 0 and m["persist_entries"] == 0
    assert m["persist_write_errors"] >= 1


def test_export_import_cache(tmp_path, tpch):
    """export_cache → import_cache moves a warm plan cache between
    services with no re-planning on the importer."""
    db, schema = tpch
    svc = QueryService(db, schema)                # no cache_dir at all
    svc.submit(FIG1)
    svc.submit(COSTLY_PARTS)
    assert svc.export_cache(tmp_path / "exported") == 2

    svc2 = QueryService(db, schema)
    assert svc2.import_cache(tmp_path / "exported") == 2
    a = svc2.submit(FIG1)
    b = svc2.submit(COSTLY_PARTS)
    assert a.error is None and b.error is None
    m = svc2.metrics()
    assert m["plan_builds"] == 0 and m["plan_hits"] == 2
    _assert_bitwise(a.values, QueryService(db, schema).submit(FIG1).values)


def test_import_from_foreign_store_never_evicts(tmp_path, tpch):
    """Regression: importing a directory written under ANOTHER schema (or
    format version) must skip every entry — not delete them.  The source
    may be a shared warm store that other services still depend on."""
    db, schema = tpch
    svc = QueryService(db, schema, cache_dir=tmp_path)
    svc.submit(FIG1)
    path = _single_entry(svc.plan_store)
    doc = json.loads(path.read_text())
    doc["schema_fingerprint"] = "f" * 64          # a foreign service's store
    path.write_text(json.dumps(doc))

    svc2 = QueryService(db, schema)
    assert svc2.import_cache(tmp_path) == 0       # nothing usable
    assert path.exists()                          # ...and nothing destroyed


def test_schema_fingerprint_sensitivity(tpch):
    _, schema = tpch
    fp = schema_fingerprint(schema)
    assert fp == schema_fingerprint(schema)       # deterministic
    mutated = Schema(relations=dict(schema.relations),
                     foreign_keys=schema.foreign_keys[:-1])
    assert schema_fingerprint(mutated) != fp


def test_store_keyed_by_planner_config(tmp_path, tpch):
    """Regression: persisted plans are planner OUTPUT — a store warmed by
    a mode='ref' service must not hand materialising plans to a default
    (auto → 0MA/Opt⁺) service sharing the cache_dir, and vice versa."""
    db, schema = tpch
    assert store_fingerprint(schema) != store_fingerprint(schema,
                                                          mode="ref")
    assert store_fingerprint(schema) != store_fingerprint(schema,
                                                          use_fkpk=True)

    ref_svc = QueryService(db, schema, mode="ref", cache_dir=tmp_path)
    res_ref = ref_svc.submit(FIG1)
    assert res_ref.stats.mode == "ref"
    assert ref_svc.metrics()["persist_writes"] == 1

    auto_svc = QueryService(db, schema, cache_dir=tmp_path)
    res_auto = auto_svc.submit(FIG1)
    m = auto_svc.metrics()
    assert res_auto.stats.mode != "ref"           # its own planner ran
    assert m["persist_hits"] == 0 and m["plan_builds"] == 1
    # ...and neither store evicted the other's entry
    assert ref_svc.metrics()["persist_entries"] == 1
    assert m["persist_entries"] == 1

    # the ref service still warm-starts from its own scoped entries
    ref2 = QueryService(db, schema, mode="ref", cache_dir=tmp_path)
    assert ref2.submit(FIG1).stats.mode == "ref"
    assert ref2.metrics()["plan_builds"] == 0
    assert ref2.metrics()["persist_hits"] == 1
