"""Op-graph IR unit tests: node content keys, DAG utilities, the pass
pipeline, and the linear ``ops`` compatibility view."""

import jax
import pytest

from repro.core import PlanNode, parse_sql, plan_query, rewrite_dag
from repro.core.plan import (
    FinalAggOp,
    FreqJoinOp,
    MaterializeJoinOp,
    ScanOp,
    SemiJoinOp,
)
from repro.core.query import Agg, AggQuery, Atom
from repro.core.rewrite import PASSES
from repro.data import make_tpch_db

jax.config.update("jax_platform_name", "cpu")

SUM3 = """SELECT SUM(s.s_acctbal) FROM supplier s, nation n, region r
WHERE s.s_nationkey = n.n_nationkey AND n.n_regionkey = r.r_regionkey
  AND r.r_name IN (2, 3)"""


@pytest.fixture(scope="module")
def schema():
    return make_tpch_db(scale=5)[1]


def test_node_keys_hash_the_whole_sub_dag(schema):
    a = plan_query(parse_sql(SUM3, schema), schema)
    b = plan_query(parse_sql(SUM3.replace("(2, 3)", "(1, 4)"), schema),
                   schema)
    # the filtered scan differs → every node ABOVE it differs too, while
    # the untouched sibling scans keep their keys
    a_keys = {n.key() for n in a.nodes}
    b_keys = {n.key() for n in b.nodes}
    assert a_keys != b_keys
    shared = a_keys & b_keys
    assert any(isinstance(n.op, ScanOp) and n.key() in shared
               for n in a.nodes)           # unfiltered scans unify
    roots = [p.root.inputs[0] for p in (a, b)]
    assert roots[0].key() != roots[1].key()  # chains diverge at the root


def test_node_key_is_alias_and_variable_blind(schema):
    renamed = """SELECT SUM(su.s_acctbal) FROM region re, supplier su,
        nation na WHERE re.r_name IN (3, 2)
        AND na.n_regionkey = re.r_regionkey
        AND su.s_nationkey = na.n_nationkey"""
    from repro.service import canonicalize
    pa = plan_query(canonicalize(parse_sql(SUM3, schema)).query, schema)
    pb = plan_query(canonicalize(parse_sql(renamed, schema)).query, schema)
    assert pa.root.key() == pb.root.key()
    assert pa.graph_key() == pb.graph_key()


def test_ops_view_is_topological(schema):
    for mode in ("ref", "opt", "opt_plus", "oma"):
        try:
            plan = plan_query(parse_sql(SUM3, schema), schema, mode=mode)
        except ValueError:
            continue
        seen: set[int] = set()
        for node in plan.nodes:
            assert all(id(i) in seen for i in node.inputs)
            seen.add(id(node))
        assert isinstance(plan.nodes[-1].op, FinalAggOp)
        assert plan.ops == tuple(n.op for n in plan.nodes)


def test_rewrite_dag_preserves_sharing():
    scan = PlanNode(ScanOp("a", "r", None), (), ("scan", "r", (0,), None))
    join = PlanNode(SemiJoinOp("a", "a", ()), (scan, scan), (("semi",), (), ()))
    out = rewrite_dag(join, lambda n, ins: PlanNode(n.op, ins, n.struct))
    assert out.inputs[0] is out.inputs[1]   # shared input rewritten once


def test_materialising_nodes_poison_keys(schema):
    plan = plan_query(parse_sql(SUM3, schema), schema, mode="ref")
    assert plan.graph_key() is None
    mat = [n for n in plan.nodes if isinstance(n.op, MaterializeJoinOp)]
    assert mat and all(n.key() is None for n in mat)
    # scans below the materialise stay shareable
    assert all(n.key() is not None for n in plan.nodes
               if isinstance(n.op, ScanOp))
    assert plan.subplan_keys() == frozenset()


def test_subplan_keys_skip_trivial_scans(schema):
    plan = plan_query(parse_sql(SUM3, schema), schema)
    keys = plan.subplan_keys()
    joins = [n for n in plan.nodes
             if isinstance(n.op, (SemiJoinOp, FreqJoinOp))]
    sel_scans = [n for n in plan.nodes
                 if isinstance(n.op, ScanOp) and n.op.spec is not None]
    bare_scans = [n for n in plan.nodes
                  if isinstance(n.op, ScanOp) and n.op.spec is None
                  and n.op.selection is None]
    assert {n.key() for n in joins} <= keys
    assert {n.key() for n in sel_scans} <= keys
    assert not ({n.key() for n in bare_scans} & keys)


def test_pass_pipeline_stages():
    names = [p.__name__ for p in PASSES]
    assert names == ["_pass_classify", "_pass_reroot_guard", "_pass_lower",
                     "_pass_fkpk_degrade", "_pass_fk_join_eliminate",
                     "_pass_prefilter_pushdown", "_pass_attach_selections"]


def test_fkpk_pass_rewrites_the_lowered_graph(schema):
    """§4.3 as an IR rewrite: the FK/PK plan differs from the plain plan
    only in degraded join nodes — scans keep their identity keys."""
    q = parse_sql("""SELECT MEDIAN(ps.ps_supplycost)
        FROM partsupp ps, part p
        WHERE ps.ps_partkey = p.p_partkey""", schema)
    plain = plan_query(q, schema, mode="opt_plus", use_fkpk=False)
    fkpk = plan_query(q, schema, mode="opt_plus", use_fkpk=True)
    assert any(isinstance(op, FreqJoinOp) for op in plain.ops)
    assert any(isinstance(op, SemiJoinOp) for op in fkpk.ops)
    plain_scans = {n.key() for n in plain.nodes
                   if isinstance(n.op, ScanOp)}
    fkpk_scans = {n.key() for n in fkpk.nodes if isinstance(n.op, ScanOp)}
    assert plain_scans == fkpk_scans


def test_opaque_selection_keys_are_object_bound():
    q1 = AggQuery(atoms=(Atom("part", "p", ("pk", "price")),),
                  aggregates=(Agg("count"),),
                  selections={"p": lambda c: c["p_price"] > 100})
    _, schema = make_tpch_db(scale=5)
    p1 = plan_query(q1, schema)
    p2 = plan_query(q1, schema)
    assert p1.root.key() == p2.root.key()   # same callable object → equal
