"""LM distribution equivalence + elastic re-mesh (8-device subprocess)."""

import os
import pathlib
import subprocess
import sys

import pytest

HELPER = pathlib.Path(__file__).parent / "helpers" / "distributed_lm_check.py"
SRC = str(pathlib.Path(__file__).parent.parent / "src")


@pytest.mark.slow
def test_sharded_training_and_elastic_remesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(HELPER)], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "ALL LM DISTRIBUTED CHECKS PASSED" in out.stdout
