"""Distributed-engine equivalence: runs the 8-device ring sweep in a
subprocess (device count must be fixed before jax initialises)."""

import os
import pathlib
import subprocess
import sys

import pytest

HELPER = pathlib.Path(__file__).parent / "helpers" / "distributed_engine_check.py"
SRC = str(pathlib.Path(__file__).parent.parent / "src")


@pytest.mark.slow
def test_ring_freq_join_matches_local_executor():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(HELPER)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "ALL DISTRIBUTED CHECKS PASSED" in out.stdout
