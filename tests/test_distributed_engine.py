"""Distributed-engine equivalence: runs the 8-device ring sweep in a
subprocess (device count must be fixed before jax initialises).

Two layers of differential coverage, both BITWISE:

* executor level — ``DistributedExecutor.compile``/``compile_multi`` vs
  the local ``Executor`` on identically-padded tables;
* service level — ``QueryService(mesh=...)`` vs a single-device
  ``QueryService`` across every planner mode (ref/opt/opt_plus/oma),
  fused-vs-individual submission, and within-bucket growth.
"""

import os
import pathlib
import subprocess
import sys

import pytest

HELPERS = pathlib.Path(__file__).parent / "helpers"
SRC = str(pathlib.Path(__file__).parent.parent / "src")


def _run_on_8_devices(helper: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, str(HELPERS / helper)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_ring_freq_join_matches_local_executor():
    out = _run_on_8_devices("distributed_engine_check.py")
    assert "ALL DISTRIBUTED CHECKS PASSED" in out


@pytest.mark.slow
def test_mesh_service_matches_local_service_all_modes():
    out = _run_on_8_devices("mesh_service_check.py")
    assert "ALL MESH SERVICE CHECKS PASSED" in out
    for mode in ("ref", "opt", "opt_plus", "oma"):
        assert f"ok mode={mode}" in out
