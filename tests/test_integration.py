"""Cross-layer integration tests: the engine inside the LM stack, and a
full train→checkpoint→serve loop."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.checkpoint import Checkpointer
from repro.data import TokenPipeline
from repro.models import init_params
from repro.models.moe import load_stats, moe_apply, moe_init
from repro.models.lm_serving import ServeEngine
from repro.training import build_train_step, init_train_state

jax.config.update("jax_platform_name", "cpu")


def test_moe_load_stats_is_a_guarded_count_query():
    """DESIGN.md §4: expert load accounting = COUNT(*) GROUP BY expert,
    computed with the paper engine's segmented-sum machinery; must equal
    a numpy bincount oracle."""
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, 8, (64, 2)), jnp.int32)
    loads = load_stats(idx, n_experts=8)
    want = np.bincount(np.asarray(idx).ravel(), minlength=8)
    np.testing.assert_array_equal(np.asarray(loads), want)


def test_moe_capacity_drop_accounting():
    cfg = dataclasses.replace(get_smoke_config("mixtral-8x22b"),
                              dtype="float32", capacity_factor=0.5)
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 16, cfg.d_model)),
                    jnp.float32)
    out, aux = moe_apply(p, cfg, x, jnp.float32)
    assert out.shape == x.shape
    # with capacity factor 0.5 some tokens must drop, but never all
    assert 0.0 < float(aux["dropped_frac"]) < 1.0


def test_train_checkpoint_serve_roundtrip(tmp_path):
    """Train a few steps, checkpoint, restore into a serving engine, and
    generate — the full production loop on one container."""
    cfg = dataclasses.replace(get_smoke_config("smollm-135m"),
                              dtype="float32")
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params)
    step = jax.jit(build_train_step(cfg, base_lr=5e-3, warmup=2,
                                    total_steps=10, remat="none"))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=16,
                         global_batch=4, seed=5)
    for i in range(5):
        state, metrics = step(state, pipe.jax_batch(i))
    ckpt = Checkpointer(tmp_path / "ck")
    ckpt.save(5, state, async_=False)

    restored = ckpt.restore(like=state)
    engine = ServeEngine(restored.params, cfg, n_slots=2, max_len=48)
    rng = np.random.default_rng(9)
    r1 = engine.submit(rng.integers(0, cfg.vocab_size, 8))
    r2 = engine.submit(rng.integers(0, cfg.vocab_size, 8))
    outs = engine.run_wave(max_tokens=6)
    assert set(outs) == {r1, r2}
    assert all(len(t) == 6 for t in outs.values())
    assert all(0 <= tok < cfg.vocab_size for t in outs.values() for tok in t)
