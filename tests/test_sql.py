"""SQL front-end + grouped-median tests."""

import jax
import numpy as np
import pytest

from repro.core import Executor, classify, plan_query
from repro.core.query import Agg, AggQuery, Atom
from repro.core.sql import SqlError, parse_sql
from repro.data import make_stats_db, make_tpch_db
from repro.data.relational import tpch_v1_query

jax.config.update("jax_platform_name", "cpu")

FIG1_SQL = """
SELECT MIN(s.s_acctbal), MAX(s.s_acctbal)
FROM region r, nation n, supplier s, partsupp ps, part p
WHERE r.r_regionkey = n.n_regionkey AND n.n_nationkey = s.s_nationkey
  AND s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
  AND r.r_name IN (2, 3) AND p.p_price > 1200.0
"""


def test_fig1_sql_is_oma_and_matches_handbuilt():
    db, schema = make_tpch_db(scale=100, seed=3)
    q = parse_sql(FIG1_SQL, schema)
    cls = classify(q, schema)
    assert cls.is_oma and cls.guard == "s"
    ex = Executor(db, schema)
    got = ex.execute(plan_query(q, schema))
    want = ex.execute(plan_query(tpch_v1_query("minmax"), schema))
    np.testing.assert_allclose(
        float(got["min(s.s_acctbal)"]), float(want["min(bal)"]))
    np.testing.assert_allclose(
        float(got["max(s.s_acctbal)"]), float(want["max(bal)"]))


def test_sql_count_group_by():
    db, schema = make_stats_db(n_users=30, n_posts=100, n_comments=250,
                               n_votes=100, seed=2)
    q = parse_sql("""
        SELECT COUNT(*) FROM posts po, comments co
        WHERE po.p_id = co.c_post
        GROUP BY po.p_owner
    """, schema)
    assert q.group_by and q.aggregates[0].func == "count"
    res = Executor(db, schema).execute(plan_query(q, schema))
    assert "groups" in res


def test_sql_errors_are_informative():
    _, schema = make_tpch_db(scale=5)
    with pytest.raises(SqlError, match="unknown relation"):
        parse_sql("SELECT COUNT(*) FROM nope x", schema)
    with pytest.raises(SqlError, match="no aggregate"):
        parse_sql("SELECT p.p_price FROM part p", schema)
    with pytest.raises(SqlError, match="unknown column"):
        parse_sql("SELECT MIN(p.bogus) FROM part p", schema)


def test_sql_malformed_aggregate():
    _, schema = make_tpch_db(scale=5)
    # empty argument list never matches the aggregate grammar
    with pytest.raises(SqlError, match="no aggregate"):
        parse_sql("SELECT MIN() FROM part p", schema)
    # unqualified column in an aggregate
    with pytest.raises(SqlError, match="qualify the column"):
        parse_sql("SELECT MIN(p_price) FROM part p", schema)
    # unknown alias inside the aggregate
    with pytest.raises(SqlError, match="unknown alias"):
        parse_sql("SELECT MIN(zz.p_price) FROM part p", schema)


def test_sql_unknown_relation_and_alias_in_where():
    _, schema = make_tpch_db(scale=5)
    with pytest.raises(SqlError, match="unknown relation"):
        parse_sql("SELECT COUNT(*) FROM part p, nosuch n "
                  "WHERE p.p_partkey = n.n_key", schema)
    with pytest.raises(SqlError, match="unknown alias"):
        parse_sql("SELECT COUNT(*) FROM part p "
                  "WHERE q.p_price > 10", schema)


def test_sql_non_equi_join_term_rejected():
    _, schema = make_tpch_db(scale=5)
    with pytest.raises(SqlError, match="non-equi join"):
        parse_sql("""
            SELECT COUNT(*) FROM partsupp ps, part p
            WHERE ps.ps_partkey = p.p_partkey
              AND ps.ps_supplycost < p.p_price
        """, schema)
    with pytest.raises(SqlError, match="unsupported WHERE term"):
        parse_sql("SELECT COUNT(*) FROM part p "
                  "WHERE p.p_price BETWEEN 1 AND 2", schema)


def test_sql_exposes_declarative_selection_specs():
    """The serving tier fingerprints queries by their declarative selection
    specs; parse_sql must populate them alongside the closures."""
    _, schema = make_tpch_db(scale=5)
    q = parse_sql(FIG1_SQL, schema)
    assert set(q.selection_specs) == set(q.selections) == {"r", "p"}
    assert ("in", "r_name", (2, 3)) in q.selection_specs["r"]
    assert (">", "p_price", 1200.0) in q.selection_specs["p"]


def test_grouped_median_matches_numpy():
    db, schema = make_stats_db(n_users=20, n_posts=60, n_comments=200,
                               n_votes=80, seed=8)
    atoms = (Atom("posts", "po", ("pid", "uid", "score")),
             Atom("comments", "co", ("pid", "cuid", "cscore")))
    q = AggQuery(atoms=atoms, group_by=("uid",),
                 aggregates=(Agg("median", "score"),))
    res = Executor(db, schema).execute(plan_query(q, schema,
                                                  mode="opt_plus"))
    cols, valid = res["groups"], res["valid"]
    got = {int(u): float(m) for u, m, v in
           zip(np.asarray(cols["uid"]), np.asarray(cols["median(score)"]),
               np.asarray(valid)) if v}

    # numpy oracle over the expanded join (weighted/lower median)
    po, co = db["posts"], db["comments"]
    pid2 = {}
    for pid, uid, sc in zip(np.asarray(po.columns["p_id"]),
                            np.asarray(po.columns["p_owner"]),
                            np.asarray(po.columns["p_score"])):
        pid2[int(pid)] = (int(uid), int(sc))
    per_user: dict[int, list[int]] = {}
    for pid in np.asarray(co.columns["c_post"]):
        if int(pid) in pid2:
            uid, sc = pid2[int(pid)]
            per_user.setdefault(uid, []).append(sc)
    want = {}
    for uid, vals in per_user.items():
        v = np.sort(vals)
        want[uid] = float(v[max(0, int(np.ceil(len(v) / 2)) - 1)])
    assert got == want
