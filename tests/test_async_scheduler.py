"""Async submission tier: per-request futures, cross-caller batch
formation, backpressure, drain-on-close, and the threaded stress test
interleaving submissions with bucket-crossing table updates."""

import threading
import time
from collections import Counter

import jax
import numpy as np
import pytest

from repro.data import make_tpch_db
from repro.service import AdmissionError, QueryService, ServiceClosedError
from repro.tables.table import Table, bucket_capacity

jax.config.update("jax_platform_name", "cpu")

FIG1 = """
SELECT MIN(s.s_acctbal), MAX(s.s_acctbal)
FROM region r, nation n, supplier s, partsupp ps, part p
WHERE r.r_regionkey = n.n_regionkey AND n.n_nationkey = s.s_nationkey
  AND s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
  AND r.r_name IN (2, 3) AND p.p_price > 1200.0
"""
_SUPP_DIMS = """FROM supplier s, nation n, region r
WHERE s.s_nationkey = n.n_nationkey AND n.n_regionkey = r.r_regionkey
  AND r.r_name IN (2, 3)"""
_PART_DIMS = """FROM partsupp ps, part p
WHERE ps.ps_partkey = p.p_partkey AND p.p_price > 1500.0"""
# the benchmark's dashboard: two subplan-overlap fusion sets
# ({supplier-dims family ∪ FIG1}, {partsupp-dims family})
DASHBOARD = [
    f"SELECT MIN(s.s_acctbal), MAX(s.s_acctbal) {_SUPP_DIMS}",
    f"SELECT SUM(s.s_acctbal) {_SUPP_DIMS}",
    f"SELECT COUNT(*) AS n, AVG(s.s_acctbal) AS avg {_SUPP_DIMS} "
    "GROUP BY s.s_nationkey",
    f"SELECT MEDIAN(s.s_acctbal) {_SUPP_DIMS}",
    f"SELECT SUM(ps.ps_supplycost), COUNT(*) {_PART_DIMS}",
    f"SELECT AVG(ps.ps_supplycost) AS avg_cost {_PART_DIMS} "
    "GROUP BY ps.ps_suppkey",
    FIG1,
]
# duplication-invariant queries (MIN/MAX only) for the stress test: the
# updater grows tables by RESAMPLING existing rows, which never changes a
# MIN/MAX answer — so every interleaving must match the serial baseline
MINMAX_QUERIES = [
    FIG1,
    f"SELECT MIN(s.s_acctbal) {_SUPP_DIMS}",
    """SELECT MIN(s.s_acctbal), MAX(s.s_acctbal)
FROM supplier s, nation n, region r, partsupp ps
WHERE s.s_nationkey = n.n_nationkey AND n.n_regionkey = r.r_regionkey
  AND s.s_suppkey = ps.ps_suppkey AND r.r_name IN (2, 3)""",
]


def _assert_values_equal(a: dict, b: dict):
    assert set(a) == set(b)
    for k, va in a.items():
        vb = b[k]
        if k == "groups":
            assert set(va) == set(vb)
            for c in va:
                np.testing.assert_array_equal(np.asarray(va[c]),
                                              np.asarray(vb[c]))
        else:
            np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))


def test_async_single_caller_roundtrip():
    db, schema = make_tpch_db(scale=30, seed=3)
    svc = QueryService(db, schema)
    try:
        fut = svc.submit_async(FIG1)
        res = fut.result(60)
        assert res.error is None
        _assert_values_equal(res.values, svc.submit(FIG1).values)
        m = svc.metrics()
        assert m["async_requests"] == 1
        assert m["async_batches"] >= 1
        assert m["queue_depth_peak"] >= 1
        assert m["rejected"] == 0
    finally:
        svc.close()


def test_async_cross_caller_batch_formation():
    """N independent callers each submitting ONE query land in one
    batching window and fuse like a single submit_many: fewer compiles
    than requests/fingerprints, answers bitwise-identical to serial."""
    db, schema = make_tpch_db(scale=30, seed=4)
    threads_n = 8
    work = [DASHBOARD[i % len(DASHBOARD)] for i in range(threads_n)]

    serial_svc = QueryService(db, schema)
    serial = [serial_svc.submit(sql) for sql in work]

    svc = QueryService(db, schema, async_max_wait_ms=500,
                       async_max_batch=64)
    try:
        barrier = threading.Barrier(threads_n)
        futs: list = [None] * threads_n

        def caller(i):
            barrier.wait()
            futs[i] = svc.submit_async(work[i])

        workers = [threading.Thread(target=caller, args=(i,))
                   for i in range(threads_n)]
        for t in workers:
            t.start()
        for t in workers:
            t.join()
        results = [f.result(120) for f in futs]
        for got, want in zip(results, serial):
            assert got.error is None
            _assert_values_equal(got.values, want.values)
        m = svc.metrics()
        assert m["async_requests"] == threads_n
        assert m["async_batches"] >= 1
        distinct = len(set(work))
        assert m["fused_compiles"] < distinct
        assert m["compiles"] < threads_n
        # cross-caller fusion happened — all but FIG1, whose heavy
        # 5-relation plan the fusion cost gate bands away from the cheap
        # supplier-dims family (it serves solo by design)
        assert m["fused_queries"] >= distinct - 1
        assert m["fusion_cost_rejects"] >= 1
    finally:
        svc.close()


def test_async_bad_batchmate_isolated():
    """A malformed query in the same batching window fails only its own
    future; co-batched valid requests still get answers."""
    db, schema = make_tpch_db(scale=30, seed=5)
    svc = QueryService(db, schema, async_max_wait_ms=500,
                       async_max_batch=64)
    try:
        before = svc.metrics()["async_batches"]
        good1 = svc.submit_async(FIG1)
        bad = svc.submit_async("SELECT MIN(x.nope) FROM nowhere x")
        good2 = svc.submit_async(DASHBOARD[1])
        r1, r2 = good1.result(120), good2.result(120)
        assert r1.error is None and r1.values
        assert r2.error is None and r2.values
        with pytest.raises(Exception, match="nowhere"):
            bad.result(120)
        m = svc.metrics()
        # one window → one batch: the bad request really was co-batched
        assert m["async_batches"] - before == 1
        assert m["request_errors"] >= 1
    finally:
        svc.close()


def test_async_backpressure_rejects_on_full_queue():
    db, schema = make_tpch_db(scale=20, seed=6)
    svc = QueryService(db, schema, async_max_queue=2, async_max_wait_ms=1)
    entered, release = threading.Event(), threading.Event()
    orig = svc.submit_many

    def blocking(queries):
        entered.set()
        assert release.wait(60), "test orchestration stalled"
        return orig(queries)

    svc.submit_many = blocking
    try:
        inflight = svc.submit_async(FIG1)
        assert entered.wait(60)          # batcher holds the first request
        queued = [svc.submit_async(FIG1) for _ in range(2)]
        with pytest.raises(AdmissionError, match="queue full"):
            svc.submit_async(FIG1)
        assert svc.metrics()["rejected"] == 1
        assert svc.metrics()["queue_depth_peak"] == 2
        release.set()
        assert inflight.result(120).error is None
        for f in queued:
            assert f.result(120).error is None
    finally:
        release.set()
        svc.close()


def test_async_close_drains_pending_requests():
    db, schema = make_tpch_db(scale=20, seed=7)
    # a window far longer than the test: only close() can flush it
    svc = QueryService(db, schema, async_max_wait_ms=60_000)
    futs = [svc.submit_async(q) for q in (FIG1, DASHBOARD[1])]
    svc.close(timeout=120)
    for f in futs:
        assert f.result(1).error is None
    # typed close-time rejection: an AdmissionError subclass (so retry
    # loops written against backpressure survive shutdown) that is ALSO
    # a RuntimeError (the pre-typed contract), counted apart from
    # backpressure rejections
    with pytest.raises(ServiceClosedError, match="closed"):
        svc.submit_async(FIG1)
    with pytest.raises(AdmissionError):
        svc.submit_async(FIG1)
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit_async(FIG1)
    m = svc.metrics()
    assert m["rejected_closed"] == 3
    assert m["rejected"] == 0
    # sync serving still works after close
    assert svc.submit(FIG1).values


def test_dropped_service_is_collectable_without_close():
    """Regression: the batcher thread holds the service only weakly (plus
    a pin while requests are pending), so a dropped QueryService — tables,
    caches, executables and all — is garbage-collected and its batcher
    thread exits even when close() was never called."""
    import gc
    import weakref

    db, schema = make_tpch_db(scale=20, seed=9)
    svc = QueryService(db, schema)
    assert svc.submit_async(FIG1).result(120).error is None
    thread = svc._scheduler._thread
    ref = weakref.ref(svc)
    del svc
    deadline = time.monotonic() + 10
    while ref() is not None and time.monotonic() < deadline:
        gc.collect()                # the batcher unpins just after serving
        time.sleep(0.05)
    assert ref() is None, "idle QueryService still pinned by its batcher"
    thread.join(5)                  # heartbeat notices the dead weakref
    assert not thread.is_alive()


def _grow_cross_bucket(tab: Table, seed: int) -> Table:
    """Resampled-row copy of `tab` grown one row past its shape bucket.
    Resampling keeps every MIN/MAX answer identical."""
    cap = tab.capacity
    extra = bucket_capacity(cap) + 1 - cap
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, cap, extra)
    cols = {name: np.concatenate([np.asarray(col), np.asarray(col)[idx]])
            for name, col in tab.columns.items()}
    return Table.from_numpy(cols)


@pytest.mark.slow
def test_stress_submissions_race_bucket_crossing_updates():
    """Threaded submit/submit_async interleaved with bucket-crossing
    update_table calls: every answer must equal the serial baseline
    bitwise, and no (cache key, bucket) may compile twice — the only
    tolerated rebuilds are invalidated stale-bucket keys."""
    db, schema = make_tpch_db(scale=40, seed=8)
    serial_svc = QueryService(db, schema)
    baseline = {sql: serial_svc.submit(sql).values for sql in MINMAX_QUERIES}

    svc = QueryService(db, schema, async_max_wait_ms=5)
    grow_rels = ("supplier", "partsupp")
    old_buckets = {(rel, bucket_capacity(db[rel].capacity))
                   for rel in grow_rels}

    built: list = []
    orig_gob = svc._get_or_build

    def spy(cache, key, build, **kwargs):
        def counted():
            if cache is not svc.cache.padded:
                # padded views legitimately re-pad after a table swap;
                # the no-duplicate claim is about plans and compiles
                built.append((id(cache), key))
            return build()
        return orig_gob(cache, key, counted, **kwargs)

    svc._get_or_build = spy

    errors: list = []
    mismatches: list = []

    def check(sql, res):
        try:
            _assert_values_equal(res.values, baseline[sql])
        except AssertionError as e:
            mismatches.append((sql, str(e)))

    def sync_worker(offset):
        try:
            for i in range(6):
                sql = MINMAX_QUERIES[(offset + i) % len(MINMAX_QUERIES)]
                check(sql, svc.submit(sql))
        except Exception as e:  # pragma: no cover
            errors.append(e)

    def async_worker(offset):
        try:
            for i in range(4):
                sql = MINMAX_QUERIES[(offset + i) % len(MINMAX_QUERIES)]
                check(sql, svc.submit_async(sql).result(120))
        except Exception as e:  # pragma: no cover
            errors.append(e)

    def updater():
        try:
            # wait for the first compiled executable so the bucket
            # crossing demonstrably invalidates cached programs, then
            # race the remaining submissions
            deadline = time.monotonic() + 60
            while (svc.metrics()["compiles"] < 1
                   and time.monotonic() < deadline):
                time.sleep(0.005)
            for j, rel in enumerate(grow_rels):
                svc.update_table(rel, _grow_cross_bucket(db[rel], seed=j))
                time.sleep(0.05)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    workers = ([threading.Thread(target=sync_worker, args=(i,))
                for i in range(4)]
               + [threading.Thread(target=async_worker, args=(i,))
                  for i in range(2)]
               + [threading.Thread(target=updater)])
    for t in workers:
        t.start()
    for t in workers:
        t.join()
    svc.close()

    assert not errors, errors
    assert not mismatches, mismatches[:3]
    m = svc.metrics()
    assert m["request_errors"] == 0
    assert m["bucket_invalidations"] >= 1   # the updates really crossed

    # compile hygiene: duplicates are legal only for keys invalidated by
    # the bucket crossings (a request that snapshotted just before the
    # update rebuilds the stale key once); every live (key, bucket) pair
    # compiled at most once
    dupes = [key for key, n in Counter(built).items() if n > 1]
    for _, key in dupes:
        assert isinstance(key, tuple), f"plan rebuilt: {key!r}"
        bucket = key[-1]
        assert any((rel, cap) in old_buckets for rel, cap in bucket), \
            f"duplicate compile for non-invalidated key {key!r}"
