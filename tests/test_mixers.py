"""Mixer-level correctness: the chunked parallel forms of Mamba2-SSD and
RWKV6 must equal their per-token recurrences (the decode paths) for any
chunk size; plus sharding-rule resolution invariants (hypothesis)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests skip without hypothesis; mixer tests always run
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.configs import get_smoke_config
from repro.models import mamba2 as m2
from repro.models import rwkv6 as rk

jax.config.update("jax_platform_name", "cpu")


def _f32(cfg):
    return dataclasses.replace(cfg, dtype="float32")


@pytest.mark.parametrize("chunk", [2, 4, 8])
def test_mamba2_chunked_equals_recurrence(chunk):
    cfg = _f32(dataclasses.replace(get_smoke_config("zamba2-1.2b"),
                                   ssm_chunk=chunk))
    p, _ = m2.mamba2_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    b, s = 2, 16
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)) * 0.3, jnp.float32)

    y_par, (state_par, conv_par) = m2.mamba2_apply(p, cfg, x, jnp.float32)

    # token-by-token recurrence (the decode path)
    ssm = jnp.zeros((b, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32)
    conv = jnp.zeros((b, cfg.conv_width - 1,
                      cfg.d_inner + 2 * cfg.ssm_state), jnp.float32)
    ys = []
    for t in range(s):
        y_t, (ssm, conv) = m2.mamba2_decode(p, cfg, x[:, t:t + 1], ssm,
                                            conv, jnp.float32)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state_par), np.asarray(ssm),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [2, 4, 8])
def test_rwkv6_chunked_equals_recurrence(chunk):
    cfg = _f32(dataclasses.replace(get_smoke_config("rwkv6-1.6b"),
                                   ssm_chunk=chunk))
    p, _ = rk.rwkv6_init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    b, s = 2, 16
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)) * 0.3, jnp.float32)

    y_par, (wkv_par, tok_par, ffn_par) = rk.rwkv6_apply(p, cfg, x,
                                                        jnp.float32)

    hd = cfg.ssm_head_dim
    h = cfg.d_model // hd
    state = (jnp.zeros((b, h, hd, hd), jnp.float32),
             jnp.zeros((b, cfg.d_model), jnp.float32),
             jnp.zeros((b, cfg.d_model), jnp.float32))
    ys = []
    for t in range(s):
        y_t, state = rk.rwkv6_decode(p, cfg, x[:, t:t + 1], state,
                                     jnp.float32)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(wkv_par), np.asarray(state[0]),
                               rtol=2e-4, atol=2e-4)


def test_rwkv6_no_overflow_with_aggressive_decay():
    """Fast-forgetting channels (very negative log-decay) must not produce
    inf/nan in the chunked form (the exp-of-differences guarantee)."""
    cfg = _f32(dataclasses.replace(get_smoke_config("rwkv6-1.6b"),
                                   ssm_chunk=8))
    p, _ = rk.rwkv6_init(jax.random.PRNGKey(2), cfg)
    p = dict(p, w_bias=jnp.full_like(p["w_bias"], 3.0))  # decay ≈ e^-e^3
    x = jnp.asarray(np.random.default_rng(2).normal(size=(1, 32, cfg.d_model)),
                    jnp.float32)
    y, _ = rk.rwkv6_apply(p, cfg, x, jnp.float32)
    assert bool(jnp.all(jnp.isfinite(y)))


# ---------------------------------------------------------------------------
# resolve_spec invariants
# ---------------------------------------------------------------------------
AXES = [None, "batch", "seq", "embed", "heads_fused", "kv_heads", "mlp",
        "vocab", "experts", "q_seq", "kv_seq"]


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(shape=st.lists(st.sampled_from([1, 2, 3, 8, 16, 30, 32, 64, 256]),
                          min_size=1, max_size=5),
           axes=st.lists(st.sampled_from(AXES), min_size=1, max_size=5))
    def test_resolve_spec_invariants(shape, axes):
        """For every shape × logical-axes combination: (1) no mesh axis is
        used twice, (2) every sharded dim is divisible by its axis product —
        i.e. the spec is always a legal jit in_sharding."""
        from repro.distributed.sharding import resolve_spec, use_mesh
        n = min(len(shape), len(axes))
        shape, axes = tuple(shape[:n]), tuple(axes[:n])
        mesh = jax.sharding.AbstractMesh((2, 2, 2), ("pod", "data", "model"))
        sizes = {"pod": 2, "data": 2, "model": 2}
        with use_mesh(mesh):
            spec = resolve_spec(shape, axes)
        seen = []
        for dim, entry in zip(shape, tuple(spec)):
            if entry is None:
                continue
            group = entry if isinstance(entry, tuple) else (entry,)
            prod = 1
            for a in group:
                assert a not in seen, (spec, shape, axes)
                seen.append(a)
                prod *= sizes[a]
            assert dim % prod == 0, (spec, shape, axes)
else:
    def test_resolve_spec_invariants_need_hypothesis():
        """Visible skip so a missing dependency is not silent."""
        pytest.importorskip("hypothesis")
