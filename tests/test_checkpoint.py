"""Fault tolerance: crash/restore resume is bit-exact; async save is safe;
elastic restore re-places onto different shardings."""

import dataclasses

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_smoke_config
from repro.data import TokenPipeline
from repro.models import init_params
from repro.training import build_train_step, init_train_state

jax.config.update("jax_platform_name", "cpu")


def _mk(tmp_path, seed=0):
    cfg = dataclasses.replace(get_smoke_config("smollm-135m"),
                              dtype="float32")
    params, _ = init_params(jax.random.PRNGKey(seed), cfg)
    state = init_train_state(params)
    step = jax.jit(build_train_step(cfg, base_lr=1e-2, warmup=2,
                                    total_steps=50, remat="none"))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=16,
                         global_batch=4, seed=11)
    ckpt = Checkpointer(tmp_path / "ckpt")
    return cfg, state, step, pipe, ckpt


def test_crash_restore_resume_is_bit_exact(tmp_path):
    _, state, step, pipe, ckpt = _mk(tmp_path)

    # uninterrupted run: 6 steps
    s_ref = state
    for i in range(6):
        s_ref, _ = step(s_ref, pipe.jax_batch(i))

    # interrupted run: 3 steps, checkpoint, "crash", restore, 3 more
    s = state
    for i in range(3):
        s, _ = step(s, pipe.jax_batch(i))
    ckpt.save(3, s, async_=False)
    del s                                    # the crash
    restored = ckpt.restore(like=state)
    assert int(restored.step) == 3
    s2 = restored
    for i in range(3, 6):                    # pipeline replays by step id
        s2, _ = step(s2, pipe.jax_batch(i))

    for a, b in zip(jax.tree.leaves(s_ref.params),
                    jax.tree.leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_restore(tmp_path):
    _, state, step, pipe, ckpt = _mk(tmp_path)
    s = state
    for i in range(2):
        s, _ = step(s, pipe.jax_batch(i))
        ckpt.save(i + 1, s, async_=True)   # overlaps next step
    ckpt.wait()
    assert ckpt.latest_step() == 2
    restored = ckpt.restore(like=state)
    np.testing.assert_array_equal(np.asarray(restored.step), 2)


def test_atomicity_tmp_dirs_ignored(tmp_path):
    _, state, _, _, ckpt = _mk(tmp_path)
    ckpt.save(1, state, async_=False)
    # a torn save must not be visible
    (tmp_path / "ckpt" / "step_9.tmp").mkdir()
    assert ckpt.latest_step() == 1


def test_elastic_restore_onto_sharding(tmp_path):
    """Restore re-places leaves under explicit shardings (elastic re-mesh:
    the 1-device mesh here; the 8-device variant runs in the distributed
    subprocess suite)."""
    _, state, _, _, ckpt = _mk(tmp_path)
    ckpt.save(1, state, async_=False)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = jax.tree.map(lambda _: sh, state)
    restored = ckpt.restore(like=state, shardings=shardings)
    leaf = jax.tree.leaves(restored.params)[0]
    assert leaf.sharding == sh
