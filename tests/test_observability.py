"""Observability subsystem: fake-clock span trees, histogram math,
single-snapshot metric consistency, Chrome-trace export, explain(), the
BENCH recorder schema, and the serving-tier clock-discipline lint."""

import json
import pathlib
import subprocess
import sys
import threading

import jax
import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.recorder import Recorder, validate_bench
from benchmarks.recorder import main as recorder_main
from repro.data import make_tpch_db
from repro.service import QueryService
from repro.service.observability import (
    _BUCKET_BOUNDS,
    NULL_SPAN,
    Histogram,
    Observability,
    TraceSpan,
)

jax.config.update("jax_platform_name", "cpu")

FIG1 = """
SELECT MIN(s.s_acctbal), MAX(s.s_acctbal)
FROM region r, nation n, supplier s, partsupp ps, part p
WHERE r.r_regionkey = n.n_regionkey AND n.n_nationkey = s.s_nationkey
  AND s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
  AND r.r_name IN (2, 3) AND p.p_price > 1200.0
"""
_SUPP_DIMS = """FROM supplier s, nation n, region r
WHERE s.s_nationkey = n.n_nationkey AND n.n_regionkey = r.r_regionkey
  AND r.r_name IN (2, 3)"""
# one fusion family: shared supplier⋈nation⋈region prefix
FAMILY = [
    f"SELECT MIN(s.s_acctbal), MAX(s.s_acctbal) {_SUPP_DIMS}",
    f"SELECT SUM(s.s_acctbal) {_SUPP_DIMS}",
    f"SELECT MEDIAN(s.s_acctbal) {_SUPP_DIMS}",
]


class FakeClock:
    """Deterministic monotonic clock: every read advances by `step`."""

    def __init__(self, step=1.0):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


# ---------------------------------------------------------------------------
# histogram
# ---------------------------------------------------------------------------
def test_histogram_percentiles_and_snapshot():
    h = Histogram()
    for ms in (1, 1, 1, 1, 1, 1, 1, 1, 1, 100):  # 9 fast + 1 slow
        h.record(ms / 1e3)
    assert h.count == 10
    assert h.sum_s == pytest.approx(0.109)
    assert h.max_s == pytest.approx(0.1)
    # p50 lands in the 1 ms bucket (upper bound within one bucket width),
    # p99 in the 100 ms bucket
    assert 1e-3 <= h.percentile(0.50) <= 1e-3 * 10 ** (1 / 8)
    assert 0.1 <= h.percentile(0.99) <= 0.1 * 10 ** (1 / 8)
    snap = h.snapshot()
    for k in ("count", "sum_s", "max_s", "p50_s", "p95_s", "p99_s",
              "buckets"):
        assert k in snap
    assert sum(c for _, c in snap["buckets"]) == 10


def test_histogram_overflow_bucket_uses_max():
    h = Histogram()
    h.record(500.0)  # beyond the 100 s top bound
    assert h.percentile(0.99) == pytest.approx(500.0)
    assert h.snapshot()["buckets"][-1] == (None, 1)


def test_bucket_bounds_cover_1us_to_100s():
    assert _BUCKET_BOUNDS[0] == pytest.approx(1e-6)
    assert _BUCKET_BOUNDS[-1] == pytest.approx(100.0)


# ---------------------------------------------------------------------------
# spans + registry (fake clock, no service)
# ---------------------------------------------------------------------------
def test_span_tree_with_fake_clock():
    obs = Observability(FakeClock())
    root = obs.begin_request(via="test")
    with obs.span(root, "plan") as sp:
        sp.note(source="built")
    obs.end_request(root)
    assert root.closed and root.duration_s > 0
    assert [c.name for c in root.children] == ["plan"]
    assert root.children[0].args == {"source": "built"}
    # children strictly nested: sum of child durations <= root duration
    assert sum(c.duration_s for c in root.children) <= root.duration_s
    snap = obs.snapshot()
    assert snap["histograms"]["request"]["count"] == 1
    assert snap["histograms"]["plan"]["count"] == 1


def test_span_shared_by_many_parents_attached_once_each():
    obs = Observability(FakeClock())
    roots = [obs.begin_request() for _ in range(3)]
    # duplicate parents are deduped by identity
    span = obs.open_span(roots + [roots[0]], "compile", fused=True)
    obs.close_span(span)
    for r in roots:
        assert r.children.count(span) == 1
    assert isinstance(span, TraceSpan)


def test_disabled_observability_is_inert():
    clock = FakeClock()
    obs = Observability(clock, enabled=False)
    root = obs.begin_request()
    assert root is NULL_SPAN
    with obs.span(root, "plan") as sp:
        assert sp is NULL_SPAN
        sp.note(ignored=True)
    obs.end_request(root)
    assert clock.t == 0.0  # no clock reads at all
    snap = obs.snapshot()
    assert snap["histograms"] == {}
    assert obs.traces() == []


def test_span_ctx_notes_error_and_closes():
    obs = Observability(FakeClock())
    root = obs.begin_request()
    with pytest.raises(ValueError):
        with obs.span(root, "parse"):
            raise ValueError("boom")
    (sp,) = root.children
    assert sp.closed
    assert sp.args["error"] == "ValueError"


def test_peak_gauge_resets_on_snapshot():
    obs = Observability(FakeClock())
    obs.set_gauge("queue_depth", 0)
    obs.register_peak_gauge("queue_depth_peak", "queue_depth")
    obs.set_gauge("queue_depth", 7)
    obs.set_gauge("queue_depth", 2)
    snap = obs.snapshot()
    assert snap["gauges"]["queue_depth"] == 2
    assert snap["gauges"]["queue_depth_peak"] == 7
    # the read reset the high-water mark to the current value
    assert obs.snapshot()["gauges"]["queue_depth_peak"] == 2


def test_trace_retention_is_bounded():
    obs = Observability(FakeClock(), max_traces=4)
    for _ in range(10):
        obs.end_request(obs.begin_request())
    assert len(obs.traces()) == 4


# ---------------------------------------------------------------------------
# service integration (real queries, fake clock where possible)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tpch():
    return make_tpch_db(scale=40)


def test_submit_trace_children_sum_within_request(tpch):
    db, schema = tpch
    svc = QueryService(db, schema, clock=FakeClock(1e-3))
    svc.submit(FIG1)
    svc.submit(FIG1)  # warm pass: same invariant with cache hits
    roots = svc.obs.traces()
    assert len(roots) == 2
    for root in roots:
        assert root.name == "request"
        names = [c.name for c in root.children]
        assert "parse" in names and "fingerprint" in names
        assert sum(c.duration_s for c in root.children) <= root.duration_s
    # cold request carries plan/pad/compile/run children
    cold_names = {c.name for c in roots[0].children}
    assert {"plan", "pad", "compile", "run"} <= cold_names
    # stats surface the same tree
    st = svc.submit(FIG1).stats
    assert st.trace is not None and st.trace.closed
    assert st.plan_source == "memory" and st.exec_source == "exec_cache"


def test_submit_many_fused_batch_has_one_shared_compile_span(tpch):
    db, schema = tpch
    svc = QueryService(db, schema, clock=FakeClock(1e-3))
    results = svc.submit_many(FAMILY)
    assert all(r.error is None for r in results)
    roots = svc.obs.traces()
    assert len(roots) == len(FAMILY)
    compile_spans = {id(s): s for root in roots for s in root.walk()
                     if s.name == "compile"}
    # exactly ONE compile span object, attached to every member's root
    assert len(compile_spans) == 1
    (span,) = compile_spans.values()
    assert span.args.get("fused") is True
    for root in roots:
        assert any(s is span for s in root.walk())
        assert sum(c.duration_s for c in root.children) <= root.duration_s
    m = svc.metrics()
    assert m["fused_queries"] == len(FAMILY)
    assert m["fused_compiles"] == 1


def test_submit_async_trace_has_queue_wait(tpch):
    db, schema = tpch
    svc = QueryService(db, schema, async_max_wait_ms=50)
    try:
        res = svc.submit_async(FIG1).result(timeout=120)
        assert res.error is None
        assert res.stats.queue_s > 0.0
        (root,) = [t for t in svc.obs.traces() if t.name == "request"]
        names = [c.name for c in root.children]
        assert "queue_wait" in names
        # the shared formation-window span nests INSIDE queue_wait (they
        # overlap in real time, so it must not be a direct root child)
        (qspan,) = [c for c in root.children if c.name == "queue_wait"]
        assert "batch_form" in [c.name for c in qspan.children]
        assert sum(c.duration_s for c in root.children) <= root.duration_s
        g = svc.metrics_v2()["gauges"]
        assert g["queue_depth"] == 0
        assert g["queue_depth_peak"] >= 1  # resettable high-water mark
        assert svc.metrics_v2()["gauges"]["queue_depth_peak"] == 0
    finally:
        svc.close()


def test_tracing_disabled_identical_answers_no_traces(tpch):
    db, schema = tpch
    traced = QueryService(db, schema)
    dark = QueryService(db, schema, tracing=False)
    for q in (FIG1, FAMILY[1]):
        a, b = traced.submit(q), dark.submit(q)
        assert a.error is None and b.error is None
        assert set(a.values) == set(b.values)
        for k in a.values:
            assert np.array_equal(np.asarray(a.values[k]),
                                  np.asarray(b.values[k]))
    assert dark.obs.traces() == []
    assert dark.metrics_v2()["histograms"] == {}
    # counters still work when tracing is off (they are correctness
    # bookkeeping, not observability sugar)
    assert dark.metrics()["requests"] == 2


def test_metrics_v2_shape_and_flat_view_equivalence(tpch):
    db, schema = tpch
    svc = QueryService(db, schema)
    svc.submit_many(FAMILY)
    v2 = svc.metrics_v2()
    assert set(v2) == {"counters", "gauges", "histograms", "tenants"}
    # sync submissions without an explicit tenant roll into the default
    # tenant's counters and latency histogram
    dt = v2["tenants"]["default"]
    assert dt["requests"] == len(FAMILY) and dt["count"] == len(FAMILY)
    assert dt["p50_s"] <= dt["p95_s"] <= dt["p99_s"]
    for stage in ("parse", "fingerprint", "plan", "pad", "compile", "run",
                  "request"):
        h = v2["histograms"][stage]
        assert h["count"] >= 1
        assert h["p50_s"] <= h["p95_s"] <= h["p99_s"]
    flat = svc.metrics()
    for k, v in v2["counters"].items():
        assert k in flat
    for k in ("queue_depth", "queue_depth_peak", "padded_relations"):
        assert k in flat
    # legacy keys the older flat dict promised
    for k in ("requests", "compiles", "dedup_saved", "plan_hits",
              "persist_hits", "async_requests", "rejected"):
        assert k in flat


def test_metrics_snapshot_invariants_under_threads(tpch):
    """The single-lock snapshot can never tear: every read must satisfy
    the program-order invariants (a request is counted before anything it
    causes), which the old three-lock metrics() could violate."""
    db, schema = tpch
    svc = QueryService(db, schema)
    svc.submit_many(FAMILY)  # warm the caches first
    stop = threading.Event()
    violations = []

    def reader():
        while not stop.is_set():
            c = svc.metrics_v2()["counters"]
            for dep in ("fused_queries", "dedup_saved", "eager_requests",
                        "request_errors"):
                if c[dep] > c["requests"]:
                    violations.append(f"{dep}={c[dep]} > "
                                      f"requests={c['requests']}")

    def writer():
        for _ in range(15):
            svc.submit_many(FAMILY)
            svc.submit(FIG1)

    readers = [threading.Thread(target=reader) for _ in range(2)]
    writers = [threading.Thread(target=writer) for _ in range(3)]
    for t in readers + writers:
        t.start()
    for t in writers:
        t.join()
    stop.set()
    for t in readers:
        t.join()
    assert not violations


# ---------------------------------------------------------------------------
# export + explain
# ---------------------------------------------------------------------------
def test_export_chrome_trace_valid_and_deduped(tpch, tmp_path):
    db, schema = tpch
    svc = QueryService(db, schema, clock=FakeClock(1e-3))
    svc.submit_many(FAMILY)
    out = tmp_path / "trace.json"
    n = svc.export_trace(out)
    doc = json.loads(out.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    events = doc["traceEvents"]
    assert len(events) == n > 0
    for ev in events:
        assert ev["ph"] == "X"
        assert ev["dur"] >= 0 and isinstance(ev["ts"], float)
        assert {"name", "pid", "tid", "cat", "args"} <= set(ev)
        # args must already be JSON-scalar (Perfetto chokes otherwise)
        for v in ev["args"].values():
            assert isinstance(v, (str, int, float, bool, type(None)))
    # the fused compile span is emitted exactly once
    assert sum(1 for ev in events if ev["name"] == "compile") == 1
    assert sum(1 for ev in events if ev["name"] == "request") == len(FAMILY)


def test_explain_names_cache_levels_and_sources(tpch):
    db, schema = tpch
    svc = QueryService(db, schema)
    cold = svc.explain(FIG1)
    assert cold["plan_source"] == "built"
    assert cold["exec_source"] == "compiled"
    warm = svc.explain(FIG1)
    assert warm["plan_source"] == "memory"
    assert warm["exec_source"] == "exec_cache"
    assert warm["cache_levels"]["plan_in_memory"] is True
    assert warm["cache_levels"]["exec_in_memory"] is True
    assert warm["fingerprint"] == cold["fingerprint"]
    assert warm["timings_s"]["total"] >= warm["timings_s"]["run"] >= 0
    assert "in-memory=True" in warm["text"]


# ---------------------------------------------------------------------------
# BENCH recorder schema
# ---------------------------------------------------------------------------
def test_recorder_roundtrip_and_validator(tmp_path, capsys):
    path = tmp_path / "BENCH_test.json"
    rec = Recorder("test", path=str(path))
    rec.add_meta(scale=1)
    rec.section("s1")
    rec.row("a.b", 12.5, "d=1")
    rec.row("a.skipped", float("nan"), "not run")
    rec.add_histograms({"run": Histogram().snapshot()})
    rec.add_metrics({"requests": 3})
    doc = rec.finish()
    assert validate_bench(doc) == []
    on_disk = json.loads(path.read_text())
    assert on_disk["rows"][0]["us_per_call"] == 12.5
    assert on_disk["rows"][1]["us_per_call"] is None  # NaN -> null
    assert recorder_main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "a.b,12.5,d=1" in out and "a.skipped,nan,not run" in out


def test_validator_rejects_malformed_documents():
    assert validate_bench([]) == ["document is not a JSON object"]
    bad = {"bench_schema_version": 99, "benchmark": "", "created_unix": "x",
           "rows": [{"name": "", "us_per_call": float("nan")}],
           "histograms": {"run": {"count": -1}}, "metrics": [], "meta": {}}
    probs = validate_bench(bad)
    assert len(probs) >= 6
    rec = Recorder("t", path="/nonexistent-dir/x.json")
    with pytest.raises(ValueError):
        rec.finish()  # no rows -> invalid, refused before any write


# ---------------------------------------------------------------------------
# clock-discipline lint
# ---------------------------------------------------------------------------
def test_lint_forbids_perf_counter_in_serving_tier(tmp_path):
    repo = pathlib.Path(__file__).resolve().parent.parent
    svc_dir = tmp_path / "src" / "repro" / "service"
    svc_dir.mkdir(parents=True)
    (svc_dir / "rogue.py").write_text(
        "import time\nT0 = time.perf_counter()\n")
    (svc_dir / "observability.py").write_text(
        "import time\nMONOTONIC = time.perf_counter\n")
    (svc_dir / "ok.py").write_text("import time\nW = time.monotonic()\n")
    proc = subprocess.run(
        [sys.executable, str(repo / "scripts" / "lint.py"),
         str(tmp_path / "src")],
        capture_output=True, text=True)
    assert proc.returncode != 0
    assert "rogue.py" in proc.stdout
    assert "observability.py" not in proc.stdout
    assert "ok.py" not in proc.stdout
    # the real serving tier is clean
    proc = subprocess.run(
        [sys.executable, str(repo / "scripts" / "lint.py"),
         str(repo / "src" / "repro" / "service")],
        capture_output=True, text=True, cwd=repo)
    assert proc.returncode == 0, proc.stdout + proc.stderr
