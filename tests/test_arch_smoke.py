"""Per-architecture smoke tests: reduced same-family config, one forward
and one train-ish step on CPU, asserting shapes and finiteness.  Also
decode-path consistency: prefill+decode must agree with the full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import (
    decode_step,
    forward,
    init_decode_state,
    init_params,
    prefill,
)

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 16


def _batch(cfg, b=B, s=S, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.frontend == "vision_stub":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_smoke_config(arch)
    params, specs = init_params(jax.random.PRNGKey(0), cfg)
    # spec tree mirrors param tree
    assert jax.tree.structure(jax.tree.map(lambda _: 0, params)) == \
        jax.tree.structure(jax.tree.map(lambda _: 0, specs,
                                        is_leaf=lambda x: isinstance(x, tuple)))
    batch = _batch(cfg)
    logits, aux = forward(params, cfg, batch)
    s_out = S + (cfg.num_patches if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (B, s_out, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    if cfg.family == "moe":
        assert bool(jnp.isfinite(aux["load_balance"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss_direction(arch):
    """One SGD step on the smoke config: grads finite, params move."""
    cfg = get_smoke_config(arch)
    params, _ = init_params(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, seed=1)

    def loss_fn(p):
        logits, aux = forward(p, cfg, batch, remat="full")
        s_txt = batch["labels"].shape[1]
        lg = logits[:, -s_txt:, :]
        ll = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(ll, batch["labels"][..., None],
                                   axis=-1).mean()
        if aux is not None and cfg.family == "moe":
            nll = nll + 0.01 * aux["load_balance"]
        return nll

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), arch
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the forward logits: the KV/SSM
    cache machinery is exact, not approximate.  Runs in f32 so that real
    state-handoff bugs aren't masked by (or blamed on) bf16 noise.
    MoE archs run with a large capacity factor: capacity DROPPING is
    inherently sequence-length-dependent (full-seq tokens compete for
    expert slots; single-token decode steps don't), so drops are excluded
    to isolate the cache machinery being tested."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params, _ = init_params(jax.random.PRNGKey(2), cfg)
    batch = _batch(cfg, b=2, s=8, seed=2)
    logits_full, _ = forward(params, cfg, batch)

    n_prefill = 4
    cache = init_decode_state(cfg, batch=2, max_len=32)
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :n_prefill]
    last_logits, cache = prefill(params, cfg, pre_batch, cache)

    img_off = cfg.num_patches if cfg.frontend == "vision_stub" else 0
    np.testing.assert_allclose(
        np.asarray(last_logits),
        np.asarray(logits_full[:, img_off + n_prefill - 1]),
        rtol=1e-3, atol=1e-3)

    # teacher-forced single-token decode for the next 4 positions
    for t in range(n_prefill, 8):
        tok = batch["tokens"][:, t:t + 1]
        logits_t, cache = decode_step(params, cfg, tok, cache)
        np.testing.assert_allclose(
            np.asarray(logits_t),
            np.asarray(logits_full[:, img_off + t]),
            rtol=1e-3, atol=1e-3,
            err_msg=f"{arch} decode step {t}")
