"""Differential property test: the op-graph executor must be bitwise
identical to the pre-refactor *linear* semantics.

``LinearReference`` replays a plan the way the pre-refactor executor did —
a single sweep over the linear op list threading one mutable state per
atom alias — while ``Executor`` interprets the op DAG (with content-key
memoisation under tracing).  On randomised acyclic queries (chain/star
join shapes, random selections, aggregates, GROUP BY, data) the two must
agree to the bit in every plan class (ref / opt / opt_plus / oma), eagerly
and compiled, and fused multi-query execution must match per-plan
compilation bitwise.

Runs as a hypothesis property test when hypothesis is installed, else as a
seeded sweep over the same case builder (visible, not silent, degradation).
"""

import jax
import numpy as np
import pytest

from repro.core import Executor, plan_query
from repro.core.distributed import DistributedExecutor
from repro.core.executor import ExecStats
from repro.core.plan import (
    FinalAggOp,
    FreqJoinOp,
    MaterializeJoinOp,
    ScanOp,
    SemiJoinOp,
)
from repro.core.query import Agg, AggQuery, Atom
from repro.tables.table import ColumnMeta, RelSchema, Schema, Table

try:  # property tests degrade to a seeded sweep without hypothesis
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

jax.config.update("jax_platform_name", "cpu")

_N_IDS = 12
SCHEMA = Schema(relations={
    "node": RelSchema("node", (
        ColumnMeta("id", domain=_N_IDS),
        ColumnMeta("grp", domain=5),
        ColumnMeta("score"),
    )),
    "edge": RelSchema("edge", (
        ColumnMeta("src", domain=_N_IDS),
        ColumnMeta("dst", domain=_N_IDS),
    )),
})


class LinearReference:
    """The pre-refactor executor semantics: one linear sweep over
    ``plan.ops``, one mutable state slot per alias.  Op-level kernels are
    shared with the graph executor, so any divergence is attributable to
    the interpretation strategy — exactly what this test pins down."""

    def __init__(self, db, schema):
        self.ex = Executor(db, schema)

    def _sweep(self, ex, plan, stats=None):
        state, results = {}, {}
        for op in plan.ops:
            if isinstance(op, ScanOp):
                state[op.alias] = st_ = ex._scan(plan, op)
                if stats is not None:
                    stats.record(f"scan({op.alias})",
                                 int(np.sum(np.asarray(st_.freq) > 0)))
            elif isinstance(op, SemiJoinOp):
                st_ = ex._semi_join(plan, op, state[op.parent],
                                    state[op.child])
                state[op.parent] = st_
                if stats is not None:
                    stats.record(f"semijoin({op.parent}⋉{op.child})",
                                 int(np.sum(np.asarray(st_.freq) > 0)))
            elif isinstance(op, FreqJoinOp):
                st_ = ex._freq_join(plan, op, state[op.parent],
                                    state[op.child])
                state[op.parent] = st_
                if stats is not None:
                    stats.record(f"freqjoin({op.parent}⋉ᶠ{op.child})",
                                 int(np.sum(np.asarray(st_.freq) > 0)))
            elif isinstance(op, MaterializeJoinOp):
                state[op.parent] = ex._materialize_join(
                    plan, op, state[op.parent], state[op.child],
                    stats if stats is not None else ExecStats())
            elif isinstance(op, FinalAggOp):
                results = ex._final_agg(plan, op, state[op.root])
        return results

    def execute(self, plan):
        stats = ExecStats()
        results = dict(self._sweep(self.ex, plan, stats))
        results["__stats__"] = stats
        return results

    def compile(self, plan):
        outer = self.ex

        def run(db):
            inner = Executor(db, outer.schema, outer.freq_dtype,
                             outer.backend, outer.interpret,
                             dense_domain=outer.dense_domain)
            return self._sweep(inner, plan)

        return jax.jit(run)


def _make_db(rng):
    n_nodes = int(rng.integers(4, 24))
    n_edges = int(rng.integers(4, 40))
    node = {
        "id": rng.integers(0, _N_IDS, n_nodes).astype(np.int32),
        "grp": rng.integers(0, 5, n_nodes).astype(np.int32),
        "score": rng.integers(0, 50, n_nodes).astype(np.float32),
    }
    edge = {
        "src": rng.integers(0, _N_IDS, n_edges).astype(np.int32),
        "dst": rng.integers(0, _N_IDS, n_edges).astype(np.int32),
    }
    return {"node": Table.from_numpy(node), "edge": Table.from_numpy(edge)}


_AGG_POOL = (("min", "sc"), ("max", "sc"), ("sum", "sc"), ("avg", "sc"),
             ("median", "sc"), ("count", None))


def _make_query(rng):
    chain_len = int(rng.integers(0, 3))
    star = bool(rng.integers(0, 2)) and chain_len > 0
    atoms = [Atom("node", "n0", ("v0", "g", "sc"))]
    if chain_len >= 1:
        atoms.append(Atom("edge", "e1", ("v0", "x1")))
    if chain_len >= 2:
        atoms.append(Atom("edge", "e2", ("x1", "x2")))
    if star:
        atoms.append(Atom("edge", "e3", ("v0", "y1")))
    n_aggs = int(rng.integers(1, 3))
    picks = rng.choice(len(_AGG_POOL), size=n_aggs, replace=False)
    aggs = tuple(Agg(_AGG_POOL[i][0], _AGG_POOL[i][1]) for i in picks)
    group_by = ("g",) if rng.integers(0, 2) else ()
    selections, specs = {}, {}
    if rng.integers(0, 2):
        lit = int(rng.integers(1, 5))
        selections["n0"] = lambda c, lit=lit: c["grp"] < lit
        specs["n0"] = (("<", "grp", lit),)
    if chain_len >= 1 and rng.integers(0, 2):
        lit = int(rng.integers(1, _N_IDS))
        selections["e1"] = lambda c, lit=lit: c["dst"] > lit
        specs["e1"] = ((">", "dst", lit),)
    return AggQuery(atoms=tuple(atoms), aggregates=aggs, group_by=group_by,
                    selections=selections, selection_specs=specs)


def _assert_bitwise(a: dict, b: dict, ctx: str = ""):
    keys_a = {k for k in a if k != "__stats__"}
    keys_b = {k for k in b if k != "__stats__"}
    assert keys_a == keys_b, ctx
    for k in keys_a:
        va, vb = a[k], b[k]
        if k == "groups":
            assert set(va) == set(vb), ctx
            for c in va:
                xa, xb = np.asarray(va[c]), np.asarray(vb[c])
                assert xa.dtype == xb.dtype and xa.shape == xb.shape, \
                    (ctx, c)
                assert xa.tobytes() == xb.tobytes(), (ctx, c)
        else:
            xa, xb = np.asarray(va), np.asarray(vb)
            assert xa.dtype == xb.dtype and xa.shape == xb.shape, (ctx, k)
            assert xa.tobytes() == xb.tobytes(), (ctx, k)


def _check_case(seed: int):
    rng = np.random.default_rng(seed)
    db = _make_db(rng)
    query = _make_query(rng)
    ref = LinearReference(db, SCHEMA)
    new = Executor(db, SCHEMA)

    jit_plans, jit_results = [], []
    for mode in ("ref", "opt", "opt_plus", "oma"):
        try:
            plan = plan_query(query, SCHEMA, mode=mode)
        except ValueError:
            continue  # mode not applicable (not 0MA, say) — by design
        want = ref.execute(plan)
        got = new.execute(plan)
        _assert_bitwise(want, got, ctx=f"eager/{mode}")
        assert (want["__stats__"].peak_tuples
                == got["__stats__"].peak_tuples), mode
        if mode in ("opt_plus", "oma"):
            want_c = dict(ref.compile(plan)(db))
            got_c = dict(new.compile(plan)(db))
            _assert_bitwise(want_c, got_c, ctx=f"compiled/{mode}")
            _assert_bitwise(want, got_c, ctx=f"eager-vs-compiled/{mode}")
            jit_plans.append(plan)
            jit_results.append(got_c)

    # fused multi-query execution (shared trace memo across members,
    # including an extra sibling so sub-DAGs overlap partially) must match
    # per-plan compilation bitwise
    if jit_plans:
        sibling = AggQuery(atoms=query.atoms, aggregates=(Agg("count"),),
                           group_by=query.group_by,
                           selections=dict(query.selections),
                           selection_specs=dict(query.selection_specs))
        plans = jit_plans + [plan_query(sibling, SCHEMA, mode="opt_plus")]
        solo = jit_results + [dict(new.compile(plans[-1])(db))]
        fused = new.compile_multi(plans)(db)
        for want_c, got_c in zip(solo, fused):
            _assert_bitwise(want_c, dict(got_c), ctx="fused-vs-solo")

        # the mesh lowering is the same graph interpreter with ring
        # evaluators — on a 1-device mesh it must be bitwise-equal to the
        # local executor over identically-padded tables, per-plan and fused
        mesh = jax.make_mesh((1,), ("data",))
        dex = DistributedExecutor(SCHEMA, mesh)
        sharded = dex.shard_db(db)
        host = {k: db[k].pad_to(sharded[k].capacity) for k in db}
        mesh_solo = []
        for plan in plans:
            want_c = dict(new.compile(plan)(host))
            got_c = dict(dex.compile(plan)(sharded))
            _assert_bitwise(want_c, got_c, ctx="mesh-vs-local")
            mesh_solo.append(got_c)
        for want_c, got_c in zip(mesh_solo, dex.compile_multi(plans)(sharded)):
            _assert_bitwise(want_c, dict(got_c), ctx="mesh-fused-vs-solo")


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=12, deadline=None)
    def test_graph_ir_matches_linear_semantics(seed):
        _check_case(seed)
else:
    @pytest.mark.parametrize("seed", range(8))
    def test_graph_ir_matches_linear_semantics(seed):
        _check_case(seed)
