"""Kernel autotuner + TuneStore: measured search, bitwise gate,
persistence discipline (corruption / version skew / read-only), service
warm starts, export/import, and the tooling that rides along (the
``report.py --compare`` perf diff and the block-shape lint rule).

Mirrors ``test_plan_store.py``'s structure: the store tests damage one
entry at a time and assert skip-and-evict (own dir) vs skip-in-place
(foreign dir); the service tests assert the ``tune_searches == 0``
warm-restart invariant — the tuning twin of ``plan_builds == 0``.
"""

import hashlib
import importlib.util
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_tpch_db
from repro.kernels import ops
from repro.kernels.autotune import (
    DEFAULT_CONFIG,
    KernelConfig,
    KernelTuner,
    TuneTable,
    bucket_shape,
    candidate_configs,
)
from repro.service import QueryService
from repro.service.tune_store import (
    TUNE_FORMAT_VERSION,
    TuneStore,
    _canonical_body,
)

jax.config.update("jax_platform_name", "cpu")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

COSTLY_PARTS = """
SELECT SUM(ps.ps_supplycost), COUNT(*)
FROM partsupp ps, part p
WHERE ps.ps_partkey = p.p_partkey AND p.p_price > 1500.0
"""


# ---------------------------------------------------------------------------
# config space + table
# ---------------------------------------------------------------------------
def test_bucket_shape_power_of_two():
    assert bucket_shape(1000, 37) == (1024, 64)
    assert bucket_shape(1024) == (1024,)
    assert bucket_shape(1025) == (2048,)
    assert bucket_shape(1) == (1,)


def test_candidates_always_include_default():
    for kernel in ("freq_join", "semi_join", "segment_sum"):
        for backend in ("xla", "pallas"):
            cands = candidate_configs(kernel, backend)
            assert DEFAULT_CONFIG in cands
            assert len(cands) == len(set(cands))  # hashable + distinct
    with pytest.raises(ValueError, match="unknown kernel"):
        candidate_configs("hash_join", "xla")


def test_tune_table_buckets_lookups():
    """Within-bucket sizes share one entry; crossing the boundary misses
    — the exact invariant that keeps within-bucket growth retune-free."""
    t = TuneTable()
    cfg = KernelConfig(dense_ratio=99)
    t.install("freq_join", (1000, 37), "xla", cfg)
    assert t.lookup("freq_join", (1024, 64), "xla") == cfg
    assert t.lookup("freq_join", (513, 33), "xla") == cfg
    assert t.lookup("freq_join", (1025, 64), "xla") is None   # next bucket
    assert t.lookup("freq_join", (1024, 64), "pallas") is None
    assert t.lookup("semi_join", (1024, 64), "xla") is None
    assert len(t) == 1


def test_search_gates_and_returns_candidate(tmp_path):
    """A real (tiny) measured search: the winner is a candidate, every
    measurement covers a candidate that passed the gate, and the result
    is persisted for the next process."""
    store = TuneStore(tmp_path)
    tuner = KernelTuner(store, backend="xla", repeats=1)
    cfg = tuner.ensure("freq_join", (256, 256))
    assert cfg in candidate_configs("freq_join", "xla")
    m = tuner.metrics()
    assert m["tune_searches"] == 1
    assert m["tune_candidates"] == len(candidate_configs("freq_join",
                                                         "xla"))
    assert m["tune_gate_rejects"] == 0
    assert m["tune_entries"] == 1
    # repeat: resolved from the table, no new search
    assert tuner.ensure("freq_join", (200, 200)) == cfg
    assert tuner.metrics()["tune_searches"] == 1
    # fresh tuner, same store: resolved from disk, no new search
    t2 = KernelTuner(TuneStore(tmp_path), backend="xla")
    assert t2.ensure("freq_join", (256, 256)) == cfg
    m2 = t2.metrics()
    assert m2["tune_searches"] == 0 and m2["tune_store_hits"] == 1


class _DivergingTuner(KernelTuner):
    """Scenario stub whose answer DEPENDS on the config: every
    non-default candidate diverges bitwise, so the gate must reject all
    of them and the default must win regardless of timings."""

    def _scenarios(self, kernel, bshape):
        return [("stub", lambda cfg: jnp.asarray([cfg.lanes_wide]))]


def test_bitwise_gate_rejects_diverging_candidates():
    tuner = _DivergingTuner(None, backend="pallas", repeats=1)
    cfg, measurements = tuner.search("segment_sum", (1024,))
    assert cfg == DEFAULT_CONFIG
    n_cands = len(candidate_configs("segment_sum", "pallas"))
    assert tuner.counters["tune_gate_rejects"] == n_cands - 1
    assert list(measurements) == ["lanes1024"]    # only the survivor


# ---------------------------------------------------------------------------
# TuneStore discipline (mirrors the plan store's)
# ---------------------------------------------------------------------------
def _single_entry(store: TuneStore):
    paths = list(store.tune_dir.glob("*.json"))
    assert len(paths) == 1
    return paths[0]


def test_store_roundtrip_across_instances(tmp_path):
    cfg = KernelConfig(lanes_wide=2048, dense_ratio=32)
    store = TuneStore(tmp_path)
    assert store.save("segment_sum", (4096,), "pallas", cfg,
                      measurements={"lanes2048": 0.001})
    assert store.metrics()["tune_persist_writes"] == 1

    fresh = TuneStore(tmp_path)
    assert fresh.load("segment_sum", (4096,), "pallas") == cfg
    assert fresh.load("segment_sum", (8192,), "pallas") is None
    m = fresh.metrics()
    assert m["tune_persist_hits"] == 1 and m["tune_persist_misses"] == 1
    assert m["tune_persist_entries"] == 1
    assert list(fresh.load_all()) == [
        (("segment_sum", (4096,), "pallas"), cfg)]


@pytest.mark.parametrize("damage", ["truncated", "flipped", "version",
                                    "key", "fields"])
def test_corrupt_entries_skipped_and_evicted(tmp_path, damage):
    """Truncation, payload bit-flips, format-version skew, key-field
    mismatch, and config-schema drift all skip + evict + count — never
    raise, never serve a damaged config."""
    store = TuneStore(tmp_path)
    store.save("freq_join", (1024, 1024), "xla",
               KernelConfig(dense_ratio=32))
    path = _single_entry(store)
    raw = path.read_bytes()
    doc = json.loads(raw)
    if damage == "truncated":
        path.write_bytes(raw[:len(raw) // 2])
    elif damage == "flipped":
        doc["payload"]["config"]["dense_ratio"] = 64   # checksum mismatch
        path.write_text(json.dumps(doc))
    elif damage == "version":
        doc["format_version"] = TUNE_FORMAT_VERSION + 99
        path.write_text(json.dumps(doc))
    elif damage == "key":
        doc["kernel"] = "semi_join"                    # moved-file aliasing
        path.write_text(json.dumps(doc))
    else:  # fields: checksum VALID but the config schema drifted
        doc["payload"]["config"]["warp_rows"] = 4
        doc["payload_sha256"] = hashlib.sha256(
            _canonical_body(doc["payload"])).hexdigest()
        path.write_text(json.dumps(doc))

    fresh = TuneStore(tmp_path)
    assert fresh.load("freq_join", (1024, 1024), "xla") is None
    m = fresh.metrics()
    assert m["tune_persist_corrupt_skipped"] == 1
    assert m["tune_persist_hits"] == 0
    assert not path.exists()                           # evicted


def test_load_all_from_foreign_dir_never_evicts(tmp_path):
    """``load_all`` (import/export path) skips damaged entries IN PLACE —
    the directory may be another service's live store."""
    store = TuneStore(tmp_path)
    store.save("freq_join", (512, 512), "xla", KernelConfig())
    path = _single_entry(store)
    path.write_bytes(path.read_bytes()[:40])
    reader = TuneStore(tmp_path)
    assert list(reader.load_all()) == []
    assert reader.metrics()["tune_persist_corrupt_skipped"] == 1
    assert path.exists()                               # NOT deleted


def test_topology_scopes_entries(tmp_path):
    """Different topologies never alias: per-shard buckets tune
    differently, so a mesh service must not read a local service's
    winners."""
    local = TuneStore(tmp_path)
    mesh = TuneStore(tmp_path, topology=(("dp",), (4,)))
    local.save("freq_join", (1024, 1024), "xla",
               KernelConfig(dense_ratio=32))
    assert mesh.load("freq_join", (1024, 1024), "xla") is None
    assert local.tune_dir != mesh.tune_dir


def test_unwritable_store_degrades(tmp_path):
    """Write failure (dir replaced by a file — root-proof sabotage, as in
    the plan-store test) returns False + counts; loads simply miss.  The
    tuner keeps working in memory."""
    store = TuneStore(tmp_path)
    for p in store.tune_dir.glob("*"):
        p.unlink()
    store.tune_dir.rmdir()
    store.tune_dir.write_text("not a directory")
    assert store.save("freq_join", (64, 64), "xla", KernelConfig()) is False
    m = store.metrics()
    assert m["tune_persist_write_errors"] == 1
    assert m["tune_persist_writes"] == 0
    tuner = KernelTuner(store, backend="xla", repeats=1)
    cfg = tuner.ensure("freq_join", (64, 64))          # search still works
    assert cfg in candidate_configs("freq_join", "xla")
    assert tuner.metrics()["tune_searches"] == 1


# ---------------------------------------------------------------------------
# service integration: warm restarts, export/import, backend re-read
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tpch():
    db, schema = make_tpch_db(scale=20, seed=5)
    return db, schema


def test_service_autotune_and_warm_restart(tmp_path, tpch):
    """Cold service: ``autotune()`` measures and persists.  Warm service
    over the same cache_dir: ``tune_searches == 0`` (the plan cache's
    ``plan_builds == 0``, for kernels) and answers stay bitwise
    identical."""
    db, schema = tpch
    kernels = ("freq_join", "segment_sum")             # keep the test fast
    svc = QueryService(db, schema, cache_dir=tmp_path)
    baseline = svc.submit(COSTLY_PARTS)
    assert baseline.error is None
    r = svc.autotune(kernels=kernels)
    assert r["searches"] > 0
    assert r["installed"] == r["searches"] > 0
    assert r["gate_rejects"] == 0
    assert r["invalidated_executables"] >= 1           # exec level dropped
    tuned = svc.submit(COSTLY_PARTS)
    for k, v in baseline.values.items():
        np.testing.assert_array_equal(np.asarray(v),
                                      np.asarray(tuned.values[k]))
    m = svc.metrics()
    assert m["tune_searches"] == r["searches"]
    assert m["tune_persist_writes"] == r["searches"]

    warm = QueryService(db, schema, cache_dir=tmp_path)
    r2 = warm.autotune(kernels=kernels)
    assert r2["searches"] == 0                         # nothing re-measured
    assert r2["invalidated_executables"] == 0          # nothing recompiled
    assert r2["entries"] >= r["searches"]
    m2 = warm.metrics()
    assert m2["tune_searches"] == 0
    assert m2["tune_store_hits"] > 0
    res = warm.submit(COSTLY_PARTS)
    for k, v in baseline.values.items():
        np.testing.assert_array_equal(np.asarray(v),
                                      np.asarray(res.values[k]))


def test_autotune_idempotent_within_process(tpch):
    """A second ``autotune()`` on the SAME service resolves everything
    from the in-memory table: zero searches, zero invalidation (no
    cache_dir needed)."""
    db, schema = tpch
    svc = QueryService(db, schema)
    r1 = svc.autotune(kernels=("segment_sum",))
    assert r1["searches"] > 0
    r2 = svc.autotune(kernels=("segment_sum",))
    assert r2["searches"] == 0 and r2["installed"] == 0
    assert r2["invalidated_executables"] == 0


def test_export_import_carries_tune_entries(tmp_path, tpch):
    db, schema = tpch
    svc = QueryService(db, schema)                     # no cache_dir
    svc.submit(COSTLY_PARTS)
    svc.autotune(kernels=("segment_sum",))
    entries = dict(svc.tuner.table.entries())
    assert entries
    svc.export_cache(tmp_path / "exported")

    svc2 = QueryService(db, schema)
    assert len(svc2.tuner.table) == 0
    svc2.import_cache(tmp_path / "exported")
    assert dict(svc2.tuner.table.entries()) == entries
    # and the importer re-measures nothing for those buckets
    r = svc2.autotune(kernels=("segment_sum",))
    assert r["searches"] == 0


def test_backend_env_is_reread_every_call(monkeypatch):
    """Regression: the backend env var used to be read at TRACE time
    inside the jitted op — flipping ``REPRO_KERNEL_BACKEND`` between
    calls was silently ignored for already-traced shapes.  The public
    wrappers must re-resolve it on every call."""
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    rng = np.random.default_rng(0)
    pk = jnp.asarray(rng.integers(0, 10, 131), jnp.int32)
    pf = jnp.ones_like(pk)
    ck = jnp.asarray(rng.integers(0, 10, 131), jnp.int32)
    cf = jnp.ones_like(ck)
    a = ops.freq_join(pk, pf, ck, cf)                  # default: xla

    called = {}
    real = ops._fj.freq_join_pallas

    def spy(*args, **kw):
        called["pallas"] = True
        return real(*args, **kw)

    monkeypatch.setattr(ops._fj, "freq_join_pallas", spy)
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas")
    b = ops.freq_join(pk, pf, ck, cf)                  # SAME shapes
    assert called.get("pallas"), \
        "env flip ignored: pallas kernel never dispatched"
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# tooling satellites: report --compare and the block-shape lint rule
# ---------------------------------------------------------------------------
def _load_module(name, rel_path):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(REPO, rel_path))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _bench_doc(rows):
    return {"bench_schema_version": 1, "benchmark": "t",
            "created_unix": 0.0, "meta": {}, "metrics": {},
            "histograms": {},
            "rows": [{"section": "s", "name": n, "us_per_call": us,
                      "derived": ""} for n, us in rows]}


def test_report_compare_flags_regressions(tmp_path):
    report = _load_module("bench_report", "benchmarks/report.py")
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    old.write_text(json.dumps(_bench_doc(
        [("a", 100.0), ("b", 50.0), ("gone", 1.0), ("untimed", None)])))
    new.write_text(json.dumps(_bench_doc(
        [("a", 100.0), ("b", 200.0), ("fresh", 1.0), ("untimed", None)])))
    assert report.compare(str(old), str(new)) == 3     # b regressed 4x
    assert report.compare(str(old), str(old)) == 0
    assert report.compare(str(old), str(new), threshold=5.0) == 0
    assert report.compare(str(tmp_path / "absent.json"), str(new)) == 2
    (tmp_path / "junk.json").write_text("{not json")
    assert report.compare(str(tmp_path / "junk.json"), str(new)) == 2


def test_report_compare_names_added_and_removed_rows(tmp_path, capsys):
    """Coverage drift is reported explicitly: dropped scenarios under a
    'removed rows' header, new ones under 'added rows'."""
    report = _load_module("bench_report", "benchmarks/report.py")
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    old.write_text(json.dumps(_bench_doc([("a", 100.0), ("gone", 1.0)])))
    new.write_text(json.dumps(_bench_doc([("a", 100.0), ("fresh", 2.0)])))
    report.compare(str(old), str(new))
    out = capsys.readouterr().out
    assert "removed rows (1" in out and "- s/gone" in out
    assert "added rows (1" in out and "+ s/fresh" in out
    report.compare(str(old), str(old))
    out = capsys.readouterr().out
    assert "row coverage unchanged" in out


def test_lint_block_shape_discipline(tmp_path):
    lint = _load_module("repro_lint", "scripts/lint.py")
    bad = tmp_path / "src" / "repro" / "service"
    bad.mkdir(parents=True)
    (bad / "sneaky.py").write_text("PARENT_BLOCK_ROWS = 4\n")
    assert lint._block_shape_discipline([str(tmp_path)]) == 1

    (bad / "sneaky.py").write_text("# PARENT_BLOCK_ROWS in a comment\n"
                                   "x = 1\n")
    assert lint._block_shape_discipline([str(tmp_path)]) == 0

    ok = tmp_path / "src" / "repro" / "kernels"
    ok.mkdir(parents=True)
    (ok / "blocks.py").write_text("LANES_WIDE = 1024\n")
    exempt = tmp_path / "tests"
    exempt.mkdir()
    (exempt / "test_x.py").write_text("CHILD_BLOCK_ROWS = 8\n")
    assert lint._block_shape_discipline([str(tmp_path)]) == 0

    # ...and the real tree is clean
    assert lint._block_shape_discipline(
        [os.path.join(REPO, "src"), os.path.join(REPO, "benchmarks"),
         os.path.join(REPO, "examples")]) == 0
