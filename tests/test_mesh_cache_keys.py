"""Topology-aware cache keys: a mesh-lowered executable must never be
served to a single-device service (or to a differently-shaped mesh), in
memory or across process restarts.

Runs in-process on a 1-device mesh — topology keying is about the KEY
(``(axis_names, shard_counts)``), not the device count, so one CPU device
is enough to pin the behaviour.  The 8-device paths are covered by the
subprocess differentials in ``test_distributed_engine.py``.
"""

import jax
import numpy as np
import pytest

from repro.data.relational import make_tpch_db, tpch_v1_query
from repro.service import QueryService
from repro.service.plan_cache import PlanCache
from repro.service.plan_store import store_fingerprint

TOPO1 = (("data",), (1,))
TOPO8 = (("data",), (8,))
TOPO24 = (("pod", "data"), (2, 4))


def _mesh1():
    return jax.make_mesh((1,), ("data",))


# ---------------------------------------------------------------- keys

def test_exec_and_fused_keys_distinct_across_topologies():
    bucket = (("edge", 64), ("node", 32))
    keys = {PlanCache.exec_key("fp", bucket, topo)
            for topo in ((), TOPO1, TOPO8, TOPO24)}
    assert len(keys) == 4
    fkeys = {PlanCache.fused_key("sig", bucket, topo)
             for topo in ((), TOPO1, TOPO8, TOPO24)}
    assert len(fkeys) == 4
    # default stays the local key — pre-mesh entries keep hitting
    assert PlanCache.exec_key("fp", bucket) == ("fp", (), bucket)


def test_invalidate_relation_spans_topologies():
    """Bucket sits LAST in every key shape, so capacity invalidation hits
    local and mesh entries for the relation alike."""
    cache = PlanCache()
    bucket = (("edge", 64),)
    other = (("node", 32),)
    for topo in ((), TOPO8):
        cache.execs.put(PlanCache.exec_key("fp", bucket, topo), "x")
        cache.execs.put(PlanCache.exec_key("fp", other, topo), "y")
        cache.fused.put(PlanCache.fused_key("sig", bucket, topo), "z")
    assert cache.invalidate_relation("edge") == 4
    assert len(cache.execs) == 2          # the "node"-bucket entries survive
    assert len(cache.fused) == 0


def test_describe_is_topology_scoped():
    cache = PlanCache()
    bucket = (("edge", 64),)
    cache.execs.put(PlanCache.exec_key("fp", bucket, TOPO8), "x")
    assert cache.describe("fp", bucket, topo=TOPO8)["exec_in_memory"]
    assert not cache.describe("fp", bucket)["exec_in_memory"]
    assert not cache.describe("fp", bucket, topo=TOPO1)["exec_in_memory"]


def test_store_fingerprint_topology_sensitivity():
    _, schema = make_tpch_db(scale=2, seed=0)
    local = store_fingerprint(schema)
    assert local == store_fingerprint(schema, topology=())
    fps = {local, store_fingerprint(schema, topology=TOPO1),
           store_fingerprint(schema, topology=TOPO8),
           store_fingerprint(schema, topology=TOPO24)}
    assert len(fps) == 4


# ------------------------------------------------------- live services

@pytest.fixture(scope="module")
def tpch():
    return make_tpch_db(scale=8, seed=7)


def test_mesh_and_local_services_occupy_distinct_exec_entries(tpch):
    db, schema = tpch
    q = tpch_v1_query("minmax")
    mesh_svc = QueryService(db, schema, mesh=_mesh1())
    local_svc = QueryService(db, schema)
    mr, lr = mesh_svc.submit(q), local_svc.submit(q)
    assert mr.error is None and lr.error is None
    for svc, topo in ((mesh_svc, TOPO1), (local_svc, ())):
        exec_keys = [k for k, _ in svc.cache.execs.items()]
        assert exec_keys and all(k[1] == topo for k in exec_keys), exec_keys
    # 1-device mesh with matching min_bucket pads identically → bitwise
    for k in mr.values:
        a, b = np.asarray(mr.values[k]), np.asarray(lr.values[k])
        assert a.dtype == b.dtype and a.tobytes() == b.tobytes(), k


def test_plan_store_is_topology_partitioned(tmp_path, tpch):
    """A mesh service warm-starts from its OWN store partition
    (plan_builds == 0 on restart) and never reads a local service's —
    and vice versa: no topology leaks through ``cache_dir``."""
    db, schema = tpch
    # SQL text → shareable fingerprint (opaque-selection queries are
    # process-salted and bypass the store by design)
    q = """
    SELECT MIN(s.s_acctbal), MAX(s.s_acctbal)
    FROM supplier s, partsupp ps, part p
    WHERE s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
      AND p.p_price > 900.0
    """
    cache_dir = str(tmp_path / "plans")

    cold = QueryService(db, schema, mesh=_mesh1(), cache_dir=cache_dir)
    assert cold.submit(q).error is None
    assert cold.metrics()["plan_builds"] == 1
    assert len(cold.plan_store) == 1

    # warm mesh restart: the disk level answers, nothing is re-planned
    warm = QueryService(db, schema, mesh=_mesh1(), cache_dir=cache_dir)
    assert warm.submit(q).error is None
    assert warm.metrics()["plan_builds"] == 0
    assert warm.metrics()["persist_hits"] >= 1

    # a LOCAL service over the same cache_dir sees an empty partition
    local = QueryService(db, schema, cache_dir=cache_dir)
    assert len(local.plan_store) == 0
    assert local.submit(q).error is None
    assert local.metrics()["plan_builds"] == 1

    # ...and a differently-shaped mesh would get its own partition too
    assert (store_fingerprint(schema, topology=TOPO1)
            != store_fingerprint(schema, topology=TOPO8))


def test_mesh_observability_surfaces(tpch):
    db, schema = tpch
    q = tpch_v1_query("minmax")
    svc = QueryService(db, schema, mesh=_mesh1())
    res = svc.submit(q)
    assert res.error is None

    gauges = svc.metrics_v2()["gauges"]
    assert gauges["mesh_devices"] == 1
    assert gauges["mesh_shard_count_data"] == 1

    # the run span carries a ring_sweep child annotated with the topology
    spans = list(res.stats.trace.walk())
    sweeps = [s for s in spans if s.name == "ring_sweep"]
    assert sweeps, [s.name for s in spans]
    assert sweeps[0].args["axes"] == "data"
    assert sweeps[0].args["shards"] == 1
    run = next(s for s in spans if s.name == "run")
    assert any(c.name == "ring_sweep" for c in run.children)

    exp = svc.explain(q)
    assert exp["topology"] == TOPO1
    assert exp["sharding"]["data_axes"] == ["data"]
    assert exp["sharding"]["placement"]
    assert "rows over data (1 shards)" in exp["text"]

    # a local service reports the absence explicitly
    local = QueryService(db, schema)
    lexp = local.explain(q)
    assert lexp["topology"] == ()
    assert lexp["sharding"] is None
    assert "single-device" in lexp["text"]
