"""Serving tier: fingerprint invariance, plan cache, shape buckets,
micro-batching, lock granularity, and the eager fallback."""

import threading
import time

import jax
import numpy as np
import pytest

from repro.core import Executor, parse_sql, plan_query
from repro.core.query import Agg, AggQuery, Atom
from repro.data import make_stats_db, make_tpch_db
from repro.service import QueryService, canonicalize, fingerprint
from repro.service.plan_cache import LRUCache, PlanCache
from repro.tables.table import Table, bucket_capacity

jax.config.update("jax_platform_name", "cpu")

FIG1 = """
SELECT MIN(s.s_acctbal), MAX(s.s_acctbal)
FROM region r, nation n, supplier s, partsupp ps, part p
WHERE r.r_regionkey = n.n_regionkey AND n.n_nationkey = s.s_nationkey
  AND s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
  AND r.r_name IN (2, 3) AND p.p_price > 1200.0
"""
# the same query under alias renaming, FROM/WHERE reordering, swapped
# SELECT list, and reversed IN list
FIG1_RENAMED = """
SELECT MAX(su.s_acctbal), MIN(su.s_acctbal)
FROM part pa, supplier su, region re, partsupp pp, nation na
WHERE pa.p_price > 1200.0 AND na.n_nationkey = su.s_nationkey
  AND re.r_regionkey = na.n_regionkey AND pp.ps_partkey = pa.p_partkey
  AND su.s_suppkey = pp.ps_suppkey AND re.r_name IN (3, 2)
"""


# ---------------------------------------------------------------------------
# fingerprinting
# ---------------------------------------------------------------------------
def test_fingerprint_invariant_under_alias_renaming():
    _, schema = make_tpch_db(scale=5)
    fa = fingerprint(parse_sql(FIG1, schema))
    fb = fingerprint(parse_sql(FIG1_RENAMED, schema))
    assert fa == fb


def test_fingerprint_distinguishes_literals_and_structure():
    _, schema = make_tpch_db(scale=5)
    base = fingerprint(parse_sql(FIG1, schema))
    other = fingerprint(parse_sql(FIG1.replace("1200.0", "900.0"), schema))
    assert base != other
    min_only = fingerprint(parse_sql(
        "SELECT MIN(p.p_price) FROM part p", schema))
    max_only = fingerprint(parse_sql(
        "SELECT MAX(p.p_price) FROM part p", schema))
    assert min_only != max_only


def _supplier_nation_query(v: dict[str, str], order=(0, 1)) -> AggQuery:
    """MIN over supplier⋈nation with caller-chosen variable names and atom
    order — structurally one query."""
    atoms = [Atom("supplier", "s", (v["sk"], v["nk"], v["bal"])),
             Atom("nation", "n", (v["nk"], v["rk"]))]
    return AggQuery(
        atoms=tuple(atoms[i] for i in order),
        aggregates=(Agg("min", v["bal"]),),
        selections={"n": lambda c: c["n_regionkey"] > 1},
        selection_specs={"n": ((">", "n_regionkey", 1),)})


def test_fingerprint_invariant_under_variable_renaming_and_atom_order():
    base = _supplier_nation_query(
        {"sk": "sk", "nk": "nk", "bal": "bal", "rk": "rk"})
    renamed = _supplier_nation_query(
        {"sk": "x1", "nk": "x2", "bal": "x3", "rk": "x4"}, order=(1, 0))
    ca, cb = canonicalize(base), canonicalize(renamed)
    assert ca.fingerprint == cb.fingerprint
    assert ca.prefix_fingerprint == cb.prefix_fingerprint
    # structurally different: aggregate over a different variable
    other = AggQuery(
        atoms=base.atoms,
        aggregates=(Agg("min", "sk"),),
        selections=dict(base.selections),
        selection_specs=dict(base.selection_specs))
    assert canonicalize(other).fingerprint != ca.fingerprint
    # ...but the join structure is the same → prefix fingerprint shared
    assert canonicalize(other).prefix_fingerprint == ca.prefix_fingerprint


def test_fingerprint_opaque_selections_never_share():
    """Hand-built queries with closure-only selections are singletons."""
    q1 = AggQuery(
        atoms=(Atom("part", "p", ("pk", "price")),),
        aggregates=(Agg("count"),),
        selections={"p": lambda c: c["p_price"] > 100})
    q2 = AggQuery(
        atoms=(Atom("part", "p", ("pk", "price")),),
        aggregates=(Agg("count"),),
        selections={"p": lambda c: c["p_price"] > 999})
    c1, c2 = canonicalize(q1), canonicalize(q2)
    assert not c1.shareable and not c2.shareable
    assert c1.fingerprint != c2.fingerprint
    # ...but the SAME object keeps its fingerprint → repeat submissions
    # of one hand-built query still hit their singleton cache entry
    assert canonicalize(q1).fingerprint == c1.fingerprint


def test_canonical_query_plans_to_same_answer():
    """Canonicalisation is semantics-preserving: planning the canonical
    query gives the same result as planning the original."""
    db, schema = make_tpch_db(scale=60, seed=1)
    q = parse_sql(FIG1, schema)
    canon = canonicalize(q)
    ex = Executor(db, schema)
    want = ex.execute(plan_query(q, schema))
    got = canon.rename_results(
        ex.execute(plan_query(canon.query, schema)))
    for key in ("min(s.s_acctbal)", "max(s.s_acctbal)"):
        np.testing.assert_allclose(float(got[key]), float(want[key]))


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------
def test_lru_cache_counters_and_eviction():
    c = LRUCache(2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1           # refresh a
    c.put("c", 3)                    # evicts b (LRU)
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    m = c.counters()
    assert m["evictions"] == 1 and m["hits"] == 3 and m["misses"] == 1


def test_plan_cache_invalidate_relation():
    pc = PlanCache(4, 4)
    pc.get_executable("fp1", (("part", 128), ("supplier", 64)), lambda: "x")
    pc.get_executable("fp2", (("nation", 32),), lambda: "y")
    assert pc.invalidate_relation("part") == 1
    assert PlanCache.exec_key("fp2", (("nation", 32),)) in pc.execs
    assert PlanCache.exec_key(
        "fp1", (("part", 128), ("supplier", 64))) not in pc.execs


def test_physical_plan_hashable_and_comparable():
    _, schema = make_tpch_db(scale=5)
    q = parse_sql(FIG1, schema)
    p1 = plan_query(q, schema)
    p2 = plan_query(q, schema)
    assert p1 == p2 and hash(p1) == hash(p2)
    p_ref = plan_query(q, schema, mode="ref")
    assert p1 != p_ref
    assert len({p1, p2, p_ref}) == 2


# ---------------------------------------------------------------------------
# table padding / buckets
# ---------------------------------------------------------------------------
def test_bucket_capacity_powers_of_two():
    assert bucket_capacity(1) == 8      # min floor
    assert bucket_capacity(8) == 8
    assert bucket_capacity(9) == 16
    assert bucket_capacity(4000) == 4096
    assert bucket_capacity(4096) == 4096
    assert bucket_capacity(4097) == 8192


def test_pad_to_is_semantically_free():
    db, schema = make_tpch_db(scale=40, seed=5)
    q = parse_sql(FIG1, schema)
    plan = plan_query(q, schema)
    want = Executor(db, schema).execute(plan)
    padded = {name: t.pad_to(bucket_capacity(t.capacity))
              for name, t in db.items()}
    got = Executor(padded, schema).execute(plan)
    for key in ("min(s.s_acctbal)", "max(s.s_acctbal)"):
        np.testing.assert_allclose(float(got[key]), float(want[key]))
    with pytest.raises(ValueError, match="never shrink"):
        db["part"].pad_to(1)


# ---------------------------------------------------------------------------
# QueryService
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tpch_service():
    db, schema = make_tpch_db(scale=50, seed=3)
    return QueryService(db, schema), db, schema


def test_service_warm_requests_hit_both_cache_levels(tpch_service):
    svc, db, schema = tpch_service
    cold = svc.submit(FIG1)
    assert not cold.stats.plan_cache_hit or svc.metrics()["requests"] > 1
    warm = svc.submit(FIG1_RENAMED)   # structurally identical
    assert warm.stats.plan_cache_hit and warm.stats.exec_cache_hit
    np.testing.assert_allclose(
        float(warm.values["min(su.s_acctbal)"]),
        float(cold.values["min(s.s_acctbal)"]))
    # answers match a from-scratch eager run
    want = Executor(db, schema).execute(
        plan_query(parse_sql(FIG1, schema), schema))
    np.testing.assert_allclose(float(cold.values["max(s.s_acctbal)"]),
                               float(want["max(s.s_acctbal)"]))


def test_service_microbatch_dedup(tpch_service):
    svc, _, _ = tpch_service
    before = svc.metrics()
    results = svc.submit_many([FIG1, FIG1_RENAMED, FIG1])
    after = svc.metrics()
    assert after["dedup_saved"] - before["dedup_saved"] == 2
    assert after["compiles"] == before["compiles"]  # warm fingerprint
    shared = [r.stats.shared_execution for r in results]
    assert shared == [False, True, True]
    vals = [float(r.values[next(k for k in r.values if k.startswith("min"))])
            for r in results]
    assert vals[0] == vals[1] == vals[2]


def test_service_group_by_renames_outputs(tpch_service):
    svc, db, _ = tpch_service
    res = svc.submit("""
        SELECT COUNT(*) AS cnt FROM supplier s, nation n
        WHERE s.s_nationkey = n.n_nationkey GROUP BY n.n_regionkey
    """)
    cols, valid = res.values["groups"], np.asarray(res.values["valid"])
    assert "cnt" in cols and "n.n_regionkey" in cols
    got = sum(int(c) for c, v in zip(np.asarray(cols["cnt"]), valid) if v)
    assert got == int(db["supplier"].live_count())


def test_service_same_bucket_growth_zero_recompiles():
    db, schema = make_tpch_db(scale=50, seed=7)
    svc = QueryService(db, schema)
    svc.submit(FIG1)
    compiles = svc.metrics()["compiles"]

    # grow partsupp inside its bucket: capacity 4000 → bucket 4096
    ps = db["partsupp"]
    bucket = bucket_capacity(ps.capacity)
    extra = bucket - ps.capacity
    assert extra > 0
    rng = np.random.default_rng(0)
    grown = {
        "ps_partkey": np.concatenate([np.asarray(ps.columns["ps_partkey"]),
                                      rng.integers(0, 1000, extra)]).astype(np.int32),
        "ps_suppkey": np.concatenate([np.asarray(ps.columns["ps_suppkey"]),
                                      rng.integers(0, 50, extra)]).astype(np.int32),
        "ps_supplycost": np.concatenate(
            [np.asarray(ps.columns["ps_supplycost"]),
             rng.gamma(2.0, 150.0, extra).astype(np.float32)]),
    }
    svc.update_table("partsupp", Table.from_numpy(grown))
    res = svc.submit(FIG1)
    m = svc.metrics()
    assert m["compiles"] == compiles          # zero recompiles
    assert m["bucket_invalidations"] == 0
    assert res.stats.exec_cache_hit

    # a dtype drift would be a cache "hit" that silently re-traces inside
    # jax.jit — update_table must refuse it
    bad = dict(grown)
    bad["ps_supplycost"] = bad["ps_supplycost"].astype(np.int32)
    with pytest.raises(ValueError, match="dtype"):
        svc.update_table("partsupp", Table.from_numpy(bad))

    # crossing the bucket boundary must invalidate and recompile
    bigger = {k: np.concatenate([v, v[:8]]) for k, v in grown.items()}
    svc.update_table("partsupp", Table.from_numpy(bigger))
    res2 = svc.submit(FIG1)
    m2 = svc.metrics()
    assert m2["bucket_invalidations"] == 1
    assert m2["compiles"] == compiles + 1
    assert not res2.stats.exec_cache_hit
    np.testing.assert_allclose(
        float(res2.values["min(s.s_acctbal)"]),
        float(res.values["min(s.s_acctbal)"]))


def test_service_eager_fallback_for_unguarded_plans():
    """MEDIAN over an FK/FK join is guarded only when the guard covers the
    output vars; an unguarded aggregate must fall back to the eager
    materialising path and still answer."""
    db, schema = make_stats_db(n_users=20, n_posts=50, n_comments=120,
                               n_votes=40, seed=1)
    svc = QueryService(db, schema)
    # aggregate vars spread over two atoms → no guard → ref plan
    q = AggQuery(
        atoms=(Atom("posts", "po", ("pid", "uid", "score")),
               Atom("comments", "co", ("pid", "cuid", "cscore"))),
        aggregates=(Agg("median", "score"), Agg("median", "cscore")))
    res = svc.submit(q)
    assert res.stats.mode == "ref"
    assert res.stats.exec_stats is not None
    assert res.stats.exec_stats.peak_tuples > 0
    assert svc.metrics()["eager_requests"] == 1


def test_service_concurrent_submissions_are_safe():
    db, schema = make_tpch_db(scale=30, seed=9)
    svc = QueryService(db, schema)
    svc.submit(FIG1)  # warm once so threads race on the hot path
    errors: list = []
    outs: list = []

    def worker():
        try:
            r = svc.submit(FIG1_RENAMED)
            outs.append(float(r.values["min(su.s_acctbal)"]))
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(set(outs)) == 1
    assert svc.metrics()["compiles"] == 1


def test_metrics_and_updates_not_blocked_by_compile():
    """Regression: the service lock guards only cache/db mutation — a
    long XLA compile in one thread must not block ``metrics()`` (or
    ``update_table``) in another."""
    db, schema = make_tpch_db(scale=30, seed=11)
    svc = QueryService(db, schema)
    compiling = threading.Event()
    release = threading.Event()
    real_compile = svc._jit_executor.compile

    def slow_compile(plan):
        compiling.set()
        assert release.wait(30), "test orchestration stalled"
        return real_compile(plan)

    svc._jit_executor.compile = slow_compile
    out: list = []
    t = threading.Thread(target=lambda: out.append(svc.submit(FIG1)))
    t.start()
    try:
        assert compiling.wait(30)
        t0 = time.perf_counter()
        m = svc.metrics()                       # must not wait on compile
        grown = {k: np.asarray(v)
                 for k, v in db["region"].columns.items()}
        svc.update_table("region", Table.from_numpy(grown))
        blocked_s = time.perf_counter() - t0
    finally:
        release.set()
        t.join(60)
    assert blocked_s < 1.0
    assert m["requests"] == 1 and m["compiles"] == 0
    assert out and "min(s.s_acctbal)" in out[0].values


def test_concurrent_cold_submissions_compile_once():
    """Two threads racing on the same cold fingerprint: the in-flight
    event makes the second wait for the first's executable instead of
    compiling its own."""
    db, schema = make_tpch_db(scale=30, seed=12)
    svc = QueryService(db, schema)
    results: list = []
    errors: list = []

    def worker(sql):
        try:
            r = svc.submit(sql)
            key = next(k for k in r.values if k.startswith("min"))
            results.append(float(r.values[key]))
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker,
                                args=(FIG1 if i % 2 else FIG1_RENAMED,))
               for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert svc.metrics()["compiles"] == 1
    assert len(set(results)) == 1


def test_submit_many_isolates_bad_requests(tpch_service):
    """Regression: one malformed query (unknown relation, SQL syntax
    error) must not abort its batch-mates — its error attaches to its own
    QueryResult, everyone else gets answers."""
    svc, _, _ = tpch_service
    want = svc.submit(FIG1)
    base = svc.metrics()
    res = svc.submit_many([FIG1,
                           "SELECT MIN(x.nope) FROM nowhere x",
                           FIG1_RENAMED,
                           "SELECT FROM WHERE"])
    assert [r.error is None for r in res] == [True, False, True, False]
    assert res[1].values == {} and res[3].values == {}
    assert "nowhere" in str(res[1].error)
    np.testing.assert_array_equal(
        np.asarray(res[0].values["min(s.s_acctbal)"]),
        np.asarray(want.values["min(s.s_acctbal)"]))
    np.testing.assert_array_equal(
        np.asarray(res[2].values["min(su.s_acctbal)"]),
        np.asarray(want.values["min(s.s_acctbal)"]))
    m = svc.metrics()
    assert m["request_errors"] - base["request_errors"] == 2
    # submit() re-raises the captured error for single-query callers
    with pytest.raises(Exception, match="nowhere"):
        svc.submit("SELECT MIN(x.nope) FROM nowhere x")


def test_submit_many_empty_batch_counts_nothing(tpch_service):
    """Regression: submit_many([]) used to increment the batches
    counter."""
    svc, _, _ = tpch_service
    before = svc.metrics()
    assert svc.submit_many([]) == []
    assert svc.submit_many(iter([])) == []
    after = svc.metrics()
    assert after["batches"] == before["batches"]
    assert after["requests"] == before["requests"]


def test_submit_many_accepts_any_iterable(tpch_service):
    """Regression: counting len(queries) up front broke generator
    inputs."""
    svc, _, _ = tpch_service
    res = svc.submit_many(q for q in [FIG1])
    assert res[0].error is None and res[0].values


def test_padded_view_cache_bounded():
    """Regression: the bucket-padded view cache was unbounded across
    relations; it is now an LRU level of the plan cache."""
    db, schema = make_tpch_db(scale=30, seed=5)
    svc = QueryService(db, schema, padded_capacity=2)
    first = svc.submit(FIG1)            # scans 5 relations
    m = svc.metrics()
    assert m["padded_relations"] <= 2
    assert m["padded_evictions"] >= 3
    # eviction is a cache concern only — answers are unaffected
    again = svc.submit(FIG1)
    np.testing.assert_array_equal(
        np.asarray(first.values["min(s.s_acctbal)"]),
        np.asarray(again.values["min(s.s_acctbal)"]))


def test_metrics_and_updates_not_blocked_by_planning(monkeypatch):
    """Regression: _plan_unit used to run the whole plan_query rewrite
    pipeline while holding the service lock; metrics()/update_table were
    stuck behind it.  Planning now builds behind an in-flight event like
    a compile."""
    import repro.service.engine as engine_mod
    db, schema = make_tpch_db(scale=30, seed=13)
    svc = QueryService(db, schema)
    planning = threading.Event()
    release = threading.Event()
    real_plan = engine_mod.plan_query

    def slow_plan(*args, **kwargs):
        planning.set()
        assert release.wait(30), "test orchestration stalled"
        return real_plan(*args, **kwargs)

    monkeypatch.setattr(engine_mod, "plan_query", slow_plan)
    out: list = []
    t = threading.Thread(target=lambda: out.append(svc.submit(FIG1)))
    t.start()
    try:
        assert planning.wait(30)
        t0 = time.perf_counter()
        m = svc.metrics()                  # must not wait on planning
        svc.update_table("region", Table.from_numpy(
            {k: np.asarray(v) for k, v in db["region"].columns.items()}))
        blocked_s = time.perf_counter() - t0
    finally:
        release.set()
        t.join(60)
    assert blocked_s < 1.0
    assert m["requests"] == 1 and m["plan_misses"] == 0
    assert out and "min(s.s_acctbal)" in out[0].values


def test_metrics_and_updates_not_blocked_by_padding(monkeypatch):
    """Regression: _snapshot used to run Table.pad_to (device work) while
    holding the service lock; padding now happens outside it against an
    immutable table snapshot."""
    db, schema = make_tpch_db(scale=30, seed=14)
    svc = QueryService(db, schema)
    padding = threading.Event()
    release = threading.Event()
    real_pad = Table.pad_to

    def slow_pad(self, cap):
        padding.set()
        assert release.wait(30), "test orchestration stalled"
        return real_pad(self, cap)

    monkeypatch.setattr(Table, "pad_to", slow_pad)
    out: list = []
    t = threading.Thread(target=lambda: out.append(svc.submit(FIG1)))
    t.start()
    try:
        assert padding.wait(30)
        t0 = time.perf_counter()
        m = svc.metrics()                  # must not wait on pad_to
        svc.update_table("region", Table.from_numpy(
            {k: np.asarray(v) for k, v in db["region"].columns.items()}))
        blocked_s = time.perf_counter() - t0
    finally:
        release.set()
        t.join(60)
    assert blocked_s < 1.0
    assert m["requests"] == 1
    assert out and "min(s.s_acctbal)" in out[0].values


def test_compile_rejects_eager_only_options():
    db, schema = make_tpch_db(scale=10)
    q = parse_sql(FIG1, schema)
    plan = plan_query(q, schema)
    guarded = Executor(db, schema, oom_guard=1000)
    with pytest.raises(ValueError, match="eager-only"):
        guarded.compile(plan)
    # jittable() strips the guard
    fn = guarded.jittable().compile(plan)
    out = fn(db)
    assert "min(s.s_acctbal)" in out
