"""Serving engine tests: greedy generation consistency and wave batching."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import forward, init_params
from repro.models.lm_serving import ServeEngine, greedy_generate

jax.config.update("jax_platform_name", "cpu")


def _greedy_via_forward(params, cfg, prompt, max_new):
    """Oracle: re-run the full forward for every generated token."""
    import jax.numpy as jnp
    toks = list(prompt)
    out = []
    for _ in range(max_new):
        logits, _ = forward(params, cfg,
                            {"tokens": jnp.asarray([toks], jnp.int32)})
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


@pytest.mark.parametrize("arch", ["smollm-135m", "rwkv6-1.6b"])
def test_greedy_generate_matches_forward_rollout(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 8)
    got = greedy_generate(params, cfg, prompt[None, :], max_new_tokens=6)
    want = _greedy_via_forward(params, cfg, list(prompt), 6)
    assert got[0].tolist() == want


def test_wave_engine_matches_greedy():
    cfg = dataclasses.replace(get_smoke_config("smollm-135m"),
                              dtype="float32")
    params, _ = init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, 8) for _ in range(3)]

    engine = ServeEngine(params, cfg, n_slots=4, max_len=64)
    rids = [engine.submit(p) for p in prompts]
    outs = engine.run_wave(max_tokens=5)
    assert set(outs) == set(rids)
    for rid, p in zip(rids, prompts):
        want = greedy_generate(params, cfg, p[None, :], max_new_tokens=5)
        assert outs[rid] == want[0].tolist(), rid


def test_wave_engine_multiple_waves():
    cfg = dataclasses.replace(get_smoke_config("smollm-135m"),
                              dtype="float32")
    params, _ = init_params(jax.random.PRNGKey(2), cfg)
    engine = ServeEngine(params, cfg, n_slots=2, max_len=32)
    rng = np.random.default_rng(7)
    rids = [engine.submit(rng.integers(0, cfg.vocab_size, 4))
            for _ in range(5)]
    served = {}
    while engine._queue:
        served.update(engine.run_wave(max_tokens=3))
    assert set(served) == set(rids)
    assert all(len(v) == 3 for v in served.values())


def test_deprecated_serving_alias_still_exports_engine():
    """The old ``repro.serving`` path re-exports from models.lm_serving
    with a DeprecationWarning (reload forces the warning even when some
    earlier import already cached the module)."""
    import importlib

    with pytest.warns(DeprecationWarning, match="repro.models.lm_serving"):
        mod = importlib.import_module("repro.serving")
        mod = importlib.reload(mod)
    assert mod.ServeEngine is ServeEngine
    assert mod.greedy_generate is greedy_generate
