"""Training substrate tests: loss goes down, microbatch invariance,
gradient-compression sanity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import TokenPipeline
from repro.models import init_params
from repro.training import build_train_step, init_train_state

jax.config.update("jax_platform_name", "cpu")


def _setup(microbatches=1, steps=40, family_arch="smollm-135m"):
    cfg = dataclasses.replace(get_smoke_config(family_arch), dtype="float32")
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params)
    step = jax.jit(build_train_step(
        cfg, microbatches=microbatches, base_lr=1e-2, warmup=5,
        total_steps=steps, remat="none"))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=32,
                         global_batch=8, seed=7)
    return cfg, state, step, pipe


def test_loss_decreases():
    _, state, step, pipe = _setup(steps=30)
    losses = []
    for i in range(30):
        state, metrics = step(state, pipe.jax_batch(i % 4))  # cycle 4 batches
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::6]
    assert np.isfinite(losses).all()


def test_microbatch_invariance():
    """Grad accumulation must not change the training trajectory."""
    _, s1, step1, pipe = _setup(microbatches=1)
    _, s4, step4, _ = _setup(microbatches=4)
    b = pipe.jax_batch(0)
    s1, m1 = step1(s1, b)
    s4, m4 = step4(s4, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    d = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))),
                     s1.params, s4.params)
    assert max(jax.tree.leaves(d)) < 1e-4, sorted(
        jax.tree.leaves(d))[-3:]


def test_moe_train_smoke():
    cfg = dataclasses.replace(get_smoke_config("mixtral-8x22b"),
                              dtype="float32")
    params, _ = init_params(jax.random.PRNGKey(1), cfg)
    state = init_train_state(params)
    step = jax.jit(build_train_step(cfg, microbatches=2, base_lr=5e-3,
                                    warmup=2, total_steps=20, remat="full"))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=16,
                         global_batch=4, seed=3)
    losses = []
    for i in range(12):
        state, metrics = step(state, pipe.jax_batch(i % 2))
        losses.append(float(metrics["loss"]))
        assert float(metrics["dropped_frac"]) <= 1.0
    assert losses[-1] < losses[0]


def test_grad_compression_preserves_convergence():
    from repro.distributed.compression import ef_int8_roundtrip
    # int8 EF roundtrip error must be < 1% of tensor scale
    g = jnp.asarray(np.random.default_rng(0).normal(size=(256,)), jnp.float32)
    r = ef_int8_roundtrip(g)
    rel = float(jnp.max(jnp.abs(g - r)) / jnp.max(jnp.abs(g)))
    assert rel < 1 / 127 + 1e-6
    # and training still converges with compression on
    cfg = dataclasses.replace(get_smoke_config("smollm-135m"),
                              dtype="float32")
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params)
    step = jax.jit(build_train_step(cfg, microbatches=1, base_lr=1e-2,
                                    warmup=5, total_steps=30, remat="none",
                                    compress_grads=True))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=32,
                         global_batch=8, seed=7)
    losses = []
    for i in range(25):
        state, metrics = step(state, pipe.jax_batch(i % 4))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.85


def test_compressed_psum_matches_psum_within_quant_error():
    from repro.distributed.compression import CompressedPsum
    mesh = jax.make_mesh((1,), ("pod",))

    grads = {"w": jnp.asarray(
        np.random.default_rng(1).normal(size=(64,)), jnp.float32)}
    res = CompressedPsum.init_state(grads)

    def f(g, r):
        return CompressedPsum.psum(g, r, "pod")

    from repro.core.distributed import _shard_map
    out, new_res = jax.jit(_shard_map(
        f, mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(),) * 2,
        out_specs=jax.sharding.PartitionSpec()))(grads, res)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(grads["w"]), atol=2e-2)
    # residual bookkeeping: g ≈ sent + residual
    np.testing.assert_allclose(
        np.asarray(out["w"] + new_res["w"]), np.asarray(grads["w"]),
        atol=1e-6)
