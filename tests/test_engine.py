"""Engine behaviour tests: GYO, 0MA classification, plan-class equivalence
(ref == opt == opt_plus == brute force), the paper's running example, and
materialisation accounting (the Fig. 6 invariant)."""

import itertools

import jax
import numpy as np
import pytest

from repro.core import (
    Agg,
    AggQuery,
    Atom,
    Executor,
    classify,
    build_join_tree,
    plan_query,
)
from repro.data import (
    make_graph_db,
    make_stats_db,
    make_tpch_db,
    path_query,
    tree_query,
)
from repro.data.relational import stats_count_query, tpch_v1_query

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# brute force oracle over tiny databases
# ---------------------------------------------------------------------------
def brute_force_count(db, schema, query):
    """Enumerate all homomorphisms (python product loop) and count."""
    rows = {}
    for a in query.atoms:
        tab = db[a.rel]
        rel = schema.relations[a.rel]
        cols = [np.asarray(tab.columns[c]) for c in rel.column_names()]
        live = np.asarray(tab.freq) > 0
        sel = query.selections.get(a.alias)
        if sel is not None:
            m = sel({c: np.asarray(tab.columns[c])
                     for c in rel.column_names()})
            live &= np.asarray(m)
        rows[a.alias] = [tuple(c[i] for c in cols)
                         for i in range(len(live)) if live[i]]
    count = 0
    for combo in itertools.product(*[rows[a.alias] for a in query.atoms]):
        binding = {}
        ok = True
        for a, tup in zip(query.atoms, combo):
            for v, val in zip(a.vars, tup):
                if v in binding and binding[v] != val:
                    ok = False
                    break
                binding[v] = val
            if not ok:
                break
        if ok:
            count += 1
    return count


# ---------------------------------------------------------------------------
# GYO / classification
# ---------------------------------------------------------------------------
def test_path_query_is_acyclic_and_tree_connected():
    q = path_query(3)
    t = build_join_tree(q.atoms)
    assert t is not None
    # connectedness: shared var of any two atoms occurs on the path
    assert len(t.postorder()) == 4


def test_triangle_is_cyclic():
    atoms = (
        Atom("edge", "e1", ("a", "b")),
        Atom("edge", "e2", ("b", "c")),
        Atom("edge", "e3", ("c", "a")),
    )
    assert build_join_tree(atoms) is None
    q = AggQuery(atoms=atoms, aggregates=(Agg("count"),))
    _, schema = make_graph_db(10, 10)
    with pytest.raises(ValueError, match="cyclic"):
        plan_query(q, schema)


def test_count_star_is_guarded_not_set_safe():
    _, schema = make_graph_db(10, 10)
    q = path_query(2)
    cls = classify(q, schema)
    assert cls.acyclic and cls.guarded and not cls.set_safe
    assert not cls.is_oma


def test_min_max_query_is_oma():
    _, schema = make_tpch_db(scale=10)
    q = tpch_v1_query("minmax")
    cls = classify(q, schema)
    assert cls.is_oma
    # guard must hold the aggregate var (s_acctbal lives in supplier)
    assert cls.guard == "s"


def test_fkpk_makes_count_set_safe():
    """All joins in the TPC-H V.1 tree are FK→PK from parent to child once
    rooted at partsupp... but rooted at the guard `s`, the ps subtree is
    child-side FK — so COUNT over the v1 query is NOT schema-set-safe,
    while a pure FK→PK chain is."""
    _, schema = make_tpch_db(scale=10)
    atoms = (
        Atom("supplier", "s", ("sk", "nk", "bal")),
        Atom("nation", "n", ("nk", "rk")),
        Atom("region", "r", ("rk", "rname")),
    )
    q = AggQuery(atoms=atoms, aggregates=(Agg("count"),))
    cls = classify(q, schema)
    # chain supplier→nation→region is FK→PK all the way: COUNT is safe
    assert cls.guarded and cls.set_safe and cls.is_oma


def test_median_query_guarded_not_oma():
    _, schema = make_tpch_db(scale=10)
    q = tpch_v1_query("median")
    cls = classify(q, schema)
    assert cls.guarded and not cls.is_oma


# ---------------------------------------------------------------------------
# plan-class equivalence on counting queries
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("qmaker", [lambda: path_query(2),
                                    lambda: path_query(3),
                                    lambda: tree_query(1),
                                    lambda: tree_query(2),
                                    lambda: tree_query(3)])
def test_plan_classes_agree_with_brute_force(qmaker):
    db, schema = make_graph_db(n_nodes=12, n_edges=40, seed=3)
    q = qmaker()
    want = brute_force_count(db, schema, q)
    ex = Executor(db, schema)
    for mode in ("ref", "opt", "opt_plus"):
        plan = plan_query(q, schema, mode=mode)
        got = ex.execute(plan)["count(*)"]
        assert int(got) == want, (mode, int(got), want)


@pytest.mark.parametrize("use_fkpk", [False, True])
def test_stats_count_modes_agree(use_fkpk):
    db, schema = make_stats_db(n_users=40, n_posts=120, n_comments=300,
                               n_votes=200, seed=1)
    q = stats_count_query()
    ex = Executor(db, schema)
    ref = ex.execute(plan_query(q, schema, mode="ref"))["count(*)"]
    for mode in ("opt", "opt_plus"):
        plan = plan_query(q, schema, mode=mode, use_fkpk=use_fkpk)
        got = ex.execute(plan)["count(*)"]
        assert int(got) == int(ref)


def test_pallas_backend_engine_agrees():
    db, schema = make_graph_db(n_nodes=10, n_edges=30, seed=5)
    q = path_query(2)
    want = brute_force_count(db, schema, q)
    ex = Executor(db, schema, backend="pallas", interpret=True)
    got = ex.execute(plan_query(q, schema, mode="opt_plus"))["count(*)"]
    assert int(got) == want


# ---------------------------------------------------------------------------
# the paper's running example
# ---------------------------------------------------------------------------
def test_tpch_v1_minmax_oma_vs_ref():
    db, schema = make_tpch_db(scale=50, seed=2)
    q = tpch_v1_query("minmax")
    ex = Executor(db, schema)
    auto = plan_query(q, schema)          # should pick oma
    assert auto.mode == "oma"
    r_oma = ex.execute(auto)
    r_ref = ex.execute(plan_query(q, schema, mode="ref"))
    np.testing.assert_allclose(float(r_oma["min(bal)"]),
                               float(r_ref["min(bal)"]), rtol=1e-6)
    np.testing.assert_allclose(float(r_oma["max(bal)"]),
                               float(r_ref["max(bal)"]), rtol=1e-6)


def test_tpch_v1_median_freq_prop_vs_ref():
    db, schema = make_tpch_db(scale=30, seed=4)
    q = tpch_v1_query("median")
    ex = Executor(db, schema)
    auto = plan_query(q, schema)          # guarded, not 0MA → opt_plus
    assert auto.mode == "opt_plus"
    med_opt = float(ex.execute(auto)["median(bal)"])
    med_ref = float(ex.execute(plan_query(q, schema, mode="ref"))["median(bal)"])
    assert med_opt == med_ref


def test_tpch_v1_fkpk_plan_uses_semijoins():
    """§4.3 / Example 4.2: with FK/PK info every FreqJoin in the V.1 plan
    degrades to a semi-join."""
    from repro.core.plan import FreqJoinOp, SemiJoinOp
    _, schema = make_tpch_db(scale=10)
    q = tpch_v1_query("median")
    plan = plan_query(q, schema, mode="opt_plus", use_fkpk=True)
    kinds = [type(op).__name__ for op in plan.ops]
    assert "SemiJoinOp" in kinds
    # the ps→p and s→ps edges: ps child of s is NOT fk/pk (s holds PK),
    # so at least one FreqJoin must remain
    assert any(isinstance(op, FreqJoinOp) for op in plan.ops)


# ---------------------------------------------------------------------------
# group-by, avg, sum
# ---------------------------------------------------------------------------
def test_group_by_count_matches_numpy():
    db, schema = make_stats_db(n_users=30, n_posts=100, n_comments=250,
                               n_votes=150, seed=7)
    atoms = (
        Atom("posts", "po", ("pid", "uid", "score")),
        Atom("comments", "co", ("pid", "cuid", "cscore")),
    )
    q = AggQuery(atoms=atoms, aggregates=(Agg("count"),),
                 group_by=("uid",))
    ex = Executor(db, schema)
    res = ex.execute(plan_query(q, schema, mode="opt_plus"))
    got = {}
    cols, valid = res["groups"], res["valid"]
    for u, c, v in zip(np.asarray(cols["uid"]),
                       np.asarray(cols["count(*)"]), np.asarray(valid)):
        if v:
            got[int(u)] = int(c)
    # numpy oracle
    po, co = db["posts"], db["comments"]
    want: dict[int, int] = {}
    pid2uid = dict(zip(np.asarray(po.columns["p_id"]).tolist(),
                       np.asarray(po.columns["p_owner"]).tolist()))
    for pid in np.asarray(co.columns["c_post"]).tolist():
        if pid in pid2uid:
            want[pid2uid[pid]] = want.get(pid2uid[pid], 0) + 1
    assert got == want


def test_sum_avg_agree_across_modes():
    db, schema = make_stats_db(n_users=25, n_posts=80, n_comments=200,
                               n_votes=100, seed=9)
    atoms = (
        Atom("posts", "po", ("pid", "uid", "score")),
        Atom("comments", "co", ("pid", "cuid", "cscore")),
        Atom("votes", "v", ("pid", "vuid")),
    )
    q = AggQuery(atoms=atoms,
                 aggregates=(Agg("sum", "score"), Agg("avg", "score")))
    ex = Executor(db, schema)
    r_ref = ex.execute(plan_query(q, schema, mode="ref"))
    r_opt = ex.execute(plan_query(q, schema, mode="opt_plus"))
    assert int(r_ref["sum(score)"]) == int(r_opt["sum(score)"])
    np.testing.assert_allclose(float(r_ref["avg(score)"]),
                               float(r_opt["avg(score)"]), rtol=1e-5)


# ---------------------------------------------------------------------------
# materialisation accounting (Fig. 6 invariant)
# ---------------------------------------------------------------------------
def test_opt_plus_never_materialises_beyond_base_relations():
    db, schema = make_graph_db(n_nodes=15, n_edges=60, seed=11)
    q = path_query(4)
    ex = Executor(db, schema)
    plan = plan_query(q, schema, mode="opt_plus")
    stats = ex.execute(plan)["__stats__"]
    base_max = max(int(t.live_count()) for t in db.values())
    assert stats.peak_tuples <= base_max
    # ref must materialise (strictly) more on this blown-up query
    ref_stats = ex.execute(plan_query(q, schema, mode="ref"))["__stats__"]
    assert ref_stats.peak_tuples > base_max


def test_oom_guard_fires_like_paper_X_entries():
    from repro.core import MaterialisationLimit
    db, schema = make_graph_db(n_nodes=20, n_edges=300, seed=13)
    q = path_query(5)
    ex = Executor(db, schema, oom_guard=10_000)
    with pytest.raises(MaterialisationLimit):
        ex.execute(plan_query(q, schema, mode="ref"))
    # opt_plus sails through the same guard
    ex.execute(plan_query(q, schema, mode="opt_plus"))


# ---------------------------------------------------------------------------
# jit path
# ---------------------------------------------------------------------------
def test_compiled_plan_matches_eager():
    db, schema = make_graph_db(n_nodes=12, n_edges=50, seed=17)
    q = path_query(3)
    ex = Executor(db, schema)
    plan = plan_query(q, schema, mode="opt_plus")
    eager = int(ex.execute(plan)["count(*)"])
    fn = ex.compile(plan)
    assert int(fn(db)["count(*)"]) == eager
    # and again (cache hit, no retrace errors)
    assert int(fn(db)["count(*)"]) == eager


# ---------------------------------------------------------------------------
# beyond-paper: dense-domain (sort-free) FreqJoin must be a pure perf knob
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("qmaker", [lambda: path_query(3),
                                    lambda: tree_query(2)])
def test_dense_domain_freqjoin_equivalence(qmaker):
    db, schema = make_graph_db(n_nodes=14, n_edges=45, seed=21)
    q = qmaker()
    base = Executor(db, schema).execute(
        plan_query(q, schema, mode="opt_plus"))["count(*)"]
    fast = Executor(db, schema, dense_domain=True).execute(
        plan_query(q, schema, mode="opt_plus"))["count(*)"]
    assert int(base) == int(fast)


def test_dense_domain_semijoin_equivalence():
    db, schema = make_tpch_db(scale=40, seed=6)
    q = tpch_v1_query("minmax")
    r1 = Executor(db, schema).execute(plan_query(q, schema, mode="oma"))
    r2 = Executor(db, schema, dense_domain=True).execute(
        plan_query(q, schema, mode="oma"))
    np.testing.assert_allclose(float(r1["min(bal)"]), float(r2["min(bal)"]))
    np.testing.assert_allclose(float(r1["max(bal)"]), float(r2["max(bal)"]))
