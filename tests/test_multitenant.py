"""Multi-tenant admission: token-bucket quotas, per-tenant queue bounds,
priority lanes + deficit-round-robin batch formation, per-tenant metrics,
the span-lifecycle bugfix sweep (roots ended on every scheduler exit
path, typed close-time rejection, note-after-close), and the
close-vs-submit race stress across tenants."""

import pathlib
import subprocess
import sys
import threading
import time
from collections import Counter

import jax
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from repro.data import make_tpch_db
from repro.service import (
    AdmissionError,
    QueryService,
    ServiceClosedError,
    TenantAdmissionError,
    TenantPolicy,
)
from repro.service.observability import Observability
from repro.service.scheduler import (
    _drr_claim,
    _Pending,
    _TenantState,
    _TokenBucket,
)

jax.config.update("jax_platform_name", "cpu")

_SUPP_DIMS = """FROM supplier s, nation n, region r
WHERE s.s_nationkey = n.n_nationkey AND n.n_regionkey = r.r_regionkey
  AND r.r_name IN (2, 3)"""
MINMAX = f"SELECT MIN(s.s_acctbal), MAX(s.s_acctbal) {_SUPP_DIMS}"
TOTAL = f"SELECT SUM(s.s_acctbal) {_SUPP_DIMS}"


@pytest.fixture(scope="module")
def tpch():
    return make_tpch_db(scale=20, seed=11)


class _Tick:
    """Manually-advanced clock for quota-refill tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# token bucket (unit)
# ---------------------------------------------------------------------------
def test_token_bucket_burst_refill_and_cap():
    tick = _Tick()
    b = _TokenBucket(rate=2.0, burst=4.0, clock=tick)
    # a fresh bucket admits its full burst, then rejects
    assert [b.try_take() for _ in range(5)] == [True] * 4 + [False]
    # 1 s at 2/s refills exactly two tokens
    tick.t += 1.0
    assert b.try_take() and b.try_take() and not b.try_take()
    # refill caps at burst no matter how long the tenant idles
    tick.t += 1e6
    assert [b.try_take() for _ in range(5)] == [True] * 4 + [False]


def test_tenant_policy_validation():
    with pytest.raises(ValueError, match="rate"):
        TenantPolicy(rate=0.0)
    with pytest.raises(ValueError, match="weight"):
        TenantPolicy(weight=0.0)
    with pytest.raises(ValueError, match="max_queue"):
        TenantPolicy(max_queue=0)


# ---------------------------------------------------------------------------
# deficit round-robin (unit)
# ---------------------------------------------------------------------------
def _state(name, n, **pol):
    st = _TenantState(name, TenantPolicy(**pol))
    st.queue.extend(
        _Pending(f"{name}:{i}", None, None, None, name) for i in range(n))
    return st


def test_drr_weights_split_the_batch_proportionally():
    a, b = _state("a", 30, weight=2.0), _state("b", 30, weight=1.0)
    batch = _drr_claim([a, b], 9)
    assert Counter(p.tenant for p in batch) == {"a": 6, "b": 3}
    # and the claim interleaves (round-robin), not a-then-b
    assert [p.tenant for p in batch[:3]] == ["a", "a", "b"]


def test_drr_priority_lane_claims_first():
    hi = _state("hi", 4, priority=0)
    lo = _state("lo", 50, priority=1)
    batch = _drr_claim([lo, hi], 8)  # listed order must not matter
    assert [p.tenant for p in batch] == ["hi"] * 4 + ["lo"] * 4


def test_drr_deficit_carries_when_cut_off_and_resets_when_drained():
    c = _state("c", 2, weight=5.0)
    assert len(_drr_claim([c], 1)) == 1
    # cut off by the full batch: unused credit carries to the next window
    assert c.deficit == pytest.approx(4.0)
    assert len(_drr_claim([c], 10)) == 1
    # queue drained: leftover credit is forfeited (no hoarding)
    assert c.deficit == 0.0


def test_drr_fractional_weight_serves_every_other_round():
    d = _state("d", 5, weight=0.5)
    full = _state("e", 100, weight=1.0)
    batch = _drr_claim([d, full], 6)
    # per round: e serves 1, d accrues 0.5 — so d lands every 2nd round
    assert Counter(p.tenant for p in batch) == {"e": 4, "d": 2}


# ---------------------------------------------------------------------------
# tenant admission through the service (integration)
# ---------------------------------------------------------------------------
def test_rate_and_depth_rejections_are_typed_and_counted(tpch):
    db, schema = tpch
    svc = QueryService(
        db, schema, async_max_wait_ms=60_000,
        tenants={"q": TenantPolicy(rate=1e-9, burst=2, max_queue=1)})
    try:
        # depth first: burst allows 2 but the queue holds only 1
        f1 = svc.submit_async(MINMAX, tenant="q")
        with pytest.raises(TenantAdmissionError, match="queue full") as ei:
            svc.submit_async(MINMAX, tenant="q")
        assert (ei.value.tenant, ei.value.kind) == ("q", "depth")
        # draining on close still serves the admitted request
        svc.close(timeout=120)
        assert f1.result(1).error is None
    finally:
        svc.close(timeout=10)
    # rate next: a one-token bucket that never refills
    svc2 = QueryService(
        db, schema, async_max_wait_ms=1,
        tenants={"q": TenantPolicy(rate=1e-9, burst=1)})
    try:
        f2 = svc2.submit_async(MINMAX, tenant="q")
        with pytest.raises(TenantAdmissionError, match="rate") as ei:
            svc2.submit_async(MINMAX, tenant="q")
        assert (ei.value.tenant, ei.value.kind) == ("q", "rate")
        assert isinstance(ei.value, AdmissionError)
        assert f2.result(120).error is None
        t = svc2.metrics_v2()["tenants"]["q"]
        assert t["rejected_rate"] == 1 and t["rejected"] == 1
        assert t["requests"] == 1
    finally:
        svc2.close(timeout=10)


def test_default_tenant_unlimited_and_rolled_up(tpch):
    db, schema = tpch
    svc = QueryService(db, schema)
    try:
        assert svc.submit_async(MINMAX).result(120).error is None
        v2 = svc.metrics_v2()
        t = v2["tenants"]["default"]
        assert t["requests"] == 1 and t["rejected"] == 0
        assert t["count"] == 1 and t["p50_s"] <= t["p99_s"]
        assert v2["gauges"]["open_requests"] == 0
    finally:
        svc.close(timeout=10)


# ---------------------------------------------------------------------------
# satellite regressions: span lifecycle on every scheduler exit path
# ---------------------------------------------------------------------------
def test_close_drain_timeout_ends_roots_and_raises_typed(tpch):
    """Regression (span leak + untyped close): a request still queued
    when close()'s join times out must resolve with ServiceClosedError
    AND have its root span ended — latency histograms and trace
    retention must see the failed request, not leak it open."""
    db, schema = tpch
    svc = QueryService(db, schema, async_max_wait_ms=1)
    release, entered = threading.Event(), threading.Event()
    inner = svc.submit_many

    def blocked(queries, **kw):
        entered.set()
        release.wait(60)
        return inner(queries, **kw)

    svc.submit_many = blocked
    f1 = svc.submit_async(MINMAX)               # claimed, stuck in serve
    assert entered.wait(30)
    f2 = svc.submit_async(TOTAL, tenant="late")  # still queued
    svc.close(timeout=0.2)                       # join times out
    with pytest.raises(ServiceClosedError, match="closed"):
        f2.result(10)
    # f2's root was ended (error-annotated) — only f1's is still open
    assert svc.obs.open_requests() == 1
    t = svc.metrics_v2()["tenants"]["late"]
    assert t["rejected_closed"] == 1 and t["count"] == 1
    release.set()
    assert f1.result(120).error is None
    svc._scheduler._thread.join(30)
    assert svc.obs.open_requests() == 0


def test_whole_batch_engine_failure_ends_roots(tpch):
    """Regression (span leak): when submit_many itself raises, every
    member's future gets the error AND every root span is ended."""
    db, schema = tpch
    svc = QueryService(db, schema, async_max_wait_ms=1)
    try:
        boom = RuntimeError("engine exploded")

        def exploding(queries, **kw):
            raise boom

        svc.submit_many = exploding
        futs = [svc.submit_async(q) for q in (MINMAX, TOTAL)]
        for f in futs:
            with pytest.raises(RuntimeError, match="engine exploded"):
                f.result(60)
        deadline = time.monotonic() + 10
        while svc.obs.open_requests() and time.monotonic() < deadline:
            time.sleep(0.01)
        assert svc.obs.open_requests() == 0
        # the failed requests landed in the latency histogram
        assert svc.metrics_v2()["histograms"]["request"]["count"] == 2
    finally:
        svc.close(timeout=10)


def test_note_on_closed_span_is_loud_under_tests():
    """Regression (note-after-close): annotating a closed span raises
    under tests instead of silently racing the trace export."""
    obs = Observability()
    root = obs.begin_request()
    sp = obs.open_span(root, "stage")
    sp.note(early=True)                      # open: fine
    obs.close_span(sp)
    with pytest.raises(RuntimeError, match="closed span"):
        sp.note(late=True)
    obs.end_request(root)
    with pytest.raises(RuntimeError, match="closed span"):
        root.note(late=True)


def test_batch_form_claimed_lands_in_chrome_export(tpch, tmp_path):
    """The batch_form span's ``claimed``/``tenants`` annotations must be
    applied before close (a closed span rejects notes under tests, so on
    the buggy ordering this roundtrip dies in the batcher)."""
    import json

    db, schema = tpch
    svc = QueryService(db, schema, async_max_wait_ms=1)
    try:
        assert svc.submit_async(MINMAX).result(120).error is None
        out = tmp_path / "trace.json"
        svc.export_trace(out)
        ev = [e for e in json.loads(out.read_text())["traceEvents"]
              if e["name"] == "batch_form"]
        assert ev and ev[0]["args"]["claimed"] >= 1
        assert ev[0]["args"]["tenants"] >= 1
    finally:
        svc.close(timeout=10)


# ---------------------------------------------------------------------------
# close() racing submit_async across tenants (stress)
# ---------------------------------------------------------------------------
def test_close_races_submissions_across_tenants(tpch):
    """Every future resolves (answer or typed error), no root span stays
    open, and per-tenant accounting balances: everything a tenant got
    admitted is either served under its name or close-drained — nothing
    is lost and nothing is served beyond what admission granted."""
    db, schema = tpch
    svc = QueryService(
        db, schema, async_max_wait_ms=1,
        tenants={"a": TenantPolicy(weight=2.0),
                 "b": TenantPolicy(priority=0),
                 "c": TenantPolicy()})
    svc.submit(MINMAX)  # warm the plan so serves are quick
    futs: dict[str, list] = {"a": [], "b": [], "c": []}
    # submit-after-close rejections, counted client-side so the
    # rejected_closed metric can be split into "future drained" vs
    # "never admitted" below
    turned_away = Counter()
    lock = threading.Lock()
    stop = threading.Event()

    def pound(tenant):
        while not stop.is_set():
            try:
                f = svc.submit_async(MINMAX, tenant=tenant)
            except ServiceClosedError:
                with lock:
                    turned_away[tenant] += 1
                return
            except AdmissionError:
                continue
            with lock:
                futs[tenant].append(f)
            time.sleep(0.001)

    threads = [threading.Thread(target=pound, args=(t,))
               for t in futs for _ in range(2)]
    for th in threads:
        th.start()
    time.sleep(0.25)
    svc.close(timeout=30)
    stop.set()
    for th in threads:
        th.join(30)
    outcomes = Counter()
    for tenant, fs in futs.items():
        for f in fs:
            try:
                res = f.result(60)        # resolves — nothing hangs
                assert res.error is None
                outcomes[tenant, "ok"] += 1
            except ServiceClosedError:
                outcomes[tenant, "drained"] += 1
    assert svc.obs.open_requests() == 0   # no span leaked anywhere
    tm = svc.metrics_v2()["tenants"]
    for tenant, fs in futs.items():
        served = tm.get(tenant, {}).get("requests", 0)
        closed = tm.get(tenant, {}).get("rejected_closed", 0)
        drained = closed - turned_away[tenant]
        # fair-share accounting: every admitted request was either served
        # under its tenant's name or close-drained — nothing lost, and
        # nothing served beyond what admission granted
        assert len(fs) == served + drained
        assert outcomes[tenant, "ok"] == served
        assert outcomes[tenant, "drained"] == drained


# ---------------------------------------------------------------------------
# lint: _resolve is the single future-resolution path
# ---------------------------------------------------------------------------
def test_lint_forbids_raw_future_resolution_in_serving_tier(tmp_path):
    repo = pathlib.Path(__file__).resolve().parent.parent
    svc_dir = tmp_path / "src" / "repro" / "service"
    svc_dir.mkdir(parents=True)
    (svc_dir / "rogue.py").write_text(
        "def hand_back(fut, val):\n    fut.set_result(val)\n")
    (svc_dir / "scheduler.py").write_text(
        "def _resolve(fut, result=None):\n    fut.set_result(result)\n")
    proc = subprocess.run(
        [sys.executable, str(repo / "scripts" / "lint.py"),
         str(tmp_path / "src")],
        capture_output=True, text=True)
    assert proc.returncode != 0
    assert "rogue.py" in proc.stdout and "set_result" in proc.stdout
    assert "scheduler.py" not in proc.stdout
