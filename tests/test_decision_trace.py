"""Cost-calibrated planning: decision traces, typed planning errors, and
the fusion-admission gate's explain surface.

Every rewrite pass is a *gated transform* — structural gate, stats
calibration, apply-or-skip — and records a machine-readable
:class:`~repro.core.Decision` either way.  These tests pin that contract:
the trace names every pass, applied decisions carry the gate values and
the statistics tokens they consulted, skips say why, the trace survives
the plan-store round-trip byte-for-byte, and serving-tier rejections
(fusion admission) name the cost disparity that caused them.
"""

import jax
import numpy as np
import pytest

from repro.core import (
    Agg,
    AggQuery,
    Atom,
    Decision,
    Executor,
    PlanningError,
    StatsCatalog,
    plan_query,
)
from repro.core.plan import SemiJoinOp, plan_from_payload, plan_to_payload
from repro.core.sql import parse_sql
from repro.data import make_graph_db, make_tpch_db
from repro.service import QueryService
from repro.tables.table import Table

jax.config.update("jax_platform_name", "cpu")

NATION_REGION = ("SELECT COUNT(*) FROM nation n, region r "
                 "WHERE n.n_regionkey = r.r_regionkey")
_SUPP_DIMS = """FROM supplier s, nation n, region r
WHERE s.s_nationkey = n.n_nationkey AND n.n_regionkey = r.r_regionkey
  AND r.r_name IN (2, 3)"""
_FIVE_WAY = """FROM region r, nation n, supplier s, partsupp ps, part p
WHERE r.r_regionkey = n.n_regionkey AND n.n_nationkey = s.s_nationkey
  AND s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
  AND r.r_name IN (2, 3) AND p.p_price > 1200.0"""


@pytest.fixture(scope="module")
def tpch():
    return make_tpch_db(scale=100, seed=1)


def _catalog(db, schema) -> StatsCatalog:
    cat = StatsCatalog(schema)
    for name, table in db.items():
        cat.refresh(name, table, db)
    return cat


# ---------------------------------------------------------------------------
# the rewrite pipeline's trace
# ---------------------------------------------------------------------------
def test_every_pass_reports_a_decision(tpch):
    db, schema = tpch
    q = parse_sql(NATION_REGION, schema)
    plan = plan_query(q, schema, stats=_catalog(db, schema))
    assert all(isinstance(d, Decision) for d in plan.decisions)
    names = {d.pass_name for d in plan.decisions}
    assert {"classify", "reroot_guard", "lower", "fkpk_degrade",
            "fk_join_eliminate", "prefilter_pushdown"} <= names
    # every decision renders: applied/skipped plus a reason
    for d in plan.decisions:
        text = d.describe()
        assert ("applied" in text) or ("skipped" in text)
        assert d.reason


def test_fk_elimination_applied_with_gate_values_and_depends(tpch):
    db, schema = tpch
    cat = _catalog(db, schema)
    q = parse_sql(NATION_REGION, schema)
    gated = plan_query(q, schema, stats=cat)
    d = next(d for d in gated.decisions
             if d.pass_name == "fk_join_eliminate" and d.applied)
    gate = dict(d.stats)
    assert gate["orphans"] == 0 and gate["max_orphans"] == 0
    deps = dict(d.depends)
    assert set(deps) == {"nation", "region"}
    assert deps["nation"] == db["nation"].content_token()
    assert deps["region"] == db["region"].content_token()

    # the decision changed the emitted graph: the semi-join is gone …
    plain = plan_query(q, schema)
    assert len(gated.ops) < len(plain.ops)
    assert not any(isinstance(op, SemiJoinOp) for op in gated.ops)
    # … while stats=None records the skip and leaves the plan as before
    skip = next(d for d in plain.decisions
                if d.pass_name == "fk_join_eliminate")
    assert not skip.applied and "no stats" in skip.reason
    # answers are identical either way
    ex = Executor(db, schema)
    assert float(ex.execute(gated)["count(*)"]) \
        == float(ex.execute(plain)["count(*)"])


def test_fk_elimination_skipped_on_measured_orphans(tpch):
    db, schema = tpch
    region = db["region"]
    keep = np.asarray(region.columns["r_regionkey"]) != 0
    db2 = {**db, "region": Table.from_numpy(
        {k: np.asarray(v)[keep] for k, v in region.columns.items()})}
    q = parse_sql(NATION_REGION, schema)
    plan = plan_query(q, schema, stats=_catalog(db2, schema))
    d = next(d for d in plan.decisions
             if d.pass_name == "fk_join_eliminate")
    assert not d.applied
    assert dict(d.stats)["orphans"] > 0
    assert any(isinstance(op, SemiJoinOp) for op in plan.ops)
    # the declared FK alone never justifies elimination — integrity is
    # measured per data version, and here it does not hold
    ex = Executor(db2, schema)
    want = int(np.asarray(keep, np.int64).size)  # sanity: query still runs
    assert float(ex.execute(plan)["count(*)"]) <= want * 25


def test_prefilter_pushdown_gated_on_selectivity(tpch):
    db, schema = tpch
    cat = _catalog(db, schema)
    price = cat.get("part").columns["p_price"]

    def sql(threshold):
        return (f"SELECT COUNT(*) FROM partsupp ps, part p "
                f"WHERE ps.ps_partkey = p.p_partkey "
                f"AND p.p_price > {threshold}")

    selective = price.lo + 0.9 * (price.hi - price.lo)   # est. sel ≈ 0.1
    q = parse_sql(sql(selective), schema)
    plan = plan_query(q, schema, mode="ref", stats=cat)
    d = next(d for d in plan.decisions
             if d.pass_name == "prefilter_pushdown" and d.applied)
    gate = dict(d.stats)
    assert gate["selectivity"] <= gate["max_selectivity"]
    assert gate["parent_rows"] >= gate["min_parent_rows"]
    assert any(isinstance(op, SemiJoinOp) for op in plan.ops)
    # answer-preserving vs. the unfiltered ref baseline
    ex = Executor(db, schema)
    base = plan_query(q, schema, mode="ref")
    assert not any(isinstance(op, SemiJoinOp) for op in base.ops)
    np.testing.assert_array_equal(
        np.asarray(ex.execute(plan)["count(*)"]),
        np.asarray(ex.execute(base)["count(*)"]))

    # an unselective filter fails the calibration and is skipped
    broad = price.lo + 0.2 * (price.hi - price.lo)
    q2 = parse_sql(sql(broad), schema)
    plan2 = plan_query(q2, schema, mode="ref", stats=cat)
    d2 = next(d for d in plan2.decisions
              if d.pass_name == "prefilter_pushdown")
    assert not d2.applied
    assert dict(d2.stats)["selectivity"] > dict(d2.stats)["max_selectivity"]


def test_decision_trace_survives_plan_payload_roundtrip(tpch):
    db, schema = tpch
    q = parse_sql(NATION_REGION, schema)
    plan = plan_query(q, schema, stats=_catalog(db, schema))
    assert plan.decisions
    rt = plan_from_payload(plan_to_payload(plan))
    assert rt.decisions == plan.decisions
    assert [d.to_payload() for d in rt.decisions] \
        == [d.to_payload() for d in plan.decisions]
    # decisions ride OUTSIDE the identity: same graph, same cache key
    assert rt.cache_key() == plan.cache_key()


# ---------------------------------------------------------------------------
# typed planning errors
# ---------------------------------------------------------------------------
_CYCLIC = AggQuery(
    atoms=(Atom("edge", "e1", ("a", "b")),
           Atom("edge", "e2", ("b", "c")),
           Atom("edge", "e3", ("c", "a"))),
    aggregates=(Agg("count"),))
_PATH = AggQuery(
    atoms=(Atom("edge", "e1", ("a", "b")),
           Atom("edge", "e2", ("b", "c"))),
    aggregates=(Agg("count"),))


def test_cyclic_query_raises_typed_planning_error():
    _, schema = make_graph_db(20, 30, seed=1)
    assert issubclass(PlanningError, ValueError)   # old handlers still work
    with pytest.raises(PlanningError, match="cyclic"):
        plan_query(_CYCLIC, schema)


def test_cyclic_batchmate_is_isolated_per_request():
    db, schema = make_graph_db(20, 30, seed=1)
    svc = QueryService(db, schema)
    good, bad = svc.submit_many([_PATH, _CYCLIC])
    assert good.ok and good.error is None
    assert not bad.ok
    assert isinstance(bad.error, PlanningError)
    assert "cyclic" in str(bad.error)
    # the single-request path re-raises the same typed error
    with pytest.raises(PlanningError, match="cyclic"):
        svc.submit(_CYCLIC)


# ---------------------------------------------------------------------------
# serving tier: explain() renders the trace; rejections name disparity
# ---------------------------------------------------------------------------
def test_explain_renders_decisions_and_fusion_rejection(tpch):
    db, schema = tpch
    svc = QueryService(db, schema)
    small = f"SELECT COUNT(*) {_SUPP_DIMS}"
    big_a = f"SELECT MIN(s.s_acctbal), MAX(s.s_acctbal) {_FIVE_WAY}"
    big_b = f"SELECT SUM(s.s_acctbal) {_FIVE_WAY}"
    results = svc.submit_many([small, big_a, big_b])
    assert all(r.ok for r in results)
    assert not results[0].stats.fused            # banded out by cost
    assert results[1].stats.fused and results[2].stats.fused
    assert svc.metrics()["fusion_cost_rejects"] >= 1

    rep = svc.explain(small)
    # machine-readable: every pass decision with its payload shape
    assert rep["decisions"]
    passes = {d["pass"] for d in rep["decisions"]}
    assert "classify" in passes and "fk_join_eliminate" in passes
    for d in rep["decisions"]:
        assert set(d) == {"pass", "target", "applied", "reason", "stats",
                          "depends"}
    # the fusion rejection names the cost disparity
    fa = rep["fusion_admission"]
    assert fa is not None and not fa["admitted"]
    assert "disparity" in fa["reason"]
    assert fa["disparity"] == pytest.approx(svc.fusion_disparity)
    assert fa["cost"] < fa["group_max_cost"]
    # and the rendered report carries both sections
    assert "planning decisions:" in rep["text"]
    assert "fusion admission: rejected" in rep["text"]


def test_feedback_demotes_regressed_fusion(tpch):
    db, schema = tpch
    svc = QueryService(db, schema)
    batch = [f"SELECT MIN(s.s_acctbal), MAX(s.s_acctbal) {_SUPP_DIMS}",
             f"SELECT SUM(s.s_acctbal) {_SUPP_DIMS}"]
    first = svc.submit_many(batch)
    assert all(r.stats.fused for r in first)
    fp = first[0].stats.fingerprint
    sig = svc.explain(batch[0])["fusion_admission"]["signature"]
    assert sig
    # force the observed-regression condition through the public feedback
    # surface: fused serve times far above the solo baseline
    svc.stats.observe_serve(fp, "", 1e-4)
    svc.stats.observe_serve(fp, sig, 1.0)
    svc.stats.observe_serve(fp, sig, 1.0)
    assert svc.stats.is_demoted(fp, sig)

    again = svc.submit_many(batch)
    assert svc.metrics()["fusion_demotions"] >= 1
    assert not any(r.stats.fused for r in again)   # group of 2 fell apart
    rep = svc.explain(batch[0])
    fa = rep["fusion_admission"]
    assert not fa["admitted"] and "demoted" in fa["reason"]
    # answers unchanged by the demotion
    for a, b in zip(first, again):
        assert set(a.values) == set(b.values)
        for k in a.values:
            np.testing.assert_array_equal(np.asarray(a.values[k]),
                                          np.asarray(b.values[k]))
