"""§Perf engine iteration 2: Ring-FreqJoin presort (8 fake devices).

Baseline rotates raw (keys, freq) and sorts the visiting shard at every
ring step (P sorts per join per shard); presort sorts once per shard and
rotates (sorted keys, prefix sums).  Exactness asserted, wall time printed.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import plan_query  # noqa: E402
from repro.core.distributed import DistributedExecutor  # noqa: E402
from repro.data import make_graph_db, path_query  # noqa: E402
from repro.launch.mesh import make_auto_mesh  # noqa: E402


def bench(presort: bool, db, schema, plan, sharded):
    dex = DistributedExecutor(schema, make_auto_mesh((8,), ("data",)),
        data_axes=("data",),
        freq_dtype="float64", presort=presort)
    fn = dex.compile(plan)
    out = fn(sharded)
    jax.block_until_ready(list(out.values()))
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        out = fn(sharded)
        jax.block_until_ready(list(out.values()))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), float(next(iter(out.values())))


def bench_dense(db, schema, plan, sharded):
    dex = DistributedExecutor(schema, make_auto_mesh((8,), ("data",)),
        data_axes=("data",),
        freq_dtype="float64", dense_domain=True)
    fn = dex.compile(plan)
    out = fn(sharded)
    jax.block_until_ready(list(out.values()))
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        out = fn(sharded)
        jax.block_until_ready(list(out.values()))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), float(next(iter(out.values())))


def main():
    with jax.experimental.enable_x64():
        db, schema = make_graph_db(40_000, 400_000, seed=0)
        plan = plan_query(path_query(4), schema, mode="opt_plus")
        mesh = make_auto_mesh((8,), ("data",))
        dex = DistributedExecutor(schema, mesh, data_axes=("data",),
                                  freq_dtype="float64")
        sharded = dex.shard_db(db)
        t0, r0 = bench(False, db, schema, plan, sharded)
        t1, r1 = bench(True, db, schema, plan, sharded)
        t2, r2 = bench_dense(db, schema, plan, sharded)
        assert r0 == r1 == r2, (r0, r1, r2)
        print(f"ring path-04 (8 shards): baseline {t0:.3f}s  "
              f"presort {t1:.3f}s ({t0 / t1:.2f}x)  "
              f"dense-psum {t2:.3f}s ({t0 / t2:.2f}x)  count={r0:.4e}")






if __name__ == "__main__":
    main()
