"""Subprocess helper: runs the distributed engine on 8 fake devices and
compares against the local executor.  Exits non-zero on mismatch.

Run as:  python tests/helpers/distributed_engine_check.py
(the test wrapper sets XLA_FLAGS before interpreter start).
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import Executor, plan_query  # noqa: E402
from repro.core.distributed import DistributedExecutor  # noqa: E402
from repro.data import make_graph_db, path_query, tree_query  # noqa: E402
from repro.data.relational import (  # noqa: E402
    make_stats_db,
    stats_count_query,
    make_tpch_db,
    tpch_v1_query,
)


def check(db, schema, q, mode, mesh, data_axes, name):
    ex = Executor(db, schema)
    want = ex.execute(plan_query(q, schema, mode=mode))
    dex = DistributedExecutor(schema, mesh, data_axes=data_axes)
    sharded = dex.shard_db(db)
    got = dex.compile(plan_query(q, schema, mode=mode))(sharded)
    for k, v in want.items():
        if k == "__stats__":
            continue
        g = float(got[k])
        w = float(v)
        assert np.isclose(g, w, rtol=1e-5), (name, k, g, w)
    print(f"ok {name}: " + ", ".join(
        f"{k}={float(v)}" for k, v in got.items()))


def main():
    assert jax.device_count() == 8, jax.device_count()

    # single-axis ring (one pod)
    mesh1 = jax.make_mesh((8,), ("data",))
    db, schema = make_graph_db(n_nodes=30, n_edges=500, seed=1)
    check(db, schema, path_query(3), "opt_plus", mesh1, ("data",),
          "path-03/1-axis")
    check(db, schema, tree_query(2), "opt_plus", mesh1, ("data",),
          "tree-02/1-axis")

    # nested pod×data ring (multi-pod)
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    check(db, schema, path_query(4), "opt_plus", mesh2, ("pod", "data"),
          "path-04/2-axis")

    sdb, sschema = make_stats_db(n_users=64, n_posts=256, n_comments=1000,
                                 n_votes=600, seed=3)
    check(sdb, sschema, stats_count_query(), "opt_plus", mesh2,
          ("pod", "data"), "stats-count/2-axis")

    # 0MA semi-join ring sweep
    tdb, tschema = make_tpch_db(scale=64, seed=5)
    check(tdb, tschema, tpch_v1_query("minmax"), "oma", mesh2,
          ("pod", "data"), "tpch-v1-minmax/2-axis")
    check(tdb, tschema, tpch_v1_query("median"), "opt_plus", mesh1,
          ("data",), "tpch-v1-median/1-axis")
    print("ALL DISTRIBUTED CHECKS PASSED")


if __name__ == "__main__":
    main()
