"""Subprocess helper: runs the distributed engine on 8 fake devices and
compares against the local executor BITWISE.  Exits non-zero on mismatch.

The mesh program and the local program see identically-padded tables
(``shard_db`` pads to per-shard power-of-two buckets; the host reference
pads to the same global capacities), so every aggregate — including float
SUM/AVG/MEDIAN and GROUP BY — must agree to the bit: the ring sweep
produces the exact integer frequencies of the local sweep, and final
aggregation runs replicated on the same arrays.  An eager run on the
UNPADDED tables sanity-checks values with np.isclose on top.

Run as:  python tests/helpers/distributed_engine_check.py
(the test wrapper sets XLA_FLAGS before interpreter start).
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import Executor, plan_query  # noqa: E402
from repro.core.distributed import DistributedExecutor  # noqa: E402
from repro.data import make_graph_db, path_query, tree_query  # noqa: E402
from repro.data.relational import (  # noqa: E402
    make_stats_db,
    make_tpch_db,
    stats_count_query,
    tpch_v1_query,
)


def assert_bitwise(want: dict, got: dict, ctx: str):
    keys = {k for k in want if k != "__stats__"}
    assert keys == {k for k in got if k != "__stats__"}, ctx
    for k in keys:
        va, vb = want[k], got[k]
        if k == "groups":
            assert set(va) == set(vb), ctx
            for c in va:
                xa, xb = np.asarray(va[c]), np.asarray(vb[c])
                assert xa.dtype == xb.dtype and xa.shape == xb.shape, (ctx, c)
                assert xa.tobytes() == xb.tobytes(), (ctx, c)
        else:
            xa, xb = np.asarray(va), np.asarray(vb)
            assert xa.dtype == xb.dtype and xa.shape == xb.shape, (ctx, k)
            assert xa.tobytes() == xb.tobytes(), (ctx, k, xa, xb)


def check(db, schema, q, mode, mesh, data_axes, name, **dex_opts):
    dex = DistributedExecutor(schema, mesh, data_axes=data_axes, **dex_opts)
    sharded = dex.shard_db(db)
    # the single-device reference over the SAME padded capacities
    host = {k: db[k].pad_to(sharded[k].capacity) for k in db}
    ex = Executor(db, schema,
                  dense_domain=dex_opts.get("dense_domain", False))
    plan = plan_query(q, schema, mode=mode)

    want = dict(ex.compile(plan)(host))
    got = dict(dex.compile(plan)(sharded))
    assert_bitwise(want, got, name)

    # eager sanity on the unpadded tables (float tolerance: different
    # reduction lengths)
    eager = ex.execute(plan)
    for k, v in eager.items():
        if k in ("__stats__", "groups", "valid"):
            continue
        assert np.isclose(float(got[k]), float(v), rtol=1e-5), (name, k)
    print(f"ok {name}: " + ", ".join(
        f"{k}={float(v)}" for k, v in got.items()
        if k not in ("groups", "valid")))
    return dex, sharded, plan, got


def check_fused(dex, sharded, plans, solo, name):
    """compile_multi (shared ring sweeps) must match per-plan compiles."""
    fused = dex.compile_multi(plans)(sharded)
    for i, (want, got) in enumerate(zip(solo, fused)):
        assert_bitwise(dict(want), dict(got), f"{name}[{i}]")
    print(f"ok {name}: {len(plans)} plans, one mesh program")


def main():
    assert jax.device_count() == 8, jax.device_count()

    # single-axis ring (one pod)
    mesh1 = jax.make_mesh((8,), ("data",))
    db, schema = make_graph_db(n_nodes=30, n_edges=500, seed=1)
    check(db, schema, path_query(3), "opt_plus", mesh1, ("data",),
          "path-03/1-axis")
    check(db, schema, tree_query(2), "opt_plus", mesh1, ("data",),
          "tree-02/1-axis")

    # nested pod×data ring (multi-pod)
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    check(db, schema, path_query(4), "opt_plus", mesh2, ("pod", "data"),
          "path-04/2-axis")

    sdb, sschema = make_stats_db(n_users=64, n_posts=256, n_comments=1000,
                                 n_votes=600, seed=3)
    check(sdb, sschema, stats_count_query(), "opt_plus", mesh2,
          ("pod", "data"), "stats-count/2-axis")

    # 0MA semi-join ring sweep + per-shard bucketing variants
    tdb, tschema = make_tpch_db(scale=64, seed=5)
    dex, sharded, p_minmax, r_minmax = check(
        tdb, tschema, tpch_v1_query("minmax"), "oma", mesh1, ("data",),
        "tpch-v1-minmax/1-axis")
    _, _, p_median, r_median = check(
        tdb, tschema, tpch_v1_query("median"), "opt_plus", mesh1,
        ("data",), "tpch-v1-median/1-axis")
    check(tdb, tschema, tpch_v1_query("minmax"), "oma", mesh2,
          ("pod", "data"), "tpch-v1-minmax/2-axis")
    check(tdb, tschema, tpch_v1_query("median"), "opt_plus", mesh1,
          ("data",), "tpch-v1-median/presort", presort=True)
    check(tdb, tschema, tpch_v1_query("minmax"), "oma", mesh1, ("data",),
          "tpch-v1-minmax/dense", dense_domain=True)

    # fused multi-query mesh program vs per-plan compiles (shared memo)
    check_fused(dex, sharded, [p_minmax, p_median], [r_minmax, r_median],
                "tpch-fused/1-axis")

    # per-shard power-of-two bucketing: shard_db pads every relation so
    # each shard holds a power-of-two block
    for rel, t in sharded.items():
        per_shard = t.capacity // 8
        assert per_shard >= 8 and (per_shard & (per_shard - 1)) == 0, \
            (rel, t.capacity)
    print("ok shard_db per-shard power-of-two buckets")
    print("ALL DISTRIBUTED CHECKS PASSED")


if __name__ == "__main__":
    main()
