"""Subprocess helper: QueryService(mesh=...) on 8 fake devices vs a
single-device QueryService, across every planner mode — BITWISE.

The local reference is constructed with ``min_bucket = n_shards *
mesh_min_bucket``: for a power-of-two mesh, ``sharded_bucket_capacity``
collapses to ``bucket_capacity(n, n_shards * min)``, so both services pad
every relation to identical global capacities and their answers must
agree to the bit (same arrays into the same replicated final-aggregate
program).  Error parity is part of the contract: a query a mode cannot
plan must fail on BOTH services.

Also checks: fused-vs-individual submission on the mesh, async
submission, within-bucket growth (zero recompiles, zero invalidations),
mesh gauges, and explain() shard placement.

Run as:  python tests/helpers/mesh_service_check.py
(the test wrapper sets XLA_FLAGS before interpreter start.)
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.data.relational import make_tpch_db  # noqa: E402
from repro.service import QueryService  # noqa: E402
from repro.tables.table import Table  # noqa: E402

MIN_BUCKET = 8
N_DEV = 8

FIG1 = """
SELECT MIN(s.s_acctbal), MAX(s.s_acctbal)
FROM region r, nation n, supplier s, partsupp ps, part p
WHERE r.r_regionkey = n.n_regionkey AND n.n_nationkey = s.s_nationkey
  AND s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
  AND r.r_name IN (2, 3) AND p.p_price > 1200.0
"""
MEDIAN = """
SELECT MEDIAN(s.s_acctbal)
FROM region r, nation n, supplier s, partsupp ps, part p
WHERE r.r_regionkey = n.n_regionkey AND n.n_nationkey = s.s_nationkey
  AND s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
  AND r.r_name IN (0, 1) AND p.p_price > 800.0
"""
GROUPBY = """
SELECT COUNT(*) AS suppliers, AVG(s.s_acctbal) AS avg_bal
FROM supplier s, nation n
WHERE s.s_nationkey = n.n_nationkey
GROUP BY s.s_nationkey
"""
COSTLY = """
SELECT SUM(ps.ps_supplycost), COUNT(*)
FROM partsupp ps, part p
WHERE ps.ps_partkey = p.p_partkey AND p.p_price > 1500.0
"""
QUERIES = [("fig1", FIG1), ("median", MEDIAN), ("groupby", GROUPBY),
           ("costly", COSTLY)]


def assert_bitwise(a: dict, b: dict, ctx: str):
    assert set(a) == set(b), (ctx, set(a) ^ set(b))
    for k in a:
        va, vb = a[k], b[k]
        if isinstance(va, dict):            # grouped "groups" columns
            assert set(va) == set(vb), (ctx, k)
            for c in va:
                xa, xb = np.asarray(va[c]), np.asarray(vb[c])
                assert xa.dtype == xb.dtype and xa.shape == xb.shape, \
                    (ctx, k, c)
                assert xa.tobytes() == xb.tobytes(), (ctx, k, c)
        else:
            xa, xb = np.asarray(va), np.asarray(vb)
            assert xa.dtype == xb.dtype and xa.shape == xb.shape, (ctx, k)
            assert xa.tobytes() == xb.tobytes(), (ctx, k)


def grow_within_bucket(db: dict, rel: str, extra: int) -> Table:
    """`rel` with `extra` rows appended (copies of its first rows)."""
    t = db[rel]
    data = {c: np.concatenate([np.asarray(a), np.asarray(a[:extra])])
            for c, a in t.columns.items()}
    return Table.from_numpy(data)


def check_mode(db, schema, mesh, mode):
    mesh_svc = QueryService(db, schema, mode=mode, mesh=mesh,
                            min_bucket=MIN_BUCKET)
    local_svc = QueryService(db, schema, mode=mode,
                             min_bucket=MIN_BUCKET * N_DEV)
    mesh_res = mesh_svc.submit_many([q for _, q in QUERIES])
    local_res = local_svc.submit_many([q for _, q in QUERIES])
    served = 0
    for (name, _), mr, lr in zip(QUERIES, mesh_res, local_res):
        ctx = f"{mode}/{name}"
        # error parity: a mode that cannot plan a query fails identically
        assert (mr.error is None) == (lr.error is None), \
            (ctx, mr.error, lr.error)
        if mr.error is not None:
            assert type(mr.error) is type(lr.error), ctx
            continue
        assert_bitwise(mr.values, lr.values, ctx)
        served += 1
    # individual submission must match the fused batch bitwise
    for (name, q), mr in zip(QUERIES, mesh_res):
        if mr.error is not None:
            continue
        assert_bitwise(mesh_svc.submit(q).values, mr.values,
                       f"{mode}/{name}/solo-vs-batch")
    print(f"ok mode={mode}: {served}/{len(QUERIES)} served bitwise, "
          f"{len(QUERIES) - served} error-parity")
    return mesh_svc, mesh_res


def main():
    assert jax.device_count() == N_DEV, jax.device_count()
    mesh = jax.make_mesh((N_DEV,), ("data",))
    db, schema = make_tpch_db(scale=50, seed=11)

    for mode in ("ref", "opt", "opt_plus", "oma"):
        check_mode(db, schema, mesh, mode)

    # deeper checks on the auto-mode mesh service
    svc, _ = check_mode(db, schema, mesh, "auto")
    local = QueryService(db, schema, min_bucket=MIN_BUCKET * N_DEV)

    # async submission flows through the same mesh pipeline
    fut = svc.submit_async(FIG1)
    assert_bitwise(fut.result(timeout=120).values, local.submit(FIG1).values,
                   "async")
    svc.close()
    print("ok async-on-mesh")

    # mesh gauges + explain placement
    m2 = svc.metrics_v2()
    assert m2["gauges"]["mesh_devices"] == N_DEV, m2["gauges"]
    assert m2["gauges"]["mesh_shard_count_data"] == N_DEV
    exp = svc.explain(FIG1)
    assert exp["topology"] == (("data",), (N_DEV,)), exp["topology"]
    assert exp["sharding"]["devices"] == N_DEV
    assert all("rows over data" in p
               for p in exp["sharding"]["placement"].values())
    assert "rows over data (8 shards)" in exp["text"]
    print("ok gauges + explain placement")

    # within-bucket per-shard growth: same mesh program, bit-for-bit —
    # zero recompiles, zero invalidations, answers track the new data
    before = svc.metrics()
    grown = grow_within_bucket(db, "partsupp", extra=N_DEV * 3)
    svc.update_table("partsupp", grown)
    local.update_table("partsupp", grown)
    after_update = svc.metrics()
    assert after_update["bucket_invalidations"] \
        == before["bucket_invalidations"], "growth crossed a bucket"
    res = svc.submit(COSTLY)
    assert_bitwise(res.values, local.submit(COSTLY).values, "after-growth")
    after = svc.metrics()
    assert after["compiles"] == before["compiles"], \
        (before["compiles"], after["compiles"])
    assert res.stats.exec_cache_hit, "grown table missed the exec cache"
    print("ok within-bucket growth: zero recompiles, answers bitwise")

    print("ALL MESH SERVICE CHECKS PASSED")


if __name__ == "__main__":
    main()
