"""Subprocess helper (8 fake devices): LM-side distribution checks.

1. A sharded (2,2,2)=pod×data×model train step matches the single-device
   trajectory bit-for-bit-ish (f32, same batches).
2. Elastic re-mesh: checkpoint saved from the (2,2,2) run restores onto a
   (4,2) mesh AND onto 1 device, and training continues identically.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint import Checkpointer  # noqa: E402
from repro.configs import get_smoke_config  # noqa: E402
from repro.data import TokenPipeline  # noqa: E402
from repro.distributed.sharding import use_mesh  # noqa: E402
from repro.launch.inputs import abstract_params, to_named_shardings  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.training import build_train_step, init_train_state  # noqa: E402
from repro.training.optimizer import AdamWState  # noqa: E402
from repro.training.step import TrainState  # noqa: E402


def make_mesh(shape, names):
    from repro.launch.mesh import make_auto_mesh
    return make_auto_mesh(shape, names)


def state_shardings(cfg, mesh):
    pshapes, pspecs = abstract_params(cfg)
    state_shapes = jax.eval_shape(init_train_state, pshapes)
    specs = TrainState(params=pspecs,
                       opt=AdamWState(step=(), m=pspecs, v=pspecs),
                       step=())
    return to_named_shardings(mesh, specs, state_shapes)


def run_steps(cfg, mesh, state, pipe, n, start=0):
    step_fn = build_train_step(cfg, microbatches=2, base_lr=5e-3,
                               warmup=2, total_steps=50, remat="none")

    if mesh is None:
        jitted = jax.jit(step_fn)
    else:
        sh = state_shardings(cfg, mesh)

        def fn(s, b):
            with use_mesh(mesh):
                return step_fn(s, b)

        jitted = jax.jit(fn, in_shardings=(sh, None),
                         out_shardings=(sh, None))
    m = None
    for i in range(start, start + n):
        state, m = jitted(state, pipe.jax_batch(i))
    return state, m


def main():
    assert jax.device_count() == 8
    cfg = dataclasses.replace(get_smoke_config("qwen3-14b"),
                              dtype="float32")
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=16,
                         global_batch=8, seed=42)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    state0 = init_train_state(params)

    # single-device reference
    s_ref, m_ref = run_steps(cfg, None, state0, pipe, 4)

    # (pod, data, model) sharded run
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    s_dist, m_dist = run_steps(cfg, mesh, state0, pipe, 4)
    np.testing.assert_allclose(float(m_ref["loss"]), float(m_dist["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(s_ref.params),
                    jax.tree.leaves(s_dist.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)
    print("ok sharded-vs-single trajectory")

    # elastic re-mesh: save from (2,2,2), restore on (4,2) and on 1 device
    ckpt = Checkpointer("/tmp/repro_elastic_ckpt")
    ckpt.save(4, s_dist, async_=False)

    mesh2 = make_mesh((4, 2), ("data", "model"))
    sh2 = state_shardings(cfg, mesh2)
    restored2 = ckpt.restore(like=jax.eval_shape(lambda: s_dist),
                             shardings=sh2)
    s2, m2 = run_steps(cfg, mesh2, restored2, pipe, 2, start=4)

    restored1 = ckpt.restore(like=jax.eval_shape(lambda: s_dist))
    s1, m1 = run_steps(cfg, None, restored1, pipe, 2, start=4)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    print("ok elastic re-mesh (2,2,2) → (4,2) → continue matches 1-device")
    print("ALL LM DISTRIBUTED CHECKS PASSED")


if __name__ == "__main__":
    main()
