"""Per-kernel validation: Pallas (interpret) and XLA twins vs. jnp oracles.

Sweeps shapes (incl. non-block-multiples) and dtypes; hypothesis property
tests check the engine-level invariants the kernels must uphold.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests skip without hypothesis; kernel tests always run
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref
from repro.kernels.autotune import DENSE_DOMAIN_CAP, KernelConfig

jax.config.update("jax_platform_name", "cpu")


def _rand_tables(rng, np_, nc, key_range, kdt, fdt):
    pk = jnp.asarray(rng.integers(0, key_range, np_), kdt)
    ck = jnp.asarray(rng.integers(0, key_range, nc), kdt)
    pf = jnp.asarray(rng.integers(0, 4, np_), fdt)
    cf = jnp.asarray(rng.integers(0, 4, nc), fdt)
    return pk, pf, ck, cf


SHAPES = [(1024, 1024), (1000, 37), (2048, 4096), (8, 8), (4096, 1000)]
DTYPES = [(jnp.int32, jnp.int32), (jnp.int32, jnp.float32)]


@pytest.mark.parametrize("np_,nc", SHAPES)
@pytest.mark.parametrize("kdt,fdt", DTYPES)
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_freq_join_matches_oracle(np_, nc, kdt, fdt, backend):
    rng = np.random.default_rng(np_ * 7919 + nc)
    pk, pf, ck, cf = _rand_tables(rng, np_, nc, key_range=50, kdt=kdt, fdt=fdt)
    got = ops.freq_join(pk, pf, ck, cf, mode="sum", backend=backend)
    want = ref.freq_join_ref(pk, pf, ck, cf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("np_,nc", SHAPES)
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_semi_join_matches_oracle(np_, nc, backend):
    rng = np.random.default_rng(nc * 31 + np_)
    pk, pf, ck, cf = _rand_tables(rng, np_, nc, key_range=30,
                                  kdt=jnp.int32, fdt=jnp.int32)
    got = ops.semi_join(pk, pf, ck, cf, backend=backend)
    want = ref.semi_join_ref(pk, pf, ck, cf)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n", [1024, 1000, 4096, 17, 2048])
@pytest.mark.parametrize("vdt", [jnp.int32, jnp.float32])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_segment_sum_matches_oracle(n, vdt, backend):
    rng = np.random.default_rng(n)
    keys = jnp.sort(jnp.asarray(rng.integers(0, max(2, n // 8), n), jnp.int32))
    vals = jnp.asarray(rng.integers(-3, 5, n), vdt)
    got, gvalid = ops.segment_sum_sorted(keys, vals, backend=backend)
    want, _wfirst = ref.segment_sum_ref(keys, vals)
    # Emission rows differ (ref: first-of-run; kernel: last-of-run), so
    # compare per-key totals, which is the semantic contract.
    def per_key(sums, mask):
        out = {}
        for k, s, m in zip(np.asarray(keys), np.asarray(sums), np.asarray(mask)):
            if m:
                out[int(k)] = out.get(int(k), 0) + s
        return out

    want_first = np.concatenate([[True], np.asarray(keys)[1:] != np.asarray(keys)[:-1]])
    assert per_key(got, gvalid) == per_key(want, want_first)
    # totals preserved
    np.testing.assert_allclose(np.asarray(jnp.sum(got)),
                               np.asarray(jnp.sum(vals)), rtol=1e-5)


@pytest.mark.parametrize("n", [64, 1000])
def test_weighted_percentile_matches_oracle(n):
    rng = np.random.default_rng(n)
    vals = jnp.asarray(rng.normal(size=n), jnp.float32)
    w = jnp.asarray(rng.integers(0, 5, n), jnp.int32)
    for q in (0.1, 0.5, 0.9):
        got = ops.weighted_percentile(vals, w, q)
        want = ref.weighted_percentile_ref(vals, w, q)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


def test_weighted_percentile_expansion_equivalence():
    """Median over frequencies == median over the expanded bag (paper §4.2)."""
    vals = jnp.asarray([5.0, 1.0, 3.0, 9.0], jnp.float32)
    w = jnp.asarray([1, 3, 2, 0], jnp.int32)
    expanded = np.repeat(np.asarray(vals), np.asarray(w))
    got = float(ops.weighted_percentile(vals, w, 0.5))
    # lower-interpolation median of [1,1,1,3,3,5]
    want = float(np.sort(expanded)[max(0, int(np.ceil(0.5 * len(expanded))) - 1)])
    assert got == want


# ---------------------------------------------------------------------------
# Config-space parametrisation (kernels/autotune.py): every point the
# tuner may pick must match the oracles bitwise, including on shapes that
# don't divide the configured blocks (padding correctness per config).
# ---------------------------------------------------------------------------
JOIN_CONFIGS = [
    KernelConfig(),
    KernelConfig(parent_block_rows=16, child_block_rows=8),
    KernelConfig(parent_block_rows=8, child_block_rows=16),
    KernelConfig(parent_block_rows=32, child_block_rows=32),
    KernelConfig(dense_ratio=0),          # sort/searchsorted always
    KernelConfig(dense_ratio=256),        # dense scatter-add eagerly
]
_JIDS = ["default", "pb16cb8", "pb8cb16", "pb32cb32", "sort", "dense"]


@pytest.mark.parametrize("config", JOIN_CONFIGS, ids=_JIDS)
@pytest.mark.parametrize("np_,nc", [(1000, 37), (2048, 1024), (8, 8)])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_freq_join_config_space_matches_oracle(config, np_, nc, backend):
    rng = np.random.default_rng(np_ * 13 + nc)
    pk, pf, ck, cf = _rand_tables(rng, np_, nc, key_range=50,
                                  kdt=jnp.int32, fdt=jnp.int32)
    got = ops.freq_join(pk, pf, ck, cf, mode="sum", backend=backend,
                        domain=50, config=config)
    want = ref.freq_join_ref(pk, pf, ck, cf)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got_semi = ops.semi_join(pk, pf, ck, cf, backend=backend,
                             domain=50, config=config)
    want_semi = ref.semi_join_ref(pk, pf, ck, cf)
    np.testing.assert_array_equal(np.asarray(got_semi),
                                  np.asarray(want_semi))


@pytest.mark.parametrize("lanes", [512, 1024, 2048])
@pytest.mark.parametrize("n", [1000, 17, 4096])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_segment_sum_config_space_matches_default(lanes, n, backend):
    """Any lane width produces bitwise the default's output — the tuner's
    gate invariant, checked directly (incl. non-divisible lengths)."""
    rng = np.random.default_rng(n * 3 + lanes)
    keys = jnp.sort(jnp.asarray(rng.integers(0, max(2, n // 8), n),
                                jnp.int32))
    vals = jnp.asarray(rng.integers(-3, 5, n), jnp.int32)
    base = ops.segment_sum_sorted(keys, vals, backend=backend)
    got = ops.segment_sum_sorted(keys, vals, backend=backend,
                                 config=KernelConfig(lanes_wide=lanes))
    for b, g in zip(base, got):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(g))


# ---------------------------------------------------------------------------
# Dense-domain dispatch boundary
# ---------------------------------------------------------------------------
def test_dense_ok_boundary_and_cap():
    cfg = KernelConfig(dense_ratio=4, dense_floor=1 << 10)
    assert cfg.dense_ok(1 << 10, 8)            # at the floor: dense
    assert not cfg.dense_ok((1 << 10) + 1, 8)  # just past: sort
    low_floor = KernelConfig(dense_ratio=4, dense_floor=1)
    assert low_floor.dense_ok(4 * 100, 100)    # at ratio*nc: dense
    assert not low_floor.dense_ok(4 * 100 + 1, 100)
    assert not cfg.dense_ok(None, 100)         # unknown domain: sort
    assert not KernelConfig(dense_ratio=0).dense_ok(16, 100)  # disabled
    # the structural int32 accumulator cap binds whatever the ratio says
    eager = KernelConfig(dense_ratio=1 << 30, dense_floor=1 << 30)
    assert not eager.dense_ok(DENSE_DOMAIN_CAP, 100)
    assert eager.dense_ok(DENSE_DOMAIN_CAP - 1, 100) is True


def test_dense_domain_cap_falls_back_to_sort():
    """domain == 2^31 with a dense-eager config must quietly use the sort
    path (no 2 GiB accumulator) and still match the oracle."""
    rng = np.random.default_rng(7)
    pk, pf, ck, cf = _rand_tables(rng, 64, 64, key_range=40,
                                  kdt=jnp.int32, fdt=jnp.int32)
    cfg = KernelConfig(dense_ratio=1 << 30, dense_floor=1 << 30)
    got = ops.freq_join(pk, pf, ck, cf, backend="xla",
                        domain=DENSE_DOMAIN_CAP, config=cfg)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.freq_join_ref(pk, pf,
                                                               ck, cf)))


@pytest.mark.parametrize("mode", ["sum", "any"])
def test_dense_path_masks_negative_and_oob_child_keys(mode):
    """Regression: ``.at[].add(mode="drop")`` wraps NEGATIVE indices
    (NumPy semantics) even though it drops too-large ones — a dead child
    tuple marked with key -1 must contribute nothing, not corrupt
    ``acc[domain-1]``.  Dense and sort dispatch must agree bitwise."""
    dom = 64
    pk = jnp.asarray([0, 5, dom - 1, 63, 12], jnp.int32)
    pf = jnp.asarray([1, 2, 3, 4, 5], jnp.int32)
    # child keys: valid, -1 (dead), dom (OOB-high), valid dup of dom-1
    ck = jnp.asarray([5, -1, dom, dom - 1, -1, 12], jnp.int32)
    cf = jnp.asarray([7, 9, 11, 2, 100, 1], jnp.int32)
    dense = ops.freq_join(pk, pf, ck, cf, mode=mode, backend="xla",
                          domain=dom,
                          config=KernelConfig(dense_ratio=1 << 20))
    sort = ops.freq_join(pk, pf, ck, cf, mode=mode, backend="xla",
                         domain=dom, config=KernelConfig(dense_ratio=0))
    want = ref.freq_join_ref(pk, pf, ck, cf) if mode == "sum" \
        else ref.semi_join_ref(pk, pf, ck, cf)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(sort))


# ---------------------------------------------------------------------------
# Property tests (hypothesis) — system invariants
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    small_ints = st.lists(st.integers(0, 12), min_size=1, max_size=40)

    @settings(max_examples=30, deadline=None)
    @given(pk=small_ints, ck1=small_ints, ck2=small_ints)
    def test_freq_join_distributes_over_child_union(pk, ck1, ck2):
        """mult(R, S1 ⊎ S2) == mult(R,S1) + mult(R,S2): the additive-semiring
        law that makes the distributed ring execution exact."""
        pk = jnp.asarray(pk, jnp.int32)
        pf = jnp.ones_like(pk)
        c1 = jnp.asarray(ck1, jnp.int32)
        c2 = jnp.asarray(ck2, jnp.int32)
        f1 = jnp.ones_like(c1)
        f2 = jnp.ones_like(c2)
        whole = ops.freq_join(pk, pf, jnp.concatenate([c1, c2]),
                              jnp.concatenate([f1, f2]), backend="xla")
        parts = (ops.freq_join(pk, pf, c1, f1, backend="xla")
                 + ops.freq_join(pk, pf, c2, f2, backend="xla"))
        np.testing.assert_array_equal(np.asarray(whole), np.asarray(parts))

    @settings(max_examples=30, deadline=None)
    @given(pk=small_ints, ck=small_ints)
    def test_semi_join_idempotent(pk, ck):
        pk = jnp.asarray(pk, jnp.int32)
        pf = jnp.ones_like(pk)
        ck = jnp.asarray(ck, jnp.int32)
        cf = jnp.ones_like(ck)
        once = ops.semi_join(pk, pf, ck, cf, backend="xla")
        twice = ops.semi_join(pk, once, ck, cf, backend="xla")
        np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))

    @settings(max_examples=30, deadline=None)
    @given(keys=small_ints)
    def test_segment_sum_mass_conservation(keys):
        ks = jnp.sort(jnp.asarray(keys, jnp.int32))
        vals = jnp.ones_like(ks)
        sums, valid = ops.segment_sum_sorted(ks, vals, backend="xla")
        assert int(jnp.sum(sums)) == len(keys)
        # one emission per distinct key
        assert int(jnp.sum(valid)) == len(set(keys))

    @settings(max_examples=20, deadline=None)
    @given(pk=small_ints, ck=small_ints)
    def test_pallas_equals_xla(pk, ck):
        pk = jnp.asarray(pk, jnp.int32)
        pf = jnp.ones_like(pk)
        ck = jnp.asarray(ck, jnp.int32)
        cf = jnp.ones_like(ck)
        a = ops.freq_join(pk, pf, ck, cf, backend="xla")
        b = ops.freq_join(pk, pf, ck, cf, backend="pallas")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
else:
    def test_property_invariants_need_hypothesis():
        """Visible skip so a missing dependency is not silent."""
        pytest.importorskip("hypothesis")
