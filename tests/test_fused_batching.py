"""Cross-fingerprint fused batching: prefix fingerprints, plan
segmentation, multi-query compilation, and the serving-tier fusion path."""

import jax
import numpy as np
import pytest

from repro.core import (
    Executor,
    parse_sql,
    plan_query,
    segment_plan,
    shared_subplan_savings,
)
from repro.core.plan import FinalAggOp, MaterializeJoinOp, op_result_keys
from repro.core.query import Agg, AggQuery, Atom
from repro.data import make_stats_db, make_tpch_db
from repro.service import QueryService, canonicalize, prefix_fingerprint
from repro.tables.table import Table

jax.config.update("jax_platform_name", "cpu")

# Four aggregates over the same dimension joins: distinct fingerprints,
# one shared scan/semi-join prefix.
_SUPP_DIMS = """FROM supplier s, nation n, region r
WHERE s.s_nationkey = n.n_nationkey AND n.n_regionkey = r.r_regionkey
  AND r.r_name IN (2, 3)"""
DASH_MINMAX = f"SELECT MIN(s.s_acctbal), MAX(s.s_acctbal) {_SUPP_DIMS}"
DASH_SUM = f"SELECT SUM(s.s_acctbal) {_SUPP_DIMS}"
DASH_GROUP = (f"SELECT COUNT(*) AS cnt, AVG(s.s_acctbal) AS avg_bal "
              f"{_SUPP_DIMS} GROUP BY s.s_nationkey")
# same structure as DASH_SUM under alias renaming + clause reordering
DASH_SUM_RENAMED = """
SELECT SUM(su.s_acctbal) FROM region re, supplier su, nation na
WHERE re.r_name IN (3, 2) AND na.n_regionkey = re.r_regionkey
  AND su.s_nationkey = na.n_nationkey
"""
# different selection literal → different prefix
DASH_SUM_OTHER_SEL = DASH_SUM.replace("(2, 3)", "(1, 4)")
FIG1 = """
SELECT MIN(s.s_acctbal), MAX(s.s_acctbal)
FROM region r, nation n, supplier s, partsupp ps, part p
WHERE r.r_regionkey = n.n_regionkey AND n.n_nationkey = s.s_nationkey
  AND s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
  AND r.r_name IN (2, 3) AND p.p_price > 1200.0
"""

DASHBOARD = [DASH_MINMAX, DASH_SUM, DASH_GROUP]


@pytest.fixture(scope="module")
def tpch():
    return make_tpch_db(scale=40, seed=3)


def _assert_values_equal(a: dict, b: dict):
    assert set(a) == set(b)
    for k, va in a.items():
        vb = b[k]
        if k == "groups":
            assert set(va) == set(vb)
            for c in va:
                np.testing.assert_array_equal(np.asarray(va[c]),
                                              np.asarray(vb[c]))
        else:
            np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))


# ---------------------------------------------------------------------------
# prefix fingerprints (query level)
# ---------------------------------------------------------------------------
def test_prefix_fingerprint_shared_across_aggregates(tpch):
    _, schema = tpch
    canons = [canonicalize(parse_sql(sql, schema)) for sql in DASHBOARD]
    fps = {c.fingerprint for c in canons}
    assert len(fps) == 3                      # distinct full fingerprints
    prefixes = {c.prefix_fingerprint for c in canons}
    assert len(prefixes) == 1                 # one shared join structure


def test_prefix_fingerprint_invariant_under_renaming(tpch):
    _, schema = tpch
    a = canonicalize(parse_sql(DASH_SUM, schema))
    b = canonicalize(parse_sql(DASH_SUM_RENAMED, schema))
    assert a.fingerprint == b.fingerprint
    assert a.prefix_fingerprint == b.prefix_fingerprint


def test_prefix_fingerprint_distinguishes_structure(tpch):
    _, schema = tpch
    base = prefix_fingerprint(parse_sql(DASH_SUM, schema))
    assert base != prefix_fingerprint(parse_sql(DASH_SUM_OTHER_SEL, schema))
    assert base != prefix_fingerprint(parse_sql(FIG1, schema))


def test_prefix_fingerprint_opaque_selections_never_share():
    q1 = AggQuery(
        atoms=(Atom("part", "p", ("pk", "price")),),
        aggregates=(Agg("count"),),
        selections={"p": lambda c: c["p_price"] > 100})
    q2 = AggQuery(
        atoms=(Atom("part", "p", ("pk", "price")),),
        aggregates=(Agg("sum", "price"),),
        selections={"p": lambda c: c["p_price"] > 100})
    c1, c2 = canonicalize(q1), canonicalize(q2)
    assert c1.prefix_fingerprint != c2.prefix_fingerprint
    # ...but stable for repeat submissions of the same object
    assert canonicalize(q1).prefix_fingerprint == c1.prefix_fingerprint


# ---------------------------------------------------------------------------
# plan segmentation
# ---------------------------------------------------------------------------
def test_segment_plan_splits_at_aggregate_boundary(tpch):
    _, schema = tpch
    plan = plan_query(parse_sql(DASH_MINMAX, schema), schema)
    seg = segment_plan(plan)
    assert seg.prefix_key is not None
    assert not any(isinstance(op, FinalAggOp) for op in seg.prefix_ops)
    assert all(isinstance(op, FinalAggOp) for op in seg.suffix_ops)
    assert len(seg.prefix_ops) + len(seg.suffix_ops) == len(plan.ops)


def test_segment_plan_prefix_keys_shared_across_canonical_queries(tpch):
    _, schema = tpch
    keys = set()
    for sql in DASHBOARD:
        canon = canonicalize(parse_sql(sql, schema))
        keys.add(segment_plan(plan_query(canon.query, schema)).prefix_key)
    assert len(keys) == 1
    other = canonicalize(parse_sql(DASH_SUM_OTHER_SEL, schema))
    assert segment_plan(
        plan_query(other.query, schema)).prefix_key not in keys


def test_segment_plan_materialising_plans_not_shareable(tpch):
    _, schema = tpch
    plan = plan_query(parse_sql(DASH_SUM, schema), schema, mode="ref")
    assert any(isinstance(op, MaterializeJoinOp) for op in plan.ops)
    assert segment_plan(plan).prefix_key is None


def test_op_result_keys_alias_and_variable_blind(tpch):
    """Two canonical plans for different aggregates over the same joins
    produce the same prefix-op keys despite role-sensitive variable
    naming."""
    _, schema = tpch
    plans = [plan_query(canonicalize(parse_sql(sql, schema)).query, schema)
             for sql in (DASH_MINMAX, DASH_SUM)]
    keysets = [{k for k in op_result_keys(p) if k is not None}
               for p in plans]
    assert keysets[0] == keysets[1]


# ---------------------------------------------------------------------------
# multi-query compilation
# ---------------------------------------------------------------------------
def test_compile_multi_matches_individual_compiles(tpch):
    db, schema = tpch
    plans = [plan_query(parse_sql(sql, schema), schema) for sql in DASHBOARD]
    ex = Executor(db, schema)
    fused = ex.compile_multi(plans)(db)
    assert len(fused) == len(plans)
    for plan, got in zip(plans, fused):
        want = ex.compile(plan)(db)
        _assert_values_equal(dict(want), dict(got))


def test_compile_multi_rejects_materialising_plans(tpch):
    db, schema = tpch
    good = plan_query(parse_sql(DASH_SUM, schema), schema)
    bad = plan_query(parse_sql(DASH_SUM, schema), schema, mode="ref")
    with pytest.raises(ValueError, match="materialises"):
        Executor(db, schema).compile_multi([good, bad])
    with pytest.raises(ValueError, match="at least one"):
        Executor(db, schema).compile_multi([])


# ---------------------------------------------------------------------------
# the serving tier's fusion path
# ---------------------------------------------------------------------------
def test_service_fuses_prefix_sharing_fingerprints(tpch):
    db, schema = tpch
    # gate off: this test pins the fusion MACHINERY (subplan-overlap
    # grouping pulling a different join shape into the group); admission
    # policy has its own tests
    svc = QueryService(db, schema, fusion_disparity=float("inf"))
    batch = DASHBOARD + [FIG1]
    results = svc.submit_many(batch)
    m = svc.metrics()
    # ONE fused program: the dashboard trio shares its whole prefix, and
    # FIG1 — a different join shape — overlaps it on the filtered region
    # scan and the nation/supplier semi-join chain, so subplan-overlap
    # grouping pulls all four together (PR 2's whole-prefix rule kept FIG1
    # out; that difference is what partial_fusions counts)
    assert m["compiles"] == 1
    assert m["fused_compiles"] == 1
    assert m["fused_batches"] == 1
    assert m["fused_queries"] == 4
    assert m["partial_fusions"] == 1
    assert m["subplan_saved"] > 0
    for r in results:
        assert r.stats.fused and r.stats.fused_group_size == 4

    # answers match individual serving bitwise
    solo_svc = QueryService(db, schema)
    for r, sql in zip(results, batch):
        _assert_values_equal(r.values, solo_svc.submit(sql).values)

    # a repeat dashboard hits the fused executable cache: zero compiles
    again = svc.submit_many(batch)
    m2 = svc.metrics()
    assert m2["compiles"] == 1
    assert m2["fused_hits"] >= 1
    assert again[0].stats.exec_cache_hit
    for r, sql in zip(again, batch):
        _assert_values_equal(r.values, solo_svc.submit(sql).values)


def test_service_fused_order_independent(tpch):
    """Any member order maps to the same fused cache entry."""
    db, schema = tpch
    svc = QueryService(db, schema)
    svc.submit_many(DASHBOARD)
    compiles = svc.metrics()["compiles"]
    svc.submit_many(list(reversed(DASHBOARD)))
    m = svc.metrics()
    assert m["compiles"] == compiles
    assert m["fused_hits"] >= 1


def test_service_fused_mixed_with_duplicates(tpch):
    """Duplicate fingerprints inside a fused batch still dedup first."""
    db, schema = tpch
    svc = QueryService(db, schema)
    batch = [DASH_MINMAX, DASH_SUM, DASH_SUM_RENAMED, DASH_MINMAX]
    results = svc.submit_many(batch)
    m = svc.metrics()
    assert m["dedup_saved"] == 2
    assert m["fused_queries"] == 2          # two distinct fingerprints
    assert m["compiles"] == m["fused_compiles"] == 1
    # same answer, renamed to each request's own aliases
    np.testing.assert_array_equal(
        np.asarray(results[1].values["sum(s.s_acctbal)"]),
        np.asarray(results[2].values["sum(su.s_acctbal)"]))
    shared = [r.stats.shared_execution for r in results]
    assert shared == [False, False, True, True]


def test_service_fused_invalidation_on_bucket_crossing(tpch):
    db, schema = tpch
    svc = QueryService(db, schema)
    svc.submit_many(DASHBOARD)
    compiles = svc.metrics()["compiles"]

    # grow supplier past its shape bucket → fused program must recompile
    sup = db["supplier"]
    cap = sup.capacity
    rng = np.random.default_rng(0)
    idx = rng.integers(0, cap, cap + 1)     # 40 rows → 81: bucket 64 → 128
    grown = {name: np.concatenate([np.asarray(col),
                                   np.asarray(col)[idx]])
             for name, col in sup.columns.items()}
    svc.update_table("supplier", Table.from_numpy(grown))
    m = svc.metrics()
    assert m["bucket_invalidations"] >= 1

    results = svc.submit_many(DASHBOARD)
    m2 = svc.metrics()
    assert m2["compiles"] == compiles + 1   # one fused recompile
    solo = QueryService({**db, "supplier": Table.from_numpy(grown)}, schema)
    for r, sql in zip(results, DASHBOARD):
        _assert_values_equal(r.values, solo.submit(sql).values)


# ---------------------------------------------------------------------------
# partial fusion across different join shapes (op-graph IR)
# ---------------------------------------------------------------------------
# 3-way, 4-way, and 5-way joins: every whole-plan prefix is distinct (PR 2's
# equal-prefix rule fuses NOTHING here), but all three overlap on the
# filtered region scan + nation semi-join sub-DAG.
MIX_3WAY = f"SELECT MIN(s.s_acctbal) {_SUPP_DIMS}"
MIX_4WAY = f"""SELECT MIN(s.s_acctbal), MAX(s.s_acctbal)
FROM supplier s, nation n, region r, partsupp ps
WHERE s.s_nationkey = n.n_nationkey AND n.n_regionkey = r.r_regionkey
  AND s.s_suppkey = ps.ps_suppkey AND r.r_name IN (2, 3)"""
MIX_5WAY = FIG1
MIXED_SHAPES = [MIX_3WAY, MIX_4WAY, MIX_5WAY]


def test_subplan_keys_overlap_across_join_shapes(tpch):
    _, schema = tpch
    plans = [plan_query(canonicalize(parse_sql(sql, schema)).query, schema)
             for sql in MIXED_SHAPES]
    prefixes = {segment_plan(p).prefix_key for p in plans}
    assert len(prefixes) == 3            # whole-prefix fusion finds nothing
    for a in plans:
        for b in plans:
            if a is not b:
                assert a.subplan_keys() & b.subplan_keys()
    savings = shared_subplan_savings(plans)
    assert savings > 0


def test_compile_multi_dedups_partial_overlap(tpch):
    """Fused compilation of different join shapes matches per-plan
    compilation bitwise."""
    db, schema = tpch
    plans = [plan_query(parse_sql(sql, schema), schema)
             for sql in MIXED_SHAPES]
    ex = Executor(db, schema)
    fused = ex.compile_multi(plans)(db)
    for plan, got in zip(plans, fused):
        want = ex.compile(plan)(db)
        _assert_values_equal(dict(want), dict(got))


def test_service_partial_fusion_across_shapes(tpch):
    db, schema = tpch
    # gate off: pins partial fusion across 3/4/5-way join shapes, whose
    # padded costs are deliberately disparate
    svc = QueryService(db, schema, fusion_disparity=float("inf"))
    results = svc.submit_many(MIXED_SHAPES)
    m = svc.metrics()
    assert m["compiles"] == 1            # one program for all three shapes
    assert m["fused_queries"] == 3
    assert m["partial_fusions"] == 1
    assert m["subplan_saved"] > 0
    solo = QueryService(db, schema)
    for r, sql in zip(results, MIXED_SHAPES):
        assert r.stats.fused and r.stats.fused_group_size == 3
        _assert_values_equal(r.values, solo.submit(sql).values)
    assert solo.metrics()["compiles"] == 3   # served alone: one compile each


def test_service_no_fusion_without_shared_subplans(tpch):
    """Queries overlapping only on bare (selection-free) scans stay
    unfused: sharing a table read saves nothing."""
    db, schema = tpch
    svc = QueryService(db, schema)
    svc.submit_many([
        "SELECT MIN(s.s_acctbal) FROM supplier s",
        "SELECT MAX(p.p_price) FROM part p",
    ])
    m = svc.metrics()
    assert m["compiles"] == 2
    assert m["fused_batches"] == 0
    assert m["partial_fusions"] == 0


def test_describe_renders_dag_with_node_keys(tpch):
    _, schema = tpch
    plans = [plan_query(canonicalize(parse_sql(sql, schema)).query, schema)
             for sql in (MIX_3WAY, MIX_5WAY)]
    texts = [p.describe() for p in plans]
    for p, t in zip(plans, texts):
        assert f"plan[{p.mode}]" in t
        assert "%0" in t and "key=" in t
    # fusion decisions are inspectable: the shared semi-join sub-DAG
    # prints the same short key in both plans
    shared = plans[0].subplan_keys() & plans[1].subplan_keys()
    assert shared
    from repro.core.plan import _short_key  # rendering helper
    for node in plans[0].nodes:
        if node.key() in shared:
            assert f"key={_short_key(node)}" in texts[0]
            assert f"key={_short_key(node)}" in texts[1]


def test_graph_key_distinguishes_aggregate_columns(tpch):
    """Regression: canonical variable names are role-coloured labels, so a
    graph key that recorded only names (not root-atom column positions)
    collided SUM(s_suppkey) with SUM(s_nationkey) — and the fused cache,
    keyed on the merged-graph signature, then served one query's compiled
    program as the other's answer."""
    db, schema = tpch
    QA = ("SELECT SUM(s.s_suppkey) FROM supplier s, nation n "
          "WHERE s.s_nationkey = n.n_nationkey")
    QB = QA.replace("SUM(s.s_suppkey)", "SUM(s.s_nationkey)")
    pa = plan_query(canonicalize(parse_sql(QA, schema)).query, schema)
    pb = plan_query(canonicalize(parse_sql(QB, schema)).query, schema)
    assert pa.graph_key() != pb.graph_key()
    ga = QA + " GROUP BY s.s_suppkey"
    gb = QA + " GROUP BY s.s_nationkey"
    assert (plan_query(canonicalize(parse_sql(ga, schema)).query,
                       schema).graph_key()
            != plan_query(canonicalize(parse_sql(gb, schema)).query,
                          schema).graph_key())

    # the end-to-end aliasing: X shares the semi-join with both, so
    # {QA, X} and {QB, X} each fuse; their signatures must differ and the
    # second batch must NOT be answered from the first batch's program
    X = ("SELECT MIN(s.s_acctbal) FROM supplier s, nation n "
         "WHERE s.s_nationkey = n.n_nationkey")
    svc = QueryService(db, schema)
    ra = svc.submit_many([QA, X])[0]
    rb = svc.submit_many([QB, X])[0]
    solo = QueryService(db, schema)
    for r, sql in ((ra, QA), (rb, QB)):
        _assert_values_equal(r.values, solo.submit(sql).values)
    assert (float(ra.values["sum(s.s_suppkey)"])
            != float(rb.values["sum(s.s_nationkey)"]))


def test_admission_error_names_missing_relation(tpch):
    db, schema = tpch
    partial_db = {k: v for k, v in db.items() if k != "part"}
    svc = QueryService(partial_db, schema)
    with pytest.raises(ValueError, match="'part'.*no table loaded"):
        svc.submit(FIG1)
    # in a batch the same failure is captured per request: the offending
    # query carries the error, its batch-mate still gets an answer
    good, bad = svc.submit_many([DASH_SUM, FIG1])
    assert good.error is None and good.values
    assert isinstance(bad.error, ValueError)
    assert "update_table" in str(bad.error) and not bad.values
    # queries over loaded relations still serve
    assert svc.submit(DASH_SUM).values


def test_service_eager_values_carry_no_stats_sentinel():
    """Regression: the executor's ``__stats__`` sentinel must not leak
    into QueryResult.values (stats travel via ServeStats.exec_stats)."""
    db, schema = make_stats_db(n_users=20, n_posts=50, n_comments=120,
                               n_votes=40, seed=1)
    svc = QueryService(db, schema)
    q = AggQuery(
        atoms=(Atom("posts", "po", ("pid", "uid", "score")),
               Atom("comments", "co", ("pid", "cuid", "cscore"))),
        aggregates=(Agg("median", "score"), Agg("median", "cscore")))
    res = svc.submit(q)
    assert res.stats.mode == "ref"
    assert "__stats__" not in res.values
    assert all(k in res.values for k in ("median(score)", "median(cscore)"))
    assert res.stats.exec_stats is not None
    assert res.stats.exec_stats.peak_tuples > 0
