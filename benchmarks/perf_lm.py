import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""§Perf hillclimb — LM cells (structural, from compiled artifacts).

Per variant, lowers the cell on the single-pod mesh and reports the
roofline terms (scan-corrected) and per-device memory.  Variants encode
the hypothesis ladder recorded in EXPERIMENTS.md §Perf:

moonshot-v1-16b-a3b × train_4k (most collective-bound):
  it0  baseline (M=8 microbatches, FSDP over pod+data)
  it1  M=2 (microbatch 128): params re-gathered 4× less often
  it2  M=2 + grads-in-bf16 accumulation? (kept f32 — rejected, see log)

mixtral-8x22b × train_4k (memory fit):
  it0  baseline (f32 Adam m/v): 18.3 GiB/dev > 16 GiB HBM
  it1  bf16 Adam m/v
  it2  bf16 m/v + M=16 (microbatch 16): smaller activations

Run:  PYTHONPATH=src python -m benchmarks.perf_lm [--quick]
"""

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch.dryrun import lower_train_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from benchmarks.roofline import (  # noqa: E402
    collective_seconds,
)


def lower_variant(arch, shape, *, microbatch=None, rules=None,
                  opt_dtype="float32", probes=True):
    """Lower a train-cell variant; return terms + memory."""
    import repro.training.step as step_mod

    cfg = get_config(arch)
    cell = SHAPES[shape]
    if microbatch:
        cell = dataclasses.replace(cell, microbatch=microbatch)
    mesh = make_production_mesh(multi_pod=False)
    n_dev = mesh.devices.size

    # opt dtype knob: patch init_train_state default through a wrapper
    orig_init = step_mod.init_train_state
    if opt_dtype != "float32":
        step_mod.init_train_state = lambda p: orig_init(
            p, jnp.bfloat16)
        import repro.launch.dryrun as dr
        dr.init_train_state = step_mod.init_train_state
    try:
        lowered = lower_train_cell(cfg, cell, mesh, rules=rules)
        compiled = lowered.compile()
    finally:
        step_mod.init_train_state = orig_init
        import repro.launch.dryrun as dr
        dr.init_train_state = orig_init

    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    coll_s_once, moved = collective_seconds(compiled.as_text(), n_dev)
    M = max(1, cell.global_batch // max(cell.microbatch, 1))
    out = {
        "arch": arch, "shape": shape, "microbatches": M,
        "opt_dtype": opt_dtype,
        # once-counted HLO values; per-ubatch collectives scale by M
        "flops_once": cost.get("flops", 0.0),
        "bytes_once": cost.get("bytes accessed", 0.0),
        "coll_s_times_M": coll_s_once * M,
        "moved_once": {k: v for k, v in moved.items()},
        "arg_GiB": (getattr(mem, "argument_size_in_bytes", 0) or 0) / 2**30,
        "temp_GiB": (getattr(mem, "temp_size_in_bytes", 0) or 0) / 2**30,
    }
    out["total_GiB"] = out["arg_GiB"] + out["temp_GiB"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all",
                    choices=["all", "moonshot", "mixtral"])
    ap.add_argument("--out", default="perf_lm_results.json")
    args = ap.parse_args()
    rows = []

    if args.cell in ("all", "moonshot"):
        for label, kw in [
            ("it0-baseline", {}),
            ("it1-M2", {"microbatch": 128}),
        ]:
            r = lower_variant("moonshot-v1-16b-a3b", "train_4k", **kw)
            r["variant"] = f"moonshot/{label}"
            rows.append(r)
            print(f"[perf_lm] {r['variant']:24s} M={r['microbatches']} "
                  f"coll≈{r['coll_s_times_M']:.3f}s×  "
                  f"mem={r['total_GiB']:.1f} GiB", flush=True)

    if args.cell in ("all", "mixtral"):
        for label, kw in [
            ("it0-baseline", {}),
            ("it1-bf16-opt", {"opt_dtype": "bfloat16"}),
            ("it2-bf16-M16", {"opt_dtype": "bfloat16", "microbatch": 16}),
        ]:
            r = lower_variant("mixtral-8x22b", "train_4k", **kw)
            r["variant"] = f"mixtral/{label}"
            rows.append(r)
            print(f"[perf_lm] {r['variant']:24s} M={r['microbatches']} "
                  f"coll≈{r['coll_s_times_M']:.3f}s×  "
                  f"mem={r['total_GiB']:.1f} GiB", flush=True)

    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    print(f"[perf_lm] → {args.out}")


if __name__ == "__main__":
    main()
