"""Benchmark recorder: one sink for the CSV harness contract AND the
schema-versioned ``BENCH_*.json`` perf-trajectory files.

Every benchmark section routes its rows through a ``Recorder``:

* ``row(name, us, derived)`` prints the ``name,us_per_call,derived`` CSV
  line the harness scrapes (NaN ``us`` prints as ``nan`` — a row that
  carries no timing, e.g. a skipped section, still satisfies the
  contract), and
* with ``--record``, the same rows — plus latency-histogram snapshots
  from ``QueryService.metrics_v2()`` and a flat metrics dict — are
  written as a schema-versioned JSON document, so successive runs leave
  a machine-readable speed trajectory that future re-anchors can diff
  (the ROADMAP's autotuning item needs exactly this history).

Document schema (``bench_schema_version`` 1)::

    {
      "bench_schema_version": 1,
      "benchmark": "serving",            # which harness wrote it
      "created_unix": 1754700000.0,
      "meta": {...},                     # freeform: scale, iters, ...
      "rows": [                          # the CSV rows, verbatim
        {"section": "...", "name": "...",
         "us_per_call": 12.3 | null,     # null == NaN (no timing)
         "derived": "..."}
      ],
      "histograms": {                    # per-stage latency snapshots
        "run": {"count": n, "sum_s": s, "max_s": m,
                "p50_s": ..., "p95_s": ..., "p99_s": ...,
                "buckets": [[upper_bound_s | null, count], ...]}
      },
      "metrics": {...}                   # flat counter snapshot
    }

``validate_bench(doc)`` checks a document against this schema and
returns a list of problems (empty == valid);
``python -m benchmarks.recorder FILE`` runs it from the command line
(wired into ``scripts/verify.sh``'s smoke).
"""

from __future__ import annotations

import json
import math
import sys
import time

BENCH_SCHEMA_VERSION = 1
_PCT_KEYS = ("p50_s", "p95_s", "p99_s")


class Recorder:
    """CSV printer + optional JSON trajectory writer (one per harness
    run).  ``path=None`` prints only — the no-``--record`` behaviour."""

    def __init__(self, benchmark: str, path=None):
        self.benchmark = benchmark
        self.path = path
        self._section = ""
        self.rows: list[dict] = []
        self.histograms: dict[str, dict] = {}
        self.metrics: dict = {}
        self.meta: dict = {}

    def section(self, title: str) -> None:
        print(f"\n### {title}", flush=True)
        self._section = title

    def row(self, name: str, us: float, derived: str = "") -> None:
        """One ``name,us_per_call,derived`` CSV row.  ``us`` may be NaN
        for rows with no timing (prints ``nan``, records ``null``)."""
        us = float(us)
        print(f"{name},{us:.1f},{derived}")
        self.rows.append({
            "section": self._section,
            "name": name,
            "us_per_call": None if math.isnan(us) else us,
            "derived": str(derived),
        })

    def note(self, text: str) -> None:
        """A non-row comment line (prefixed so harness scrapers skip it)."""
        print(f"# {text}")

    def add_histograms(self, histograms: dict) -> None:
        """Merge per-stage histogram snapshots (the ``histograms`` half
        of ``QueryService.metrics_v2()``)."""
        self.histograms.update(histograms)

    def add_metrics(self, metrics: dict) -> None:
        self.metrics.update(metrics)

    def add_meta(self, **kv) -> None:
        self.meta.update(kv)

    def document(self) -> dict:
        return {
            "bench_schema_version": BENCH_SCHEMA_VERSION,
            "benchmark": self.benchmark,
            "created_unix": time.time(),
            "meta": self.meta,
            "rows": self.rows,
            "histograms": self.histograms,
            "metrics": self.metrics,
        }

    def finish(self) -> dict | None:
        """Write the trajectory file (when recording) and return the
        document.  Refuses to write an invalid document — a schema bug
        fails the benchmark run, not the later reader."""
        if self.path is None:
            return None
        doc = self.document()
        problems = validate_bench(doc)
        if problems:
            raise ValueError("recorder produced an invalid document: "
                             + "; ".join(problems))
        with open(self.path, "w") as f:
            json.dump(doc, f, indent=1, default=float)
        print(f"# recorded {len(self.rows)} rows + "
              f"{len(self.histograms)} histograms -> {self.path}")
        return doc


def validate_bench(doc) -> list[str]:
    """Validate a BENCH_*.json document; returns problems (empty = OK)."""
    probs: list[str] = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    if doc.get("bench_schema_version") != BENCH_SCHEMA_VERSION:
        probs.append(f"bench_schema_version "
                     f"{doc.get('bench_schema_version')!r} != "
                     f"{BENCH_SCHEMA_VERSION}")
    if not isinstance(doc.get("benchmark"), str) or not doc.get("benchmark"):
        probs.append("missing/empty 'benchmark' name")
    if not isinstance(doc.get("created_unix"), (int, float)):
        probs.append("'created_unix' is not a number")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        probs.append("'rows' missing or empty")
        rows = []
    for i, r in enumerate(rows):
        if not isinstance(r, dict):
            probs.append(f"rows[{i}] is not an object")
            continue
        if not isinstance(r.get("name"), str) or not r.get("name"):
            probs.append(f"rows[{i}] missing 'name'")
        us = r.get("us_per_call", "absent")
        if us == "absent":
            probs.append(f"rows[{i}] missing 'us_per_call'")
        elif us is not None and (not isinstance(us, (int, float))
                                 or isinstance(us, bool)
                                 or math.isnan(float(us))):
            probs.append(f"rows[{i}].us_per_call must be a number or "
                         f"null, got {us!r}")
        if "derived" not in r or not isinstance(r["derived"], str):
            probs.append(f"rows[{i}] missing string 'derived'")
    hists = doc.get("histograms")
    if not isinstance(hists, dict):
        probs.append("'histograms' is not an object")
        hists = {}
    for stage, h in hists.items():
        if not isinstance(h, dict):
            probs.append(f"histograms[{stage!r}] is not an object")
            continue
        for k in ("count", "sum_s", "max_s") + _PCT_KEYS:
            if not isinstance(h.get(k), (int, float)) \
                    or isinstance(h.get(k), bool):
                probs.append(f"histograms[{stage!r}].{k} missing or "
                             "non-numeric")
        if not (isinstance(h.get("count"), int) and h["count"] >= 0):
            probs.append(f"histograms[{stage!r}].count must be an int "
                         ">= 0")
    if not isinstance(doc.get("metrics"), dict):
        probs.append("'metrics' is not an object")
    if not isinstance(doc.get("meta"), dict):
        probs.append("'meta' is not an object")
    return probs


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if len(argv) != 1:
        print("usage: python -m benchmarks.recorder BENCH_file.json",
              file=sys.stderr)
        return 2
    try:
        with open(argv[0]) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"INVALID {argv[0]}: unreadable ({e})", file=sys.stderr)
        return 1
    problems = validate_bench(doc)
    if problems:
        for p in problems:
            print(f"INVALID {argv[0]}: {p}", file=sys.stderr)
        return 1
    n_pct = sum(1 for h in doc["histograms"].values()
                if all(k in h for k in _PCT_KEYS))
    print(f"OK {argv[0]}: benchmark={doc['benchmark']} "
          f"rows={len(doc['rows'])} histograms={len(doc['histograms'])} "
          f"(with p50/p95/p99: {n_pct})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
