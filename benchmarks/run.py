"""Benchmark harness entry point — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run             # engine benchmarks
    PYTHONPATH=src python -m benchmarks.run --full      # + roofline/dryrun
                                                          (subprocess, slow)
    PYTHONPATH=src python -m benchmarks.run --record    # + BENCH_run.json

EVERY section emits ``name,us_per_call,derived`` CSV rows through one
``benchmarks.recorder.Recorder`` sink — including the roofline/dry-run
summaries when their artifact files are absent (a ``*.skipped`` row with
``us_per_call=nan``), so the harness contract holds in ``--fast`` runs
too.  ``--record`` additionally writes the rows as a schema-versioned
``BENCH_run.json`` trajectory.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also run the dry-run + roofline matrices "
                         "(hours of compile on 1 CPU core)")
    ap.add_argument("--fast", action="store_true",
                    help="smaller datasets (CI-sized)")
    ap.add_argument("--record", nargs="?", const="BENCH_run.json",
                    default=None, metavar="PATH",
                    help="write the rows as a schema-versioned JSON "
                         "trajectory (default PATH: BENCH_run.json)")
    args = ap.parse_args()

    from benchmarks import analytics, graph_counting, materialisation
    from benchmarks.recorder import Recorder

    rec = Recorder("run", path=args.record)
    rec.add_meta(fast=args.fast, full=args.full)

    rec.section("Table 1 — SNAP-like graph counting (Ref / Opt / Opt+)")
    if args.fast:
        rows = graph_counting.run(n_nodes=2_000, n_edges=20_000, repeats=1)
    else:
        rows = graph_counting.main()
    for r in rows:
        rec.row(f"graph.{r['query']}.opt_plus", r["opt_plus_s"] * 1e6,
                f"count={r['count']:.3e}")
        if r.get("ref_s"):
            rec.row(f"graph.{r['query']}.ref", r["ref_s"] * 1e6,
                    f"speedup={r['ref_s'] / r['opt_plus_s']:.2f}x")
        else:
            rec.row(f"graph.{r['query']}.ref", float("nan"), "X(oom-guard)")

    rec.section("Table 2 — analytic benchmarks (TPC-H V.1, STATS-CEB-like)")
    rows = analytics.main() if not args.fast else analytics.run(
        tpch_scale=500, repeats=1)
    for r in rows:
        rec.row(f"analytics.{r['query'].replace(' ', '_')}",
                r["opt_plus_s"] * 1e6,
                f"plan={r['plan']};ref="
                f"{'X' if r.get('ref_s') is None else round(r['ref_s'], 4)}")

    rec.section("Fig. 6 — peak materialised tuples per plan class")
    rows = materialisation.main()
    for r in rows:
        rec.row(f"materialisation.{r['query']}", 0.0,
                f"ref={r['ref']};opt={r['opt']};opt_plus={r['opt_plus']};"
                f"base_max={r['base_max']}")

    # roofline & dry-run: read cached artifacts if present (full runs are
    # launched explicitly — they recompile the 512-device matrix)
    root = pathlib.Path(__file__).resolve().parent.parent
    if args.full:
        rec.section("Dry-run matrix (recomputing)")
        subprocess.run([sys.executable, "-m", "repro.launch.dryrun",
                        "--mesh", "both",
                        "--out", str(root / "dryrun_results.json")],
                       check=True)
        rec.section("Roofline matrix (recomputing)")
        subprocess.run([sys.executable, "-m", "benchmarks.roofline",
                        "--out", str(root / "roofline_results.json")],
                       check=True)

    rec.section("Roofline summary (from roofline_results.json)")
    rf = root / "roofline_results.json"
    if rf.exists():
        rows = json.loads(rf.read_text())["rows"]
        for r in rows:
            rec.row(f"roofline.{r['arch']}.{r['shape']}",
                    r["step_time_bound_s"] * 1e6,
                    f"bottleneck={r['bottleneck']};"
                    f"frac={r['roofline_fraction']:.3f};"
                    f"useful={r['useful_flops_ratio']:.2f}")
    else:
        # contract-shaped even when skipped: a nan-timed row, not prose
        rec.row("roofline.skipped", float("nan"),
                "roofline_results.json not found; run benchmarks.roofline")

    rec.section("Dry-run summary (from dryrun_results.json)")
    df = root / "dryrun_results.json"
    if df.exists():
        res = json.loads(df.read_text())
        ok = len(res["results"])
        bad = len(res["failures"])
        for r in res["results"]:
            mem = r["memory"]
            tot = sum(v for v in (mem["argument_bytes"],
                                  mem["temp_bytes"]) if v)
            rec.row(f"dryrun.{r['arch']}.{r['shape']}.{r['mesh']}",
                    r["compile_s"] * 1e6,
                    f"flops={r['flops']:.3e};mem_GiB={tot / 2**30:.2f}")
        rec.note(f"dry-run: {ok} cells OK, {bad} failed")
    else:
        rec.row("dryrun.skipped", float("nan"),
                "dryrun_results.json not found; run repro.launch.dryrun")

    rec.finish()


if __name__ == "__main__":
    main()
