"""Paper Table 1: path/tree homomorphism counting on SNAP-like graphs.

Ref   — materialising join plan (standard engine behaviour)
Opt   — §4.2 logical rewrite (freq propagation, joins + regrouping)
Opt⁺  — §5 FreqJoin physical operator (jitted, zero materialisation)

Ref/Opt run eagerly with an OOM guard; guard trips reproduce the paper's
X entries.  Opt⁺ times the compiled executable (compile excluded — steady
state, like the paper's warm runs).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import Executor, MaterialisationLimit, plan_query
from repro.data import make_graph_db, path_query, tree_query

OOM_GUARD = 20_000_000  # materialised-tuple budget for the baselines


def _time(fn, repeats=3):
    fn()  # warm-up (matches the paper's protocol)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts)), float(np.std(ts))


def run(n_nodes=20_000, n_edges=200_000, seed=0, repeats=3, queries=None):
    with jax.experimental.enable_x64():
        db, schema = make_graph_db(n_nodes, n_edges, seed=seed)
        ex = Executor(db, schema, freq_dtype="float64",
                      oom_guard=OOM_GUARD)
        if queries is None:
            queries = [(f"path-{k:02d}", path_query(k)) for k in (3, 4, 5)] \
                + [(f"tree-{v:02d}", tree_query(v)) for v in (1, 2, 3)]
        rows = []
        for name, q in queries:
            row = {"query": name}
            # Opt+ (jitted FreqJoin plan)
            plan = plan_query(q, schema, mode="opt_plus")
            fn = ex.jittable().compile(plan)

            def run_optp():
                out = fn(db)
                jax.block_until_ready(list(out.values()))
                return out

            mean, std = _time(run_optp, repeats)
            row["opt_plus_s"] = mean
            row["opt_plus_std"] = std
            row["count"] = float(run_optp()["count(*)"])

            # Opt (freq propagation with materialised pairwise joins)
            try:
                mean, std = _time(
                    lambda: ex.execute(plan_query(q, schema, mode="opt")),
                    repeats=1)
                row["opt_s"] = mean
            except MaterialisationLimit:
                row["opt_s"] = None  # X
            # Ref (materialising baseline)
            try:
                mean, std = _time(
                    lambda: ex.execute(plan_query(q, schema, mode="ref")),
                    repeats=1)
                row["ref_s"] = mean
            except MaterialisationLimit:
                row["ref_s"] = None  # X — the paper's OOM entries
            rows.append(row)
        return rows


def main():
    rows = run()
    print(f"{'query':10s} {'Ref':>10s} {'Opt':>10s} {'Opt+':>10s} "
          f"{'speedup':>8s}  count")
    for r in rows:
        ref = f"{r['ref_s']:.3f}" if r["ref_s"] else "X"
        opt = f"{r['opt_s']:.3f}" if r["opt_s"] else "X"
        sp = (f"{r['ref_s'] / r['opt_plus_s']:.1f}x"
              if r["ref_s"] else "inf")
        print(f"{r['query']:10s} {ref:>10s} {opt:>10s} "
              f"{r['opt_plus_s']:>10.4f} {sp:>8s}  {r['count']:.3e}")
    return rows


if __name__ == "__main__":
    main()
