"""Serving benchmark: cold-compile vs warm-cache latency and throughput.

Drives a mixed TPC-H-style query stream through ``QueryService`` and
measures the properties the serving tier exists for:

  1. warm-cache latency ≥ 10× lower than cold-compile latency on the same
     stream (the plan + executable caches amortise parse/GYO/XLA work);
  2. repeated queries after same-bucket data growth trigger ZERO recompiles
     (shape bucketing + freq-masked padding), verified via cache counters;
  3. micro-batched throughput on a skewed request mix (dashboards repeat
     the same handful of fingerprints).

    PYTHONPATH=src python benchmarks/serving_queries.py [--tiny]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.data import make_tpch_db
from repro.service import QueryService
from repro.tables.table import Table, bucket_capacity

FIG1 = """
SELECT MIN(s.s_acctbal), MAX(s.s_acctbal)
FROM region r, nation n, supplier s, partsupp ps, part p
WHERE r.r_regionkey = n.n_regionkey AND n.n_nationkey = s.s_nationkey
  AND s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
  AND r.r_name IN (2, 3) AND p.p_price > 1200.0
"""
FIG1_RENAMED = """
SELECT MAX(su.s_acctbal), MIN(su.s_acctbal)
FROM part pa, supplier su, region re, partsupp pp, nation na
WHERE pa.p_price > 1200.0 AND na.n_nationkey = su.s_nationkey
  AND re.r_regionkey = na.n_regionkey AND pp.ps_partkey = pa.p_partkey
  AND su.s_suppkey = pp.ps_suppkey AND re.r_name IN (3, 2)
"""
FIG1_MEDIAN = """
SELECT MEDIAN(s.s_acctbal)
FROM region r, nation n, supplier s, partsupp ps, part p
WHERE r.r_regionkey = n.n_regionkey AND n.n_nationkey = s.s_nationkey
  AND s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
  AND r.r_name IN (0, 1) AND p.p_price > 800.0
"""
SUPP_BY_NATION = """
SELECT COUNT(*) AS suppliers, AVG(s.s_acctbal) AS avg_bal
FROM supplier s, nation n
WHERE s.s_nationkey = n.n_nationkey
GROUP BY s.s_nationkey
"""
# grouping by a nation attribute spreads the output vars over two atoms →
# unguarded → served by the eager fallback (reported separately; its cost
# never amortises, which is the point of the comparison)
SUPP_BY_REGION_EAGER = """
SELECT COUNT(*) AS suppliers, AVG(s.s_acctbal) AS avg_bal
FROM supplier s, nation n
WHERE s.s_nationkey = n.n_nationkey
GROUP BY n.n_regionkey
"""
COSTLY_PARTS = """
SELECT SUM(ps.ps_supplycost), COUNT(*)
FROM partsupp ps, part p
WHERE ps.ps_partkey = p.p_partkey AND p.p_price > 1500.0
"""

# (name, sql) — all jittable; FIG1_RENAMED shares FIG1's fingerprint
DISTINCT_QUERIES = [
    ("fig1-minmax", FIG1),
    ("fig1-median", FIG1_MEDIAN),
    ("supp-by-nation", SUPP_BY_NATION),
    ("costly-parts", COSTLY_PARTS),
]


def _grow_within_bucket(db: dict[str, Table], rel: str, seed: int = 0):
    """New-rows copy of `rel` grown to exactly its current shape bucket."""
    tab = db[rel]
    bucket = bucket_capacity(tab.capacity)
    extra = bucket - tab.capacity
    if extra == 0:
        return None, 0
    rng = np.random.default_rng(seed)
    cols = {}
    for name, col in tab.columns.items():
        base = np.asarray(col)
        new = base[rng.integers(0, len(base), extra)]  # resample real rows
        cols[name] = np.concatenate([base, new])
    return Table.from_numpy(cols), extra


def run(scale: int = 1000, warm_iters: int = 25, seed: int = 0):
    db, schema = make_tpch_db(scale=scale, seed=seed)
    svc = QueryService(db, schema)
    report: dict = {"scale": scale}

    # ---- cold pass: first sight of each fingerprint (parse+plan+compile)
    cold = {}
    for name, sql in DISTINCT_QUERIES:
        t0 = time.perf_counter()
        svc.submit(sql)
        cold[name] = time.perf_counter() - t0
    report["cold_s"] = cold

    # ---- warm pass: mixed stream over the same fingerprints -------------
    stream = []
    for i in range(warm_iters):
        stream.append(DISTINCT_QUERIES[i % len(DISTINCT_QUERIES)])
        if i % 3 == 0:
            # alias-renamed → same fingerprint as fig1-minmax
            stream.append(("fig1-minmax", FIG1_RENAMED))
    lat: list[float] = []
    per_query: dict[str, list[float]] = {}
    t_stream = time.perf_counter()
    for name, sql in stream:
        t0 = time.perf_counter()
        svc.submit(sql)
        dt = time.perf_counter() - t0
        lat.append(dt)
        per_query.setdefault(name, []).append(dt)
    stream_s = time.perf_counter() - t_stream
    report["warm_median_s"] = float(np.median(lat))
    report["warm_p99_s"] = float(np.percentile(lat, 99))
    report["throughput_qps"] = len(stream) / stream_s
    # per-fingerprint amortisation: this query's cold (parse+plan+compile+
    # run) over its own warm median (run only)
    report["speedup_per_query"] = {
        name: cold[name] / float(np.median(ts))
        for name, ts in per_query.items()}
    report["speedup"] = min(report["speedup_per_query"].values())

    # ---- micro-batched throughput (skewed mix, one submit_many call) ----
    batch = [FIG1, FIG1_RENAMED] * 8 + [SUPP_BY_NATION] * 4
    t0 = time.perf_counter()
    svc.submit_many(batch)
    report["batched_qps"] = len(batch) / (time.perf_counter() - t0)

    # ---- eager fallback (unguarded plan), for contrast -----------------
    t0 = time.perf_counter()
    r = svc.submit(SUPP_BY_REGION_EAGER)
    report["eager_s"] = time.perf_counter() - t0
    report["eager_mode"] = r.stats.mode

    # ---- growth inside the shape bucket: zero recompiles ----------------
    compiles_before = svc.metrics()["compiles"]
    grown, extra = _grow_within_bucket(db, "partsupp", seed=seed + 1)
    if grown is not None:
        svc.update_table("partsupp", grown)
    for sql in (FIG1, FIG1_MEDIAN, COSTLY_PARTS):
        svc.submit(sql)
    m = svc.metrics()
    report["growth_rows"] = extra
    report["growth_recompiles"] = m["compiles"] - compiles_before
    report["metrics"] = m
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test scale (CI)")
    ap.add_argument("--scale", type=int, default=None)
    ap.add_argument("--warm-iters", type=int, default=None)
    args = ap.parse_args(argv)
    scale = args.scale or (50 if args.tiny else 1000)
    warm_iters = args.warm_iters or (8 if args.tiny else 25)

    jax.config.update("jax_platform_name", "cpu")
    r = run(scale=scale, warm_iters=warm_iters)

    print(f"serving benchmark  scale={r['scale']}")
    print(f"{'query':16s} {'cold (ms)':>10s} {'speedup':>9s}")
    for name, s in r["cold_s"].items():
        sp = r["speedup_per_query"][name]
        print(f"{name:16s} {s * 1e3:>10.1f} {sp:>8.1f}x")
    print(f"warm median       {r['warm_median_s'] * 1e3:>10.2f} ms")
    print(f"warm p99          {r['warm_p99_s'] * 1e3:>10.2f} ms")
    print(f"throughput        {r['throughput_qps']:>10.0f} qps")
    print(f"batched           {r['batched_qps']:>10.0f} qps")
    print(f"cold/warm speedup {r['speedup']:>10.1f}x (min per-query)")
    print(f"eager fallback    {r['eager_s'] * 1e3:>10.1f} ms "
          f"(mode={r['eager_mode']}, never amortises)")
    print(f"growth rows       {r['growth_rows']:>10d} "
          f"(recompiles={r['growth_recompiles']})")
    m = r["metrics"]
    print(f"cache: plan {m['plan_hits']}/{m['plan_hits'] + m['plan_misses']}"
          f" hit, exec {m['exec_hits']}/{m['exec_hits'] + m['exec_misses']}"
          f" hit, compiles={m['compiles']}, "
          f"dedup_saved={m['dedup_saved']}")

    ok = True
    if r["speedup"] < 10:
        print(f"FAIL: warm-cache speedup {r['speedup']:.1f}x < 10x")
        ok = False
    if r["growth_recompiles"] != 0:
        print(f"FAIL: same-bucket growth caused "
              f"{r['growth_recompiles']} recompiles")
        ok = False
    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
