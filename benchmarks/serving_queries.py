"""Serving benchmark: cold-compile vs warm-cache latency and throughput.

Drives a mixed TPC-H-style query stream through ``QueryService`` and
measures the properties the serving tier exists for:

  1. warm-cache latency ≥ 10× lower than cold-compile latency on the same
     stream (the plan + executable caches amortise parse/GYO/XLA work);
  2. repeated queries after same-bucket data growth trigger ZERO recompiles
     (shape bucketing + freq-masked padding), verified via cache counters;
  3. micro-batched throughput on a skewed request mix (dashboards repeat
     the same handful of fingerprints);
  4. cross-fingerprint fusion: a dashboard of N *distinct* queries whose
     plan DAGs overlap served via one ``submit_many`` must beat serving
     them individually on total XLA compiles AND wall-clock, with
     bitwise-identical answers per query;
  5. partial fusion across join shapes: a workload where every whole plan
     prefix is distinct (so PR 2's equal-prefix rule fuses nothing) must
     still fuse via shared subplans — gated on the ``partial_fusions`` and
     ``subplan_saved`` counters;
  6. cross-CALLER batch formation: N threads each submitting ONE query via
     ``submit_async`` land in one batching window, so the scheduler runs
     fewer fused compiles than there are requests or even distinct
     fingerprints, with answers bitwise-identical to serial ``submit``
     calls — and a malformed query in the window fails only its own
     future while every valid batch-mate is still answered;
  7. RESTART warm start: two successive *processes* share a ``cache_dir``.
     The first (cold) persists every plan, XLA executable, and table
     statistic; the second (warm) must answer the same query mix with
     ZERO plan rebuilds (``plan_builds == 0``, ``persist_hits`` ==
     distinct fingerprints), ZERO statistics recomputes
     (``stat_refreshes == 0``), a gating-decision trace identical to the
     cold process's, bitwise-identical answers, and — in the timed run —
     a lower startup-to-answers wall-clock than the cold process.

    PYTHONPATH=src python benchmarks/serving_queries.py [--tiny] [--smoke]

  8. OBSERVABILITY overhead: the same warm query mix through a traced
     and an untraced (``tracing=False``) service must produce bitwise
     identical answers, and tracing's warm hot-path cost must stay ≤ 3%
     (plus a small absolute floor, so micro-benchmark noise on tiny
     tables cannot flake the gate); the traced service's per-stage
     latency histograms (p50/p95/p99) feed the ``--record`` trajectory.

  9. MESH serving: a database 4× larger than any other scenario here,
     served by ``QueryService(mesh=...)`` on 8 devices (forced host
     devices in a subprocess).  Answers must be bitwise-identical to a
     single-device service padded to the same capacities (the
     ``min_bucket = n_shards × mesh_min_bucket`` identity), individually
     AND fused; within-bucket per-shard growth must cause zero
     recompiles; and a warm restart over the shared ``cache_dir`` must
     re-plan nothing (``plan_builds == 0``) — the same serving
     guarantees, one graph interpreter, beyond one device.

 10. MIS-FUSION gate: a cheap 3-way lookup whose op DAG overlaps two
     expensive 5-way dashboards.  Overlap grouping alone would fuse all
     three, so every lookup pays the dashboards' latency; the default
     cost-calibrated admission must band the lookup out
     (``fusion_cost_rejects``) while still fusing the two dashboards,
     its p95 engine-measured serve time must beat the ungated
     ``fusion_disparity=float("inf")`` baseline, answers must stay
     bitwise-identical, and a forced serve-time regression on the fused
     pair must demote it on the very next batch (``fusion_demotions``).

``--smoke`` runs only the fused-batching + mixed-shape + async +
mis-fusion + restart + observability + mesh scenarios on tiny tables and
asserts cache/fusion/scheduler/persistence/calibration counters and
answer identity (plus the tracing overhead and mis-fusion p95 gates) —
what ``scripts/verify.sh --smoke`` runs so serving regressions fail CI
fast.  ``--record [PATH]`` writes a
schema-versioned ``BENCH_serving.json`` (rows + per-stage histogram
snapshots + counters; validated by ``python -m benchmarks.recorder``).
"""

from __future__ import annotations

import argparse
import itertools
import json
import os
import shutil
import subprocess
import sys
import tempfile
import threading
import time

import jax
import numpy as np

# run both as `python benchmarks/serving_queries.py` (script dir on
# sys.path, repo root not) and as `python -m benchmarks.serving_queries`
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.data import make_tpch_db
from repro.service import (QueryService, TenantAdmissionError, TenantPolicy)
from repro.tables.table import Table, bucket_capacity

FIG1 = """
SELECT MIN(s.s_acctbal), MAX(s.s_acctbal)
FROM region r, nation n, supplier s, partsupp ps, part p
WHERE r.r_regionkey = n.n_regionkey AND n.n_nationkey = s.s_nationkey
  AND s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
  AND r.r_name IN (2, 3) AND p.p_price > 1200.0
"""
FIG1_RENAMED = """
SELECT MAX(su.s_acctbal), MIN(su.s_acctbal)
FROM part pa, supplier su, region re, partsupp pp, nation na
WHERE pa.p_price > 1200.0 AND na.n_nationkey = su.s_nationkey
  AND re.r_regionkey = na.n_regionkey AND pp.ps_partkey = pa.p_partkey
  AND su.s_suppkey = pp.ps_suppkey AND re.r_name IN (3, 2)
"""
FIG1_MEDIAN = """
SELECT MEDIAN(s.s_acctbal)
FROM region r, nation n, supplier s, partsupp ps, part p
WHERE r.r_regionkey = n.n_regionkey AND n.n_nationkey = s.s_nationkey
  AND s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
  AND r.r_name IN (0, 1) AND p.p_price > 800.0
"""
SUPP_BY_NATION = """
SELECT COUNT(*) AS suppliers, AVG(s.s_acctbal) AS avg_bal
FROM supplier s, nation n
WHERE s.s_nationkey = n.n_nationkey
GROUP BY s.s_nationkey
"""
# grouping by a nation attribute spreads the output vars over two atoms →
# unguarded → served by the eager fallback (reported separately; its cost
# never amortises, which is the point of the comparison)
SUPP_BY_REGION_EAGER = """
SELECT COUNT(*) AS suppliers, AVG(s.s_acctbal) AS avg_bal
FROM supplier s, nation n
WHERE s.s_nationkey = n.n_nationkey
GROUP BY n.n_regionkey
"""
COSTLY_PARTS = """
SELECT SUM(ps.ps_supplycost), COUNT(*)
FROM partsupp ps, part p
WHERE ps.ps_partkey = p.p_partkey AND p.p_price > 1500.0
"""

# (name, sql) — all jittable; FIG1_RENAMED shares FIG1's fingerprint
DISTINCT_QUERIES = [
    ("fig1-minmax", FIG1),
    ("fig1-median", FIG1_MEDIAN),
    ("supp-by-nation", SUPP_BY_NATION),
    ("costly-parts", COSTLY_PARTS),
]

# ---- mixed dashboard workload (cross-fingerprint fusion) -------------------
# N distinct queries over shared dimension joins.  Family A: four aggregates
# over supplier⋈nation⋈region with identical selections (one shared
# semi-join prefix); family B: two over partsupp⋈part (a second prefix);
# plus the 5-way FIG1, whose join shape matches nobody but whose DAG
# overlaps family A on the filtered region scan + nation/supplier semi-join
# chain.  Subplan-overlap grouping therefore fuses {A ∪ FIG1} and {B}:
# 2 compiles instead of 7, with the A∪FIG1 program counted as a *partial*
# fusion (its members do not share one whole prefix).
_SUPP_DIMS = """FROM supplier s, nation n, region r
WHERE s.s_nationkey = n.n_nationkey AND n.n_regionkey = r.r_regionkey
  AND r.r_name IN (2, 3)"""
_PART_DIMS = """FROM partsupp ps, part p
WHERE ps.ps_partkey = p.p_partkey AND p.p_price > 1500.0"""
DASHBOARD_QUERIES = [
    ("dash-minmax", f"SELECT MIN(s.s_acctbal), MAX(s.s_acctbal) {_SUPP_DIMS}"),
    ("dash-sum", f"SELECT SUM(s.s_acctbal) {_SUPP_DIMS}"),
    ("dash-by-nation", "SELECT COUNT(*) AS suppliers, AVG(s.s_acctbal) AS "
                       f"avg_bal {_SUPP_DIMS} GROUP BY s.s_nationkey"),
    ("dash-median", f"SELECT MEDIAN(s.s_acctbal) {_SUPP_DIMS}"),
    ("dash-supplycost", f"SELECT SUM(ps.ps_supplycost), COUNT(*) {_PART_DIMS}"),
    ("dash-by-supp", "SELECT AVG(ps.ps_supplycost) AS avg_cost "
                     f"{_PART_DIMS} GROUP BY ps.ps_suppkey"),
    ("dash-fig1", FIG1),
]
DASHBOARD_FUSION_SETS = 2     # {A-family ∪ FIG1}, {B-family}
DASHBOARD_FUSED_PROGRAMS = 2  # fusion sets with ≥ 2 members
DASHBOARD_FUSED_QUERIES = 7   # members of the two multi-query programs

# ---- mixed-JOIN-SHAPE dashboard (partial fusion) ---------------------------
# Four queries whose whole plan prefixes are pairwise DISTINCT — under
# PR 2's equal-prefix rule nothing here fuses, ever — but whose op DAGs
# overlap: the 3/4/5-way queries share the filtered region scan and the
# nation/supplier semi-join chain, and the 2-way query shares the filtered
# part scan + partsupp semi-join with the 5-way.  Overlap grouping is
# transitive, so the op-graph executor compiles ALL FOUR into one program.
MIX_3WAY = f"SELECT MIN(s.s_acctbal) {_SUPP_DIMS}"
MIX_4WAY = """SELECT MIN(s.s_acctbal), MAX(s.s_acctbal)
FROM supplier s, nation n, region r, partsupp ps
WHERE s.s_nationkey = n.n_nationkey AND n.n_regionkey = r.r_regionkey
  AND s.s_suppkey = ps.ps_suppkey AND r.r_name IN (2, 3)"""
MIX_2WAY = """SELECT SUM(ps.ps_supplycost) FROM partsupp ps, part p
WHERE ps.ps_partkey = p.p_partkey AND p.p_price > 1200.0"""
MIXED_SHAPE_QUERIES = [
    ("mix-3way", MIX_3WAY),
    ("mix-4way", MIX_4WAY),
    ("mix-5way", FIG1),
    ("mix-2way", MIX_2WAY),
]

# ---- MIS-FUSION workload (cost-calibrated admission + feedback) ------------
# A cheap 3-way lookup whose op DAG overlaps two expensive 5-way dashboards
# (shared filtered-region scan + nation/supplier semi-join chain).  Overlap
# grouping alone would fuse all three into ONE program, so every lookup
# would pay the 5-way program's latency — the mis-fusion the cost gate
# exists to prevent.  The gated (default) service must band the lookup out
# (``fusion_cost_rejects``) while still fusing the two bigs; the ungated
# baseline (``fusion_disparity=float("inf")``) fuses everything.
FIG1_SUM = """
SELECT SUM(s.s_acctbal)
FROM region r, nation n, supplier s, partsupp ps, part p
WHERE r.r_regionkey = n.n_regionkey AND n.n_nationkey = s.s_nationkey
  AND s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
  AND r.r_name IN (2, 3) AND p.p_price > 1200.0
"""
MISFUSION_QUERIES = [
    ("small-lookup", f"SELECT COUNT(*) {_SUPP_DIMS}"),
    ("big-minmax", FIG1),
    ("big-sum", FIG1_SUM),
]


def _values_equal(a: dict, b: dict) -> bool:
    """Bitwise equality of two QueryResult.values dicts."""
    if set(a) != set(b):
        return False
    for k, va in a.items():
        vb = b[k]
        if k == "groups":
            if set(va) != set(vb) or any(
                    not np.array_equal(np.asarray(va[c]), np.asarray(vb[c]))
                    for c in va):
                return False
        elif not np.array_equal(np.asarray(va), np.asarray(vb)):
            return False
    return True


def _grow_within_bucket(db: dict[str, Table], rel: str, seed: int = 0):
    """New-rows copy of `rel` grown to exactly its current shape bucket."""
    tab = db[rel]
    bucket = bucket_capacity(tab.capacity)
    extra = bucket - tab.capacity
    if extra == 0:
        return None, 0
    rng = np.random.default_rng(seed)
    cols = {}
    for name, col in tab.columns.items():
        base = np.asarray(col)
        new = base[rng.integers(0, len(base), extra)]  # resample real rows
        cols[name] = np.concatenate([base, new])
    return Table.from_numpy(cols), extra


def run(scale: int = 1000, warm_iters: int = 25, seed: int = 0):
    db, schema = make_tpch_db(scale=scale, seed=seed)
    svc = QueryService(db, schema)
    report: dict = {"scale": scale}

    # ---- cold pass: first sight of each fingerprint (parse+plan+compile)
    cold = {}
    for name, sql in DISTINCT_QUERIES:
        t0 = time.perf_counter()
        svc.submit(sql)
        cold[name] = time.perf_counter() - t0
    report["cold_s"] = cold

    # ---- warm pass: mixed stream over the same fingerprints -------------
    stream = []
    for i in range(warm_iters):
        stream.append(DISTINCT_QUERIES[i % len(DISTINCT_QUERIES)])
        if i % 3 == 0:
            # alias-renamed → same fingerprint as fig1-minmax
            stream.append(("fig1-minmax", FIG1_RENAMED))
    lat: list[float] = []
    per_query: dict[str, list[float]] = {}
    t_stream = time.perf_counter()
    for name, sql in stream:
        t0 = time.perf_counter()
        svc.submit(sql)
        dt = time.perf_counter() - t0
        lat.append(dt)
        per_query.setdefault(name, []).append(dt)
    stream_s = time.perf_counter() - t_stream
    report["warm_median_s"] = float(np.median(lat))
    report["warm_p99_s"] = float(np.percentile(lat, 99))
    report["throughput_qps"] = len(stream) / stream_s
    # per-fingerprint amortisation: this query's cold (parse+plan+compile+
    # run) over its own warm median (run only)
    report["speedup_per_query"] = {
        name: cold[name] / float(np.median(ts))
        for name, ts in per_query.items()}
    report["speedup"] = min(report["speedup_per_query"].values())

    # ---- micro-batched throughput (skewed mix, one submit_many call) ----
    batch = [FIG1, FIG1_RENAMED] * 8 + [SUPP_BY_NATION] * 4
    t0 = time.perf_counter()
    svc.submit_many(batch)
    report["batched_qps"] = len(batch) / (time.perf_counter() - t0)

    # ---- eager fallback (unguarded plan), for contrast -----------------
    t0 = time.perf_counter()
    r = svc.submit(SUPP_BY_REGION_EAGER)
    report["eager_s"] = time.perf_counter() - t0
    report["eager_mode"] = r.stats.mode

    # ---- growth inside the shape bucket: zero recompiles ----------------
    compiles_before = svc.metrics()["compiles"]
    grown, extra = _grow_within_bucket(db, "partsupp", seed=seed + 1)
    if grown is not None:
        svc.update_table("partsupp", grown)
    for sql in (FIG1, FIG1_MEDIAN, COSTLY_PARTS):
        svc.submit(sql)
    m = svc.metrics()
    report["growth_rows"] = extra
    report["growth_recompiles"] = m["compiles"] - compiles_before
    report["metrics"] = m
    return report


def run_fused(scale: int = 1000, repeats: int = 3, seed: int = 0):
    """Mixed dashboard workload: N distinct prefix-sharing queries, served
    individually vs via fused ``submit_many``.  Returns walls, compile
    counts, per-query identity, and the fused service's metrics."""
    db, schema = make_tpch_db(scale=scale, seed=seed)
    sqls = [sql for _, sql in DASHBOARD_QUERIES]

    svc_solo = QueryService(db, schema)
    t0 = time.perf_counter()
    for _ in range(repeats):
        solo = [svc_solo.submit(sql) for sql in sqls]
    solo_s = time.perf_counter() - t0

    # disparity=inf: this scenario pins the fusion MACHINERY (grouping,
    # partial fusion, subplan dedup, the fused cache) on a deliberately
    # cost-disparate mix; admission POLICY is the mis-fusion scenario's job
    svc_fused = QueryService(db, schema, fusion_disparity=float("inf"))
    t0 = time.perf_counter()
    for _ in range(repeats):
        fused = svc_fused.submit_many(sqls)
    fused_s = time.perf_counter() - t0

    identical = all(_values_equal(a.values, b.values)
                    for a, b in zip(solo, fused))
    return {
        "queries": len(sqls),
        "repeats": repeats,
        "solo_s": solo_s,
        "fused_s": fused_s,
        "solo_compiles": svc_solo.metrics()["compiles"],
        "fused_compiles": svc_fused.metrics()["compiles"],
        "identical": identical,
        "fused_metrics": svc_fused.metrics(),
    }


def check_fused(rf: dict) -> list[str]:
    """Gate the fused scenario's counters + identity; returns failures."""
    fails = []
    m = rf["fused_metrics"]
    if not rf["identical"]:
        fails.append("fused answers differ from individual serving")
    if rf["fused_compiles"] >= rf["solo_compiles"]:
        fails.append(f"fused used {rf['fused_compiles']} compiles, "
                     f"individual used {rf['solo_compiles']}")
    if rf["fused_compiles"] != DASHBOARD_FUSION_SETS:
        fails.append(f"expected {DASHBOARD_FUSION_SETS} fused-path "
                     f"compiles, got {rf['fused_compiles']}")
    if m["fused_queries"] != rf["repeats"] * DASHBOARD_FUSED_QUERIES:
        fails.append(f"fused_queries={m['fused_queries']} != "
                     f"{rf['repeats']} × {DASHBOARD_FUSED_QUERIES}")
    if m["fused_hits"] < (rf["repeats"] - 1) * DASHBOARD_FUSED_PROGRAMS:
        fails.append(f"fused executable cache hits {m['fused_hits']} — "
                     "repeat dashboards are not reusing fused programs")
    if m["partial_fusions"] < rf["repeats"]:
        fails.append(f"partial_fusions={m['partial_fusions']} — FIG1 is "
                     "not being fused into the A-family program")
    if m["subplan_saved"] <= 0:
        fails.append("subplan_saved=0 — the fused trace memo deduped "
                     "nothing")
    return fails


def run_mixed(scale: int = 1000, repeats: int = 3, seed: int = 0):
    """Mixed-JOIN-SHAPE dashboard: whole-prefix fusion (PR 2's rule) finds
    zero fusable pairs here, the op-graph executor fuses everything.
    Served individually vs via ``submit_many``; returns walls, compile
    counts, identity, whole-prefix diversity, and fused metrics."""
    from repro.core import plan_query, segment_plan
    from repro.service import canonicalize
    from repro.core.sql import parse_sql

    db, schema = make_tpch_db(scale=scale, seed=seed)
    sqls = [sql for _, sql in MIXED_SHAPE_QUERIES]

    # document the premise: every member has a DIFFERENT whole prefix
    prefixes = {
        segment_plan(plan_query(canonicalize(parse_sql(s, schema)).query,
                                schema)).prefix_key for s in sqls}

    svc_solo = QueryService(db, schema)
    t0 = time.perf_counter()
    for _ in range(repeats):
        solo = [svc_solo.submit(sql) for sql in sqls]
    solo_s = time.perf_counter() - t0

    # disparity=inf, as in run_fused: partial fusion across join shapes is
    # machinery; whether these four SHOULD fuse is the admission gate's
    # call, exercised by the mis-fusion scenario
    svc_fused = QueryService(db, schema, fusion_disparity=float("inf"))
    t0 = time.perf_counter()
    for _ in range(repeats):
        fused = svc_fused.submit_many(sqls)
    fused_s = time.perf_counter() - t0

    identical = all(_values_equal(a.values, b.values)
                    for a, b in zip(solo, fused))
    return {
        "queries": len(sqls),
        "repeats": repeats,
        "distinct_prefixes": len(prefixes),
        "solo_s": solo_s,
        "fused_s": fused_s,
        "solo_compiles": svc_solo.metrics()["compiles"],
        "fused_compiles": svc_fused.metrics()["compiles"],
        "identical": identical,
        "fused_metrics": svc_fused.metrics(),
    }


def check_mixed(rm: dict) -> list[str]:
    """Gate the mixed-shape scenario; returns failures."""
    fails = []
    m = rm["fused_metrics"]
    if rm["distinct_prefixes"] != rm["queries"]:
        fails.append(f"premise broken: {rm['distinct_prefixes']} distinct "
                     f"prefixes over {rm['queries']} queries — whole-prefix "
                     "fusion would not be zero here")
    if not rm["identical"]:
        fails.append("mixed-shape fused answers differ from individual "
                     "serving")
    if rm["fused_compiles"] >= rm["solo_compiles"]:
        fails.append(f"mixed-shape fused used {rm['fused_compiles']} "
                     f"compiles, individual used {rm['solo_compiles']}")
    if m["partial_fusions"] < rm["repeats"]:
        fails.append(f"partial_fusions={m['partial_fusions']} < "
                     f"{rm['repeats']} — different join shapes not fusing")
    if m["subplan_saved"] <= 0:
        fails.append("subplan_saved=0 on the mixed-shape workload")
    return fails


def run_async(scale: int = 1000, threads: int = 8, seed: int = 0):
    """Concurrent-callers scenario: `threads` independent threads each
    submit ONE query from the shared-subplan dashboard via
    ``submit_async``.  The background batcher forms the batch, so the
    requests fuse exactly as a single ``submit_many`` caller's would —
    fewer compiles than requests — and answers are bitwise-identical to
    serial ``submit`` calls.  A follow-up window co-batches a malformed
    query with a valid one to show per-request fault isolation."""
    db, schema = make_tpch_db(scale=scale, seed=seed)
    sqls = [sql for _, sql in DASHBOARD_QUERIES]
    work = [sqls[i % len(sqls)] for i in range(threads)]

    svc_serial = QueryService(db, schema)
    t0 = time.perf_counter()
    serial = [svc_serial.submit(sql) for sql in work]
    serial_s = time.perf_counter() - t0

    # a wide formation window: the barrier releases all threads at once,
    # so one window captures every caller deterministically
    svc = QueryService(db, schema, async_max_wait_ms=500,
                       async_max_batch=max(64, threads))
    barrier = threading.Barrier(threads)
    futs: list = [None] * threads

    def caller(i):
        barrier.wait()
        futs[i] = svc.submit_async(work[i])

    callers = [threading.Thread(target=caller, args=(i,))
               for i in range(threads)]
    t0 = time.perf_counter()
    for t in callers:
        t.start()
    for t in callers:
        t.join()
    results = [f.result(300) for f in futs]
    async_s = time.perf_counter() - t0

    identical = all(r.error is None and _values_equal(a.values, r.values)
                    for a, r in zip(serial, results))

    # fault isolation across callers: a malformed query co-batched with a
    # valid one must fail alone
    bad_fut = svc.submit_async("SELECT MIN(x.nope) FROM no_such_relation x")
    good_fut = svc.submit_async(sqls[0])
    bad_error = bad_fut.exception(300)
    good_res = good_fut.result(300)
    good_ok = (good_res.error is None
               and _values_equal(good_res.values, serial[0].values))

    m = svc.metrics()
    svc.close()
    return {
        "threads": threads,
        "distinct": len(set(work)),
        "serial_s": serial_s,
        "async_s": async_s,
        "identical": identical,
        "bad_error": bad_error,
        "good_ok": good_ok,
        "serial_compiles": svc_serial.metrics()["compiles"],
        "metrics": m,
    }


def check_async(ra: dict) -> list[str]:
    """Gate the concurrent-callers scenario; returns failures."""
    fails = []
    m = ra["metrics"]
    if not ra["identical"]:
        fails.append("async answers differ from serial submit calls")
    if m["async_batches"] < 1:
        fails.append("async_batches=0 — the background batcher never ran")
    if m["async_requests"] < ra["threads"]:
        fails.append(f"async_requests={m['async_requests']} < "
                     f"{ra['threads']} submitted")
    if m["fused_compiles"] >= ra["distinct"]:
        fails.append(f"fused_compiles={m['fused_compiles']} not below "
                     f"{ra['distinct']} distinct fingerprints — "
                     "cross-caller batch formation is not fusing")
    if m["compiles"] >= ra["threads"]:
        fails.append(f"compiles={m['compiles']} >= {ra['threads']} "
                     "requests — no cross-caller amortisation")
    if ra["bad_error"] is None:
        fails.append("malformed query's future did not carry its error")
    if not ra["good_ok"]:
        fails.append("valid batch-mate of the malformed query was not "
                     "answered correctly")
    if m["request_errors"] != 1:
        fails.append(f"request_errors={m['request_errors']} != 1")
    if m["rejected"] != 0:
        fails.append(f"rejected={m['rejected']} — queue backpressure "
                     "tripped on an idle-sized workload")
    return fails


# ---- multi-tenant fair admission (adversarial mix) -------------------------
# The victim's client-measured p95 (submit → future resolution, exact
# wall-clock — NOT the log-bucketed histogram p95, whose ~33%/bucket
# quantisation would dominate a 2× comparison) under flood must stay
# within 2× its solo baseline; the absolute floor absorbs tiny-table
# noise on a shared box.  The per-tenant histograms still gate
# presence/shape via the metrics_v2()["tenants"] breakdown.
MT_VICTIM_P95_BOUND = 2.0
MT_VICTIM_P95_FLOOR_S = 0.05


def run_multitenant(scale: int = 1000, rounds: int = 6, seed: int = 0):
    """Adversarial tenant mix: one tenant floods malformed + oversized
    (largest-tables join) queries under a tight token-bucket quota while
    a victim tenant serves its dashboard.  The quota + per-tenant queues + DRR
    keep the victim's engine-measured p95 near its solo baseline and its
    answers bitwise-identical; a second window shows N tenants firing
    the same dashboard share ONE fused program (fused compiles <
    distinct requests across tenants) while accounting stays per-tenant."""
    db, schema = make_tpch_db(scale=scale, seed=seed)
    victim_sqls = [sql for _, sql in DASHBOARD_QUERIES[:4]]  # A-family
    # oversized: the B-family scan over the two LARGEST tables
    # (partsupp⋈part) — structurally disjoint from the victim's
    # supplier⋈nation⋈region dashboards, so union-find never groups the
    # flood with the victim and every window composition the flood
    # creates reuses warmed signatures (the fairness gate then measures
    # scheduling, not compile-on-novel-composition transients; fusing
    # ACROSS tenants is gated by the 4-tenant window below)
    flood_big = DASHBOARD_QUERIES[4][1]
    flood_bad = "SELECT MIN(x.nope) FROM no_such_relation x"

    svc0 = QueryService(db, schema)
    baseline = [svc0.submit(q) for q in victim_sqls]

    tenants = {
        "victim": TenantPolicy(weight=2.0, priority=0),
        "flood": TenantPolicy(rate=50.0, burst=8, max_queue=16,
                              priority=1),
    }

    def new_service():
        return QueryService(db, schema, async_max_wait_ms=5,
                            async_max_batch=64, tenants=tenants)

    def warm(svc):
        # warm every plan/executable so both runs measure the warm path
        # (cold compiles would swamp the fairness comparison)
        for q in victim_sqls + [flood_big]:
            svc.submit(q)
        # ...including every FUSED composition a formation window can
        # produce — a fused-program signature is a new executable even
        # when every member plan is warm.  Window splits form subsets of
        # the dashboard, and the serve-time feedback loop can demote a
        # member mid-stream and re-group the REMAINDER into a novel
        # signature (e.g. {v1,v2,v4} after v3 demotes), so compile every
        # ≥2-member subset once up front; the flood query is
        # structurally disjoint and always serves in its own singleton
        # group, so it adds no compositions
        for k in range(2, len(victim_sqls) + 1):
            for combo in itertools.combinations(victim_sqls, k):
                svc.submit_many(list(combo))
        # then drive the calibrator to its steady state on the measured
        # compositions: stop once two consecutive passes serve purely
        # from caches — the fairness gate must time the steady state,
        # not the calibration transient
        quiet = 0
        for _ in range(25):
            rs = (svc.submit_many(victim_sqls)
                  + svc.submit_many(victim_sqls + [flood_big]))
            cached = all(r.stats.exec_source in ("exec_cache",
                                                 "fused_cache")
                         for r in rs)
            quiet = quiet + 1 if cached else 0
            if quiet >= 2:
                break

    def victim_rounds(svc):
        out, lats = [], []
        for _ in range(rounds):
            futs = []
            for q in victim_sqls:
                t0 = time.perf_counter()
                f = svc.submit_async(q, tenant="victim")
                f.add_done_callback(
                    lambda _f, t0=t0: lats.append(time.perf_counter() - t0))
                futs.append(f)
            out.append([f.result(300) for f in futs])
        return out, lats

    # solo baseline: the victim alone on an identically-configured service
    svc_solo = new_service()
    warm(svc_solo)
    solo_results, solo_lats = victim_rounds(svc_solo)
    svc_solo.close(timeout=300)

    # adversarial mix: the flooder hammers as fast as it can; its quota
    # (not the victim's latency) is what bounds what gets through
    svc = new_service()
    warm(svc)
    stop = threading.Event()
    flood = {"submitted": 0, "rejected_rate": 0, "rejected_depth": 0}

    def flooder():
        i = 0
        while not stop.is_set():
            q = flood_bad if i % 2 == 0 else flood_big
            i += 1
            flood["submitted"] += 1
            try:
                svc.submit_async(q, tenant="flood")
            except TenantAdmissionError as e:
                flood[f"rejected_{e.kind}"] += 1
            time.sleep(0.0005)

    th = threading.Thread(target=flooder)
    th.start()
    mixed_results, mixed_lats = victim_rounds(svc)
    stop.set()
    th.join(30)
    svc.close(timeout=300)             # drain the flooder's leftovers
    v2 = svc.metrics_v2()

    victim_identical = all(
        r.error is None and _values_equal(b.values, r.values)
        for rnd in (solo_results, mixed_results) for row in rnd
        for b, r in zip(baseline, row))

    # cross-tenant fusion: 4 tenants × the same 2-query dashboard in one
    # formation window → one fused program, per-tenant accounting
    xt_tenants = [f"t{i}" for i in range(4)]
    xt_sqls = [sql for _, sql in DASHBOARD_QUERIES[:2]]
    svc_x = QueryService(db, schema, async_max_wait_ms=500,
                         async_max_batch=64)
    pairs = [(t, q) for t in xt_tenants for q in xt_sqls]
    barrier = threading.Barrier(len(pairs))
    xfuts: list = [None] * len(pairs)

    def xcaller(i):
        barrier.wait()
        xfuts[i] = svc_x.submit_async(pairs[i][1], tenant=pairs[i][0])

    xthreads = [threading.Thread(target=xcaller, args=(i,))
                for i in range(len(pairs))]
    for t in xthreads:
        t.start()
    for t in xthreads:
        t.join()
    xres = [f.result(300) for f in xfuts]
    x_identical = all(
        r.error is None and _values_equal(baseline[j % 2].values, r.values)
        for j, r in enumerate(xres))
    xv2 = svc_x.metrics_v2()
    svc_x.close()

    return {
        "rounds": rounds,
        "victim_queries": len(victim_sqls),
        "solo_p95_s": float(np.percentile(solo_lats, 95)),
        "mixed_p95_s": float(np.percentile(mixed_lats, 95)),
        "victim_identical": victim_identical,
        "flood_client": flood,
        "tenants": v2["tenants"],
        "metrics": {**v2["counters"], **v2["gauges"]},
        "xt_requests": len(pairs),
        "xt_distinct": len(xt_sqls),
        "xt_identical": x_identical,
        "xt_tenants": xv2["tenants"],
        "xt_metrics": {**xv2["counters"], **xv2["gauges"]},
    }


def check_multitenant(rt: dict) -> list[str]:
    """Gate the adversarial-mix scenario; returns failures."""
    fails = []
    vt = rt["tenants"].get("victim", {})
    ft = rt["tenants"].get("flood", {})
    # per-tenant counters/histograms must be present and populated
    for name, t in (("victim", vt), ("flood", ft)):
        for k in ("requests", "rejected", "fused_share", "p50_s", "p95_s",
                  "p99_s"):
            if k not in t:
                fails.append(f"metrics_v2()['tenants'][{name!r}] missing "
                             f"{k!r}")
    expected = rt["rounds"] * rt["victim_queries"]
    if vt.get("requests", 0) != expected:
        fails.append(f"victim served {vt.get('requests')} != {expected} "
                     "submitted")
    if vt.get("errors", 0) != 0:
        fails.append(f"victim errors={vt.get('errors')} — flood damage "
                     "leaked across tenants")
    if not rt["victim_identical"]:
        fails.append("victim answers under flood differ from serial "
                     "submission")
    # the flooding tenant must be held back by ITS quota...
    if ft.get("rejected", 0) < 1:
        fails.append("flooding tenant was never rejected — per-tenant "
                     "quota is not enforcing")
    # ...while whatever it got admitted stayed isolated (malformed
    # queries fail alone, under the flooder's name)
    if ft.get("errors", 0) < 1:
        fails.append("no flood error captured — malformed queries were "
                     "not served/isolated under the flooder's tenant")
    bound = (MT_VICTIM_P95_BOUND * rt["solo_p95_s"]
             + MT_VICTIM_P95_FLOOR_S)
    if rt["mixed_p95_s"] > bound:
        fails.append(f"victim p95 {rt['mixed_p95_s'] * 1e3:.1f} ms under "
                     f"flood exceeds {MT_VICTIM_P95_BOUND}x solo "
                     f"{rt['solo_p95_s'] * 1e3:.1f} ms (+ floor)")
    # cross-tenant fusion: N tenants × one dashboard = ONE program
    xm = rt["xt_metrics"]
    if not rt["xt_identical"]:
        fails.append("cross-tenant answers differ from serial submission")
    if xm["fused_compiles"] >= rt["xt_requests"]:
        fails.append(f"fused_compiles={xm['fused_compiles']} not below "
                     f"{rt['xt_requests']} distinct requests across "
                     "tenants")
    if xm["compiles"] > rt["xt_distinct"]:
        fails.append(f"compiles={xm['compiles']} > {rt['xt_distinct']} "
                     "distinct fingerprints — tenants are not sharing "
                     "programs")
    if xm["dedup_saved"] < rt["xt_requests"] - rt["xt_distinct"]:
        fails.append(f"dedup_saved={xm['dedup_saved']} — same-fingerprint "
                     "requests across tenants did not dedup")
    for t in ("t0", "t1", "t2", "t3"):
        if rt["xt_tenants"].get(t, {}).get("requests", 0) != 2:
            fails.append(f"tenant {t} accounting lost requests")
    if rt["metrics"].get("open_requests", 0) != 0:
        fails.append(f"open_requests={rt['metrics']['open_requests']} "
                     "after the mix — root spans leaked")
    return fails


# ---- observability overhead: traced vs untraced ----------------------------
TRACING_OVERHEAD_FRAC = 0.03     # the ≤ 3% warm hot-path budget
TRACING_OVERHEAD_FLOOR_S = 3e-4  # absolute noise floor for tiny tables


def run_misfusion(scale: int = 1000, repeats: int = 5, seed: int = 0):
    """Cost-gated fusion admission vs the ungated baseline, on a workload
    built to mis-fuse: one cheap lookup + two expensive dashboards whose
    DAGs overlap it.  Measures the lookup's engine-side serve time per
    round under both services (warm, compile excluded), then forces an
    observed regression on the fused big pair through the public feedback
    surface and re-serves — the next batch must demote it."""
    db, schema = make_tpch_db(scale=scale, seed=seed)
    sqls = [sql for _, sql in MISFUSION_QUERIES]

    gated = QueryService(db, schema)
    ungated = QueryService(db, schema, fusion_disparity=float("inf"))

    # warm both services (plans + XLA), then measure steady-state rounds
    gated.submit_many(sqls)
    u_first = ungated.submit_many(sqls)
    lookup_gated_s, lookup_ungated_s = [], []
    for _ in range(repeats):
        g = gated.submit_many(sqls)
        u = ungated.submit_many(sqls)
        lookup_gated_s.append(g[0].stats.run_s)
        lookup_ungated_s.append(u[0].stats.run_s)
    identical = all(_values_equal(a.values, b.values)
                    for a, b in zip(g, u))
    fa = gated.explain(sqls[0])["fusion_admission"]

    # forced regression: tell the feedback loop the fused big pair serves
    # far slower than its solo baseline; the NEXT batch must demote it
    big_fp = g[1].stats.fingerprint
    big_sig = gated.explain(sqls[1])["fusion_admission"]["signature"]
    gated.stats.observe_serve(big_fp, "", 1e-4)
    gated.stats.observe_serve(big_fp, big_sig, 1.0)
    gated.stats.observe_serve(big_fp, big_sig, 1.0)
    demoted = gated.submit_many(sqls)
    demoted_identical = all(_values_equal(a.values, b.values)
                            for a, b in zip(g, demoted))

    return {
        "queries": len(sqls),
        "repeats": repeats,
        "gated_p95_s": float(np.percentile(lookup_gated_s, 95)),
        "ungated_p95_s": float(np.percentile(lookup_ungated_s, 95)),
        "lookup_fused_gated": g[0].stats.fused,
        "lookup_fused_ungated": u_first[0].stats.fused,
        "bigs_fused_gated": g[1].stats.fused and g[2].stats.fused,
        "identical": identical,
        "rejection": fa,
        "bigs_fused_after_demotion": any(r.stats.fused for r in demoted),
        "demoted_identical": demoted_identical,
        "gated_metrics": gated.metrics(),
        "ungated_metrics": ungated.metrics(),
    }


def check_misfusion(rz: dict) -> list[str]:
    """Gate the mis-fusion scenario; returns failures.  The p95 gate runs
    in smoke too: it compares two ENGINE-measured warm serve times whose
    programs differ by orders of magnitude (3-way lookup vs 5-way fused
    dashboard), not wall-clock on a noisy box."""
    fails = []
    gm, um = rz["gated_metrics"], rz["ungated_metrics"]
    if rz["lookup_fused_gated"]:
        fails.append("cost gate OFF: the cheap lookup joined the 5-way "
                     "fusion group under the default disparity")
    if gm["fusion_cost_rejects"] < rz["repeats"]:
        fails.append(f"fusion_cost_rejects={gm['fusion_cost_rejects']} < "
                     f"{rz['repeats']} — the gate is not counting its "
                     "rejections")
    if not rz["bigs_fused_gated"]:
        fails.append("the two cost-compatible dashboards did not fuse "
                     "under the gate — banding is over-rejecting")
    if not rz["lookup_fused_ungated"]:
        fails.append("premise broken: the ungated baseline did not fuse "
                     "the lookup into the big program")
    if um["fusion_cost_rejects"] != 0:
        fails.append(f"ungated baseline counted "
                     f"{um['fusion_cost_rejects']} cost rejects — "
                     "disparity=inf must disable the gate")
    if not rz["identical"]:
        fails.append("gated answers differ from the ungated baseline — "
                     "admission policy must never change results")
    fa = rz["rejection"]
    if fa is None or fa.get("admitted") or "disparity" not in \
            str(fa.get("reason", "")):
        fails.append("explain() does not name the cost disparity for the "
                     f"rejected lookup (got {fa!r})")
    if rz["gated_p95_s"] >= rz["ungated_p95_s"]:
        fails.append(f"gated lookup p95 {rz['gated_p95_s'] * 1e3:.3f} ms "
                     f"not below ungated {rz['ungated_p95_s'] * 1e3:.3f} "
                     "ms — banding the lookup out bought nothing")
    if gm["fusion_demotions"] < 1:
        fails.append("forced serve-time regression did not demote the "
                     "fused pair (fusion_demotions=0)")
    if rz["bigs_fused_after_demotion"]:
        fails.append("demoted fusion signature was re-admitted on the "
                     "next batch")
    if not rz["demoted_identical"]:
        fails.append("answers changed after demotion — the feedback loop "
                     "must only re-route, never re-answer")
    return fails


def run_overhead(scale: int = 1000, iters: int = 30, seed: int = 0):
    """Warm hot-path cost of tracing: one traced and one untraced
    service, same query mix, interleaved measurement rounds (drift in
    either direction hits both populations equally).  Returns identity,
    medians, and the traced service's metrics_v2 snapshot — the
    per-stage histograms ``--record`` persists."""
    db, schema = make_tpch_db(scale=scale, seed=seed)
    svc_traced = QueryService(db, schema, tracing=True)
    svc_plain = QueryService(db, schema, tracing=False)
    sqls = [sql for _, sql in DISTINCT_QUERIES]
    answers = {}
    for svc in (svc_traced, svc_plain):          # cold pass: warm caches
        answers[id(svc)] = [svc.submit(sql).values for sql in sqls]
    identical = all(
        _values_equal(a, b) for a, b in zip(answers[id(svc_traced)],
                                            answers[id(svc_plain)]))

    lat = {id(svc_traced): [], id(svc_plain): []}
    for _ in range(iters):
        for svc in (svc_plain, svc_traced):      # interleaved rounds
            for sql in sqls:
                t0 = time.perf_counter()
                svc.submit(sql)
                lat[id(svc)].append(time.perf_counter() - t0)
    traced_s = float(np.median(lat[id(svc_traced)]))
    plain_s = float(np.median(lat[id(svc_plain)]))
    v2 = svc_traced.metrics_v2()
    return {
        "iters": iters,
        "identical": identical,
        "traced_median_s": traced_s,
        "untraced_median_s": plain_s,
        "overhead_frac": traced_s / plain_s - 1.0 if plain_s > 0 else 0.0,
        "histograms": v2["histograms"],
        "metrics": svc_traced.metrics(),
    }


def check_overhead(ro: dict) -> list[str]:
    """Gate the observability scenario: identity always; the overhead
    budget with an absolute floor so µs-level timer noise on tiny
    tables cannot flake CI."""
    fails = []
    if not ro["identical"]:
        fails.append("traced answers differ from tracing=False answers")
    budget = (ro["untraced_median_s"] * (1.0 + TRACING_OVERHEAD_FRAC)
              + TRACING_OVERHEAD_FLOOR_S)
    if ro["traced_median_s"] > budget:
        fails.append(
            f"tracing overhead: warm median {ro['traced_median_s'] * 1e3:.3f}"
            f" ms traced vs {ro['untraced_median_s'] * 1e3:.3f} ms untraced "
            f"(> {TRACING_OVERHEAD_FRAC:.0%} + "
            f"{TRACING_OVERHEAD_FLOOR_S * 1e3:.1f} ms floor)")
    for stage in ("parse", "plan", "pad", "compile", "run", "request"):
        h = ro["histograms"].get(stage)
        if h is None or h["count"] < 1:
            fails.append(f"traced service recorded no '{stage}' histogram")
        elif not all(k in h for k in ("p50_s", "p95_s", "p99_s")):
            fails.append(f"'{stage}' histogram lacks p50/p95/p99")
    return fails


# ---- restart scenario: cross-process warm start ----------------------------
# Two successive processes over one cache_dir: the cold child plans,
# compiles and persists; the warm child must serve the same mix from disk —
# zero plan rebuilds, XLA binaries from the persistent compilation cache,
# bitwise-identical answers.  Both phases run as real subprocesses so each
# starts with an empty in-process JAX executable cache (the thing
# persistence exists to survive).


def _encode_values(values: dict) -> dict:
    """QueryResult.values → a JSON-able, bitwise-comparable form."""
    def enc(v):
        a = np.asarray(v)
        return {"dtype": str(a.dtype), "shape": list(a.shape),
                "hex": a.tobytes().hex()}

    out = {}
    for k, v in values.items():
        out[k] = {c: enc(a) for c, a in v.items()} if k == "groups" \
            else enc(v)
    return out


def run_restart_child(cache_dir: str, scale: int, seed: int) -> dict:
    """One serving process's life: start, build the db, serve the distinct
    query mix once, report wall-clock + answers + metrics as JSON on
    stdout (the parent compares cold vs warm)."""
    t0 = time.perf_counter()
    db, schema = make_tpch_db(scale=scale, seed=seed)
    svc = QueryService(db, schema, cache_dir=cache_dir)
    answers = {}
    for name, sql in DISTINCT_QUERIES:
        answers[name] = _encode_values(svc.submit(sql).values)
    wall_s = time.perf_counter() - t0
    # gating-decision digest: the machine-readable planning trace per
    # query (explain re-serves from the warm caches — no extra builds).
    # Cold computes stats and persists them; warm must install the same
    # numbers from the store and reach every gate decision identically.
    decisions = {name: svc.explain(sql)["decisions"]
                 for name, sql in DISTINCT_QUERIES}
    m = svc.metrics()
    return {"wall_s": wall_s, "answers": answers,
            "decisions": decisions,
            "plan_builds": m["plan_builds"],
            "compiles": m["compiles"],
            "compile_s_total": m["compile_s_total"],
            "stat_refreshes": m["stat_refreshes"],
            "stats_persist_hits": m["stats_persist_hits"],
            "stats_persist_writes": m["stats_persist_writes"],
            "persist_hits": m["persist_hits"],
            "persist_misses": m["persist_misses"],
            "persist_writes": m["persist_writes"],
            "persist_corrupt_skipped": m["persist_corrupt_skipped"]}


def _spawn_restart_child(cache_dir: str, scale: int, seed: int) -> dict:
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--restart-child",
         cache_dir, "--scale", str(scale), "--seed", str(seed)],
        capture_output=True, text=True, env=env, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(f"restart child failed:\n{proc.stderr[-2000:]}")
    # the JSON report is the last non-empty stdout line (jax may chat above)
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run_restart(scale: int = 1000, seed: int = 0,
                cache_dir: str | None = None) -> dict:
    own_dir = cache_dir is None
    cache_dir = cache_dir or tempfile.mkdtemp(prefix="serving-warm-cache-")
    try:
        cold = _spawn_restart_child(cache_dir, scale, seed)
        warm = _spawn_restart_child(cache_dir, scale, seed)
    finally:
        if own_dir:          # plans + XLA binaries: don't accrete in /tmp
            shutil.rmtree(cache_dir, ignore_errors=True)
    return {"queries": len(DISTINCT_QUERIES), "cache_dir": cache_dir,
            "cold": cold, "warm": warm}


def check_restart(rr: dict) -> list[str]:
    """Gate the restart scenario's counters + identity; returns failures.
    (The compile-time and wall-clock gates are applied by the timed run
    only — smoke asserts no measured-time properties.)"""
    fails = []
    cold, warm = rr["cold"], rr["warm"]
    n = rr["queries"]
    if cold["persist_writes"] != n:
        fails.append(f"cold process persisted {cold['persist_writes']} "
                     f"plans, expected {n}")
    if warm["plan_builds"] != 0:
        fails.append(f"warm process rebuilt {warm['plan_builds']} plans — "
                     "the persistent store is not warm-starting planning")
    if warm["persist_hits"] != n:
        fails.append(f"warm persist_hits={warm['persist_hits']} != {n} "
                     "distinct fingerprints")
    if warm["answers"] != cold["answers"]:
        fails.append("warm-started answers are not bitwise-identical to "
                     "the cold process")
    if cold["stat_refreshes"] == 0 or cold["stats_persist_writes"] == 0:
        fails.append("cold process computed no table statistics "
                     f"(stat_refreshes={cold['stat_refreshes']}, "
                     f"writes={cold['stats_persist_writes']}) — the "
                     "calibration layer is not running")
    if warm["stat_refreshes"] != 0:
        fails.append(f"warm process recomputed {warm['stat_refreshes']} "
                     "table statistics — the stats store is not "
                     "warm-starting calibration")
    if warm["stats_persist_hits"] == 0:
        fails.append("warm process loaded zero persisted statistics")
    if warm["decisions"] != cold["decisions"]:
        fails.append("warm gating decisions differ from cold — persisted "
                     "stats did not reproduce the planning trace")
    return fails


# ---- mesh scenario: serving beyond one device ------------------------------
# A database 4× larger than any other scenario, sharded row-wise over an
# 8-device mesh behind the SAME QueryService surface.  Runs in a
# subprocess because the fake host device count must be fixed before jax
# initialises (XLA_FLAGS), like the tests' differential helpers.  The
# single-device reference uses min_bucket = 8 × the mesh's min_bucket:
# for a power-of-two shard count, sharded per-shard buckets and one big
# local bucket round to IDENTICAL global capacities, so mesh answers must
# match the local service to the bit.

MESH_DEVICES = 8
MESH_SCALE_FACTOR = 4    # mesh db is 4× the other scenarios' scale
MESH_MIN_BUCKET = 8


def run_mesh_child(cache_dir: str, scale: int, seed: int) -> dict:
    """One mesh serving process: shard the db over all devices, serve the
    distinct mix individually + fused, grow a relation within its
    per-shard bucket, and report answers/counters as JSON on stdout."""
    if jax.device_count() != MESH_DEVICES:
        raise RuntimeError(f"expected {MESH_DEVICES} devices, got "
                           f"{jax.device_count()} (XLA_FLAGS not set?)")
    t0 = time.perf_counter()
    db, schema = make_tpch_db(scale=scale, seed=seed)
    mesh = jax.make_mesh((MESH_DEVICES,), ("data",))
    svc = QueryService(db, schema, mesh=mesh, cache_dir=cache_dir,
                       min_bucket=MESH_MIN_BUCKET)
    # identically-padded single-device reference (no cache_dir: its
    # store partition would be separate anyway — see topology keys)
    ref = QueryService(db, schema,
                       min_bucket=MESH_MIN_BUCKET * MESH_DEVICES)

    answers, ref_answers = {}, {}
    for name, sql in DISTINCT_QUERIES:
        r = svc.submit(sql)
        if r.error is not None:
            raise RuntimeError(f"{name} failed on mesh: {r.error!r}")
        answers[name] = _encode_values(r.values)
        ref_answers[name] = _encode_values(ref.submit(sql).values)
    fused = svc.submit_many([sql for _, sql in DISTINCT_QUERIES])
    fused_answers = {name: _encode_values(r.values)
                     for (name, _), r in zip(DISTINCT_QUERIES, fused)}
    wall_s = time.perf_counter() - t0

    # within-bucket growth on the sharded service: zero recompiles, and
    # the answers keep tracking the reference bit-for-bit
    compiles_before = svc.metrics()["compiles"]
    tab = db["partsupp"]
    rng = np.random.default_rng(seed + 1)
    extra = MESH_DEVICES * 4
    cols = {}
    for cname, col in tab.columns.items():
        base = np.asarray(col)
        cols[cname] = np.concatenate(
            [base, base[rng.integers(0, len(base), extra)]])
    grown = Table.from_numpy(cols)
    svc.update_table("partsupp", grown)
    ref.update_table("partsupp", grown)
    growth_identical = _values_equal(svc.submit(COSTLY_PARTS).values,
                                     ref.submit(COSTLY_PARTS).values)

    m = svc.metrics()
    gauges = svc.metrics_v2()["gauges"]
    return {"wall_s": wall_s, "scale": scale,
            "answers": answers, "ref_answers": ref_answers,
            "fused_answers": fused_answers,
            "growth_rows": extra,
            "growth_recompiles": m["compiles"] - compiles_before,
            "growth_identical": growth_identical,
            "plan_builds": m["plan_builds"],
            "compiles": m["compiles"],
            "persist_hits": m["persist_hits"],
            "persist_writes": m["persist_writes"],
            "mesh_devices": gauges.get("mesh_devices", 0),
            "mesh_shards": gauges.get("mesh_shard_count_data", 0)}


def _spawn_mesh_child(cache_dir: str, scale: int, seed: int) -> dict:
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{MESH_DEVICES}")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--mesh-child",
         cache_dir, "--scale", str(scale), "--seed", str(seed)],
        capture_output=True, text=True, env=env, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(f"mesh child failed:\n{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def run_mesh(scale: int = 1000, seed: int = 0) -> dict:
    """Cold + warm mesh serving processes over one cache_dir, at
    ``MESH_SCALE_FACTOR ×`` the surrounding benchmark's scale."""
    mesh_scale = scale * MESH_SCALE_FACTOR
    cache_dir = tempfile.mkdtemp(prefix="serving-mesh-cache-")
    try:
        cold = _spawn_mesh_child(cache_dir, mesh_scale, seed)
        warm = _spawn_mesh_child(cache_dir, mesh_scale, seed)
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)
    return {"queries": len(DISTINCT_QUERIES), "scale": mesh_scale,
            "cold": cold, "warm": warm}


def check_mesh(rx: dict) -> list[str]:
    """Gate the mesh scenario; returns failures."""
    fails = []
    cold, warm = rx["cold"], rx["warm"]
    if cold["mesh_devices"] != MESH_DEVICES \
            or cold["mesh_shards"] != MESH_DEVICES:
        fails.append(f"mesh gauges report {cold['mesh_devices']} devices / "
                     f"{cold['mesh_shards']} shards, expected "
                     f"{MESH_DEVICES}")
    if cold["answers"] != cold["ref_answers"]:
        fails.append("mesh answers differ bitwise from the identically-"
                     "padded single-device service")
    if cold["fused_answers"] != cold["answers"]:
        fails.append("fused mesh answers differ from individual mesh "
                     "serving")
    if cold["growth_recompiles"] != 0:
        fails.append(f"within-bucket growth on the mesh caused "
                     f"{cold['growth_recompiles']} recompiles")
    if not cold["growth_identical"]:
        fails.append("post-growth mesh answers diverged from the "
                     "reference")
    if warm["plan_builds"] != 0:
        fails.append(f"warm mesh process rebuilt {warm['plan_builds']} "
                     "plans — the store's topology partition is not "
                     "warm-starting")
    if warm["persist_hits"] != rx["queries"]:
        fails.append(f"warm mesh persist_hits={warm['persist_hits']} != "
                     f"{rx['queries']} distinct fingerprints")
    if warm["answers"] != cold["answers"]:
        fails.append("warm mesh answers are not bitwise-identical to the "
                     "cold process")
    return fails


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test scale (CI)")
    ap.add_argument("--smoke", action="store_true",
                    help="fused scenario only, counter assertions, no "
                         "timing gates (what scripts/verify.sh runs)")
    ap.add_argument("--scale", type=int, default=None)
    ap.add_argument("--warm-iters", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--restart-child", metavar="CACHE_DIR", default=None,
                    help="internal: run one restart-scenario serving "
                         "process against CACHE_DIR and print its JSON "
                         "report")
    ap.add_argument("--mesh-child", metavar="CACHE_DIR", default=None,
                    help="internal: run one mesh serving process (needs "
                         "XLA_FLAGS forcing 8 host devices) against "
                         "CACHE_DIR and print its JSON report")
    ap.add_argument("--record", nargs="?", const="BENCH_serving.json",
                    default=None, metavar="PATH",
                    help="write a schema-versioned perf trajectory "
                         "(rows + per-stage latency histograms; default "
                         "PATH: BENCH_serving.json)")
    args = ap.parse_args(argv)
    tiny = args.tiny or args.smoke
    scale = args.scale or (50 if tiny else 1000)
    warm_iters = args.warm_iters or (8 if tiny else 25)

    jax.config.update("jax_platform_name", "cpu")

    if args.restart_child is not None:
        print(json.dumps(run_restart_child(args.restart_child, scale,
                                           args.seed)))
        return 0
    if args.mesh_child is not None:
        print(json.dumps(run_mesh_child(args.mesh_child, scale,
                                        args.seed)))
        return 0

    from benchmarks.recorder import Recorder
    rec = Recorder("serving", path=args.record)
    rec.add_meta(scale=scale, tiny=tiny, smoke=args.smoke, seed=args.seed)

    rf = run_fused(scale=scale, repeats=2 if tiny else 3)
    m = rf["fused_metrics"]
    print(f"fused dashboard   {rf['queries']} distinct queries × "
          f"{rf['repeats']} rounds")
    print(f"  individual      {rf['solo_s'] * 1e3:>10.1f} ms "
          f"({rf['solo_compiles']} compiles)")
    print(f"  fused           {rf['fused_s'] * 1e3:>10.1f} ms "
          f"({rf['fused_compiles']} compiles)")
    print(f"  identical={rf['identical']} "
          f"fused_batches={m['fused_batches']} "
          f"fused_queries={m['fused_queries']} "
          f"partial_fusions={m['partial_fusions']} "
          f"subplan_saved={m['subplan_saved']} "
          f"fused cache {m['fused_hits']}/{m['fused_hits'] + m['fused_misses']} hit")
    per_q = rf["queries"] * rf["repeats"]
    rec.row("serving.fused.individual", rf["solo_s"] / per_q * 1e6,
            f"compiles={rf['solo_compiles']}")
    rec.row("serving.fused.fused", rf["fused_s"] / per_q * 1e6,
            f"compiles={rf['fused_compiles']};"
            f"subplan_saved={m['subplan_saved']}")
    fused_fails = check_fused(rf)
    if not args.smoke and rf["fused_s"] >= rf["solo_s"]:
        fused_fails.append(f"fused wall {rf['fused_s']:.3f}s not below "
                           f"individual {rf['solo_s']:.3f}s")

    rm = run_mixed(scale=scale, repeats=2 if tiny else 3)
    mm = rm["fused_metrics"]
    print(f"mixed join shapes {rm['queries']} queries, "
          f"{rm['distinct_prefixes']} distinct whole prefixes "
          f"(whole-prefix fusion: zero) × {rm['repeats']} rounds")
    print(f"  individual      {rm['solo_s'] * 1e3:>10.1f} ms "
          f"({rm['solo_compiles']} compiles)")
    print(f"  fused           {rm['fused_s'] * 1e3:>10.1f} ms "
          f"({rm['fused_compiles']} compiles)")
    print(f"  identical={rm['identical']} "
          f"partial_fusions={mm['partial_fusions']} "
          f"subplan_saved={mm['subplan_saved']}")
    per_q = rm["queries"] * rm["repeats"]
    rec.row("serving.mixed.individual", rm["solo_s"] / per_q * 1e6,
            f"compiles={rm['solo_compiles']}")
    rec.row("serving.mixed.fused", rm["fused_s"] / per_q * 1e6,
            f"compiles={rm['fused_compiles']};"
            f"partial_fusions={mm['partial_fusions']}")
    fused_fails += check_mixed(rm)
    if not args.smoke and rm["fused_s"] >= rm["solo_s"]:
        fused_fails.append(f"mixed-shape fused wall {rm['fused_s']:.3f}s "
                           f"not below individual {rm['solo_s']:.3f}s")

    ra = run_async(scale=scale, threads=8)
    ma = ra["metrics"]
    print(f"concurrent callers {ra['threads']} threads × 1 query "
          f"({ra['distinct']} distinct fingerprints)")
    print(f"  serial          {ra['serial_s'] * 1e3:>10.1f} ms "
          f"({ra['serial_compiles']} compiles)")
    print(f"  async batched   {ra['async_s'] * 1e3:>10.1f} ms "
          f"({ma['compiles']} compiles, "
          f"{ma['async_batches']} async batches)")
    print(f"  identical={ra['identical']} "
          f"async_requests={ma['async_requests']} "
          f"queue_depth_peak={ma['queue_depth_peak']} "
          f"rejected={ma['rejected']} "
          f"bad-query isolated={ra['bad_error'] is not None and ra['good_ok']}")
    rec.row("serving.async.serial", ra["serial_s"] / ra["threads"] * 1e6,
            f"compiles={ra['serial_compiles']}")
    rec.row("serving.async.batched", ra["async_s"] / ra["threads"] * 1e6,
            f"compiles={ma['compiles']};batches={ma['async_batches']};"
            f"queue_depth_peak={ma['queue_depth_peak']}")
    fused_fails += check_async(ra)

    rt = run_multitenant(scale=scale, rounds=4 if tiny else 6,
                         seed=args.seed)
    vt, ft = rt["tenants"]["victim"], rt["tenants"]["flood"]
    print(f"multi-tenant mix  victim {rt['rounds']}×"
          f"{rt['victim_queries']} dashboard queries vs a flooding "
          f"tenant ({rt['flood_client']['submitted']} attempts)")
    print(f"  victim p95      {rt['mixed_p95_s'] * 1e3:>10.1f} ms under "
          f"flood vs {rt['solo_p95_s'] * 1e3:.1f} ms solo "
          f"(identical={rt['victim_identical']}, errors={vt['errors']})")
    print(f"  flood held to   {ft['requests']:>10d} served "
          f"(rejected {ft['rejected']}: rate={ft['rejected_rate']} "
          f"depth={ft['rejected_depth']}; errors={ft['errors']} isolated)")
    print(f"  cross-tenant    {rt['xt_requests']:>10d} requests / "
          f"{rt['xt_distinct']} fingerprints over 4 tenants → "
          f"{rt['xt_metrics']['compiles']} compiles "
          f"(fused_queries={rt['xt_metrics']['fused_queries']}, "
          f"identical={rt['xt_identical']})")
    rec.row("serving.tenant.victim_solo", rt["solo_p95_s"] * 1e6,
            "p95;victim alone")
    rec.row("serving.tenant.victim_flooded", rt["mixed_p95_s"] * 1e6,
            f"p95;flood_rejected={ft['rejected']};"
            f"flood_served={ft['requests']}")
    fused_fails += check_multitenant(rt)

    rz = run_misfusion(scale=scale, repeats=3 if tiny else 5,
                       seed=args.seed)
    zg, zu = rz["gated_metrics"], rz["ungated_metrics"]
    print(f"mis-fusion gate   1 cheap lookup + {rz['queries'] - 1} "
          f"overlapping 5-way dashboards × {rz['repeats']} rounds")
    print(f"  gated lookup    {rz['gated_p95_s'] * 1e6:>10.1f} us p95 "
          f"(cost_rejects={zg['fusion_cost_rejects']}, "
          f"bigs fused={rz['bigs_fused_gated']})")
    print(f"  ungated lookup  {rz['ungated_p95_s'] * 1e6:>10.1f} us p95 "
          f"(disparity=inf: lookup fused={rz['lookup_fused_ungated']})")
    print(f"  identical={rz['identical']} "
          f"demotions={zg['fusion_demotions']} "
          f"refused-after-demotion={not rz['bigs_fused_after_demotion']}")
    rec.row("serving.misfusion.gated", rz["gated_p95_s"] * 1e6,
            f"cost_rejects={zg['fusion_cost_rejects']};"
            f"demotions={zg['fusion_demotions']}")
    rec.row("serving.misfusion.ungated", rz["ungated_p95_s"] * 1e6,
            f"disparity=inf;rejects={zu['fusion_cost_rejects']}")
    fused_fails += check_misfusion(rz)

    rr = run_restart(scale=scale, seed=args.seed)
    cold, warm = rr["cold"], rr["warm"]
    print(f"restart warm start {rr['queries']} distinct queries, "
          f"cache_dir={rr['cache_dir']}")
    print(f"  cold process    {cold['wall_s'] * 1e3:>10.1f} ms "
          f"(plan_builds={cold['plan_builds']}, "
          f"compile_s={cold['compile_s_total'] * 1e3:.1f} ms, "
          f"persist_writes={cold['persist_writes']})")
    print(f"  warm process    {warm['wall_s'] * 1e3:>10.1f} ms "
          f"(plan_builds={warm['plan_builds']}, "
          f"compile_s={warm['compile_s_total'] * 1e3:.1f} ms, "
          f"persist_hits={warm['persist_hits']})")
    print(f"  identical={warm['answers'] == cold['answers']} "
          f"stat_refreshes cold={cold['stat_refreshes']} "
          f"warm={warm['stat_refreshes']} "
          f"decisions-identical={warm['decisions'] == cold['decisions']}")
    rec.row("serving.restart.cold", cold["wall_s"] * 1e6,
            f"plan_builds={cold['plan_builds']};"
            f"persist_writes={cold['persist_writes']}")
    rec.row("serving.restart.warm", warm["wall_s"] * 1e6,
            f"plan_builds={warm['plan_builds']};"
            f"persist_hits={warm['persist_hits']}")
    fused_fails += check_restart(rr)
    # timing gates (timed run only; --smoke asserts counters + identity):
    # the persistent XLA cache must cut compile time, and the whole warm
    # start must beat the cold one on wall-clock
    if not args.smoke:
        if warm["compile_s_total"] >= max(cold["compile_s_total"], 1e-9):
            fused_fails.append(
                f"warm compile_s_total {warm['compile_s_total']:.3f}s not "
                f"below cold {cold['compile_s_total']:.3f}s — the "
                "persistent XLA compilation cache is not being hit")
        if warm["wall_s"] >= cold["wall_s"]:
            fused_fails.append(
                f"warm-start wall {warm['wall_s']:.2f}s not below cold "
                f"{cold['wall_s']:.2f}s")

    ro = run_overhead(scale=scale, iters=20 if tiny else 30,
                      seed=args.seed)
    print(f"tracing overhead  warm median "
          f"{ro['traced_median_s'] * 1e3:.3f} ms traced vs "
          f"{ro['untraced_median_s'] * 1e3:.3f} ms untraced "
          f"({ro['overhead_frac']:+.1%}), identical={ro['identical']}, "
          f"{len(ro['histograms'])} stage histograms")
    rec.row("serving.tracing.on", ro["traced_median_s"] * 1e6,
            f"overhead={ro['overhead_frac']:+.3%}")
    rec.row("serving.tracing.off", ro["untraced_median_s"] * 1e6,
            "baseline")
    rec.add_histograms(ro["histograms"])
    rec.add_metrics(ro["metrics"])
    fused_fails += check_overhead(ro)

    rx = run_mesh(scale=scale, seed=args.seed)
    cold, warm = rx["cold"], rx["warm"]
    print(f"mesh serving      {rx['queries']} distinct queries at scale="
          f"{rx['scale']} ({MESH_SCALE_FACTOR}× everything above) over "
          f"{cold['mesh_devices']} devices")
    print(f"  cold process    {cold['wall_s'] * 1e3:>10.1f} ms "
          f"(plan_builds={cold['plan_builds']}, "
          f"compiles={cold['compiles']}, "
          f"persist_writes={cold['persist_writes']})")
    print(f"  warm process    {warm['wall_s'] * 1e3:>10.1f} ms "
          f"(plan_builds={warm['plan_builds']}, "
          f"persist_hits={warm['persist_hits']})")
    print(f"  bitwise-vs-local={cold['answers'] == cold['ref_answers']} "
          f"fused-identical={cold['fused_answers'] == cold['answers']} "
          f"growth +{cold['growth_rows']} rows → "
          f"{cold['growth_recompiles']} recompiles")
    rec.row("serving.mesh.cold", cold["wall_s"] * 1e6,
            f"scale={rx['scale']};devices={cold['mesh_devices']};"
            f"plan_builds={cold['plan_builds']}")
    rec.row("serving.mesh.warm", warm["wall_s"] * 1e6,
            f"plan_builds={warm['plan_builds']};"
            f"persist_hits={warm['persist_hits']}")
    fused_fails += check_mesh(rx)

    if args.smoke:
        rec.finish()
        for f in fused_fails:
            print(f"FAIL: {f}")
        print("PASS" if not fused_fails else "FAIL")
        return 0 if not fused_fails else 1

    r = run(scale=scale, warm_iters=warm_iters)

    print(f"serving benchmark  scale={r['scale']}")
    print(f"{'query':16s} {'cold (ms)':>10s} {'speedup':>9s}")
    for name, s in r["cold_s"].items():
        sp = r["speedup_per_query"][name]
        print(f"{name:16s} {s * 1e3:>10.1f} {sp:>8.1f}x")
    print(f"warm median       {r['warm_median_s'] * 1e3:>10.2f} ms")
    print(f"warm p99          {r['warm_p99_s'] * 1e3:>10.2f} ms")
    print(f"throughput        {r['throughput_qps']:>10.0f} qps")
    print(f"batched           {r['batched_qps']:>10.0f} qps")
    print(f"cold/warm speedup {r['speedup']:>10.1f}x (min per-query)")
    print(f"eager fallback    {r['eager_s'] * 1e3:>10.1f} ms "
          f"(mode={r['eager_mode']}, never amortises)")
    print(f"growth rows       {r['growth_rows']:>10d} "
          f"(recompiles={r['growth_recompiles']})")
    m = r["metrics"]
    print(f"cache: plan {m['plan_hits']}/{m['plan_hits'] + m['plan_misses']}"
          f" hit, exec {m['exec_hits']}/{m['exec_hits'] + m['exec_misses']}"
          f" hit, compiles={m['compiles']}, "
          f"dedup_saved={m['dedup_saved']}")
    rec.row("serving.warm.median", r["warm_median_s"] * 1e6,
            f"p99_us={r['warm_p99_s'] * 1e6:.1f}")
    rec.row("serving.throughput", 1e6 / max(r["throughput_qps"], 1e-9),
            f"qps={r['throughput_qps']:.0f};batched_qps="
            f"{r['batched_qps']:.0f}")
    rec.row("serving.eager", r["eager_s"] * 1e6,
            f"mode={r['eager_mode']}")
    rec.add_metrics(m)
    rec.finish()

    ok = True
    if r["speedup"] < 10:
        print(f"FAIL: warm-cache speedup {r['speedup']:.1f}x < 10x")
        ok = False
    if r["growth_recompiles"] != 0:
        print(f"FAIL: same-bucket growth caused "
              f"{r['growth_recompiles']} recompiles")
        ok = False
    for f in fused_fails:
        print(f"FAIL: {f}")
        ok = False
    print("PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
