"""Paper Fig. 6: peak materialised/live tuples per plan class.

The paper's headline systems metric: Opt⁺ never materialises a tuple
beyond the largest base relation; Ref blows up by orders of magnitude;
Opt sits in between (pairwise joins materialise, then regroup).
"""

from __future__ import annotations

import jax

from repro.core import Executor, MaterialisationLimit, plan_query
from repro.data import make_graph_db, make_stats_db, path_query
from repro.data.relational import stats_count_query

OOM_GUARD = 50_000_000


def peak_tuples(ex, db, schema, q, mode):
    try:
        stats = ex.execute(plan_query(q, schema, mode=mode))["__stats__"]
        return stats.peak_tuples
    except MaterialisationLimit:
        return None  # exceeded guard (reported as > guard)


def run():
    rows = []
    with jax.experimental.enable_x64():
        db, schema = make_graph_db(5_000, 60_000, seed=2)
        ex = Executor(db, schema, freq_dtype="int64", oom_guard=OOM_GUARD)
        base_max = max(int(t.live_count()) for t in db.values())
        for k in (2, 3, 4):
            q = path_query(k)
            row = {"query": f"path-{k:02d}", "base_max": base_max}
            for mode in ("ref", "opt", "opt_plus"):
                row[mode] = peak_tuples(ex, db, schema, q, mode)
            rows.append(row)

        sdb, sschema = make_stats_db(n_users=5_000, n_posts=20_000,
                                     n_comments=100_000, n_votes=60_000)
        sex = Executor(sdb, sschema, freq_dtype="int64",
                       oom_guard=OOM_GUARD)
        base_max = max(int(t.live_count()) for t in sdb.values())
        q = stats_count_query()
        row = {"query": "stats-full", "base_max": base_max}
        for mode in ("ref", "opt", "opt_plus"):
            row[mode] = peak_tuples(sex, sdb, sschema, q, mode)
        rows.append(row)
    return rows


def main():
    rows = run()
    print(f"{'query':12s} {'base-max':>10s} {'Ref':>12s} {'Opt':>12s} "
          f"{'Opt+':>10s}")
    ok = True
    for r in rows:
        ref = str(r["ref"]) if r["ref"] is not None else f">{OOM_GUARD}"
        opt = str(r["opt"]) if r["opt"] is not None else f">{OOM_GUARD}"
        print(f"{r['query']:12s} {r['base_max']:>10d} {ref:>12s} "
              f"{opt:>12s} {r['opt_plus']:>10d}")
        # the paper's invariant: Opt+ peak == largest scanned relation
        ok &= r["opt_plus"] <= r["base_max"]
    print(f"Opt+ ≤ max base relation: {'OK' if ok else 'VIOLATED'}")
    return rows


if __name__ == "__main__":
    main()
