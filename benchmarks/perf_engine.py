"""§Perf hillclimb — engine cell (the paper-representative workload).

Measures the hypothesis→change ladder on the frequency-propagation
queries where the baseline engine LOST to Ref (EXPERIMENTS §Repro):

  it0  baseline         — paper-faithful: per-edge child sort + pregroup
  it1  +dense-domain    — sort-free scatter-add FreqJoin when the packed
                          key domain is known (embedding-grad pattern)

and on the distributed ring (8 fake devices, subprocess-launched by the
caller when XLA_FLAGS allows):

  it2  ring presort     — sort each child shard once, rotate (keys,
                          prefix) instead of re-sorting every ring step

Run:  PYTHONPATH=src python -m benchmarks.perf_engine
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import Executor, plan_query
from repro.data import make_graph_db, make_stats_db, make_tpch_db, path_query
from repro.data.relational import stats_count_query, tpch_v1_query


def _time(fn, repeats=5):
    fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_local():
    rows = []
    with jax.experimental.enable_x64():
        cases = []
        db, schema = make_tpch_db(scale=5000, seed=0)
        cases.append(("tpch-v1-median", db, schema, tpch_v1_query("median")))
        sdb, sschema = make_stats_db(n_users=20_000, n_posts=100_000,
                                     n_comments=400_000, n_votes=250_000)
        cases.append(("stats-q4-count", sdb, sschema, stats_count_query()))
        gdb, gschema = make_graph_db(20_000, 200_000, seed=0)
        cases.append(("path-05-count", gdb, gschema, path_query(5)))

        for name, db_, schema_, q in cases:
            plan = plan_query(q, schema_, mode="opt_plus")
            row = {"query": name}
            for label, dense in (("baseline", False), ("dense_domain", True)):
                ex = Executor(db_, schema_, freq_dtype="float64",
                              dense_domain=dense)
                fn = ex.compile(plan)

                def run():
                    out = fn(db_)
                    jax.block_until_ready(list(out.values()))
                    return out

                row[label] = _time(run)
                row[f"{label}_result"] = float(
                    next(v for k, v in run().items() if k != "__stats__"))
            # results must agree exactly
            assert row["baseline_result"] == row["dense_domain_result"], row
            row["speedup"] = row["baseline"] / row["dense_domain"]
            rows.append(row)
            # Ref comparison (eager numpy baseline)
            try:
                ex = Executor(db_, schema_, freq_dtype="float64",
                              oom_guard=20_000_000)
                row["ref"] = _time(
                    lambda: ex.execute(plan_query(q, schema_, mode="ref")),
                    repeats=1)
            except Exception:  # noqa: BLE001
                row["ref"] = None
    return rows


def main():
    rows = bench_local()
    print(f"{'query':18s} {'Ref':>9s} {'it0 base':>9s} {'it1 dense':>10s} "
          f"{'it1/it0':>8s} {'vs Ref':>8s}")
    for r in rows:
        ref = f"{r['ref']:.3f}" if r.get("ref") else "X"
        vs = (f"{r['ref'] / r['dense_domain']:.2f}x" if r.get("ref")
              else "inf")
        print(f"{r['query']:18s} {ref:>9s} {r['baseline']:9.3f} "
              f"{r['dense_domain']:10.3f} {r['speedup']:7.2f}x {vs:>8s}")
    return rows


if __name__ == "__main__":
    main()
