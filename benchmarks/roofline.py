"""Roofline analysis per (arch × shape) on the single-pod mesh (§Roofline).

Terms (seconds, per device, per step):

    compute    = HLO_FLOPs / PEAK_FLOPS
    memory     = HLO_bytes / HBM_BW
    collective = Σ collectives bytes_moved / ICI_BW

`cost_analysis` counts a `while` body once, so scanned layers/microbatches
would be undercounted by ~L·M.  We therefore lower *probe* models at
depths L∈{0,1,2} with EVERY scan fully unrolled (probe compiles stay small
because at most 2 layers of chunk bodies ever unroll) and compose:

    f(0) = embed+head(+loss/grads)          — per microbatch/pass
    F_layer  = f(1) − f(0)                  — one block, fwd(+bwd)
    F_shared = f(1) − f(0) − F_layer_mamba  — hybrid only, where
               F_layer_mamba = f(2) − f(1)  (L=2 ⇒ 1 shared + 2 mamba)
    per_pass = f(0) + L·F_layer [+ apps·F_shared]
    train:   total = M·per_pass + analytic optimizer tail
             (opt flops ≈ 15·N/dev, opt bytes ≈ 56·N/dev B, no collectives
             — state is sharded identically to params)
    serve:   total = per_pass

Collective bytes come from the partitioned HLO text: per-op local shapes ×
ring-transfer factors with the parsed replica-group size.

Hardware model (TPU v5e-class): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s ICI per chip.
"""

import argparse
import dataclasses
import json
import os
import re

from repro.configs import ARCHS, SHAPES, cells_for, get_config
from repro.launch.dryrun import _LOWER
from repro.launch.mesh import make_production_mesh


def _force_host_devices(n: int = 512) -> None:
    """Opt IN to the fake 512-device host platform.  Must run before jax
    initialises its backend, so ``main()`` calls it first thing; merely
    importing this module (e.g. for :func:`collective_seconds`) leaves
    the process's device topology alone."""
    os.environ["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n} "
                               + os.environ.get("XLA_FLAGS", ""))

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}
_OP_RE = re.compile(
    r"= *((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)) *"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n]*")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^}]*\}|\[\d+,\d+\])")


def _group_size(attr_text: str, default: int) -> int:
    m = _GROUPS_RE.search(attr_text)
    if not m:
        return default
    g = m.group(1)
    if g.startswith("[{") or g.startswith("{{"):
        first = g.split("}")[0]
        return max(1, first.count(",") + 1)
    if g.startswith("["):
        dims = [int(x) for x in g.strip("[]").split(",")]
        return dims[1] if len(dims) == 2 else default
    return default


def collective_seconds(hlo: str, n_dev: int) -> tuple[float, dict]:
    """Estimated per-device seconds on the interconnect for ONE pass of
    the HLO text (loop bodies counted once) + per-kind byte totals."""
    moved = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
             "all-to-all": 0.0, "collective-permute": 0.0}
    for m in _OP_RE.finditer(hlo):
        shapes = _SHAPE_RE.findall(m.group(1))
        out_bytes = 0
        for dt, dims in shapes:
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            out_bytes += n * _DTYPE_BYTES[dt]
        kind = m.group(2)
        g = _group_size(m.group(0), n_dev)
        ring = (g - 1) / max(g, 1)
        factor = {"all-gather": ring, "all-reduce": 2 * ring,
                  "reduce-scatter": (g - 1), "all-to-all": ring,
                  "collective-permute": 1.0}[kind]
        moved[kind] += out_bytes * factor
    return sum(moved.values()) / ICI_BW, moved


def _lower_cost(cfg, cell, mesh):
    lowered = _LOWER[cell.kind](cfg, cell, mesh)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll_s, moved = collective_seconds(compiled.as_text(),
                                       mesh.devices.size)
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "coll_s": coll_s, "moved": moved}


def _probe(cfg, cell, mesh, n_layers):
    """Probes fully unroll every scan so cost_analysis sees real trip
    counts (layer bodies AND chunk/KV-block scans)."""
    return _lower_cost(
        dataclasses.replace(cfg, n_layers=n_layers, probe_unroll=True),
        cell, mesh)


def _compose(cfg, cell, probes, n_dev):
    """Scan-aware composition of per-device totals (see module doc)."""
    L = cfg.n_layers
    M = max(1, cell.global_batch // max(cell.microbatch, 1)) \
        if cell.kind == "train" else 1
    n_params_dev = cfg.param_count() / n_dev

    def comb(key):
        f0, f1, f2 = probes[0][key], probes[1][key], probes[2][key]
        if cfg.family == "hybrid":
            from repro.models.model import _hybrid_groups
            f_mamba = f2 - f1                 # L=2: 1 shared + 2 mamba
            f_shared = max(f1 - f0 - f_mamba, 0.0)
            apps = len(_hybrid_groups(cfg))
            per_pass = f0 + apps * f_shared + L * f_mamba
        else:
            f_layer = f1 - f0
            per_pass = f0 + L * f_layer
        per_pass = max(per_pass, 0.0)
        if cell.kind == "train":
            opt_tail = {"flops": 15.0 * n_params_dev,
                        "bytes": 56.0 * n_params_dev,
                        "coll_s": 0.0}[key]
            return M * per_pass + opt_tail
        return per_pass

    return {"flops": comb("flops"), "bytes": comb("bytes"),
            "coll_s": comb("coll_s")}


def model_flops(cfg, cell) -> float:
    """Analytic MODEL_FLOPS for the whole step (all devices)."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch  # decode: one token per row


def run_cell(arch: str, shape: str):
    cfg = get_config(arch)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=False)
    n_dev = mesh.devices.size
    probes = {n: _probe(cfg, cell, mesh, n) for n in (0, 1, 2)}
    tot = _compose(cfg, cell, probes, n_dev)

    compute_s = tot["flops"] / PEAK_FLOPS
    memory_s = tot["bytes"] / HBM_BW
    coll_s = tot["coll_s"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, cell)
    hlo_flops_alldev = tot["flops"] * n_dev
    return {
        "arch": arch, "shape": shape, "mesh": "16x16", "devices": n_dev,
        **{k: float(v) for k, v in terms.items()},
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_per_dev": tot["flops"],
        "useful_flops_ratio": mf / max(hlo_flops_alldev, 1.0),
        "step_time_bound_s": max(terms.values()),
        "roofline_fraction": (mf / n_dev / PEAK_FLOPS)
        / max(max(terms.values()), 1e-12),
    }


def main():
    _force_host_devices()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--out", default="roofline_results.json")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ARCHS)
    rows, failures = [], []
    for arch in archs:
        shapes = [args.shape] if args.shape else cells_for(arch)
        for shape in shapes:
            if shape not in cells_for(arch):
                continue
            try:
                r = run_cell(arch, shape)
                rows.append(r)
                print(f"[roofline] {arch:22s} {shape:12s} "
                      f"C={r['compute_s']:.3e}s M={r['memory_s']:.3e}s "
                      f"N={r['collective_s']:.3e}s → {r['bottleneck']:10s} "
                      f"frac={r['roofline_fraction']:.3f} "
                      f"useful={r['useful_flops_ratio']:.2f}", flush=True)
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape, repr(e)))
                print(f"[roofline] FAIL {arch} {shape}: {e}", flush=True)
    with open(args.out, "w") as f:
        json.dump({"rows": rows, "failures": failures}, f, indent=1)
    print(f"[roofline] {len(rows)} cells → {args.out}")


if __name__ == "__main__":
    main()
