"""Kernel autotuning gate: untuned vs tuned, bitwise-identical, warm.

Three checks, mirroring the serving benchmark's counter gates:

1. **Win gate** — run the measured search (``repro.kernels.autotune``)
   for each kernel at benchmark scale, then time the untuned
   ``DEFAULT_CONFIG`` against the winner over the SAME workload the
   search scored (the sum over the key-domain probe grid for joins).
   The full run asserts a strict speedup on >= 2 of the 3 kernels; the
   ``--smoke`` run prints the ratios but only gates correctness
   (timings at smoke scale are noise).

2. **Bitwise gate** — the tuned config's answers must be EXACTLY the
   untuned answers on every workload, re-checked here independently of
   the search's own per-candidate gate.

3. **Warm-restart gate** — a second ``KernelTuner`` over the same
   ``TuneStore`` directory must resolve every bucket from disk:
   ``tune_searches == 0``, mirroring the plan cache's
   ``plan_builds == 0`` invariant.

Usage::

    PYTHONPATH=src python benchmarks/kernel_tuning.py            # full
    PYTHONPATH=src python benchmarks/kernel_tuning.py --smoke
    PYTHONPATH=src python benchmarks/kernel_tuning.py --smoke \
        --record BENCH_tuning.json   # + schema-versioned trajectory
"""

from __future__ import annotations

import argparse
import os
import sys
import tempfile

# run as `python benchmarks/kernel_tuning.py` (script dir on sys.path,
# repo root not) and as `python -m benchmarks.kernel_tuning`
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np  # noqa: E402

from repro.kernels import ops  # noqa: E402
from repro.kernels.autotune import (  # noqa: E402
    DEFAULT_CONFIG,
    KernelTuner,
    _domain_probes,
    _synth_join,
    _synth_segment,
    bucket_shape,
    measure,
)
from repro.service.tune_store import TuneStore  # noqa: E402

# (kernel, backend, shape) per scale — the backends each kernel is
# actually tuned for: the XLA joins' dense/sort dispatch is what the CPU
# benchmarks time; the pallas segmented sum's block width is searched in
# interpret mode (same-lowering twin of the TPU path).
CASES = {
    "full": [
        ("freq_join", "xla", (1 << 17, 1 << 17)),
        ("semi_join", "xla", (1 << 17, 1 << 17)),
        ("segment_sum", "pallas", (1 << 15,)),
    ],
    "smoke": [
        ("freq_join", "xla", (1 << 12, 1 << 12)),
        ("semi_join", "xla", (1 << 12, 1 << 12)),
        ("segment_sum", "pallas", (1 << 13,)),
    ],
}


def workloads(kernel: str, backend: str, shape):
    """(label, config -> answer) closures — the comparison workload,
    built from public ops only.  Joins get one closure per key-domain
    probe (dispatch-policy wins must hold across the crossover range)."""
    bshape = bucket_shape(*shape)
    if kernel in ("freq_join", "semi_join"):
        mode = "any" if kernel == "semi_join" else "sum"
        out = []
        for dom in _domain_probes(bshape[1]):
            args = _synth_join(bshape, dom)

            def fn(cfg, args=args, dom=dom):
                return ops.freq_join(*args, mode=mode, backend=backend,
                                     domain=dom, config=cfg)

            out.append((f"domain{dom}", fn))
        return out
    keys, vals = _synth_segment(bshape)

    def fn(cfg):
        return ops.segment_sum_sorted(keys, vals, backend=backend,
                                      config=cfg)

    return [("sorted", fn)]


def run_case(tuner: KernelTuner, kernel: str, shape, rec) -> dict:
    """Tune one (kernel, bucket), then compare untuned vs tuned on the
    comparison workload.  Returns {kernel, tuned_is_default, untuned_s,
    tuned_s, speedup, bitwise}."""
    cfg = tuner.ensure(kernel, shape)
    wl = workloads(kernel, tuner.backend, shape)
    untuned_s = tuned_s = 0.0
    bitwise = True
    for label, fn in wl:
        base = fn(DEFAULT_CONFIG)
        got = fn(cfg)
        flat_b = [np.asarray(x) for x in
                  (base if isinstance(base, tuple) else (base,))]
        flat_g = [np.asarray(x) for x in
                  (got if isinstance(got, tuple) else (got,))]
        if not all(np.array_equal(b, g) for b, g in zip(flat_b, flat_g)):
            bitwise = False
        untuned_s += measure(lambda: fn(DEFAULT_CONFIG), tuner.repeats)
        tuned_s += measure(lambda: fn(cfg), tuner.repeats)
    speedup = untuned_s / tuned_s if tuned_s > 0 else float("inf")
    rec.row(f"{kernel}/untuned", untuned_s * 1e6, tuner.backend)
    rec.row(f"{kernel}/tuned", tuned_s * 1e6,
            f"{tuner.backend} speedup={speedup:.2f} cfg={cfg}")
    return {"kernel": kernel, "tuned_is_default": cfg == DEFAULT_CONFIG,
            "untuned_s": untuned_s, "tuned_s": tuned_s,
            "speedup": speedup, "bitwise": bitwise}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes; gates correctness + warm restart "
                         "only (timings advisory)")
    ap.add_argument("--record", nargs="?", const="BENCH_tuning.json",
                    default=None, metavar="PATH",
                    help="write the schema-versioned trajectory JSON")
    args = ap.parse_args(argv)
    scale = "smoke" if args.smoke else "full"
    cases = CASES[scale]

    from benchmarks.recorder import Recorder
    rec = Recorder("tuning", path=args.record)
    rec.add_meta(scale=scale)

    failures: list[str] = []
    with tempfile.TemporaryDirectory(prefix="tune_bench_") as cache_dir:
        results = []
        for kernel, backend, shape in cases:
            rec.section(f"{kernel} ({backend}, "
                        f"{'x'.join(map(str, shape))})")
            store = TuneStore(cache_dir)
            tuner = KernelTuner(store, backend=backend,
                                repeats=2 if args.smoke else 3, row=rec.row)
            r = run_case(tuner, kernel, shape, rec)
            r["backend"] = backend
            r["shape"] = shape
            results.append(r)
            rec.add_metrics({f"{kernel}_{k}": v
                             for k, v in tuner.metrics().items()})
            print(f"# {kernel:12s} untuned {r['untuned_s'] * 1e3:8.1f} ms  "
                  f"tuned {r['tuned_s'] * 1e3:8.1f} ms  "
                  f"speedup {r['speedup']:.2f}x  "
                  f"bitwise={'OK' if r['bitwise'] else 'FAIL'}")
            if not r["bitwise"]:
                failures.append(f"{kernel}: tuned answers diverge bitwise")
            if tuner.counters["tune_searches"] != 1:
                failures.append(f"{kernel}: expected 1 cold search, got "
                                f"{tuner.counters['tune_searches']}")

        # warm-restart gate: a fresh tuner over the same cache dir must
        # resolve every bucket from disk — zero measured searches
        rec.section("warm restart")
        warm_total = {"searches": 0, "hits": 0}
        for kernel, backend, shape in cases:
            warm = KernelTuner(TuneStore(cache_dir), backend=backend)
            warm.load_persisted()
            warm.ensure(kernel, shape)
            warm_total["searches"] += warm.counters["tune_searches"]
            warm_total["hits"] += warm.counters["tune_store_hits"]
        rec.row("warm/tune_searches", float("nan"),
                str(warm_total["searches"]))
        print(f"# warm restart: tune_searches={warm_total['searches']} "
              f"store_hits={warm_total['hits']}")
        if warm_total["searches"] != 0:
            failures.append("warm restart re-searched "
                            f"{warm_total['searches']} bucket(s)")
        rec.add_metrics({"warm_tune_searches": warm_total["searches"],
                         "warm_tune_store_hits": warm_total["hits"]})

        wins = sum(1 for r in results
                   if not r["tuned_is_default"] and r["speedup"] > 1.0)
        print(f"# tuned wins: {wins}/{len(results)} kernels")
        rec.add_metrics({"tuned_wins": wins})
        if not args.smoke and wins < 2:
            failures.append(f"only {wins}/3 kernels improved at full scale")

    rec.finish()
    if failures:
        for fmsg in failures:
            print(f"FAIL: {fmsg}", file=sys.stderr)
        return 1
    print("kernel_tuning: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
