"""Render benchmark JSON: roofline markdown tables and perf diffs.

Two modes:

* ``python benchmarks/report.py [roofline_results.json]`` — the
  original EXPERIMENTS.md markdown table from a roofline run.

* ``python benchmarks/report.py --compare OLD.json NEW.json`` — a
  per-row ``us_per_call`` diff between two schema-versioned
  ``BENCH_*.json`` trajectory files (``benchmarks/recorder.py``).
  Rows are matched by ``(section, name)``; rows present on only one
  side, or with no timing (``null``), are listed but never compared.
  Regressions beyond ``--threshold`` (default 1.25×) exit 3 so a
  caller MAY gate on it; ``scripts/verify.sh`` wires it as advisory
  (prints, never fails the build) because single-run timings on a
  shared CI box are noisy.
"""

import argparse
import json
import os
import sys

# run as `python benchmarks/report.py` (script dir on sys.path, repo root
# not) and as `python -m benchmarks.report`
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.recorder import validate_bench  # noqa: E402


def roofline_table(path="roofline_results.json"):
    d = json.load(open(path))
    rows = d["rows"]
    print("| arch | shape | compute (s) | memory (s) | collective (s) | "
          "bottleneck | MODEL_FLOPS | useful | roofline frac |")
    print("|---|---|---:|---:|---:|---|---:|---:|---:|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
              f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
              f"{r['bottleneck']} | {r['model_flops']:.2e} | "
              f"{r['useful_flops_ratio']:.2f} | "
              f"{r['roofline_fraction']:.4f} |")
    worst = min(rows, key=lambda r: r["roofline_fraction"])
    print(f"\nworst fraction: {worst['arch']} × {worst['shape']} "
          f"({worst['roofline_fraction']:.5f})")
    cb = [r for r in rows if r["bottleneck"] == "collective"]
    if cb:
        m = max(cb, key=lambda r: r["collective_s"] / max(r["compute_s"],
                                                          1e-12))
        print(f"most collective-bound: {m['arch']} × {m['shape']} "
              f"(N/C = {m['collective_s'] / max(m['compute_s'], 1e-12):.1f})")
    return 0


def _load_bench(path: str) -> dict | None:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"compare: unreadable {path}: {e}", file=sys.stderr)
        return None
    problems = validate_bench(doc)
    if problems:
        for p in problems:
            print(f"compare: invalid {path}: {p}", file=sys.stderr)
        return None
    return doc


def _timed_rows(doc: dict) -> dict:
    """(section, name) -> us_per_call for rows that carry a timing."""
    out = {}
    for r in doc["rows"]:
        if isinstance(r.get("us_per_call"), (int, float)):
            out[(r.get("section", ""), r["name"])] = float(r["us_per_call"])
    return out


def compare(old_path: str, new_path: str, threshold: float = 1.25) -> int:
    """Per-row perf diff OLD → NEW.  Exit 0 (clean), 2 (unreadable
    input), 3 (regression beyond threshold — advisory for callers)."""
    old_doc, new_doc = _load_bench(old_path), _load_bench(new_path)
    if old_doc is None or new_doc is None:
        return 2
    old, new = _timed_rows(old_doc), _timed_rows(new_doc)
    shared = sorted(set(old) & set(new))
    print(f"compare: {old_path} ({old_doc['benchmark']}, "
          f"{len(old)} timed rows) -> {new_path} "
          f"({new_doc['benchmark']}, {len(new)} timed rows), "
          f"{len(shared)} shared")
    regressions = []
    print(f"{'section/name':48s} {'old_us':>12s} {'new_us':>12s} "
          f"{'ratio':>7s}")
    for key in shared:
        o, n = old[key], new[key]
        ratio = n / o if o > 0 else float("inf")
        tag = ""
        if ratio > threshold:
            tag = "  REGRESSION"
            regressions.append((key, ratio))
        elif ratio < 1.0 / threshold:
            tag = "  improved"
        label = "/".join(p for p in key if p)
        print(f"{label[:48]:48s} {o:12.1f} {n:12.1f} {ratio:7.2f}{tag}")
    # coverage drift is a first-class signal, not a footnote: a renamed
    # or dropped scenario silently shrinks what the regression gate sees
    removed = sorted(set(old) - set(new))
    added = sorted(set(new) - set(old))
    if removed:
        print(f"removed rows ({len(removed)} — timed in old only):")
        for key in removed:
            print(f"  - {'/'.join(p for p in key if p)}")
    if added:
        print(f"added rows ({len(added)} — timed in new only):")
        for key in added:
            print(f"  + {'/'.join(p for p in key if p)}")
    if not removed and not added:
        print("row coverage unchanged: no rows added or removed")
    if regressions:
        worst = max(regressions, key=lambda kr: kr[1])
        print(f"compare: {len(regressions)} regression(s) > "
              f"{threshold:.2f}x (worst: "
              f"{'/'.join(p for p in worst[0] if p)} at {worst[1]:.2f}x)")
        return 3
    print(f"compare: no regressions > {threshold:.2f}x")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default="roofline_results.json",
                    help="roofline JSON to render as markdown")
    ap.add_argument("--compare", nargs=2, metavar=("OLD.json", "NEW.json"),
                    help="diff two BENCH_*.json trajectory files instead")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="ratio above which a row counts as a regression")
    args = ap.parse_args(argv)
    if args.compare:
        return compare(args.compare[0], args.compare[1], args.threshold)
    return roofline_table(args.path)


if __name__ == "__main__":
    raise SystemExit(main())
