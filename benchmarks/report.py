"""Render the roofline JSON into the EXPERIMENTS.md markdown table."""

import json
import sys


def main(path="roofline_results.json"):
    d = json.load(open(path))
    rows = d["rows"]
    print("| arch | shape | compute (s) | memory (s) | collective (s) | "
          "bottleneck | MODEL_FLOPS | useful | roofline frac |")
    print("|---|---|---:|---:|---:|---|---:|---:|---:|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
              f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
              f"{r['bottleneck']} | {r['model_flops']:.2e} | "
              f"{r['useful_flops_ratio']:.2f} | "
              f"{r['roofline_fraction']:.4f} |")
    worst = min(rows, key=lambda r: r["roofline_fraction"])
    print(f"\nworst fraction: {worst['arch']} × {worst['shape']} "
          f"({worst['roofline_fraction']:.5f})")
    cb = [r for r in rows if r["bottleneck"] == "collective"]
    if cb:
        m = max(cb, key=lambda r: r["collective_s"] / max(r["compute_s"],
                                                          1e-12))
        print(f"most collective-bound: {m['arch']} × {m['shape']} "
              f"(N/C = {m['collective_s'] / max(m['compute_s'], 1e-12):.1f})")


if __name__ == "__main__":
    main(*sys.argv[1:])
