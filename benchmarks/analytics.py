"""Paper Table 2: analytic benchmarks.

  TPC-H V.1  — the paper's running example (Fig. 1): MIN/MAX (0MA) and the
               MEDIAN variant (guarded → frequency propagation), with and
               without FK/PK information (§4.3).
  STATS-CEB  — FK/FK COUNT(*) over the stack-exchange-like schema, end to
               end over a family of queries (all guarded COUNT → all
               optimisable, as in the paper).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import Executor, MaterialisationLimit, plan_query
from repro.core.query import Agg, AggQuery, Atom
from repro.data import make_stats_db, make_tpch_db
from repro.data.relational import tpch_v1_query

OOM_GUARD = 20_000_000


def _time(fn, repeats=3):
    fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts)), float(np.std(ts))


def _bench_query(ex, db, schema, q, use_fkpk=False, repeats=3,
                 oma_ok=True):
    row = {}
    auto = plan_query(q, schema, mode="auto", use_fkpk=use_fkpk)
    row["plan"] = auto.mode
    fn = ex.jittable().compile(auto)

    def run_opt():
        out = fn(db)
        jax.block_until_ready(list(out.values()))
        return out

    row["opt_plus_s"], _ = _time(run_opt, repeats)
    try:
        row["ref_s"], _ = _time(
            lambda: ex.execute(plan_query(q, schema, mode="ref")), 1)
    except MaterialisationLimit:
        row["ref_s"] = None
    return row


def stats_query_family():
    """A STATS-CEB-like family: COUNT(*) joins of growing width."""
    u = Atom("users", "u", ("uid", "rep"))
    po = Atom("posts", "po", ("pid", "uid", "score"))
    co = Atom("comments", "co", ("pid", "cuid", "cscore"))
    v = Atom("votes", "v", ("pid", "vuid"))
    fams = [
        ("q1 posts-comments", (po, co)),
        ("q2 posts-votes", (po, v)),
        ("q3 users-posts-comments", (u, po, co)),
        ("q4 full", (u, po, co, v)),
        ("q5 comments-votes via posts", (po, co, v)),
    ]
    return [(n, AggQuery(atoms=a, aggregates=(Agg("count"),)))
            for n, a in fams]


def run(tpch_scale=5000, repeats=3):
    rows = []
    with jax.experimental.enable_x64():
        db, schema = make_tpch_db(scale=tpch_scale, seed=0)
        ex = Executor(db, schema, freq_dtype="int64", oom_guard=OOM_GUARD)
        for name, agg, fkpk in [
            ("TPC-H V.1 minmax (0MA)", "minmax", False),
            ("TPC-H V.1 median", "median", False),
            ("TPC-H V.1 median +FK/PK", "median", True),
        ]:
            q = tpch_v1_query(agg)
            r = _bench_query(ex, db, schema, q, use_fkpk=fkpk,
                             repeats=repeats)
            r["query"] = name
            rows.append(r)

        sdb, sschema = make_stats_db(n_users=20_000, n_posts=100_000,
                                     n_comments=400_000, n_votes=250_000)
        sex = Executor(sdb, sschema, freq_dtype="int64",
                       oom_guard=OOM_GUARD)
        e2e_opt, e2e_ref = 0.0, 0.0
        ref_failed = False
        for name, q in stats_query_family():
            r = _bench_query(sex, sdb, sschema, q, repeats=repeats)
            r["query"] = f"STATS {name}"
            rows.append(r)
            e2e_opt += r["opt_plus_s"]
            if r["ref_s"] is None:
                ref_failed = True
            else:
                e2e_ref += r["ref_s"]
        rows.append({"query": "STATS-CEB e2e", "plan": "opt_plus",
                     "opt_plus_s": e2e_opt,
                     "ref_s": None if ref_failed else e2e_ref})
    return rows


def main():
    rows = run()
    print(f"{'query':32s} {'plan':9s} {'Ref':>10s} {'Opt+':>10s} "
          f"{'speedup':>8s}")
    for r in rows:
        ref = f"{r['ref_s']:.3f}" if r.get("ref_s") else "X"
        sp = (f"{r['ref_s'] / r['opt_plus_s']:.2f}x" if r.get("ref_s")
              else "inf")
        print(f"{r['query']:32s} {r['plan']:9s} {ref:>10s} "
              f"{r['opt_plus_s']:>10.4f} {sp:>8s}")
    return rows


if __name__ == "__main__":
    main()
