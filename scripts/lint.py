#!/usr/bin/env python
"""Lint step for scripts/verify.sh.

Prefers ruff, then pyflakes (whichever the environment provides); when
neither is installed it degrades — visibly — to a built-in check that
still catches the common breakage classes a refactor leaves behind:
syntax errors (via compile()) and unused imports (via ast).

    python scripts/lint.py [paths...]       # default: src tests benchmarks
                                            #          examples scripts
"""

from __future__ import annotations

import ast
import pathlib
import re
import subprocess
import sys

_IDENT = re.compile(r"[A-Za-z_][A-Za-z0-9_]*")

DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples", "scripts")


def _external(tool_args: list[str], paths: list[str]) -> int | None:
    """Run an external linter if importable; None means unavailable."""
    probe = subprocess.run([sys.executable, "-m", tool_args[0], "--version"],
                           capture_output=True)
    if probe.returncode != 0:
        return None
    print(f"lint: using {' '.join(tool_args)}")
    return subprocess.run([sys.executable, "-m", *tool_args, *paths]).returncode


def _py_files(paths: list[str]) -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for p in paths:
        path = pathlib.Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    return [p for p in out if "__pycache__" not in p.parts]


def _unused_imports(tree: ast.Module) -> list[tuple[int, str]]:
    """Names imported at module level but never referenced.  Conservative:
    re-export modules (``__all__`` present or __init__-style) and
    ``import x as x`` re-export idiom are exempted by the caller."""
    imported: dict[str, int] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                name = (a.asname or a.name).split(".")[0]
                imported[name] = node.lineno
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue  # compiler directive, not a binding to "use"
            for a in node.names:
                if a.name == "*":
                    continue
                imported[a.asname or a.name] = node.lineno
    used: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            base = node
            while isinstance(base, ast.Attribute):
                base = base.value
            if isinstance(base, ast.Name):
                used.add(base.id)
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            # string annotations / doctest snippets reference names
            # textually — treat any identifier-ish token inside as a use
            used.update(_IDENT.findall(node.value))
    return [(ln, name) for name, ln in sorted(imported.items(),
                                              key=lambda kv: kv[1])
            if name not in used]


def _clock_discipline(paths: list[str]) -> int:
    """Forbid raw ``time.perf_counter()`` in the serving tier outside
    ``observability.py``.  The serving tier must take timestamps through
    the injectable ``Observability`` clock (``service.obs``) so tests can
    drive spans with a fake clock and the no-tracing path stays free of
    clock reads; a raw ``perf_counter`` bypasses both.  (``time
    .monotonic`` stays legal: the scheduler's formation-window deadline
    is a real-time ``Condition.wait`` bound that a frozen fake clock must
    never be able to hang.)  Always runs, even when ruff/pyflakes handle
    the general lint."""
    failures = 0
    for f in _py_files(paths):
        parts = f.parts
        if "service" not in parts or "repro" not in parts:
            continue
        if f.name == "observability.py":
            continue
        for ln, line in enumerate(f.read_text().splitlines(), start=1):
            if "perf_counter" in line.split("#")[0]:
                print(f"{f}:{ln}: raw perf_counter in the serving tier — "
                      "use the injectable Observability clock "
                      "(service.obs) instead")
                failures += 1
    return 1 if failures else 0


def _shard_map_discipline(paths: list[str]) -> int:
    """Forbid ``shard_map`` imports in ``src/`` outside
    ``core/distributed.py``.  The mesh lowering is ONE place — the graph
    interpreter's ring evaluators behind ``DistributedExecutor`` — so
    every other layer (service, planner, tables) stays
    topology-agnostic and single-device code never grows a second,
    subtly-different collective path.  Tests and benchmarks are exempt
    (they exercise the public surface).  Always runs, even when
    ruff/pyflakes handle the general lint."""
    failures = 0
    pat = re.compile(r"import\s+shard_map|shard_map\s*=|"
                     r"from\s+\S*shard_map|jax\.experimental\.shard_map")
    for f in _py_files(paths):
        parts = f.parts
        if "src" not in parts or f.name == "distributed.py":
            continue
        for ln, line in enumerate(f.read_text().splitlines(), start=1):
            if pat.search(line.split("#")[0]):
                print(f"{f}:{ln}: shard_map outside core/distributed.py — "
                      "mesh lowering lives in DistributedExecutor only")
                failures += 1
    return 1 if failures else 0


def _block_shape_discipline(paths: list[str]) -> int:
    """Forbid kernel block-shape constants (``PARENT_BLOCK_ROWS``,
    ``CHILD_BLOCK_ROWS``, ``LANES_WIDE``, ``LANES``) outside
    ``src/repro/kernels/``.  Block shapes are tuning parameters owned by
    the autotuner (``kernels/autotune.py``): a caller that hard-codes one
    silently pins a shape the measured search would otherwise pick, and
    within-bucket zero-recompile guarantees break when two layers disagree
    about padding granularity.  Callers pass a ``KernelConfig`` (or None
    for the tuned/default dispatch) instead.  Tests are exempt (they pin
    configs on purpose to exercise the parametrisation).  Always runs,
    even when ruff/pyflakes handle the general lint."""
    failures = 0
    pat = re.compile(r"\b(PARENT_BLOCK_ROWS|CHILD_BLOCK_ROWS|"
                     r"LANES_WIDE|LANES)\b")
    for f in _py_files(paths):
        parts = f.parts
        if "tests" in parts or f.name == "lint.py":
            continue
        if "kernels" in parts and "repro" in parts:
            continue
        for ln, line in enumerate(f.read_text().splitlines(), start=1):
            if pat.search(line.split("#")[0]):
                print(f"{f}:{ln}: kernel block-shape constant outside "
                      "src/repro/kernels/ — block shapes belong to the "
                      "autotuner; pass a KernelConfig instead")
                failures += 1
    return 1 if failures else 0


def _stats_threshold_discipline(paths: list[str]) -> int:
    """Forbid cardinality/selectivity policy constants outside
    ``src/repro/core/stats.py``.  Cost-calibrated planning has ONE home
    for its thresholds (``FK_ELIM_MAX_ORPHANS``,
    ``PREFILTER_MAX_SELECTIVITY``, ``FUSION_COST_DISPARITY``, the
    demotion/EWMA knobs, …): a second copy in a pass or the engine drifts
    from the calibrated value and the decision traces stop telling the
    truth about which gate was applied.  Callers import the constant or
    accept a parameter defaulting to it.  Tests are exempt (they pin
    thresholds on purpose to exercise the gates).  Always runs, even when
    ruff/pyflakes handle the general lint."""
    failures = 0
    pat = re.compile(
        r"^\s*[A-Z0-9_]*(SELECTIVITY|CARDINALITY|DISPARITY|ORPHANS?"
        r"|DEMOTION|EWMA)[A-Z0-9_]*\s*(?::[^=]+)?=[^=]")
    for f in _py_files(paths):
        parts = f.parts
        if "tests" in parts or f.name == "lint.py":
            continue
        if f.name == "stats.py" and "repro" in parts and "core" in parts:
            continue
        for ln, line in enumerate(f.read_text().splitlines(), start=1):
            if pat.search(line.split("#")[0]):
                print(f"{f}:{ln}: cardinality/selectivity threshold "
                      "constant outside src/repro/core/stats.py — planner "
                      "policy knobs live there; import the constant "
                      "instead")
                failures += 1
    return 1 if failures else 0


def _future_resolution_discipline(paths: list[str]) -> int:
    """Forbid direct ``Future.set_result``/``set_exception`` calls in
    ``src/repro/service/`` outside ``scheduler._resolve``.  ``_resolve``
    is the single sanctioned resolution path: it tolerates the
    caller-side cancel race (``InvalidStateError``), so a raw call
    elsewhere reintroduces the crash a cancelled future causes mid-serve.
    Tests are exempt (they resolve throwaway futures to build fixtures).
    Always runs, even when ruff/pyflakes handle the general lint."""
    failures = 0
    for f in _py_files(paths):
        parts = f.parts
        if "service" not in parts or "repro" not in parts:
            continue
        try:
            tree = ast.parse(f.read_text(), filename=str(f))
        except SyntaxError:
            continue  # the builtin lint reports syntax errors
        allowed: list[tuple[int, int]] = []  # _resolve line ranges
        if f.name == "scheduler.py":
            allowed = [(n.lineno, n.end_lineno or n.lineno)
                       for n in ast.walk(tree)
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))
                       and n.name == "_resolve"]
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("set_result", "set_exception")):
                continue
            if any(lo <= node.lineno <= hi for lo, hi in allowed):
                continue
            print(f"{f}:{node.lineno}: direct Future."
                  f"{node.func.attr} in the serving tier — resolve "
                  "futures through scheduler._resolve (the cancel-race "
                  "guard must stay the single resolution path)")
            failures += 1
    return 1 if failures else 0


def _builtin_lint(paths: list[str]) -> int:
    print("lint: ruff/pyflakes not installed — built-in syntax + "
          "unused-import check")
    failures = 0
    for f in _py_files(paths):
        src = f.read_text()
        try:
            tree = ast.parse(src, filename=str(f))
            compile(src, str(f), "exec")
        except SyntaxError as e:
            print(f"{f}:{e.lineno}: syntax error: {e.msg}")
            failures += 1
            continue
        has_all = any(isinstance(n, ast.Assign) and any(
            isinstance(t, ast.Name) and t.id == "__all__" for t in n.targets)
            for n in tree.body)
        if f.name == "__init__.py" or has_all:
            continue  # re-export surface: unused-import check not meaningful
        for ln, name in _unused_imports(tree):
            print(f"{f}:{ln}: unused import {name!r}")
            failures += 1
    return 1 if failures else 0


def main(argv: list[str]) -> int:
    paths = argv or [p for p in DEFAULT_PATHS if pathlib.Path(p).exists()]
    clock_rc = _clock_discipline(paths)
    shard_rc = _shard_map_discipline(paths)
    block_rc = _block_shape_discipline(paths)
    stats_rc = _stats_threshold_discipline(paths)
    future_rc = _future_resolution_discipline(paths)
    rc = _external(["ruff", "check"], paths)
    if rc is None:
        rc = _external(["pyflakes"], paths)
    if rc is None:
        rc = _builtin_lint(paths)
    rc = rc or clock_rc or shard_rc or block_rc or stats_rc or future_rc
    print("lint: OK" if rc == 0 else "lint: FAIL")
    return rc


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
