#!/usr/bin/env bash
# Tier-1 verify + serving smoke: what CI runs and what every PR must keep
# green.  Usage: scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: serving benchmark (tiny) =="
python benchmarks/serving_queries.py --tiny
