#!/usr/bin/env bash
# Tier-1 verify + serving smoke: what CI runs and what every PR must keep
# green.  Usage: scripts/verify.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

# tiny tables; gates cache counters, fused-batching counters + answer
# identity, warm speedup, and zero same-bucket recompiles.  For an even
# faster counters-only pass use `--smoke` instead.
echo "== smoke: serving benchmark (tiny, incl. fused counters) =="
python benchmarks/serving_queries.py --tiny
