#!/usr/bin/env bash
# Tier-1 verify + serving smoke: what CI runs and what every PR must keep
# green.
#
#   scripts/verify.sh            # lint + full pytest + tiny serving bench
#   scripts/verify.sh --smoke    # lint + serving-counter smoke only (fast):
#                                # asserts the fused-dashboard counters,
#                                # partial_fusions > 0 / subplan_saved > 0
#                                # on the mixed-join-shape workload, the
#                                # concurrent-callers scenario (async_batches
#                                # > 0, fused compiles < async requests,
#                                # malformed batch-mates isolated), AND the
#                                # restart warm-start scenario (a second
#                                # process over the same cache_dir: zero
#                                # plan rebuilds, persist_hits == distinct
#                                # fingerprints, bitwise-identical answers;
#                                # the XLA-cache compile-time and wall-clock
#                                # wins are gated by the timed run only),
#                                # AND the tracing-overhead scenario
#                                # (tracing-on answers bitwise-identical to
#                                # tracing-off, warm overhead bounded, all
#                                # pipeline-stage histograms populated),
#                                # AND the mesh-serving scenario (a 4×-scale
#                                # db through QueryService(mesh=...) on 8
#                                # forced host devices: answers bitwise-
#                                # identical to an identically-padded
#                                # single-device service, individually and
#                                # fused; zero recompiles on within-bucket
#                                # per-shard growth; warm restart with
#                                # plan_builds == 0 from the topology-keyed
#                                # store partition), AND the multi-tenant
#                                # adversarial-mix scenario (one tenant
#                                # flooding malformed + oversized queries is
#                                # held to its token-bucket/queue quota with
#                                # TYPED rejections while the victim
#                                # tenant's p95 stays within 2x its solo
#                                # baseline and its answers stay bitwise-
#                                # identical; cross-tenant submissions still
#                                # fuse — fused compiles < requests; per-
#                                # tenant counters/histograms appear in
#                                # metrics_v2()["tenants"]; no root span
#                                # leaks — open_requests == 0).
#                                # Writes + schema-validates the
#                                # BENCH_serving.json perf trajectory.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== lint (ruff/pyflakes, or built-in fallback) =="
python scripts/lint.py

if [[ "${1:-}" == "--smoke" ]]; then
  # keep the previous trajectory around for the advisory perf diff
  for f in BENCH_serving.json BENCH_tuning.json; do
    [[ -f "$f" ]] && cp "$f" "$f.prev"
  done
  echo "== smoke: fused + mixed + async + restart + tracing + mesh + tenant gates =="
  python benchmarks/serving_queries.py --smoke --record BENCH_serving.json
  echo "== smoke: BENCH_serving.json schema check =="
  python -m benchmarks.recorder BENCH_serving.json
  echo "== smoke: kernel autotuning gates (bitwise + warm restart) =="
  python benchmarks/kernel_tuning.py --smoke --record BENCH_tuning.json
  echo "== smoke: BENCH_tuning.json schema check =="
  python -m benchmarks.recorder BENCH_tuning.json
  # advisory perf diff vs the previous run: printed, never fails the
  # build (single-run timings on a shared box are noisy)
  for f in BENCH_serving.json BENCH_tuning.json; do
    if [[ -f "$f.prev" ]]; then
      echo "== smoke: advisory perf diff $f.prev -> $f =="
      python benchmarks/report.py --compare "$f.prev" "$f" || true
    fi
  done
  exit 0
fi

echo "== tier-1: pytest =="
python -m pytest -x -q

# tiny tables; gates cache counters, fused-batching + partial-fusion
# counters, answer identity, warm speedup, and zero same-bucket recompiles.
echo "== smoke: serving benchmark (tiny, incl. fusion counters) =="
python benchmarks/serving_queries.py --tiny
