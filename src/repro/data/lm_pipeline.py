"""Deterministic, shardable LM token pipeline.

Production framing: batches are a pure function of (seed, step), so

  * resuming from a checkpoint replays *exactly* the same stream
    (fault tolerance: no data-loader state to persist beyond the step);
  * any host can compute any shard of any batch (elastic re-scaling:
    a restarted job with a different DP degree re-slices the same stream);
  * stragglers are mitigated by skip-ahead: a slow host can drop to
    batch(step+1) without coordination because schedules are static.

On this container the source is a synthetic Zipf-ish token sampler; the
`corpus` hook takes any memory-mapped token array.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    corpus: np.ndarray | None = None  # optional real token stream

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Full global batch for `step` (host-level, numpy)."""
        if self.corpus is not None:
            n = self.global_batch * (self.seq_len + 1)
            start = (step * n) % max(1, len(self.corpus) - n)
            flat = self.corpus[start:start + n]
            toks = flat.reshape(self.global_batch, self.seq_len + 1)
        else:
            rng = np.random.default_rng((self.seed, step))
            # zipf-flavoured token stream, clipped into the vocab
            toks = rng.zipf(1.3, size=(self.global_batch, self.seq_len + 1))
            toks = (toks % self.vocab_size).astype(np.int32)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def shard_at(self, step: int, shard: int, num_shards: int):
        """Rows of the global batch owned by `shard` — any host can compute
        any shard (see module docstring)."""
        b = self.batch_at(step)
        rows = self.global_batch // num_shards
        sl = slice(shard * rows, (shard + 1) * rows)
        return {k: v[sl] for k, v in b.items()}

    def jax_batch(self, step: int) -> dict[str, jax.Array]:
        return {k: jnp.asarray(v) for k, v in self.batch_at(step).items()}
