from repro.data.relational import (
    make_graph_db,
    make_stats_db,
    make_tpch_db,
    path_query,
    star_query,
    tree_query,
)
from repro.data.lm_pipeline import TokenPipeline

__all__ = [
    "make_graph_db",
    "make_stats_db",
    "make_tpch_db",
    "path_query",
    "star_query",
    "tree_query",
    "TokenPipeline",
]
