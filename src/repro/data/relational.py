"""Synthetic relational datasets mirroring the paper's benchmarks.

  make_graph_db  — power-law directed graph (SNAP stand-in, Table 1)
  make_tpch_db   — mini TPC-H star schema: region→nation→supplier→partsupp
                   ←part, with FK/PK metadata (running example, §1/§4)
  make_stats_db  — FK/FK-joined tables à la STATS-CEB (Table 2)

plus query builders for the paper's path/tree/star counting queries.
"""

from __future__ import annotations

import numpy as np

from repro.core.query import Agg, AggQuery, Atom
from repro.tables.table import ColumnMeta, ForeignKey, RelSchema, Schema, Table


# --------------------------------------------------------------------------
# SNAP-like graphs
# --------------------------------------------------------------------------
def make_graph_db(n_nodes: int, n_edges: int, seed: int = 0,
                  zipf_a: float = 1.5):
    """Directed multigraph with zipf-ish degree skew (like SNAP graphs)."""
    rng = np.random.default_rng(seed)

    def zipf_nodes(size):
        r = rng.zipf(zipf_a, size=size) % n_nodes
        return r.astype(np.int32)

    src = zipf_nodes(n_edges)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    schema = Schema(
        relations={
            "edge": RelSchema("edge", (
                ColumnMeta("src", domain=n_nodes),
                ColumnMeta("dst", domain=n_nodes),
            )),
        },
    )
    db = {"edge": Table.from_numpy({"src": src, "dst": dst})}
    return db, schema


def path_query(k: int) -> AggQuery:
    """COUNT(*) over a k-join path: e1.dst=e2.src ∧ ... (paper §6.1,
    'path-0k' counts homomorphisms of a (k+1)-edge path)."""
    atoms = tuple(
        Atom("edge", f"e{i}", (f"x{i}", f"x{i+1}")) for i in range(k + 1))
    return AggQuery(atoms=atoms, aggregates=(Agg("count"),))


def tree_query(variant: int = 1) -> AggQuery:
    """Small tree-shaped counting queries (paper's tree-01..03)."""
    if variant == 1:      # out-star of 3 from a center reached by an edge
        atoms = (
            Atom("edge", "e0", ("r", "c")),
            Atom("edge", "e1", ("c", "a")),
            Atom("edge", "e2", ("c", "b")),
            Atom("edge", "e3", ("c", "d")),
        )
    elif variant == 2:    # depth-2 binary tree
        atoms = (
            Atom("edge", "e0", ("r", "u")),
            Atom("edge", "e1", ("r", "v")),
            Atom("edge", "e2", ("u", "a")),
            Atom("edge", "e3", ("u", "b")),
            Atom("edge", "e4", ("v", "c")),
        )
    else:                 # caterpillar
        atoms = (
            Atom("edge", "e0", ("a", "b")),
            Atom("edge", "e1", ("b", "c")),
            Atom("edge", "e2", ("c", "d")),
            Atom("edge", "e3", ("b", "p")),
            Atom("edge", "e4", ("c", "q")),
        )
    return AggQuery(atoms=atoms, aggregates=(Agg("count"),))


def star_query(fanout: int) -> AggQuery:
    atoms = tuple(
        Atom("edge", f"e{i}", ("c", f"x{i}")) for i in range(fanout))
    return AggQuery(atoms=atoms, aggregates=(Agg("count"),))


# --------------------------------------------------------------------------
# Mini TPC-H (the paper's running example, Figures 1/2)
# --------------------------------------------------------------------------
def make_tpch_db(scale: int = 1000, seed: int = 0):
    """region(5) ← nation(25) ← supplier(s) ← partsupp(ps) → part(p).

    Cardinalities scale like TPC-H: |supplier| = scale,
    |part| = 20·scale, |partsupp| = 80·scale.
    """
    rng = np.random.default_rng(seed)
    n_region, n_nation = 5, 25
    n_supp, n_part = scale, 20 * scale
    n_ps = 80 * scale

    region = {
        "r_regionkey": np.arange(n_region, dtype=np.int32),
        "r_name": np.arange(n_region, dtype=np.int32),  # dict-encoded name
    }
    nation = {
        "n_nationkey": np.arange(n_nation, dtype=np.int32),
        "n_regionkey": rng.integers(0, n_region, n_nation).astype(np.int32),
    }
    supplier = {
        "s_suppkey": np.arange(n_supp, dtype=np.int32),
        "s_nationkey": rng.integers(0, n_nation, n_supp).astype(np.int32),
        "s_acctbal": rng.normal(5000, 2500, n_supp).astype(np.float32),
    }
    part = {
        "p_partkey": np.arange(n_part, dtype=np.int32),
        "p_price": rng.gamma(4.0, 300.0, n_part).astype(np.float32),
    }
    partsupp = {
        "ps_partkey": rng.integers(0, n_part, n_ps).astype(np.int32),
        "ps_suppkey": rng.integers(0, n_supp, n_ps).astype(np.int32),
        "ps_supplycost": rng.gamma(2.0, 150.0, n_ps).astype(np.float32),
    }

    schema = Schema(
        relations={
            "region": RelSchema("region", (
                ColumnMeta("r_regionkey", unique=True, domain=n_region),
                ColumnMeta("r_name", domain=n_region),
            )),
            "nation": RelSchema("nation", (
                ColumnMeta("n_nationkey", unique=True, domain=n_nation),
                ColumnMeta("n_regionkey", domain=n_region),
            )),
            "supplier": RelSchema("supplier", (
                ColumnMeta("s_suppkey", unique=True, domain=n_supp),
                ColumnMeta("s_nationkey", domain=n_nation),
                ColumnMeta("s_acctbal"),
            )),
            "part": RelSchema("part", (
                ColumnMeta("p_partkey", unique=True, domain=n_part),
                ColumnMeta("p_price"),
            )),
            "partsupp": RelSchema("partsupp", (
                ColumnMeta("ps_partkey", domain=n_part),
                ColumnMeta("ps_suppkey", domain=n_supp),
                ColumnMeta("ps_supplycost"),
            )),
        },
        foreign_keys=(
            ForeignKey("nation", "n_regionkey", "region", "r_regionkey"),
            ForeignKey("supplier", "s_nationkey", "nation", "n_nationkey"),
            ForeignKey("partsupp", "ps_suppkey", "supplier", "s_suppkey"),
            ForeignKey("partsupp", "ps_partkey", "part", "p_partkey"),
        ),
    )
    db = {name: Table.from_numpy(data) for name, data in
          [("region", region), ("nation", nation), ("supplier", supplier),
           ("part", part), ("partsupp", partsupp)]}
    return db, schema


def tpch_v1_query(agg: str = "minmax", price_threshold: float = 1200.0,
                  regions=(2, 3)) -> AggQuery:
    """The paper's running example (Fig. 1): MIN/MAX (0MA) or MEDIAN
    (guarded, frequency propagation) of s_acctbal over the 5-way join.

    The nested `p_price > (SELECT avg(p_price) ...)` subquery is a local
    selection after decorrelation — we model it as the σ threshold.
    """
    atoms = (
        Atom("region", "r", ("rk", "rname")),
        Atom("nation", "n", ("nk", "rk")),
        Atom("supplier", "s", ("sk", "nk", "bal")),
        Atom("partsupp", "ps", ("pk", "sk", "cost")),
        Atom("part", "p", ("pk", "price")),
    )
    sels = {
        "r": lambda c: np.isin(np.asarray(c["r_name"]), regions)
        if isinstance(c["r_name"], np.ndarray)
        else _isin(c["r_name"], regions),
        "p": lambda c: c["p_price"] > price_threshold,
    }
    if agg == "minmax":
        aggs = (Agg("min", "bal"), Agg("max", "bal"))
    elif agg == "median":
        aggs = (Agg("median", "bal"),)
    elif agg == "count":
        aggs = (Agg("count"),)
    else:
        raise ValueError(agg)
    return AggQuery(atoms=atoms, aggregates=aggs, selections=sels)


def _isin(arr, values):
    import jax.numpy as jnp
    m = jnp.zeros(arr.shape, bool)
    for v in values:
        m = m | (arr == v)
    return m


# --------------------------------------------------------------------------
# STATS-CEB-like FK/FK schema
# --------------------------------------------------------------------------
def make_stats_db(n_users: int = 2000, n_posts: int = 8000,
                  n_comments: int = 30000, n_votes: int = 20000,
                  seed: int = 0):
    """users ← posts ← {comments, votes}: joins are FK/FK-style (many-many
    through shared key columns), like STATS-CEB."""
    rng = np.random.default_rng(seed)
    users = {
        "u_id": np.arange(n_users, dtype=np.int32),
        "u_rep": rng.integers(0, 1000, n_users).astype(np.int32),
    }
    posts = {
        "p_id": np.arange(n_posts, dtype=np.int32),
        "p_owner": rng.integers(0, n_users, n_posts).astype(np.int32),
        "p_score": rng.integers(-10, 100, n_posts).astype(np.int32),
    }
    comments = {
        "c_post": rng.integers(0, n_posts, n_comments).astype(np.int32),
        "c_user": rng.integers(0, n_users, n_comments).astype(np.int32),
        "c_score": rng.integers(0, 50, n_comments).astype(np.int32),
    }
    votes = {
        "v_post": rng.integers(0, n_posts, n_votes).astype(np.int32),
        "v_user": rng.integers(0, n_users, n_votes).astype(np.int32),
    }
    schema = Schema(
        relations={
            "users": RelSchema("users", (
                ColumnMeta("u_id", unique=True, domain=n_users),
                ColumnMeta("u_rep", domain=1000),
            )),
            "posts": RelSchema("posts", (
                ColumnMeta("p_id", unique=True, domain=n_posts),
                ColumnMeta("p_owner", domain=n_users),
                ColumnMeta("p_score"),
            )),
            "comments": RelSchema("comments", (
                ColumnMeta("c_post", domain=n_posts),
                ColumnMeta("c_user", domain=n_users),
                ColumnMeta("c_score"),
            )),
            "votes": RelSchema("votes", (
                ColumnMeta("v_post", domain=n_posts),
                ColumnMeta("v_user", domain=n_users),
            )),
        },
        foreign_keys=(
            ForeignKey("posts", "p_owner", "users", "u_id"),
            ForeignKey("comments", "c_post", "posts", "p_id"),
            ForeignKey("votes", "v_post", "posts", "p_id"),
        ),
    )
    db = {name: Table.from_numpy(d) for name, d in
          [("users", users), ("posts", posts), ("comments", comments),
           ("votes", votes)]}
    return db, schema


def stats_count_query() -> AggQuery:
    """COUNT(*) over users⋈posts⋈comments⋈votes (STATS-CEB shape)."""
    atoms = (
        Atom("users", "u", ("uid", "rep")),
        Atom("posts", "po", ("pid", "uid", "score")),
        Atom("comments", "co", ("pid", "cuid", "cscore")),
        Atom("votes", "v", ("pid", "vuid")),
    )
    return AggQuery(atoms=atoms, aggregates=(Agg("count"),))
