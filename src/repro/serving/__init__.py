"""Deprecated alias for :mod:`repro.models.lm_serving`.

The LM serving loop moved next to the model code it drives; this package
name is kept only so existing imports keep working, and will be removed.
It is unrelated to :mod:`repro.service`, the guarded-aggregate query
serving tier.
"""

import warnings

from repro.models.lm_serving import ServeEngine, greedy_generate

warnings.warn(
    "repro.serving is deprecated; import from repro.models.lm_serving "
    "instead (repro.service is the query serving tier)",
    DeprecationWarning, stacklevel=2)

__all__ = ["ServeEngine", "greedy_generate"]
