from repro.serving.engine import ServeEngine, greedy_generate

__all__ = ["ServeEngine", "greedy_generate"]
