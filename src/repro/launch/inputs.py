"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape) cell.

`input_specs(cfg, cell)` returns weak-type-correct, shardable abstractions
of every model input — no device allocation ever happens in the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ShapeCell
from repro.distributed.sharding import use_mesh
from repro.models import decode_state_specs, init_decode_state, init_params
from repro.models.config import ModelConfig


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Abstract model inputs for one shape cell."""
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        s_txt = s - (cfg.num_patches if cfg.frontend == "vision_stub" else 0)
        out = {"tokens": sds((b, s_txt), jnp.int32),
               "labels": sds((b, s_txt), jnp.int32)}
        if cfg.frontend == "vision_stub":
            out["image_embeds"] = sds((b, cfg.num_patches, cfg.d_model),
                                      jnp.float32)
        return out
    if cell.kind == "prefill":
        s_txt = s - (cfg.num_patches if cfg.frontend == "vision_stub" else 0)
        out = {"tokens": sds((b, s_txt), jnp.int32)}
        if cfg.frontend == "vision_stub":
            out["image_embeds"] = sds((b, cfg.num_patches, cfg.d_model),
                                      jnp.float32)
        return out
    if cell.kind == "decode":
        return {"tokens": sds((b, 1), jnp.int32)}
    raise ValueError(cell.kind)


def batch_shardings(mesh, specs_tree):
    """Batch inputs shard over ("pod","data") on dim 0 (shape-aware: a
    batch of 1 falls back to replication)."""
    from repro.distributed.sharding import resolve_spec

    def one(x):
        axes = ("batch",) + (None,) * (len(x.shape) - 1)
        with use_mesh(mesh):
            sp = resolve_spec(tuple(x.shape), axes)
        return jax.sharding.NamedSharding(mesh, sp)

    return jax.tree.map(one, specs_tree)


def abstract_params(cfg: ModelConfig, dtype=None):
    """(ShapeDtypeStruct tree, logical-spec tree) with zero allocation."""
    captured = {}

    def f(key):
        p, s = init_params(key, cfg)
        captured["specs"] = s
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    if dtype is not None:
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                x.shape,
                dtype if jnp.issubdtype(x.dtype, jnp.floating) else x.dtype),
            shapes)
    return shapes, captured["specs"]


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    """(ShapeDtypeStruct tree, logical-spec tree) for the decode state."""
    shapes = jax.eval_shape(
        lambda: init_decode_state(cfg, batch, max_len))
    return shapes, decode_state_specs(cfg)


def to_named_shardings(mesh, spec_tree, shapes_tree, rules=None):
    """Map a logical-axis spec tree to shape-aware NamedShardings on `mesh`
    (divisibility fallbacks live in distributed.sharding.resolve_spec)."""
    from repro.distributed.sharding import resolve_spec

    def is_spec(x):
        return isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x)

    def one(axes, shape_leaf):
        with use_mesh(mesh, rules):
            sp = resolve_spec(tuple(shape_leaf.shape), tuple(axes))
        return jax.sharding.NamedSharding(mesh, sp)

    return jax.tree.map(one, spec_tree, shapes_tree, is_leaf=is_spec)
