"""Training launcher: config → mesh → sharded state → resumable loop.

On TPU pods this is the per-host entry point (jax.distributed.initialize
is called when COORDINATOR_ADDRESS is set); on this container it runs the
same code path over the host mesh.  Fault tolerance comes from three
pieces working together (each separately tested):

  * deterministic data pipeline  — batch(step) is a pure function, so a
    restarted job replays the stream exactly (tests/test_checkpoint.py);
  * async atomic checkpoints     — snapshot every --ckpt-every steps, off
    the critical path;
  * elastic restore              — the checkpoint carries logical shapes
    only; --mesh at restart may differ from the mesh at save time.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/run1 [--smoke]
"""

from __future__ import annotations

import argparse
import os
import time

import jax

from repro.checkpoint import Checkpointer
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.data import TokenPipeline
from repro.distributed.sharding import use_mesh
from repro.launch.inputs import abstract_params, to_named_shardings
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_params
from repro.training import build_train_step, init_train_state
from repro.training.optimizer import AdamWState
from repro.training.step import TrainState


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots"])
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    if os.environ.get("COORDINATOR_ADDRESS"):
        jax.distributed.initialize()  # multi-host pod entry

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = {"host": make_host_mesh,
            "single": lambda: make_production_mesh(multi_pod=False),
            "multi": lambda: make_production_mesh(multi_pod=True)}[args.mesh]()
    print(f"[train] {cfg.name} on mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    pshapes, pspecs = abstract_params(cfg)
    state_specs = TrainState(params=pspecs,
                             opt=AdamWState(step=(), m=pspecs, v=pspecs),
                             step=())
    state_shapes = jax.eval_shape(init_train_state, pshapes)
    state_sh = to_named_shardings(mesh, state_specs, state_shapes)

    with use_mesh(mesh):
        params = jax.jit(
            lambda k: init_params(k, cfg)[0],
            out_shardings=to_named_shardings(mesh, pspecs, pshapes),
        )(jax.random.PRNGKey(0))
        state = init_train_state(params)

    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch, seed=1234)
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        state = ckpt.restore(like=state, shardings=state_sh)
        start = int(state.step)
        print(f"[train] resumed from step {start}")

    step_fn = build_train_step(cfg, microbatches=args.microbatches,
                               base_lr=args.lr, warmup=min(100, args.steps),
                               total_steps=args.steps, remat=args.remat,
                               compress_grads=args.compress_grads)

    def fn(state, batch):
        with use_mesh(mesh):
            return step_fn(state, batch)

    jitted = jax.jit(fn, in_shardings=(state_sh, None),
                     out_shardings=(state_sh, None), donate_argnums=(0,))

    t0 = time.time()
    tokens_done = 0
    for step in range(start, args.steps):
        batch = pipe.jax_batch(step)
        state, metrics = jitted(state, batch)
        tokens_done += args.batch * args.seq
        if (step + 1) % args.log_every == 0 or step == start:
            dt = time.time() - t0
            print(f"[train] step {step + 1}/{args.steps} "
                  f"loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"tok/s={tokens_done / max(dt, 1e-9):.0f}")
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state, async_=True)
    if ckpt is not None:
        ckpt.save(args.steps, state, async_=False)
        print(f"[train] final checkpoint at step {args.steps}")


if __name__ == "__main__":
    main()
