import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, with ZERO device allocation:

  * proof the sharding config is coherent (SPMD partitioning succeeds),
  * compiled.memory_analysis()  — per-device bytes (does it fit HBM?),
  * compiled.cost_analysis()    — FLOPs / bytes for the roofline,
  * the collective schedule     — parsed from the compiled HLO text.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                    # all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k --mesh multi --out results.json
"""

import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, SHAPES, cells_for, get_config  # noqa: E402
from repro.distributed.sharding import use_mesh  # noqa: E402
from repro.launch.inputs import (  # noqa: E402
    abstract_cache,
    abstract_params,
    batch_shardings,
    input_specs,
    to_named_shardings,
)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import decode_step, prefill  # noqa: E402
from repro.training import init_train_state  # noqa: E402
from repro.training.optimizer import AdamWState  # noqa: E402
from repro.training.step import TrainState, build_train_step  # noqa: E402

# HLO collective ops whose operand bytes count toward the collective term
_COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^(]*\(", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}


def collective_bytes_of_text(hlo: str) -> dict:
    """Sum output-shape bytes of every collective op in an HLO dump.

    Counts each textual op once — callers scale loop bodies by trip count
    (see benchmarks/roofline.py)."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for line in hlo.splitlines():
        line = line.strip()
        m = re.match(
            r".*= *((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)) *"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)", line)
        if not m:
            continue
        shapes = _SHAPE_RE.findall(m.group(1))
        nbytes = 0
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES.get(dt, 4)
        kind = m.group(2)
        out[kind] += nbytes
        counts[kind] += 1
    out["ops"] = counts
    return out


# --------------------------------------------------------------------------
# cell lowering
# --------------------------------------------------------------------------
def lower_train_cell(cfg, cell, mesh, rules=None):
    pshapes, pspecs = abstract_params(cfg)
    state_shapes = jax.eval_shape(init_train_state, pshapes)
    state_specs = TrainState(
        params=pspecs,
        opt=AdamWState(step=(), m=pspecs, v=pspecs),
        step=())
    state_sh = to_named_shardings(mesh, state_specs, state_shapes, rules)
    batch_abs = input_specs(cfg, cell)
    batch_sh = batch_shardings(mesh, batch_abs)
    micro = max(1, cell.global_batch // max(cell.microbatch, 1))
    step_fn = build_train_step(cfg, microbatches=micro, remat="full")

    def fn(state, batch):
        with use_mesh(mesh, rules):
            return step_fn(state, batch)

    jitted = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,))
    return jitted.lower(state_shapes, batch_abs)


def lower_prefill_cell(cfg, cell, mesh, rules=None):
    pshapes, pspecs = abstract_params(cfg, dtype=jnp.bfloat16)
    cache_shapes, cache_specs = abstract_cache(cfg, cell.global_batch,
                                               cell.seq_len)
    p_sh = to_named_shardings(mesh, pspecs, pshapes, rules)
    c_sh = to_named_shardings(mesh, cache_specs, cache_shapes, rules)
    batch_abs = input_specs(cfg, cell)
    batch_sh = batch_shardings(mesh, batch_abs)

    def fn(params, batch, cache):
        with use_mesh(mesh, rules):
            return prefill(params, cfg, batch, cache)

    jitted = jax.jit(fn, in_shardings=(p_sh, batch_sh, c_sh),
                     out_shardings=(None, c_sh), donate_argnums=(2,))
    return jitted.lower(pshapes, batch_abs, cache_shapes)


def lower_decode_cell(cfg, cell, mesh, rules=None):
    pshapes, pspecs = abstract_params(cfg, dtype=jnp.bfloat16)
    cache_shapes, cache_specs = abstract_cache(cfg, cell.global_batch,
                                               cell.seq_len)
    p_sh = to_named_shardings(mesh, pspecs, pshapes, rules)
    c_sh = to_named_shardings(mesh, cache_specs, cache_shapes, rules)
    tok_abs = input_specs(cfg, cell)
    tok_sh = batch_shardings(mesh, tok_abs)

    def fn(params, tokens, cache):
        with use_mesh(mesh, rules):
            return decode_step(params, cfg, tokens["tokens"], cache)

    jitted = jax.jit(fn, in_shardings=(p_sh, tok_sh, c_sh),
                     out_shardings=(None, c_sh), donate_argnums=(2,))
    return jitted.lower(pshapes, tok_abs, cache_shapes)


_LOWER = {"train": lower_train_cell, "prefill": lower_prefill_cell,
          "decode": lower_decode_cell}


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True):
    cfg = get_config(arch)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered = _LOWER[cell.kind](cfg, cell, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes_of_text(compiled.as_text())
    n_dev = mesh.devices.size
    result = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": {k: v for k, v in coll.items() if k != "ops"},
        "collective_ops": coll["ops"],
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        },
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape} × {result['mesh']}: "
              f"compile {result['compile_s']}s, "
              f"flops={result['flops']:.3e}, "
              f"coll={sum(result['collective_bytes'].values()):.3e} B")
        print(f"         memory_analysis: {result['memory']}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results, failures = [], []
    for arch in archs:
        shapes = [args.shape] if args.shape else cells_for(arch)
        for shape in shapes:
            if shape not in cells_for(arch):
                print(f"[dryrun] skip {arch} × {shape} (see DESIGN.md §6)")
                continue
            for mp in meshes:
                try:
                    results.append(run_cell(arch, shape, mp))
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    traceback.print_exc()
    with open(args.out, "w") as f:
        json.dump({"results": results,
                   "failures": [list(x) for x in failures]}, f, indent=1)
    print(f"[dryrun] {len(results)} cells OK, {len(failures)} failed "
          f"→ {args.out}")
    if failures:
        for f_ in failures:
            print("  FAIL:", f_)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
