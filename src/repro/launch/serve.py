"""Serving launcher: batched greedy generation over request waves.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --n-requests 8 --prompt-len 16 --max-new 32
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import init_params
from repro.models.lm_serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, n_slots=args.n_slots,
                         max_len=args.prompt_len + args.max_new + 8)

    rng = np.random.default_rng(0)
    for _ in range(args.n_requests):
        engine.submit(rng.integers(0, cfg.vocab_size, args.prompt_len))

    t0 = time.time()
    total = 0
    while engine._queue:
        outs = engine.run_wave(max_tokens=args.max_new)
        total += sum(len(v) for v in outs.values())
        for rid, toks in sorted(outs.items()):
            print(f"[serve] req {rid}: {len(toks)} tokens, "
                  f"head={toks[:8]}")
    dt = time.time() - t0
    print(f"[serve] {total} tokens in {dt:.2f}s "
          f"({total / max(dt, 1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
