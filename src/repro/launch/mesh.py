"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module touches no jax device state — required because the dry-run pins the
device count via XLA_FLAGS before any jax initialisation.
"""

from __future__ import annotations

import jax
import numpy as np


def make_auto_mesh(shape, axes, devices=None):
    """jax.make_mesh with explicit Auto axis types where the installed jax
    supports them (≥0.5.x); older versions are Auto-only, so the kwarg is
    simply dropped."""
    kwargs = {} if devices is None else {"devices": devices}
    axis_type = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) = one v5e pod (256 chips) as (data, model);
    (2, 16, 16) = two pods with a leading "pod" DP axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devs)} — the "
            "dry-run entry point must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    return make_auto_mesh(shape, axes, devices=devs[:n])


def make_host_mesh():
    """Whatever this host has (tests / examples): 1×N (data, model)."""
    n = len(jax.devices())
    return make_auto_mesh((n, 1), ("data", "model"))
