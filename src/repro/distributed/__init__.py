from repro.distributed.sharding import (
    LOGICAL_RULES,
    axis_rules,
    current_mesh,
    logical_spec,
    shard,
    use_mesh,
)

__all__ = [
    "LOGICAL_RULES",
    "axis_rules",
    "current_mesh",
    "logical_spec",
    "shard",
    "use_mesh",
]
