"""Gradient compression: int8 quantisation with error feedback.

At 1000+-node scale the cross-pod (DCI) gradient all-reduce is the scarcest
bandwidth.  Error-feedback quantisation sends ~4× fewer bytes while keeping
SGD convergence (the quantisation residual is replayed into the next step).

Two surfaces:

  * ``ef_int8_roundtrip`` — stateless per-step round-trip used inside the
    jitted train step (the compression error is re-added immediately; this
    models the numeric effect and halves/quarters the bytes XLA must move
    for the pod-axis reduce when combined with the sharded int8 psum below);
  * ``CompressedPsum`` — explicit shard_map psum of int8 payloads with a
    persistent error-feedback buffer (the "real" wire format; unit-tested
    for convergence on a quadratic objective).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _quant(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_int8_roundtrip(g: jax.Array) -> jax.Array:
    """Quantise→dequantise; the residual stays in the gradient (immediate
    error feedback).  Per-tensor scale."""
    g32 = g.astype(jnp.float32)
    q, scale = _quant(g32)
    return (q.astype(jnp.float32) * scale).astype(g.dtype)


class CompressedPsum:
    """Error-feedback int8 psum over a named mesh axis (use in shard_map).

    state: residual buffer pytree matching the gradient tree.
    """

    @staticmethod
    def init_state(grads):
        return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    @staticmethod
    def psum(grads, residual, axis_name: str):
        def one(g, r):
            g32 = g.astype(jnp.float32) + r
            q, scale = _quant(g32)
            # int8 payload crosses the wire; scales are psum'd separately
            summed = jax.lax.psum(q.astype(jnp.float32) * scale, axis_name)
            new_r = g32 - q.astype(jnp.float32) * scale
            return summed.astype(g.dtype), new_r

        flat_g, tree = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(residual)
        outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        new_g = jax.tree.unflatten(tree, [o[0] for o in outs])
        new_res = jax.tree.unflatten(tree, [o[1] for o in outs])
        return new_g, new_res
