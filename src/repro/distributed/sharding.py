"""Logical-axis sharding rules (MaxText/flax-partitioning style).

Model code annotates tensors with *logical* axis names; a rules table maps
them onto mesh axes.  Outside a mesh context every annotation is a no-op, so
the same model runs on this 1-CPU container (smoke tests) and on the
(pod, data, model) production mesh (dry-run / real TPU).

Parallelism encoding (see DESIGN.md §8):
  batch   → ("pod", "data")   DP across pods and within pods
  fsdp    → "data"            parameter/optimizer sharding (ZeRO-3)
  tensor  → "model"           TP: heads / ffn / vocab / expert-ffn
  expert  → "data"            EP: expert dim of MoE weights rides the fsdp
                              axis (tokens shuffle via all_to_all)
  kv_seq  → "data"            SP for long-context decode KV caches
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis → mesh axis (None = replicated)
LOGICAL_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    # Megatron-style sequence parallelism: the residual stream between
    # blocks shards its seq dim over "model"; inside a block the seq axis
    # is dropped automatically wherever it would collide with a tensor
    # dim that already uses "model" (resolve_spec dedup).
    "seq": "model",
    "embed": ("pod", "data"),  # FSDP/ZeRO-3 weight dim — across pods too
    "act_embed": None,      # activations keep embed unsharded (TP gathers)
    "heads": "model",
    "heads_fused": "model",  # h·hd fused projection dim (always divisible)
    "kv_heads": "model",
    "head_dim": None,
    "kv_head_dim": "model",  # KV-cache head_dim takes "model" when the
                             # kv-head count can't (GQA kv < 16)
    "mlp": "model",
    "vocab": "model",
    "experts": ("pod", "data"),
    "expert_mlp": "model",
    "dispatch_embed": "model",  # d_model during MoE scatter/gather: keeps
                                # the scatter local per shard (no replication)
    "ssm_inner": "model",
    "ssm_state": None,
    "conv": None,
    "kv_seq": ("pod", "data"),  # sequence-parallel long-context KV
    "q_seq": "model",       # context parallelism: q positions take "model"
                            # when the kv-head count can't split it
    "layers": None,
    "stack": None,
}

# Secondary claims: if a dim's PRIMARY axes were unavailable/indivisible
# and another dim freed one of these axes, the named logical axis may
# claim it in a second pass.  E.g. h2o-danube's d_head=120 can't take
# "model", so its 32k KV-cache seq dim does — 256-way instead of 16-way
# sharding (EXPERIMENTS §Dry-run footnote 4).
SECONDARY_RULES: dict[str, tuple] = {
    "kv_seq": ("model",),
}

_state = threading.local()


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


def current_rules() -> dict:
    return getattr(_state, "rules", LOGICAL_RULES)


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: dict | None = None):
    prev_mesh = getattr(_state, "mesh", None)
    prev_rules = getattr(_state, "rules", LOGICAL_RULES)
    _state.mesh = mesh
    _state.rules = dict(rules) if rules is not None else LOGICAL_RULES
    try:
        yield
    finally:
        _state.mesh = prev_mesh
        _state.rules = prev_rules


@contextlib.contextmanager
def axis_rules(**overrides):
    """Temporarily override logical→mesh rules (perf experiments)."""
    rules = dict(current_rules())
    rules.update(overrides)
    with use_mesh(current_mesh(), rules):
        yield


def _mesh_axes_of(mesh: Mesh) -> set:
    return set(mesh.axis_names)


def resolve_spec(shape: tuple[int, ...] | None,
                 logical_axes: tuple[str | None, ...]) -> P:
    """Map logical axis names to a PartitionSpec under the current rules.

    Robustness rules that let ONE rules table serve every arch, mesh and
    shape cell (see DESIGN.md §8):

      * mesh axes absent from the current mesh are dropped (single-pod vs
        multi-pod share the table);
      * a mesh axis may appear only once per spec — later duplicates are
        dropped (e.g. decode KV caches: batch already consumed "data", so
        kv_seq replicates; with batch=1 the batch dim frees "data" and the
        sequence dim takes it — exactly the SP long-context layout);
      * a dimension not divisible by its mesh-axis product is not sharded
        on it; freed axes are greedily re-assigned to later unsharded,
        divisible dimensions (e.g. mixtral's 8 experts can't split 16-way,
        so the "data" axis moves onto the d_model dim — EP degrades to
        2-D FSDP×TP instead of failing).

    `shape=None` skips divisibility checks (mesh-presence and duplicate
    rules still apply).
    """
    mesh = current_mesh()
    rules = current_rules()
    avail = _mesh_axes_of(mesh) if mesh is not None else set()
    sizes = dict(mesh.shape) if mesh is not None else {}

    def candidates(ax):
        tgt = rules.get(ax) if ax is not None else None
        if tgt is None:
            return ()
        if isinstance(tgt, tuple):
            return tuple(t for t in tgt if t in avail)
        return (tgt,) if tgt in avail else ()

    used: set[str] = set()
    freed: list[str] = []
    out: list = []
    for i, ax in enumerate(logical_axes):
        cand = tuple(a for a in candidates(ax) if a not in used)
        dim = shape[i] if shape is not None else None

        def divides(axes):
            if dim is None or not axes:
                return bool(axes)
            prod = 1
            for a in axes:
                prod *= sizes.get(a, 1)
            return prod > 0 and dim % prod == 0

        chosen = ()
        if divides(cand):
            chosen = cand
        else:
            for a in cand:
                if divides((a,)):
                    chosen = (a,)
                    break
            freed.extend(a for a in cand if a not in chosen)
        used.update(chosen)
        out.append(chosen)

    # second pass: re-home freed axes onto *wildcard* dims (logical None)
    # that are unsharded and divisible — jointly first (so e.g. mixtral's
    # expert weights get the full pod×data FSDP product on d_model when
    # the 8-expert dim can't take it), then singly.
    if shape is not None:
        freed = [a for i, a in enumerate(freed)
                 if a not in used and a not in freed[:i]]

        def try_place(axes_tuple):
            prod = 1
            for a in axes_tuple:
                prod *= sizes.get(a, 1)
            if prod <= 1:
                return False
            for i, cur in enumerate(out):
                if not cur and logical_axes[i] is None \
                        and shape[i] % prod == 0 and shape[i] > 1:
                    out[i] = axes_tuple
                    used.update(axes_tuple)
                    return True
            return False

        if freed and not try_place(tuple(freed)):
            for a in list(freed):
                if a not in used:
                    try_place((a,))

        # third pass: SECONDARY_RULES — named dims may claim still-unused
        # axes their primary rule didn't include (see table above)
        for i, ax in enumerate(logical_axes):
            if out[i] or ax not in SECONDARY_RULES:
                continue
            for a in SECONDARY_RULES[ax]:
                if a in used or a not in avail:
                    continue
                if sizes.get(a, 1) > 1 and shape[i] % sizes.get(a, 1) == 0:
                    out[i] = (a,)
                    used.add(a)
                    break

    norm = [c if len(c) > 1 else (c[0] if c else None) for c in out]
    return P(*norm)


def logical_spec(*logical_axes: str | None, shape=None) -> P:
    return resolve_spec(shape, logical_axes)


def shard(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """with_sharding_constraint under the current mesh; identity if none."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = resolve_spec(tuple(x.shape), tuple(logical_axes))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))
