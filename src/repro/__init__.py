"""repro — Avoiding Materialisation for Guarded Aggregate Queries, in JAX.

A production-grade JAX framework that implements the paper's contribution
(0MA semi-join evaluation, frequency propagation, and the FreqJoin physical
operator) as the analytics layer of a multi-pod LM training/serving stack.

Layers:
  repro.core        — the paper: query IR, join trees, 0MA, rewrites, executor
  repro.tables      — fixed-shape columnar substrate
  repro.kernels     — Pallas TPU kernels (+ XLA twins + jnp oracles)
  repro.models      — LM zoo for the 10 assigned architectures
  repro.training    — optimizer / microbatching / remat / losses
  repro.service     — SQL serving tier: fingerprints, plan cache, QueryService
  repro.checkpoint  — sharded, elastic checkpointing
  repro.data        — synthetic relational + LM token pipelines
  repro.distributed — mesh rules, grad compression, collective helpers
  repro.configs     — one module per assigned architecture
  repro.launch      — mesh / dryrun / train / serve entry points
"""

__version__ = "1.0.0"
