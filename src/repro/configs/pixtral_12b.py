"""pixtral-12b — mistral-nemo-style decoder with a stub ViT frontend.

[hf:mistralai/Pixtral-12B-2409; unverified]  40L d_model=5120 32H
(GQA kv=8) d_ff=14336 vocab=131072.  The ViT is a STUB (assignment:
backbone only): input_specs provides 256 precomputed patch embeddings
[B, 256, d_model] prepended to seq_len−256 text tokens.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_head=128,
        d_ff=14336, vocab_size=131072,
        frontend="vision_stub", num_patches=256,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256,
        frontend="vision_stub", num_patches=4,
    )
