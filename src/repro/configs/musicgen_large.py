"""musicgen-large — decoder-only over EnCodec tokens.

[arXiv:2306.05284; hf]  48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048.  The EnCodec frontend + codebook delay pattern are STUBs
(assignment: backbone only); input is a single pre-delayed token stream.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="dense",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
        d_ff=8192, vocab_size=2048,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=64,
    )
