"""zamba2-1.2b — Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf]  38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64.  Shared transformer block applied every 6 Mamba2
layers (weights shared across applications; per-application LoRA omitted —
DESIGN.md §10).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
        d_ff=8192, vocab_size=32000,
        ssm_state=64, ssm_head_dim=64, ssm_chunk=128,
        shared_attn_every=6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=256,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8, ssm_expand=2,
        shared_attn_every=2,
    )
