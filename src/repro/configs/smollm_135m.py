"""smollm-135m — llama-architecture small dense model.

[hf:HuggingFaceTB/SmolLM-135M; hf]  30L d_model=576 9H (GQA kv=3)
d_ff=1536 vocab=49152.  Also the end-to-end training-example model.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m", family="dense",
        n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_head=64,
        d_ff=1536, vocab_size=49152,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-smoke", family="dense",
        n_layers=2, d_model=48, n_heads=3, n_kv_heads=3, d_head=16,
        d_ff=96, vocab_size=256,
    )
