"""gemma3-1b — dense, 5:1 local:global attention, MQA (kv=1), 262k vocab.

[hf:google/gemma-3-1b-pt; unverified]  26L d_model=1152 4H (GQA kv=1)
d_ff=6912 vocab=262144; local window 512; d_head 256; sqrt(d) embed scale.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_head=256,
        d_ff=6912, vocab_size=262144,
        local_global_ratio=5, local_window=512, embed_scale=True,
        rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_head=16,
        d_ff=128, vocab_size=512,
        local_global_ratio=1, local_window=8, embed_scale=True,
    )
