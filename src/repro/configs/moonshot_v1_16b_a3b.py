"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) — 64-expert top-6 MoE.

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (GQA kv=16)
d_ff=1408 (per expert), vocab=163840, MoE 64e top-6 + 1 shared expert
(DeepSeek-V3-style; simplification noted in DESIGN.md §10).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=1408, vocab_size=163840,
        n_experts=64, top_k=6, n_shared_experts=1, capacity_factor=1.25,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=32, vocab_size=256,
        n_experts=8, top_k=2, n_shared_experts=1,
    )
