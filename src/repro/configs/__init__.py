"""Assigned-architecture registry: full configs, smoke configs, shapes.

``get_config(name)`` returns the exact published configuration;
``get_smoke_config(name)`` a reduced same-family config for CPU tests.

Input-shape cells (assignment):
    train_4k     seq 4096  × global_batch 256   (train_step)
    prefill_32k  seq 32768 × global_batch 32    (serve: prefill)
    decode_32k   seq 32768 × global_batch 128   (serve: 1 token, 32k KV)
    long_500k    seq 524288 × global_batch 1    (serve: 1 token, 500k KV;
                 sub-quadratic archs only — see DESIGN.md §6)
"""

from __future__ import annotations

import dataclasses
import importlib

ARCHS = (
    "zamba2-1.2b",
    "musicgen-large",
    "moonshot-v1-16b-a3b",
    "mixtral-8x22b",
    "gemma3-1b",
    "smollm-135m",
    "h2o-danube-3-4b",
    "qwen3-14b",
    "pixtral-12b",
    "rwkv6-1.6b",
)

_MODULES = {name: "repro.configs." + name.replace("-", "_").replace(".", "p")
            for name in ARCHS}


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"
    microbatch: int = 0  # train: per-step microbatch rows (0 = whole batch)


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train", microbatch=32),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def get_config(name: str):
    mod = importlib.import_module(_MODULES[name])
    return mod.config()


def get_smoke_config(name: str):
    mod = importlib.import_module(_MODULES[name])
    return mod.smoke_config()


def cells_for(name: str):
    """The shape cells this arch runs (long_500k only when sub-quadratic)."""
    cfg = get_config(name)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        out.append("long_500k")
    return out
