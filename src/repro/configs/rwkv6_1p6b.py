"""rwkv6-1.6b ("Finch") — attention-free, data-dependent decay.

[arXiv:2404.05892; unverified]  24L d_model=2048 (attn-free) d_ff=7168
vocab=65536.  Chunked linear attention, chunk 32 (DESIGN.md §10).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="rwkv6",
        n_layers=24, d_model=2048, n_heads=0, n_kv_heads=0,
        d_ff=7168, vocab_size=65536,
        ssm_head_dim=64, ssm_chunk=64,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="rwkv6",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
        d_ff=128, vocab_size=256,
        ssm_head_dim=16, ssm_chunk=8,
    )
