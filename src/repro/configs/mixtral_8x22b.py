"""mixtral-8x22b — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf]  56L d_model=6144 48H (GQA kv=8) d_ff=16384
(per expert), vocab=32768, MoE 8e top-2, SWA 4096.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
        d_ff=16384, vocab_size=32768,
        n_experts=8, top_k=2, sliding_window=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=16,
        d_ff=64, vocab_size=256,
        n_experts=4, top_k=2, sliding_window=16,
    )
