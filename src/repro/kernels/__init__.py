"""Pallas TPU kernels for the paper's physical operators.

  freq_join.py   — FreqJoin (paper §5): blocked broadcast-compare sum-product
  semi_join.py   — Boolean-semiring specialisation (0MA sweep, §4.1)
  segment_sum.py — sorted group-by-SUM (frequency pre-grouping, §4.2/§4.3)
  ops.py         — public wrappers, padding, XLA twins, config dispatch
  autotune.py    — measured block/dispatch search per shape bucket
  ref.py         — pure-jnp O(N·M) oracles (ground truth for tests)
"""

from repro.kernels.autotune import (
    DEFAULT_CONFIG,
    KernelConfig,
    KernelTuner,
    TuneTable,
)
from repro.kernels.ops import (
    freq_join,
    group_by_sum,
    segment_sum_sorted,
    semi_join,
    weighted_percentile,
)

__all__ = [
    "DEFAULT_CONFIG",
    "KernelConfig",
    "KernelTuner",
    "TuneTable",
    "freq_join",
    "group_by_sum",
    "segment_sum_sorted",
    "semi_join",
    "weighted_percentile",
]
