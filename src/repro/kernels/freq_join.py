"""FreqJoin Pallas TPU kernel (paper §5, Algorithms 1/2 adapted to TPU).

The paper implements FreqJoin as a 20-line tweak to Spark's sort-merge /
shuffled-hash joins: per parent tuple, sum the frequencies of matching child
tuples and multiply.  Neither pointer-chasing hash probes nor data-dependent
row loops map onto a TPU, so we adapt the *insight* (join + aggregate fused,
zero join tuples emitted) to the TPU's blocked, vectorised model:

  grid = (parent_blocks, child_blocks)              # 2-D, child inner
  parent block  : (PB_R, 128) keys + freqs in VMEM
  child  block  : (CB_R, 128) keys + freqs in VMEM
  inner loop    : for each child sub-row (128 lanes), broadcast-compare
                  against the whole parent block and accumulate
                  acc += Σ_lane child_freq · [keys equal]
  at the last child block: out = parent_freq · acc

The comparison `parent_block[:, :, None] == child_row[None, None, :]` and the
reduction are pure VPU work on hardware-aligned tiles; the accumulator lives
in the (revisited) output block, exploiting TPU Pallas' sequential grid.
No join tuple is ever materialised — the VMEM footprint is
O(PB + CB + PB·128) per step regardless of join multiplicity.

Works for any semiring-like accumulation the engine needs:
  mode="sum"  — ℕ/ℝ semiring (COUNT/SUM/AVG/MEDIAN propagation)
  mode="any"  — Boolean semiring (semi-join; see semi_join.py for the
                dedicated entry point)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes: sublane × lane tiles. 8×128 is the fp32 native
# tile; larger parent blocks amortise child traffic (see EXPERIMENTS.md
# §Perf).  Both kernels take the block rows as static arguments so the
# autotuner (kernels/autotune.py) can search per shape bucket; these
# module constants are only the untuned defaults.
PARENT_BLOCK_ROWS = 8
CHILD_BLOCK_ROWS = 8
LANES = 128


def _freq_join_kernel(pk_ref, pf_ref, ck_ref, cf_ref, out_ref, *, mode: str,
                      n_child_blocks: int):
    """One (parent-block i, child-block j) grid step."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pk = pk_ref[...]                                   # (PB_R, 128)
    acc = out_ref[...]

    def body(r, acc):
        ck_row = ck_ref[r, :]                          # (128,)
        cf_row = cf_ref[r, :]
        eq = pk[:, :, None] == ck_row[None, None, :]   # (PB_R, 128, 128)
        if mode == "sum":
            contrib = jnp.sum(
                jnp.where(eq, cf_row[None, None, :], 0).astype(acc.dtype),
                axis=-1,
            )
            return acc + contrib
        else:  # "any": Boolean semiring — OR of live matches
            live = eq & (cf_row[None, None, :] > 0)
            return jnp.maximum(acc, jnp.any(live, axis=-1).astype(acc.dtype))

    acc = jax.lax.fori_loop(0, ck_ref.shape[0], body, acc)
    out_ref[...] = acc

    @pl.when(j == n_child_blocks - 1)
    def _finalise():
        out_ref[...] = pf_ref[...] * out_ref[...]


@functools.partial(jax.jit, static_argnames=("mode", "interpret",
                                             "parent_block_rows",
                                             "child_block_rows"))
def freq_join_pallas(parent_keys, parent_freq, child_keys, child_freq,
                     *, mode: str = "sum", interpret: bool = False,
                     parent_block_rows: int = PARENT_BLOCK_ROWS,
                     child_block_rows: int = CHILD_BLOCK_ROWS):
    """Blocked FreqJoin. Inputs must be pre-padded:

    parent_keys/freq : (Np,)  Np % (parent_block_rows*128) == 0
    child_keys/freq  : (Nc,)  Nc % (child_block_rows*128) == 0
    Padded child rows must carry freq 0 (so they contribute nothing);
    padded parent rows produce garbage that the caller slices off.

    Returns new parent frequencies, shape (Np,).
    """
    pbr, cbr = parent_block_rows, child_block_rows
    np_, nc = parent_keys.shape[0], child_keys.shape[0]
    pb, cb = pbr * LANES, cbr * LANES
    assert np_ % pb == 0 and nc % cb == 0, (np_, nc)
    n_pb, n_cb = np_ // pb, nc // cb

    pk2 = parent_keys.reshape(n_pb * pbr, LANES)
    pf2 = parent_freq.reshape(n_pb * pbr, LANES)
    ck2 = child_keys.reshape(n_cb * cbr, LANES)
    cf2 = child_freq.reshape(n_cb * cbr, LANES)

    kernel = functools.partial(_freq_join_kernel, mode=mode, n_child_blocks=n_cb)
    out = pl.pallas_call(
        kernel,
        grid=(n_pb, n_cb),
        in_specs=[
            pl.BlockSpec((pbr, LANES), lambda i, j: (i, 0)),
            pl.BlockSpec((pbr, LANES), lambda i, j: (i, 0)),
            pl.BlockSpec((cbr, LANES), lambda i, j: (j, 0)),
            pl.BlockSpec((cbr, LANES), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((pbr, LANES), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(pf2.shape, parent_freq.dtype),
        interpret=interpret,
    )(pk2, pf2, ck2, cf2)
    return out.reshape(np_)
