"""Sorted segmented-sum Pallas TPU kernel (paper §4.2/§4.3 pre-grouping).

The frequency-propagation rewrite repeatedly needs `GROUP BY key, SUM(val)`
over a key-sorted column pair — e.g. compressing a child relation to
(distinct key, total frequency) before a FreqJoin, and the final aggregate.
On TPU this is a single sequential-grid pass:

  * blocks of (1, LANES_WIDE) in VMEM; the TPU grid runs in order, so an
    SMEM scratch cell carries the running sum of a run that spans blocks;
  * run boundaries come from *shifted key columns* (prev/next) that the
    ops.py wrapper materialises once — no cross-block peeking inside the
    kernel;
  * within a block, a segmented cumulative sum runs as an associative scan
    over (value, start-flag) pairs — log-depth, vectorised.

Emission convention: the run total is written at the LAST row of each run
(valid=1 there, 0 elsewhere).  Consumers never care where a group's row
sits, only that each distinct key appears exactly once with its total —
rows with valid=0 carry value 0 and are dead by the engine's freq=0
convention.

This kernel is shared verbatim by the MoE layer (expert-load counting is a
guarded COUNT(*) GROUP BY expert — see DESIGN.md §4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default block width; (1, lanes_wide) blocks: flat order == lane order.
# The width is a static argument so the autotuner can search it per shape
# bucket; this constant is only the untuned default.
LANES_WIDE = 1024


def _seg_comb(a, b):
    """Associative op for segmented sum: (sum, started) pairs."""
    s1, f1 = a
    s2, f2 = b
    return jnp.where(f2, s2, s1 + s2), f1 | f2


def _segment_sum_kernel(keys_ref, pkeys_ref, nkeys_ref, vals_ref,
                        out_ref, valid_ref, carry_ref, *, n_total: int,
                        lanes_wide: int):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        carry_ref[0, 0] = jnp.zeros((), carry_ref.dtype)

    keys = keys_ref[0, :]
    pkeys = pkeys_ref[0, :]
    nkeys = nkeys_ref[0, :]
    v = vals_ref[0, :]

    gpos = j * lanes_wide + jax.lax.broadcasted_iota(
        jnp.int32, (1, lanes_wide), 1
    )[0, :]
    starts = (keys != pkeys) | (gpos == 0)
    is_last = (keys != nkeys) | (gpos == n_total - 1)

    seg, _ = jax.lax.associative_scan(_seg_comb, (v, starts))
    # rows before the first run boundary continue the carried-over run
    in_carried_run = jnp.cumsum(starts.astype(jnp.int32)) == 0
    seg = seg + jnp.where(in_carried_run, carry_ref[0, 0], jnp.zeros((), v.dtype))
    carry_ref[0, 0] = seg[-1]

    out_ref[0, :] = jnp.where(is_last, seg, jnp.zeros((), v.dtype))
    valid_ref[0, :] = is_last.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "lanes_wide"))
def segment_sum_pallas(sorted_keys, values, *, interpret: bool = False,
                       lanes_wide: int = LANES_WIDE):
    """Segmented sum over key-sorted arrays.

    Contract: len % lanes_wide == 0; padded tail rows sort last (keys >= all
    real keys) and carry value 0.  Returns (sums, valid) with run totals at
    the last row of each run.
    """
    n = sorted_keys.shape[0]
    assert n % lanes_wide == 0, n
    n_blocks = n // lanes_wide

    pkeys = jnp.roll(sorted_keys, 1)
    nkeys = jnp.roll(sorted_keys, -1)

    def as2d(a):
        return a.reshape(n_blocks, lanes_wide)

    kernel = functools.partial(_segment_sum_kernel, n_total=n,
                               lanes_wide=lanes_wide)
    out, valid = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, lanes_wide), lambda j: (j, 0))] * 4,
        out_specs=[
            pl.BlockSpec((1, lanes_wide), lambda j: (j, 0)),
            pl.BlockSpec((1, lanes_wide), lambda j: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, lanes_wide), values.dtype),
            jax.ShapeDtypeStruct((n_blocks, lanes_wide), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1, 1), values.dtype)],
        interpret=interpret,
    )(as2d(sorted_keys), as2d(pkeys), as2d(nkeys), as2d(values))
    return out.reshape(n), valid.reshape(n).astype(bool)
