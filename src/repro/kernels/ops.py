"""Public kernel ops: padding, backend dispatch, XLA twins.

Every physical operator has two interchangeable backends:

  * ``"pallas"`` — the TPU kernels in freq_join.py / semi_join.py /
    segment_sum.py (on this CPU container they run in interpret mode,
    which executes the kernel body in Python and is used for validation);
  * ``"xla"``    — algorithmically equivalent sort/searchsorted/segment-sum
    formulations lowered by XLA; these are what the CPU benchmarks time and
    what the distributed executor traces through `shard_map` (collectives
    compose with XLA ops on every backend).

Both are tested against the O(N·M) oracles in ref.py.

Dispatch parameters — pallas block shapes and the XLA dense-domain
crossover — come from a ``KernelConfig`` (``kernels/autotune.py``);
``config=None`` means the untuned ``DEFAULT_CONFIG``.  The serving tier
threads tuned configs per shape bucket through ``Executor``; standalone
callers can pass one explicitly.

The public entry points are deliberately NOT jitted: they resolve the
backend (``REPRO_KERNEL_BACKEND`` is re-read on EVERY call, so flipping
the env var between calls takes effect even for already-traced shapes)
and the config, then dispatch to jitted implementations that carry both
as static arguments.  Under an outer ``jax.jit`` trace the wrappers
inline like any other Python, so compiled plans pay nothing for the
indirection.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import freq_join as _fj
from repro.kernels import segment_sum as _ss
from repro.kernels import semi_join as _sj
from repro.kernels.autotune import DEFAULT_CONFIG, KernelConfig


def default_backend() -> str:
    return os.environ.get("REPRO_KERNEL_BACKEND", "xla")


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pad1(a: jax.Array, n: int, fill) -> jax.Array:
    if a.shape[0] == n:
        return a
    return jnp.concatenate([a, jnp.full((n - a.shape[0],), fill, a.dtype)])


# --------------------------------------------------------------------------
# FreqJoin
# --------------------------------------------------------------------------
def freq_join(parent_keys, parent_freq, child_keys, child_freq, *,
              mode: str = "sum", backend: str | None = None,
              interpret: bool = True, domain: int | None = None,
              config: KernelConfig | None = None):
    """R ⋉^freq S — returns updated parent frequencies (paper §5).

    mode="sum": ℕ-semiring (COUNT/SUM propagation);
    mode="any": Boolean semiring (semi-join).

    `domain` (beyond-paper, EXPERIMENTS §Perf): when the packed join-key
    domain is known and dense, the sort+searchsorted pipeline collapses to
    one scatter-add into a domain-sized accumulator plus one gather —
    O(N) instead of O(N log N), and on TPU the exact memory pattern of an
    embedding-gradient update (well-optimised).  Falls back to sorting when
    the domain is unknown or too sparse to justify the accumulator; the
    crossover comes from ``config`` (``dense_ratio``/``dense_floor``).
    """
    backend = backend or default_backend()
    config = config or DEFAULT_CONFIG
    return _freq_join_impl(parent_keys, parent_freq, child_keys, child_freq,
                           mode=mode, backend=backend, interpret=interpret,
                           domain=domain, config=config)


@functools.partial(jax.jit, static_argnames=("mode", "backend", "interpret",
                                             "domain", "config"))
def _freq_join_impl(parent_keys, parent_freq, child_keys, child_freq, *,
                    mode: str, backend: str, interpret: bool,
                    domain: int | None, config: KernelConfig):
    if backend == "xla":
        nc = child_keys.shape[0]
        if config.dense_ok(domain, nc):
            cf = child_freq
            if mode == "any":
                cf = (cf > 0).astype(parent_freq.dtype)
            # scatter-add with EXPLICIT masking: ``mode="drop"`` alone
            # drops indices >= domain but follows NumPy semantics for
            # negative ones (wrapping them onto valid slots), which would
            # corrupt acc[domain-1] whenever dead/out-of-range child keys
            # are negative — mask to zero contribution instead
            live = (child_keys >= 0) & (child_keys < domain)
            acc = jnp.zeros((domain,), cf.dtype)
            acc = acc.at[jnp.clip(child_keys, 0, domain - 1)].add(
                jnp.where(live, cf, 0))
            mult = acc[jnp.clip(parent_keys, 0, domain - 1)]
            mult = jnp.where(
                (parent_keys >= 0) & (parent_keys < domain), mult, 0)
            mult = mult.astype(parent_freq.dtype)
            if mode == "any":
                mult = (mult > 0).astype(parent_freq.dtype)
            return parent_freq * mult
        order = jnp.argsort(child_keys)
        ck = child_keys[order]
        cf = child_freq[order]
        if mode == "any":
            cf = (cf > 0).astype(parent_freq.dtype)
        zero = jnp.zeros((1,), cf.dtype)
        prefix = jnp.concatenate([zero, jnp.cumsum(cf)])
        lo = jnp.searchsorted(ck, parent_keys, side="left")
        hi = jnp.searchsorted(ck, parent_keys, side="right")
        mult = (prefix[hi] - prefix[lo]).astype(parent_freq.dtype)
        if mode == "any":
            mult = (mult > 0).astype(parent_freq.dtype)
        return parent_freq * mult

    np_, nc = parent_keys.shape[0], child_keys.shape[0]
    ppad = config.parent_block_rows * _fj.LANES
    cpad = config.child_block_rows * _fj.LANES
    npp, ncp = _round_up(np_, ppad), _round_up(nc, cpad)
    pk = _pad1(parent_keys, npp, 0)
    pf = _pad1(parent_freq, npp, 0)
    ck = _pad1(child_keys, ncp, 0)
    cf = _pad1(child_freq, ncp, 0)  # freq-0 padding contributes nothing
    fn = _sj.semi_join_pallas if mode == "any" else functools.partial(
        _fj.freq_join_pallas, mode=mode)
    out = fn(pk, pf, ck, cf, interpret=interpret,
             parent_block_rows=config.parent_block_rows,
             child_block_rows=config.child_block_rows)
    return out[:np_]


def semi_join(parent_keys, parent_freq, child_keys, child_freq, *,
              backend: str | None = None, interpret: bool = True,
              domain: int | None = None,
              config: KernelConfig | None = None):
    """R ⋉ S over live tuples (0MA sweep step, paper §4.1)."""
    return freq_join(parent_keys, parent_freq, child_keys, child_freq,
                     mode="any", backend=backend, interpret=interpret,
                     domain=domain, config=config)


# --------------------------------------------------------------------------
# Segment sum (sorted group-by-SUM)
# --------------------------------------------------------------------------
def segment_sum_sorted(sorted_keys, values, *, backend: str | None = None,
                       interpret: bool = True,
                       config: KernelConfig | None = None):
    """GROUP BY key, SUM(value) over key-sorted input.

    Returns (sums, valid): run total at the LAST row of each run.
    """
    backend = backend or default_backend()
    config = config or DEFAULT_CONFIG
    return _segment_sum_impl(sorted_keys, values, backend=backend,
                             interpret=interpret, config=config)


@functools.partial(jax.jit, static_argnames=("backend", "interpret",
                                             "config"))
def _segment_sum_impl(sorted_keys, values, *, backend: str, interpret: bool,
                      config: KernelConfig):
    n = sorted_keys.shape[0]
    if backend == "xla":
        is_first = jnp.concatenate(
            [jnp.ones((1,), bool), sorted_keys[1:] != sorted_keys[:-1]])
        is_last = jnp.concatenate(
            [sorted_keys[1:] != sorted_keys[:-1], jnp.ones((1,), bool)])
        run_id = jnp.cumsum(is_first.astype(jnp.int32)) - 1
        sums = jax.ops.segment_sum(values, run_id, num_segments=n)
        out = jnp.where(is_last, jnp.take(sums, run_id), jnp.zeros((), values.dtype))
        return out, is_last

    npad = _round_up(n, config.lanes_wide)
    # padded keys must sort last: use max-representable key
    maxk = jnp.asarray(jnp.iinfo(sorted_keys.dtype).max, sorted_keys.dtype)
    ks = _pad1(sorted_keys, npad, maxk)
    vs = _pad1(values, npad, 0)
    out, valid = _ss.segment_sum_pallas(ks, vs, interpret=interpret,
                                        lanes_wide=config.lanes_wide)
    return out[:n], valid[:n]


def group_by_sum(keys, values, *, backend: str | None = None,
                 interpret: bool = True,
                 config: KernelConfig | None = None):
    """Unsorted group-by: sort once, then segment-sum.  Returns
    (sorted_keys, sums, valid) so downstream FreqJoins can reuse the sort."""
    order = jnp.argsort(keys)
    ks = keys[order]
    vs = values[order]
    sums, valid = segment_sum_sorted(ks, vs, backend=backend,
                                     interpret=interpret, config=config)
    return ks, sums, valid


# --------------------------------------------------------------------------
# Weighted percentile (MEDIAN rewrite, paper §4.2)
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=())
def weighted_percentile(values, weights, q):
    """PERCENTILE(q, A, freq) — lower-interpolation weighted percentile.

    Rows with weight 0 (dead tuples) are ignored: their values are moved to
    +inf before the sort so they never land below the target mass.
    """
    big = jnp.asarray(jnp.finfo(values.dtype).max if
                      jnp.issubdtype(values.dtype, jnp.floating)
                      else jnp.iinfo(values.dtype).max, values.dtype)
    v = jnp.where(weights > 0, values, big)
    order = jnp.argsort(v)
    vs = v[order]
    acc_dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    ws = weights[order].astype(acc_dtype)
    cw = jnp.cumsum(ws)
    target = q * cw[-1]
    idx = jnp.clip(jnp.searchsorted(cw, target, side="left"), 0,
                   values.shape[0] - 1)
    return vs[idx]
