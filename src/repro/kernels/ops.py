"""Public kernel ops: padding, backend dispatch, XLA twins.

Every physical operator has two interchangeable backends:

  * ``"pallas"`` — the TPU kernels in freq_join.py / semi_join.py /
    segment_sum.py (on this CPU container they run in interpret mode,
    which executes the kernel body in Python and is used for validation);
  * ``"xla"``    — algorithmically equivalent sort/searchsorted/segment-sum
    formulations lowered by XLA; these are what the CPU benchmarks time and
    what the distributed executor traces through `shard_map` (collectives
    compose with XLA ops on every backend).

Both are tested against the O(N·M) oracles in ref.py.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import freq_join as _fj
from repro.kernels import segment_sum as _ss
from repro.kernels import semi_join as _sj

_PARENT_PAD = _fj.PARENT_BLOCK_ROWS * _fj.LANES
_CHILD_PAD = _fj.CHILD_BLOCK_ROWS * _fj.LANES


def default_backend() -> str:
    return os.environ.get("REPRO_KERNEL_BACKEND", "xla")


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pad1(a: jax.Array, n: int, fill) -> jax.Array:
    if a.shape[0] == n:
        return a
    return jnp.concatenate([a, jnp.full((n - a.shape[0],), fill, a.dtype)])


# --------------------------------------------------------------------------
# FreqJoin
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("mode", "backend", "interpret",
                                              "domain"))
def freq_join(parent_keys, parent_freq, child_keys, child_freq, *,
              mode: str = "sum", backend: str | None = None,
              interpret: bool = True, domain: int | None = None):
    """R ⋉^freq S — returns updated parent frequencies (paper §5).

    mode="sum": ℕ-semiring (COUNT/SUM propagation);
    mode="any": Boolean semiring (semi-join).

    `domain` (beyond-paper, EXPERIMENTS §Perf): when the packed join-key
    domain is known and dense, the sort+searchsorted pipeline collapses to
    one scatter-add into a domain-sized accumulator plus one gather —
    O(N) instead of O(N log N), and on TPU the exact memory pattern of an
    embedding-gradient update (well-optimised).  Falls back to sorting when
    the domain is unknown or too sparse to justify the accumulator.
    """
    backend = backend or default_backend()
    if backend == "xla":
        nc = child_keys.shape[0]
        if domain is not None and domain <= max(4 * nc, 1 << 20) \
                and domain < (1 << 31):
            cf = child_freq
            if mode == "any":
                cf = (cf > 0).astype(parent_freq.dtype)
            acc = jnp.zeros((domain,), cf.dtype)
            acc = acc.at[child_keys].add(cf, mode="drop")
            mult = acc[jnp.clip(parent_keys, 0, domain - 1)]
            mult = jnp.where(
                (parent_keys >= 0) & (parent_keys < domain), mult, 0)
            mult = mult.astype(parent_freq.dtype)
            if mode == "any":
                mult = (mult > 0).astype(parent_freq.dtype)
            return parent_freq * mult
        order = jnp.argsort(child_keys)
        ck = child_keys[order]
        cf = child_freq[order]
        if mode == "any":
            cf = (cf > 0).astype(parent_freq.dtype)
        zero = jnp.zeros((1,), cf.dtype)
        prefix = jnp.concatenate([zero, jnp.cumsum(cf)])
        lo = jnp.searchsorted(ck, parent_keys, side="left")
        hi = jnp.searchsorted(ck, parent_keys, side="right")
        mult = (prefix[hi] - prefix[lo]).astype(parent_freq.dtype)
        if mode == "any":
            mult = (mult > 0).astype(parent_freq.dtype)
        return parent_freq * mult

    np_, nc = parent_keys.shape[0], child_keys.shape[0]
    npp, ncp = _round_up(np_, _PARENT_PAD), _round_up(nc, _CHILD_PAD)
    pk = _pad1(parent_keys, npp, 0)
    pf = _pad1(parent_freq, npp, 0)
    ck = _pad1(child_keys, ncp, 0)
    cf = _pad1(child_freq, ncp, 0)  # freq-0 padding contributes nothing
    fn = _sj.semi_join_pallas if mode == "any" else functools.partial(
        _fj.freq_join_pallas, mode=mode)
    out = fn(pk, pf, ck, cf, interpret=interpret)
    return out[:np_]


def semi_join(parent_keys, parent_freq, child_keys, child_freq, *,
              backend: str | None = None, interpret: bool = True,
              domain: int | None = None):
    """R ⋉ S over live tuples (0MA sweep step, paper §4.1)."""
    return freq_join(parent_keys, parent_freq, child_keys, child_freq,
                     mode="any", backend=backend, interpret=interpret,
                     domain=domain)


# --------------------------------------------------------------------------
# Segment sum (sorted group-by-SUM)
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("backend", "interpret"))
def segment_sum_sorted(sorted_keys, values, *, backend: str | None = None,
                       interpret: bool = True):
    """GROUP BY key, SUM(value) over key-sorted input.

    Returns (sums, valid): run total at the LAST row of each run.
    """
    backend = backend or default_backend()
    n = sorted_keys.shape[0]
    if backend == "xla":
        is_first = jnp.concatenate(
            [jnp.ones((1,), bool), sorted_keys[1:] != sorted_keys[:-1]])
        is_last = jnp.concatenate(
            [sorted_keys[1:] != sorted_keys[:-1], jnp.ones((1,), bool)])
        run_id = jnp.cumsum(is_first.astype(jnp.int32)) - 1
        sums = jax.ops.segment_sum(values, run_id, num_segments=n)
        out = jnp.where(is_last, jnp.take(sums, run_id), jnp.zeros((), values.dtype))
        return out, is_last

    npad = _round_up(n, _ss.LANES_WIDE)
    # padded keys must sort last: use max-representable key
    maxk = jnp.asarray(jnp.iinfo(sorted_keys.dtype).max, sorted_keys.dtype)
    ks = _pad1(sorted_keys, npad, maxk)
    vs = _pad1(values, npad, 0)
    out, valid = _ss.segment_sum_pallas(ks, vs, interpret=interpret)
    return out[:n], valid[:n]


def group_by_sum(keys, values, *, backend: str | None = None,
                 interpret: bool = True):
    """Unsorted group-by: sort once, then segment-sum.  Returns
    (sorted_keys, sums, valid) so downstream FreqJoins can reuse the sort."""
    order = jnp.argsort(keys)
    ks = keys[order]
    vs = values[order]
    sums, valid = segment_sum_sorted(ks, vs, backend=backend,
                                     interpret=interpret)
    return ks, sums, valid


# --------------------------------------------------------------------------
# Weighted percentile (MEDIAN rewrite, paper §4.2)
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=())
def weighted_percentile(values, weights, q):
    """PERCENTILE(q, A, freq) — lower-interpolation weighted percentile.

    Rows with weight 0 (dead tuples) are ignored: their values are moved to
    +inf before the sort so they never land below the target mass.
    """
    big = jnp.asarray(jnp.finfo(values.dtype).max if
                      jnp.issubdtype(values.dtype, jnp.floating)
                      else jnp.iinfo(values.dtype).max, values.dtype)
    v = jnp.where(weights > 0, values, big)
    order = jnp.argsort(v)
    vs = v[order]
    acc_dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    ws = weights[order].astype(acc_dtype)
    cw = jnp.cumsum(ws)
    target = q * cw[-1]
    idx = jnp.clip(jnp.searchsorted(cw, target, side="left"), 0,
                   values.shape[0] - 1)
    return vs[idx]
