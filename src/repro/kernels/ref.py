"""Pure-jnp oracles for every kernel in repro.kernels.

These are the semantic ground truth: O(N·M) / unvectorised-but-obvious
implementations that the Pallas kernels (interpret mode) and the XLA twins
in ops.py are tested against.
"""

from __future__ import annotations

import jax.numpy as jnp


def freq_join_ref(parent_keys, parent_freq, child_keys, child_freq):
    """FreqJoin (paper §5), ℕ-semiring sum-product.

    For each parent row i:
        mult_i = Σ_j child_freq[j] · [child_keys[j] == parent_keys[i]]
        out_i  = parent_freq[i] · mult_i

    A dangling parent tuple (no join partner) gets out_i = 0, which is the
    static-shape analogue of the paper's "if r.c = 0 then delete".
    """
    eq = parent_keys[:, None] == child_keys[None, :]          # [Np, Nc]
    mult = jnp.sum(jnp.where(eq, child_freq[None, :], 0), axis=1)
    return parent_freq * mult.astype(parent_freq.dtype)


def semi_join_ref(parent_keys, parent_freq, child_keys, child_freq):
    """Semi-join (0MA sweep, §4.1): Boolean semiring specialisation.

    out_i = parent_freq[i] if parent_keys[i] has a live join partner else 0.
    """
    eq = parent_keys[:, None] == child_keys[None, :]
    live = eq & (child_freq[None, :] > 0)
    return jnp.where(jnp.any(live, axis=1), parent_freq, 0)


def segment_sum_ref(sorted_keys, values):
    """Group-by-SUM over a key-sorted array (paper §4.2 pre-grouping).

    Returns (out_values, out_valid):
      out_values[i] = Σ_j values[j] over the run of keys equal to
                      sorted_keys[i], emitted at the FIRST row of each run
                      (0 elsewhere);
      out_valid[i]  = True iff row i is the first row of its run.

    Dead rows (freq 0) are the caller's concern: they carry value 0 and thus
    do not perturb sums; a run consisting only of dead rows emits sum 0.
    """
    n = sorted_keys.shape[0]
    is_first = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_keys[1:] != sorted_keys[:-1]]
    )
    # run id per row, then one-hot sum — O(N^2) oracle, clear and exact.
    run_id = jnp.cumsum(is_first.astype(jnp.int32)) - 1
    eq = run_id[:, None] == run_id[None, :]                    # [N, N]
    run_sums = jnp.sum(jnp.where(eq, values[None, :], 0), axis=1)
    out = jnp.where(is_first, run_sums.astype(values.dtype), 0)
    return out, is_first


def weighted_percentile_ref(values, weights, q):
    """Weighted percentile with *lower* interpolation over live rows.

    Equivalent to Spark's PERCENTILE(q, A, freq) on the expanded bag:
    the smallest v such that cumweight(v) >= q * totalweight.
    Rows with weight 0 are ignored.  Oracle is a simple sort + scan.
    """
    order = jnp.argsort(values)
    v = values[order]
    w = weights[order].astype(jnp.float64 if values.dtype == jnp.float64 else jnp.float32)
    cw = jnp.cumsum(w)
    total = cw[-1]
    target = q * total
    idx = jnp.searchsorted(cw, target, side="left")
    idx = jnp.clip(idx, 0, values.shape[0] - 1)
    return v[idx]
