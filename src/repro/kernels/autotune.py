"""Kernel autotuner: measured block/dispatch search per shape bucket.

The three kernels (freq_join, semi_join, segment_sum) historically ran
fixed block shapes and a hard-coded dense-domain dispatch threshold
regardless of input size or backend.  This module closes the loop that
``benchmarks/roofline.py`` opened: it parametrises the kernels over a
small config space (``KernelConfig``), measures every candidate on
synthetic inputs shaped like the serving bucket, gates each candidate on
BITWISE equality with the untuned result, and keeps the winner in a
``TuneTable`` keyed by ``(kernel, shape bucket, backend)``.

Shape buckets are the SAME power-of-two buckets the plan cache uses
(``repro.tables.table.bucket_capacity`` semantics): a table growing
inside its bucket hits the same tune entry, so within-bucket growth
never retunes — matching the serving tier's never-recompile invariant.

The config space, per (kernel, backend):

* ``("freq_join"|"semi_join", "xla")``   — ``dense_ratio``/``dense_floor``:
  where the sort+searchsorted pipeline should hand over to the
  scatter-add dense-domain path (``kernels/ops.py``).  Candidates are
  measured over a grid of key-domain probes spanning the crossover, so
  the winning ratio is the one that dispatches best across the whole
  domain range the bucket may see, not at one lucky point.
* ``("freq_join"|"semi_join", "pallas")`` — ``parent_block_rows`` ×
  ``child_block_rows`` for the blocked broadcast-compare kernels.
* ``("segment_sum", "pallas")``          — ``lanes_wide`` block width.
* ``("segment_sum", "xla")``             — nothing to tune (one
  candidate); ``search`` returns the default without measuring.

Persistence lives one layer up (``repro.service.tune_store.TuneStore``,
same cache_dir and store discipline as the plan store); ``KernelTuner``
consults it table → store → measured search, so a warm-started service
re-measures nothing (``tune_searches == 0``).

Timing uses ``time.perf_counter`` directly — this is the kernel layer's
offline calibration path, not the serving tier (whose clock discipline
``scripts/lint.py`` enforces for ``src/repro/service/`` only).  Rows can
be forwarded to a ``benchmarks.recorder.Recorder`` by passing its
``row`` method as the sink.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

KERNELS = ("freq_join", "semi_join", "segment_sum")

# structural (non-tunable) bound on the dense-domain accumulator: int32
# packed keys cannot index past 2^31 regardless of measured preference
DENSE_DOMAIN_CAP = 1 << 31


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One point in the kernel config space.  Frozen (hashable), so a
    config is a valid ``jax.jit`` static argument — ``kernels/ops.py``
    traces one program per (shapes, backend, config).

    The defaults reproduce the untuned behaviour exactly: 8×128 fp32
    native tiles for the blocked joins, (1, 1024) blocks for the
    segmented sum, and the historical ``max(4·nc, 2^20)`` dense-domain
    crossover.  ``dense_ratio <= 0`` disables the dense path entirely.
    """

    parent_block_rows: int = 8
    child_block_rows: int = 8
    lanes_wide: int = 1024
    dense_ratio: int = 4
    dense_floor: int = 1 << 20

    def dense_ok(self, domain: int | None, n_child: int) -> bool:
        """Should the XLA freq-join dispatch to the scatter-add dense
        path for this (domain, child-size)?"""
        return (domain is not None and self.dense_ratio > 0
                and domain <= max(self.dense_ratio * n_child,
                                  self.dense_floor)
                and domain < DENSE_DOMAIN_CAP)


DEFAULT_CONFIG = KernelConfig()


def _pow2(n: int) -> int:
    n = max(1, int(n))
    return 1 << (n - 1).bit_length()


def bucket_shape(*sizes: int) -> tuple[int, ...]:
    """Round each size up to a power of two — the tune-table key uses the
    same bucket boundaries as the serving tier's shape buckets, so a
    bucket-padded input always looks up the entry its bucket was tuned
    at."""
    return tuple(_pow2(s) for s in sizes)


def candidate_configs(kernel: str, backend: str) -> list[KernelConfig]:
    """The measured search space for one (kernel, backend).  Always
    includes ``DEFAULT_CONFIG`` (so the search can never do worse than
    untuned) and keeps irrelevant fields at their defaults (so configs
    stay comparable and the jit static-arg space stays small)."""
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}")
    out = [DEFAULT_CONFIG]
    if kernel in ("freq_join", "semi_join"):
        if backend == "xla":
            for ratio in (0, 32, 256):
                out.append(dataclasses.replace(DEFAULT_CONFIG,
                                               dense_ratio=ratio))
        else:
            for pbr, cbr in ((16, 8), (8, 16), (16, 16), (32, 8)):
                out.append(dataclasses.replace(
                    DEFAULT_CONFIG, parent_block_rows=pbr,
                    child_block_rows=cbr))
    elif kernel == "segment_sum" and backend != "xla":
        for lw in (512, 2048, 4096):
            out.append(dataclasses.replace(DEFAULT_CONFIG, lanes_wide=lw))
    return out


class TuneTable:
    """In-memory tuned-config table: (kernel, shape bucket, backend) →
    ``KernelConfig``.  Lookups bucket the raw sizes, so callers pass the
    concrete (already bucket-padded) array lengths they are about to run.
    Misses return None — ``kernels/ops.py`` treats that as
    ``DEFAULT_CONFIG``.  Thread-safe: the serving tier reads it from
    concurrent compile threads while ``autotune()`` installs entries."""

    def __init__(self):
        self._d: dict[tuple, KernelConfig] = {}
        self._lock = threading.Lock()

    @staticmethod
    def key(kernel: str, shape, backend: str) -> tuple:
        return (kernel, bucket_shape(*shape), backend)

    def lookup(self, kernel: str, shape, backend: str) -> KernelConfig | None:
        with self._lock:
            return self._d.get(self.key(kernel, shape, backend))

    def install(self, kernel: str, shape, backend: str,
                config: KernelConfig) -> None:
        with self._lock:
            self._d[self.key(kernel, shape, backend)] = config

    def entries(self) -> list[tuple[tuple, KernelConfig]]:
        with self._lock:
            return list(self._d.items())

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)


# --------------------------------------------------------------------------
# synthetic inputs + measurement
# --------------------------------------------------------------------------
def _synth_join(shape: tuple[int, int], domain: int):
    """Deterministic join inputs for one bucket: keys uniform over
    ``domain`` (including a sprinkle of out-of-range/negative child keys,
    so the bitwise gate also covers the scatter path's masking), freqs
    small positive ints."""
    np_, nc = shape
    rng = np.random.default_rng((np_, nc, domain, 0xA11CE))
    pk = rng.integers(0, domain, np_, dtype=np.int64).astype(np.int32)
    ck = rng.integers(0, domain, nc, dtype=np.int64).astype(np.int32)
    # a few dead/OOB child keys exercise every candidate's masking
    oob = rng.random(nc) < 0.01
    ck = np.where(oob, np.where(rng.random(nc) < 0.5, -1, domain), ck)
    pf = rng.integers(1, 4, np_, dtype=np.int32)
    cf = rng.integers(0, 4, nc, dtype=np.int32)
    return (jnp.asarray(pk), jnp.asarray(pf),
            jnp.asarray(ck), jnp.asarray(cf))


def _synth_segment(shape: tuple[int, ...]):
    (n,) = shape
    rng = np.random.default_rng((n, 0x5E6))
    keys = np.sort(rng.integers(0, max(2, n // 4), n,
                                dtype=np.int64).astype(np.int32))
    vals = rng.integers(0, 100, n, dtype=np.int64).astype(np.int32)
    return jnp.asarray(keys), jnp.asarray(vals)


def _domain_probes(nc: int) -> list[int]:
    """Key-domain grid spanning the dense/sort crossover for a child
    bucket of ``nc`` rows — from comfortably-dense to clearly-sparse,
    capped below the structural 2^31 accumulator bound."""
    probes = []
    for mult in (1, 8, 16, 64):
        d = nc * mult
        if 2 <= d < DENSE_DOMAIN_CAP:
            probes.append(d)
    return probes or [max(2, nc)]


def measure(fn: Callable[[], Any], repeats: int = 3) -> float:
    """Best-of-``repeats`` wall-clock seconds for ``fn`` (one warmup call
    first, so compile/trace time never pollutes the comparison)."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _bitwise_equal(a, b) -> bool:
    flat_a = jax.tree_util.tree_leaves(a)
    flat_b = jax.tree_util.tree_leaves(b)
    if len(flat_a) != len(flat_b):
        return False
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(flat_a, flat_b))


class KernelTuner:
    """Measured config search with a store-backed warm path.

    Resolution order in ``ensure``: in-memory ``TuneTable`` → persistent
    ``TuneStore`` (when constructed with one) → measured ``search``.
    Only the last bumps ``tune_searches`` — a warm-started service whose
    store already holds every bucket reports ``tune_searches == 0``,
    mirroring the plan cache's ``plan_builds == 0`` invariant.

    ``row(name, us, derived)`` is an optional timing sink with the
    ``benchmarks.recorder.Recorder.row`` signature, so benchmark runs
    can record the full candidate trajectory without this package
    depending on ``benchmarks/``.
    """

    def __init__(self, store=None, *, backend: str = "xla",
                 interpret: bool = True, repeats: int = 3,
                 row: Callable[..., Any] | None = None):
        self.table = TuneTable()
        self.store = store
        self.backend = backend
        self.interpret = interpret
        self.repeats = repeats
        self.row = row
        self._lock = threading.Lock()
        self.counters = {
            "tune_searches": 0,        # measured searches actually run
            "tune_candidates": 0,      # candidate configs measured
            "tune_gate_rejects": 0,    # candidates failing the bitwise gate
            "tune_store_hits": 0,      # configs loaded from the store
            "tune_installs": 0,        # entries installed into the table
        }

    # ---- resolution ------------------------------------------------------
    def load_persisted(self) -> int:
        """Install every valid store entry for this tuner's backend into
        the table (warm start).  Returns the number installed."""
        if self.store is None:
            return 0
        n = 0
        for (kernel, shape, backend), config in self.store.load_all():
            if backend != self.backend:
                continue
            self.table.install(kernel, shape, backend, config)
            n += 1
        if n:
            with self._lock:
                self.counters["tune_store_hits"] += n
                self.counters["tune_installs"] += n
        return n

    def ensure(self, kernel: str, shape) -> KernelConfig:
        """The tuned config for (kernel, bucket(shape)) — from the table,
        the store, or a fresh measured search (persisted on the way
        out)."""
        bshape = bucket_shape(*shape)
        cfg = self.table.lookup(kernel, bshape, self.backend)
        if cfg is not None:
            return cfg
        if self.store is not None:
            cfg = self.store.load(kernel, bshape, self.backend)
            if cfg is not None:
                self.table.install(kernel, bshape, self.backend, cfg)
                with self._lock:
                    self.counters["tune_store_hits"] += 1
                    self.counters["tune_installs"] += 1
                return cfg
        cfg, measurements = self.search(kernel, bshape)
        self.table.install(kernel, bshape, self.backend, cfg)
        with self._lock:
            self.counters["tune_installs"] += 1
        if self.store is not None:
            self.store.save(kernel, bshape, self.backend, cfg,
                            measurements=measurements)
        return cfg

    # ---- search ----------------------------------------------------------
    def search(self, kernel: str,
               shape) -> tuple[KernelConfig, dict[str, float]]:
        """Measure every candidate for (kernel, bucket(shape)); return
        (winner, per-candidate best seconds).  Every candidate's answer
        is bitwise-gated against ``DEFAULT_CONFIG``'s; a gate failure
        drops the candidate (counted), it can never win."""
        bshape = bucket_shape(*shape)
        cands = candidate_configs(kernel, self.backend)
        with self._lock:
            self.counters["tune_searches"] += 1
        if len(cands) == 1:
            return cands[0], {}

        scenarios = self._scenarios(kernel, bshape)
        baselines = [fn(DEFAULT_CONFIG) for _, fn in scenarios]
        best_cfg, best_t = DEFAULT_CONFIG, float("inf")
        measurements: dict[str, float] = {}
        for cfg in cands:
            with self._lock:
                self.counters["tune_candidates"] += 1
            total = 0.0
            ok = True
            for (label, fn), base in zip(scenarios, baselines):
                if not _bitwise_equal(fn(cfg), base):
                    ok = False
                    break
                total += measure(lambda: fn(cfg), self.repeats)
            tag = self._cfg_tag(kernel, cfg)
            if not ok:
                # zero-drift gate: a diverging candidate is dropped on
                # the spot — it can never win, however fast it measured
                with self._lock:
                    self.counters["tune_gate_rejects"] += 1
                continue
            measurements[tag] = total
            if self.row is not None:
                self.row(f"tune/{kernel}/{self.backend}/"
                         f"{'x'.join(map(str, bshape))}/{tag}",
                         total * 1e6,
                         {"candidates": len(cands)})
            if total < best_t:
                best_cfg, best_t = cfg, total
        return best_cfg, measurements

    def _scenarios(self, kernel: str, bshape: tuple[int, ...]):
        """(label, config → answer) closures the search measures.  Joins
        run one scenario per domain probe so dispatch-policy candidates
        are scored across the whole crossover range."""
        from repro.kernels import ops  # deferred: ops imports KernelConfig

        if kernel in ("freq_join", "semi_join"):
            mode = "any" if kernel == "semi_join" else "sum"
            out = []
            for dom in _domain_probes(bshape[1]):
                args = _synth_join(bshape, dom)

                def fn(cfg, args=args, dom=dom):
                    return ops.freq_join(
                        *args, mode=mode, backend=self.backend,
                        interpret=self.interpret, domain=dom, config=cfg)

                out.append((f"domain{dom}", fn))
            return out
        keys, vals = _synth_segment(bshape)

        def fn(cfg):
            return ops.segment_sum_sorted(
                keys, vals, backend=self.backend,
                interpret=self.interpret, config=cfg)

        return [("sorted", fn)]

    @staticmethod
    def _cfg_tag(kernel: str, cfg: KernelConfig) -> str:
        if kernel == "segment_sum":
            return f"lanes{cfg.lanes_wide}"
        return (f"pb{cfg.parent_block_rows}_cb{cfg.child_block_rows}"
                f"_ratio{cfg.dense_ratio}")

    # ---- observability ---------------------------------------------------
    def metrics(self) -> dict[str, int]:
        with self._lock:
            out = dict(self.counters)
        out["tune_entries"] = len(self.table)
        return out


TUNE_ZEROS = {
    "tune_searches": 0, "tune_candidates": 0, "tune_gate_rejects": 0,
    "tune_store_hits": 0, "tune_installs": 0, "tune_entries": 0,
}
