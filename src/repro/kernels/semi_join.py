"""Semi-join Pallas TPU kernel (paper §4.1, the 0MA bottom-up sweep).

The 0MA evaluation strategy reduces a whole aggregate query to a chain of
semi-joins.  A semi-join is the Boolean-semiring specialisation of FreqJoin
(paper §5: "in the worst case FreqJoin effectively becomes a semi-join"), so
the kernel shares its blocked broadcast-compare structure with
freq_join.py, accumulating with OR instead of +.

out_i = parent_freq[i]  if ∃ live child row with equal key, else 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.freq_join import (
    CHILD_BLOCK_ROWS,
    LANES,
    PARENT_BLOCK_ROWS,
)


def _semi_join_kernel(pk_ref, pf_ref, ck_ref, cf_ref, out_ref, *,
                      n_child_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pk = pk_ref[...]
    acc = out_ref[...]

    def body(r, acc):
        ck_row = ck_ref[r, :]
        cf_row = cf_ref[r, :]
        eq = pk[:, :, None] == ck_row[None, None, :]
        live = eq & (cf_row[None, None, :] > 0)
        return jnp.maximum(acc, jnp.any(live, axis=-1).astype(acc.dtype))

    acc = jax.lax.fori_loop(0, ck_ref.shape[0], body, acc)
    out_ref[...] = acc

    @pl.when(j == n_child_blocks - 1)
    def _finalise():
        out_ref[...] = pf_ref[...] * out_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",
                                             "parent_block_rows",
                                             "child_block_rows"))
def semi_join_pallas(parent_keys, parent_freq, child_keys, child_freq,
                     *, interpret: bool = False,
                     parent_block_rows: int = PARENT_BLOCK_ROWS,
                     child_block_rows: int = CHILD_BLOCK_ROWS):
    """Blocked semi-join; same padding contract as freq_join_pallas."""
    pbr, cbr = parent_block_rows, child_block_rows
    np_, nc = parent_keys.shape[0], child_keys.shape[0]
    pb, cb = pbr * LANES, cbr * LANES
    assert np_ % pb == 0 and nc % cb == 0, (np_, nc)
    n_pb, n_cb = np_ // pb, nc // cb

    pk2 = parent_keys.reshape(n_pb * pbr, LANES)
    pf2 = parent_freq.reshape(n_pb * pbr, LANES)
    ck2 = child_keys.reshape(n_cb * cbr, LANES)
    cf2 = child_freq.reshape(n_cb * cbr, LANES)

    kernel = functools.partial(_semi_join_kernel, n_child_blocks=n_cb)
    out = pl.pallas_call(
        kernel,
        grid=(n_pb, n_cb),
        in_specs=[
            pl.BlockSpec((pbr, LANES), lambda i, j: (i, 0)),
            pl.BlockSpec((pbr, LANES), lambda i, j: (i, 0)),
            pl.BlockSpec((cbr, LANES), lambda i, j: (j, 0)),
            pl.BlockSpec((cbr, LANES), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((pbr, LANES), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(pf2.shape, parent_freq.dtype),
        interpret=interpret,
    )(pk2, pf2, ck2, cf2)
    return out.reshape(np_)
