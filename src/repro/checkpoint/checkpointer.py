"""Sharded, async, elastic checkpointing.

Layout:  <dir>/step_<N>/manifest.json + one .npy per pytree leaf.

Design points for 1000+-node deployments (scaled to this container):

  * **Sharding-agnostic restore.** Leaves are saved as full logical arrays
    with a manifest of paths/shapes/dtypes; `restore(..., shardings=...)`
    re-places them under ANY mesh — a job checkpointed on (16,16) restores
    onto (2,16,16) or a single CPU (elastic re-scaling test in
    tests/test_checkpoint.py).  On a real multi-host pod each host would
    write only its addressable shards with the same manifest format; the
    single-process container degenerates to full arrays.
  * **Async save** off the critical path (background thread; `wait()`
    joins).  Training continues while the previous step serialises.
  * **Atomicity**: saves land in `step_N.tmp` and are renamed only after
    the manifest is fully written — a mid-save crash can't corrupt the
    latest complete checkpoint.
  * **Resume idempotence**: `latest_step()` + the deterministic data
    pipeline (repro.data.lm_pipeline) make restart-replay exact.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str | pathlib.Path):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, async_: bool = True):
        """Snapshot `tree` at `step`. Device arrays are fetched to host
        before the background write so training can mutate them freely."""
        flat, _ = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {}
            for i, (k, v) in enumerate(sorted(host.items())):
                fname = f"leaf_{i:05d}.npy"
                np.save(tmp / fname, v)
                manifest[k] = {"file": fname, "shape": list(v.shape),
                               "dtype": str(v.dtype)}
            with open(tmp / "manifest.json", "w") as f:
                json.dump({"step": step, "leaves": manifest}, f)
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)

        self.wait()
        if async_:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                 if not p.name.endswith(".tmp")]
        return max(steps) if steps else None

    def restore(self, like: Any, step: int | None = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `like`.  `shardings` (optional)
        is a matching pytree of jax.sharding.Sharding for elastic
        re-placement onto a (possibly different) mesh."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step}"
        with open(d / "manifest.json") as f:
            manifest = json.load(f)["leaves"]

        flat_like, treedef = _flatten(like)
        if set(flat_like) != set(manifest):
            missing = set(flat_like) ^ set(manifest)
            raise ValueError(f"checkpoint/model structure mismatch: {missing}")

        flat_sh = None
        if shardings is not None:
            flat_sh, _ = _flatten(shardings)

        out = {}
        for k in flat_like:
            arr = np.load(d / manifest[k]["file"])
            if flat_sh is not None:
                out[k] = jax.device_put(arr, flat_sh[k])
            else:
                out[k] = jnp.asarray(arr)
        leaves = [out[k] for k in sorted(flat_like)]
        ordered = [out[k] for k, _ in
                   sorted(((k, None) for k in flat_like), key=lambda x: x[0])]
        # rebuild in the original leaf order of `like`
        paths, _ = jax.tree_util.tree_flatten_with_path(like)
        keys_in_order = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path) for path, _ in paths]
        del leaves, ordered
        return jax.tree_util.tree_unflatten(
            treedef, [out[k] for k in keys_in_order])
