from repro.tables.table import (
    ColumnMeta,
    ForeignKey,
    RelSchema,
    Schema,
    Table,
    pack_keys,
)

__all__ = [
    "ColumnMeta",
    "ForeignKey",
    "RelSchema",
    "Schema",
    "Table",
    "pack_keys",
]
