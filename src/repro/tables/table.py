"""Fixed-shape columnar table substrate.

JAX requires static shapes, so relations never shrink or grow: a ``Table``
has a fixed ``capacity`` and carries a *frequency* column ``freq``.  A live
tuple has ``freq > 0``; selections and semi-joins zero frequencies instead of
deleting rows; the FreqJoin operator multiplies them.  This is exactly the
paper's K-relation view (semiring annotations) made static.

Columns are 1-D arrays of identical length.  Schema metadata (primary keys,
uniqueness, FK edges, domain sizes) drives the paper's §4.1 set-safety and
§4.3 FK/PK optimisations.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ColumnMeta:
    """Static metadata for one column of a relation."""

    name: str
    unique: bool = False          # declared UNIQUE / PK component
    domain: int | None = None     # values are ints in [0, domain) if known


@dataclasses.dataclass(frozen=True)
class ForeignKey:
    """FK edge: ``src.src_col`` references ``dst.dst_col`` (a PK/unique col)."""

    src: str
    src_col: str
    dst: str
    dst_col: str


@dataclasses.dataclass(frozen=True)
class RelSchema:
    """Schema of one relation."""

    name: str
    columns: tuple[ColumnMeta, ...]

    def column_names(self) -> tuple[str, ...]:
        return tuple(c.name for c in self.columns)

    def meta(self, name: str) -> ColumnMeta:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(f"{self.name} has no column {name!r}")

    def is_unique(self, cols: Sequence[str]) -> bool:
        """True if `cols` contains at least one declared-unique column.
        Unknown names raise (via ``meta``): a typo in FK/PK metadata must
        not silently flip a §4.3 pre-grouping decision."""
        return any(self.meta(c).unique for c in cols)


@dataclasses.dataclass(frozen=True)
class Schema:
    """Database schema: relations + FK edges."""

    relations: Mapping[str, RelSchema]
    foreign_keys: tuple[ForeignKey, ...] = ()

    def fk_edge(self, src: str, src_col: str, dst: str, dst_col: str) -> bool:
        """True if src.src_col → dst.dst_col is a declared FK into a unique col."""
        for fk in self.foreign_keys:
            if (fk.src, fk.src_col, fk.dst, fk.dst_col) == (src, src_col, dst, dst_col):
                return True
        return False


@jax.tree_util.register_pytree_node_class
class Table:
    """A fixed-capacity columnar relation with a frequency column.

    ``columns``: dict name → 1-D array, all of length ``capacity``.
    ``freq``:    1-D array of length ``capacity``; 0 marks dead/padded rows.
    """

    def __init__(self, columns: dict[str, jax.Array], freq: jax.Array):
        self.columns = dict(columns)
        self.freq = freq

    # ---- pytree protocol --------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        children = tuple(self.columns[n] for n in names) + (self.freq,)
        return children, names

    @classmethod
    def tree_unflatten(cls, names, children):
        cols = dict(zip(names, children[:-1]))
        return cls(cols, children[-1])

    # ---- construction -----------------------------------------------------
    @classmethod
    def from_numpy(
        cls,
        data: Mapping[str, np.ndarray],
        freq_dtype: Any = jnp.int32,
        capacity: int | None = None,
    ) -> "Table":
        n = len(next(iter(data.values())))
        cap = capacity if capacity is not None else n
        if cap < n:
            raise ValueError(
                f"capacity {cap} below data length {n}; tables never "
                "shrink (drop rows by zeroing freq instead)")
        cols = {}
        for k, v in data.items():
            arr = np.asarray(v)
            if cap > n:
                pad = np.zeros((cap - n,) + arr.shape[1:], dtype=arr.dtype)
                arr = np.concatenate([arr, pad])
            cols[k] = jnp.asarray(arr)
        freq = jnp.concatenate(
            [jnp.ones((n,), freq_dtype), jnp.zeros((cap - n,), freq_dtype)]
        )
        return cls(cols, freq)

    # ---- basic properties ---------------------------------------------
    @property
    def capacity(self) -> int:
        return int(self.freq.shape[0])

    @property
    def column_names(self) -> tuple[str, ...]:
        return tuple(sorted(self.columns))

    def live_count(self) -> jax.Array:
        """Number of live tuples (rows with freq > 0) — the paper's
        'materialised tuples' metric for this relation."""
        return jnp.sum((self.freq > 0).astype(jnp.int64 if jax.config.jax_enable_x64 else jnp.int32))

    def weight_total(self) -> jax.Array:
        """Sum of frequencies = bag cardinality this table represents."""
        return jnp.sum(self.freq)

    def content_token(self) -> str:
        """Cheap content hash of the table's data version: one sha256 over
        every column's bytes plus the frequency column.  The statistics
        layer keys per-table stats on this token, so a warm restart over
        identical data recognises its persisted stats without recomputing
        them, and any data change (new rows, zeroed frequencies, padding)
        invalidates every decision calibrated against the old version."""
        import hashlib
        h = hashlib.sha256()
        for name in self.column_names:
            arr = np.asarray(self.columns[name])
            h.update(name.encode())
            h.update(str(arr.dtype).encode())
            h.update(arr.tobytes())
        f = np.asarray(self.freq)
        h.update(b"__freq__")
        h.update(str(f.dtype).encode())
        h.update(f.tobytes())
        return h.hexdigest()

    # ---- relational primitives (frequency-aware) -----------------------
    def select(self, pred: Callable[[dict[str, jax.Array]], jax.Array]) -> "Table":
        """σ: zero out frequencies of rows failing `pred` (no compaction)."""
        mask = pred(self.columns)
        return Table(self.columns, jnp.where(mask, self.freq, 0))

    def with_freq(self, freq: jax.Array) -> "Table":
        return Table(self.columns, freq)

    def project(self, names: Sequence[str]) -> "Table":
        """π (frequency-preserving; duplicates remain encoded by rows+freq)."""
        return Table({n: self.columns[n] for n in names}, self.freq)

    def pad_to(self, capacity: int) -> "Table":
        """Grow capacity to `capacity` by appending dead rows (freq = 0).

        Padding is semantically free: every operator in the engine masks by
        frequency, so zero-freq rows join, select, and aggregate to nothing.
        The serving tier pads tables to power-of-two buckets so that data
        growth inside a bucket keeps jitted executables' shapes — and hence
        their compiled programs — valid (zero recompiles)."""
        cap = self.capacity
        if capacity == cap:
            return self
        if capacity < cap:
            raise ValueError(
                f"pad_to({capacity}) below current capacity {cap}; tables "
                "never shrink (drop rows by zeroing freq instead)")
        extra = capacity - cap
        cols = {}
        for name, col in self.columns.items():
            pad = jnp.zeros((extra,) + col.shape[1:], col.dtype)
            cols[name] = jnp.concatenate([col, pad])
        freq = jnp.concatenate(
            [self.freq, jnp.zeros((extra,), self.freq.dtype)])
        return Table(cols, freq)

    def __repr__(self) -> str:  # pragma: no cover
        return f"Table(cap={self.capacity}, cols={list(self.column_names)})"


def bucket_capacity(n: int, min_capacity: int = 8) -> int:
    """Smallest power of two ≥ max(n, min_capacity) — the shape bucket a
    table of n rows compiles against.  Bucketing trades ≤2× padded rows for
    XLA program reuse across data growth."""
    n = max(int(n), min_capacity, 1)
    return 1 << (n - 1).bit_length()


def sharded_bucket_capacity(n: int, n_shards: int,
                            min_capacity: int = 8) -> int:
    """Shape bucket for a table of n rows ROW-SHARDED over `n_shards`
    devices: each shard holds a power-of-two block of
    ``bucket_capacity(ceil(n / n_shards))`` rows, so the total is both
    divisible by the shard count (a shard_map requirement) and stable
    under per-shard growth — rows added anywhere inside the per-shard
    bucket never change the mesh program's shapes.

    For power-of-two shard counts this equals
    ``bucket_capacity(n, n_shards * min_capacity)`` (the per-shard
    rounding distributes over the product), which is what makes a mesh
    service's padded capacities reproducible on one device: a local
    service with ``min_bucket = n_shards * min_capacity`` pads every
    relation to exactly the mesh's global shapes."""
    n_shards = int(n_shards)
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    per_shard = -(-max(int(n), 1) // n_shards)   # ceil
    return n_shards * bucket_capacity(per_shard, min_capacity)


def pack_keys(
    cols: Sequence[jax.Array],
    domains: Sequence[int | None],
    dtype: Any = None,
) -> jax.Array:
    """Pack multi-attribute join keys into a single integer key.

    If all domains are known, packing is collision-free mixed-radix:
    ``key = ((c0 * d1 + c1) * d2 + c2) ...``.  Otherwise a 64/32-bit
    Fibonacci mixing hash combine is used (documented collision risk —
    exact engines should declare domains; our generators always do).
    """
    if dtype is None:
        dtype = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    if len(cols) == 1:
        return cols[0].astype(dtype)
    if all(d is not None for d in domains):
        key = cols[0].astype(dtype)
        for c, d in zip(cols[1:], domains[1:]):
            key = key * jnp.asarray(d, dtype) + c.astype(dtype)
        return key
    # hash combine fallback
    phi = jnp.asarray(0x9E3779B9 if dtype == jnp.int32 else 0x9E3779B97F4A7C15, dtype)
    key = cols[0].astype(dtype)
    for c in cols[1:]:
        key = key ^ (c.astype(dtype) + phi + (key << 6) + (key >> 2))
    return key
