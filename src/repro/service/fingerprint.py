"""Query fingerprinting: canonicalise an AggQuery into a stable identity.

Two SQL texts that differ only in alias names, alias order, WHERE-clause
order, SELECT-list order, or the variable names a front-end invented must
hit the same plan-cache entry — the whole point of serving guarded
aggregate plans is that the (classify → re-root → rewrite → jit) pipeline
runs once per query *structure*, not once per request string.

Canonicalisation:

  1. Colour query variables by a Weisfeiler–Leman-style refinement over
     their occurrences (relation, column position, selection specs of the
     host atom, colours of co-occurring variables) seeded with their
     aggregate/grouping roles.  Variables are renamed ``v0, v1, ...`` in
     colour order; atoms are sorted by (relation, renamed vars, selection
     spec) and re-aliased ``t0, t1, ...``; aggregates and GROUP BY keys are
     sorted canonically with positional back-maps to the caller's names.
  2. The fingerprint is the SHA-256 of the canonical structure.

Colour ties between non-symmetric variables can at worst split one
structure over two fingerprints (a spurious cache miss, never a spurious
hit): a fingerprint *collision* requires identical canonical structures,
which by construction describe the same query up to renaming.

Queries carrying opaque selection callables without declarative
``selection_specs`` cannot be proven equivalent to anything, so their
fingerprints are salted with a process-unique nonce: they cache as
singletons (repeat submissions of the *same object* still hit).

Besides the full fingerprint, canonicalisation exposes a **prefix
fingerprint**: the identity of the scan/join structure alone, computed
with aggregate/GROUP BY roles excluded from the colouring.  Two queries
with different fingerprints but equal prefix fingerprints read the same
relations through the same join shape with the same selections.  (Since
the op-graph IR, fusion *grouping* is plan-level — subplan-key overlap on
the plan DAG, which also admits partially overlapping join shapes; the
prefix fingerprint remains the query-level whole-prefix identity, used for
diagnostics such as the ``partial_fusions`` counter.)
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import weakref

from repro.core.query import Agg, AggQuery, Atom

_OPAQUE_NONCE = itertools.count()
# query object → its salted fingerprint, so re-submitting the SAME object
# re-uses its singleton cache entry (weak: dropping the query drops it)
_OPAQUE_FPS: "weakref.WeakKeyDictionary[AggQuery, str]" = \
    weakref.WeakKeyDictionary()


def _h(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()


@dataclasses.dataclass(frozen=True)
class CanonicalQuery:
    """A canonicalised query plus the maps back to the request's names.

    ``query``        — the canonical AggQuery (plan and compile against
                       this; structurally identical requests share it).
    ``fingerprint``  — stable hex identity (plan-cache key).
    ``prefix_fingerprint`` — identity of the query's scan/join structure
                       alone (atoms + selections, aggregate- and
                       GROUP-BY-blind).  Two *different* fingerprints with
                       equal prefix fingerprints read the same relations
                       through the same join shape and are candidates for
                       fused cross-fingerprint batching (the exact test is
                       plan-level: ``repro.core.plan.segment_plan``, which
                       also accounts for guard rooting).
    ``shareable``    — False when opaque selections forced a singleton.
    ``agg_names``    — requested output name per canonical aggregate
                       (canonical aggregate i is named ``agg{i}``).
    ``group_names``  — requested variable name per canonical GROUP BY key.
    """

    query: AggQuery
    fingerprint: str
    prefix_fingerprint: str
    shareable: bool
    agg_names: tuple[str, ...]
    group_names: tuple[str, ...]

    def rename_results(self, results: dict) -> dict:
        """Map a canonical result dict back to the request's names.

        Only answer keys survive: executor bookkeeping such as the
        ``__stats__`` sentinel never reaches ``QueryResult.values`` (eager
        stats travel via ``ServeStats.exec_stats``)."""
        out = {}
        for i, name in enumerate(self.agg_names):
            key = f"agg{i}"
            if key in results:
                out[name] = results[key]
        if "groups" in results:
            cols = {}
            canon_groups = self.query.group_by
            back = dict(zip(canon_groups, self.group_names))
            for k, v in results["groups"].items():
                cols[back.get(k, k)] = v
            # grouped aggregate columns keyed agg{i} live inside "groups"
            for i, name in enumerate(self.agg_names):
                key = f"agg{i}"
                if key in cols:
                    cols[name] = cols.pop(key)
            out["groups"] = cols
            out["valid"] = results["valid"]
        return out


def _canon_spec(spec: tuple) -> tuple:
    """Order-independent form of one alias's selection terms."""
    terms = []
    for op, col, val in spec:
        if op == "in":
            val = tuple(sorted(val, key=repr))
        terms.append((op, col, val))
    return tuple(sorted(terms, key=repr))


def _canonical_atom_entries(query: AggQuery, specs: dict[str, tuple],
                            seed_roles: bool, occ=None):
    """WL-colour variables and return sorted canonical atom entries.

    ``seed_roles=True`` seeds colours with aggregate/GROUP BY roles — the
    full-query canonical form.  ``seed_roles=False`` colours by occurrence
    structure alone, so two queries differing only in which aggregates they
    compute over the same join produce identical entries: the basis of the
    prefix fingerprint.  ``occ`` lets the caller share one occurrence map
    across both colourings."""
    if occ is None:
        occ = {}
        for a in query.atoms:
            for i, v in enumerate(a.vars):
                occ.setdefault(v, []).append((a.rel, i, a.alias))
    roles: dict[str, list] = {}
    if seed_roles:
        for ag in query.aggregates:
            if ag.var is not None:
                roles.setdefault(ag.var, []).append((ag.func, ag.distinct))
    color = {}
    for v, sites in occ.items():
        color[v] = _h((sorted((r, i) for r, i, _ in sites),
                       seed_roles and v in query.group_by,
                       sorted(roles.get(v, ()))))
    for _ in range(len(color)):
        new = {}
        for v, sites in occ.items():
            ctx = []
            for rel, i, alias in sites:
                at = query.atom(alias)
                ctx.append((rel, i, specs.get(alias, ()),
                            tuple(color[w] for w in at.vars)))
            new[v] = _h((color[v], sorted(ctx, key=repr)))
        if new == color:
            break
        color = new

    # ties keep first-occurrence order (sorted() is stable) — symmetric
    # variables are interchangeable, non-symmetric WL ties only risk a
    # spurious miss (see module docstring)
    vmap = {v: f"v{i}"
            for i, v in enumerate(sorted(occ, key=lambda v: color[v]))}

    entries = sorted(
        ((a.rel, tuple(vmap[v] for v in a.vars), specs.get(a.alias, ()),
          a.alias) for a in query.atoms),
        key=lambda e: (e[0], e[1], repr(e[2])))
    return entries, vmap


def canonicalize(query: AggQuery) -> CanonicalQuery:
    # --- declarative selection specs (or opaque markers) per alias -------
    specs: dict[str, tuple] = {}
    shareable = True
    for alias in query.selections:
        spec = query.selection_specs.get(alias)
        if spec is None:
            shareable = False
            specs[alias] = ("<opaque>",)
        else:
            specs[alias] = _canon_spec(spec)

    occ: dict[str, list[tuple[str, int, str]]] = {}
    for a in query.atoms:
        for i, v in enumerate(a.vars):
            occ.setdefault(v, []).append((a.rel, i, a.alias))
    entries, vmap = _canonical_atom_entries(query, specs, seed_roles=True,
                                            occ=occ)
    amap = {alias: f"t{i}" for i, (_, _, _, alias) in enumerate(entries)}
    catoms = tuple(Atom(rel, amap[alias], vars_)
                   for rel, vars_, _, alias in entries)

    # --- canonical aggregates (sorted; positional name back-map) ---------
    agg_entries = sorted(
        ((ag.func, vmap[ag.var] if ag.var is not None else "",
          ag.distinct, idx) for idx, ag in enumerate(query.aggregates)))
    caggs = tuple(Agg(func, var or None, distinct=distinct, name=f"agg{i}")
                  for i, (func, var, distinct, _) in enumerate(agg_entries))
    agg_names = tuple(query.aggregates[idx].name
                      for _, _, _, idx in agg_entries)

    # --- canonical GROUP BY (sorted; name back-map) ----------------------
    g_entries = sorted((vmap[g], g) for g in query.group_by)
    cgroup = tuple(cv for cv, _ in g_entries)
    group_names = tuple(g for _, g in g_entries)

    csel = {amap[alias]: fn for alias, fn in query.selections.items()}
    cspecs = {amap[alias]: specs[alias] for alias in query.selections
              if query.selection_specs.get(alias) is not None}
    cquery = AggQuery(atoms=catoms, aggregates=caggs, group_by=cgroup,
                      selections=csel, selection_specs=cspecs)

    payload = (tuple((rel, vars_, spec) for rel, vars_, spec, _ in entries),
               tuple((f, v, d) for f, v, d, _ in agg_entries),
               cgroup,
               tuple(sorted((amap[a], s) for a, s in specs.items())))
    fp = _h(payload)

    # --- prefix fingerprint: the scan/join structure, role-blind ---------
    # when the query has no variable roles at all (COUNT(*), no GROUP BY)
    # the seeded colouring already IS role-blind — skip the second pass
    if not query.group_by and all(ag.var is None for ag in query.aggregates):
        p_entries = entries
    else:
        p_entries, _ = _canonical_atom_entries(query, specs,
                                               seed_roles=False, occ=occ)
    prefix_fp = _h(tuple((rel, vars_, spec)
                         for rel, vars_, spec, _ in p_entries))

    if not shareable:
        salted = _OPAQUE_FPS.get(query)
        if salted is None:
            salted = f"{fp}:opaque{next(_OPAQUE_NONCE)}"
            _OPAQUE_FPS[query] = salted
        fp = salted
        # an opaque selection can't be proven equal to anything, so the
        # prefix can't fuse across objects either: salt it identically
        prefix_fp = f"{prefix_fp}:{salted.rsplit(':', 1)[1]}"
    return CanonicalQuery(cquery, fp, prefix_fp, shareable,
                          agg_names, group_names)


def fingerprint(query: AggQuery) -> str:
    """Convenience: the stable identity alone."""
    return canonicalize(query).fingerprint


def prefix_fingerprint(query: AggQuery) -> str:
    """Convenience: the aggregate-blind scan/join-structure identity."""
    return canonicalize(query).prefix_fingerprint
