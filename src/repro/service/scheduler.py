"""Async submission tier: cross-caller batch formation for QueryService.

``QueryService.submit_many`` already fuses everything ONE caller hands it
— requests sharing a fingerprint dedup to one execution, and distinct
fingerprints whose op-graph DAGs overlap on content-addressed subplans
compile into one multi-query XLA program.  What it cannot do is fuse
across *callers*: a dashboard fleet where every client submits its own
single query gets N independent pipelines and N compiles.

``AsyncScheduler`` closes that gap with the classic batch-formation
pattern:

* ``submit_async(query) -> Future[QueryResult]`` appends the request to a
  bounded admission queue and returns immediately.  A full queue rejects
  with ``AdmissionError`` — backpressure the caller can see and retry —
  rather than growing without bound under overload.
* A background batcher thread drains the queue on a window: it wakes on
  the first enqueue, then waits up to ``max_wait_ms`` for co-arriving
  requests (or until ``max_batch`` are pending), and hands the whole
  window to the engine's shared batch pipeline
  (``QueryService._serve_batch`` via ``submit_many``) in one call.  There
  the op-graph IR's ``subplan_keys()`` union-find forms fusion groups
  exactly as for a single-caller batch — so N callers × one query each
  still share subplan work and compiled programs.
* Results fan back out per request: each future resolves to its own
  ``QueryResult`` (output names included), and a request whose
  admission/parse/serve failed gets ITS exception set on ITS future —
  batch-mates are never aborted (the engine's per-request fault
  isolation).

Counters (``async_requests``, ``async_batches``, ``queue_depth_peak``,
``rejected``) are merged into ``QueryService.metrics()``.

Latency/throughput trade-off: ``max_wait_ms`` is the most a lone request
waits for company; under load the window closes early at ``max_batch``,
so the added latency shrinks exactly when batching pays most.
"""

from __future__ import annotations

import collections
import threading
import time
import weakref
from concurrent.futures import Future, InvalidStateError
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # import cycle guard: engine lazily imports this module
    from repro.service.engine import QueryResult, QueryService


def _resolve(fut: Future, result=None, error: BaseException | None = None):
    """Set a future's outcome, tolerating a caller-side cancel race."""
    try:
        if error is not None:
            fut.set_exception(error)
        else:
            fut.set_result(result)
    except InvalidStateError:
        pass  # the caller cancelled while we were serving — drop the answer


class AsyncScheduler:
    """Background batcher turning independent ``submit_async`` callers
    into fused ``submit_many`` batches.  See the module docstring."""

    def __init__(self, service: QueryService, *, max_batch: int = 64,
                 max_wait_ms: float = 2.0, max_queue: int = 1024):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if max_wait_ms < 0:
            raise ValueError("max_wait_ms must be >= 0")
        # weak: the service owns the scheduler, never the reverse.  The
        # batcher thread references only this object, so an IDLE dropped
        # service (tables, caches, executables and all) stays collectable
        # even if the owner forgot to call close() — the idle heartbeat
        # below notices the dead ref and lets the thread exit.  While
        # requests are pending, ``_keepalive`` pins the service so
        # in-flight futures always get served.
        self._service_ref = weakref.ref(service)
        self._keepalive: QueryService | None = None
        self._max_batch = max_batch
        self._max_wait_s = max_wait_ms / 1e3
        self._max_queue = max_queue
        self._queue: collections.deque[tuple[object, Future]] = \
            collections.deque()
        self._cv = threading.Condition()
        self._closed = False
        self._counters = {"async_requests": 0, "async_batches": 0,
                          "queue_depth_peak": 0, "rejected": 0}
        self._thread = threading.Thread(target=self._drain_loop,
                                        name="query-service-batcher",
                                        daemon=True)
        self._thread.start()

    # ---- caller side -----------------------------------------------------
    def submit_async(self, query) -> Future[QueryResult]:
        """Enqueue one query; returns its future.  Raises
        ``AdmissionError`` when the admission queue is full."""
        from repro.service.engine import AdmissionError
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if len(self._queue) >= self._max_queue:
                self._counters["rejected"] += 1
                raise AdmissionError(
                    f"admission queue full ({self._max_queue} requests "
                    "pending); backpressure — retry later")
            self._queue.append((query, fut))
            self._keepalive = self._service_ref()  # pin while work pends
            self._counters["async_requests"] += 1
            self._counters["queue_depth_peak"] = max(
                self._counters["queue_depth_peak"], len(self._queue))
            self._cv.notify_all()
        return fut

    def metrics(self) -> dict[str, int]:
        with self._cv:
            return dict(self._counters)

    def close(self, timeout: float | None = 10.0) -> None:
        """Stop the batcher.  Requests already queued are drained and
        answered first; anything still pending after `timeout` fails with
        ``RuntimeError``."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._thread.join(timeout)
        with self._cv:
            leftovers = list(self._queue)
            self._queue.clear()
        for _, fut in leftovers:  # join timed out mid-drain
            _resolve(fut, error=RuntimeError("scheduler closed before the "
                                             "request could be served"))

    # ---- batcher side ----------------------------------------------------
    def _drain_loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            try:
                self._serve(batch)
            finally:
                with self._cv:
                    if not self._queue:      # idle again: unpin the service
                        self._keepalive = None

    def _next_batch(self) -> list[tuple[object, Future]] | None:
        """Block until work arrives, hold the formation window open, then
        claim up to ``max_batch`` requests.  None means closed + drained
        (or the owning service was garbage-collected)."""
        with self._cv:
            while not self._queue:
                if self._closed or self._service_ref() is None:
                    return None
                # bounded wait: the heartbeat re-checks service liveness
                self._cv.wait(timeout=1.0)
            # formation window: wait for co-arriving callers (skipped when
            # the queue is already a full batch, or on shutdown)
            deadline = time.monotonic() + self._max_wait_s
            while len(self._queue) < self._max_batch and not self._closed:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cv.wait(remaining)
            n = min(len(self._queue), self._max_batch)
            batch = [self._queue.popleft() for _ in range(n)]
            self._counters["async_batches"] += 1
        return batch

    def _serve(self, batch: list[tuple[object, Future]]) -> None:
        """One shared pipeline run for the whole window; per-request
        fan-out of answers and captured errors onto the futures."""
        service = self._service_ref()
        if service is None:
            for _, fut in batch:
                _resolve(fut, error=RuntimeError(
                    "QueryService was garbage-collected before the "
                    "request could be served"))
            return
        try:
            results = service.submit_many([q for q, _ in batch])
        except BaseException as e:  # engine bug — fail loudly, hang nobody
            for _, fut in batch:
                _resolve(fut, error=e)
            return
        for (_, fut), res in zip(batch, results):
            if res.error is not None:
                _resolve(fut, error=res.error)
            else:
                _resolve(fut, result=res)
