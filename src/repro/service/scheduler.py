"""Async submission tier: tenant-aware admission and cross-caller batch
formation for QueryService.

``QueryService.submit_many`` already fuses everything ONE caller hands it
— requests sharing a fingerprint dedup to one execution, and distinct
fingerprints whose op-graph DAGs overlap on content-addressed subplans
compile into one multi-query XLA program.  What it cannot do is fuse
across *callers*: a dashboard fleet where every client submits its own
single query gets N independent pipelines and N compiles.

``AsyncScheduler`` closes that gap with the classic batch-formation
pattern, made safe for many mutually-untrusting callers:

* ``submit_async(query, tenant=...) -> Future[QueryResult]`` admits the
  request into its tenant's bounded queue and returns immediately.
  Admission is per tenant: a token-bucket quota (``TenantPolicy.rate`` /
  ``burst``) and a queue-depth bound (``TenantPolicy.max_queue``), so one
  chatty tenant exhausts ITS budget, never the scheduler.  A rejected
  request raises ``TenantAdmissionError`` naming the tenant and whether
  the cause was ``"rate"`` or ``"depth"`` — backpressure the caller can
  see and retry — and a closed scheduler raises ``ServiceClosedError``
  (typed: it subclasses both ``AdmissionError`` and ``RuntimeError``).
  The default tenant has no quota and the scheduler-wide depth bound, so
  single-tenant callers see exactly the pre-tenant behaviour.
* A background batcher thread drains the queues on a window: it wakes on
  the first enqueue, then waits up to ``max_wait_ms`` for co-arriving
  requests (or until ``max_batch`` are pending across tenants).  The
  window is formed by **priority lanes + deficit round-robin**: lanes
  are served in ascending ``TenantPolicy.priority`` order, and within a
  lane each tenant's deficit grows by its ``weight`` per round and pays
  one unit per claimed request — weighted max-min fair sharing of every
  batch, with a tenant's unused deficit forfeited when its queue drains
  (no credit hoarding).  The whole window then flows through the
  engine's shared batch pipeline (``QueryService._serve_batch`` via
  ``submit_many``) in ONE call — so N *tenants* firing the same guarded
  dashboard still dedup, fuse, and share one compiled program, while
  quota accounting stayed per-tenant at admission.
* Results fan back out per request: each future resolves to its own
  ``QueryResult``, and a request whose admission/parse/serve failed gets
  ITS exception set on ITS future — batch-mates are never aborted (the
  engine's per-request fault isolation).  Every future resolution goes
  through ``_resolve`` (the cancel-race guard); ``scripts/lint.py``
  forbids any other ``set_result``/``set_exception`` in the service tier.

Observability: the scheduler books its counters (``async_requests``,
``async_batches``, ``rejected``, ``rejected_closed``) and the
``queue_depth`` gauge (total across tenants) straight into the service's
``Observability`` registry — ``queue_depth_peak`` is a PEAK GAUGE there.
Per-tenant counters (requests, rejections split by cause, fused share)
and request-latency histograms land under ``metrics_v2()["tenants"]``.
Each request's root ``TraceSpan`` is opened at enqueue (tagged with its
tenant) with a ``queue_wait`` child closed when the batcher claims it;
the formation window records a shared ``batch_form`` span.  Every root
is ended on EVERY exit path — served, close-drained, engine failure, or
service GC — with an error annotation on the abnormal ones, so latency
histograms and trace retention see exactly the failed requests too
(``Observability.open_requests()`` is the leak detector).

Latency/throughput trade-off: ``max_wait_ms`` is the most a lone request
waits for company; under load the window closes early at ``max_batch``,
so the added latency shrinks exactly when batching pays most.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
import weakref
from concurrent.futures import Future, InvalidStateError
from typing import TYPE_CHECKING, Callable

from repro.service.observability import DEFAULT_TENANT, NULL_SPAN

if TYPE_CHECKING:  # import cycle guard: engine lazily imports this module
    from repro.service.engine import QueryResult, QueryService


def _resolve(fut: Future, result=None, error: BaseException | None = None):
    """Set a future's outcome, tolerating a caller-side cancel race."""
    try:
        if error is not None:
            fut.set_exception(error)
        else:
            fut.set_result(result)
    except InvalidStateError:
        pass  # the caller cancelled while we were serving — drop the answer


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """Admission contract for one tenant.

    ``rate``      admitted requests/second through a token bucket (None =
                  unlimited; no clock is read for unlimited tenants).
    ``burst``     bucket capacity — the most that can be admitted at once
                  after idling (default: max(rate, 1)).
    ``max_queue`` pending-request bound for this tenant's queue (None =
                  the scheduler-wide ``max_queue``).
    ``weight``    deficit-round-robin share of every formed batch,
                  relative to the other tenants in the same lane.
    ``priority``  lane number; lower lanes are claimed first when a batch
                  forms (quotas, not priorities, bound a lane's intake).
    """

    rate: float | None = None
    burst: float | None = None
    max_queue: int | None = None
    weight: float = 1.0
    priority: int = 1

    def __post_init__(self):
        if self.rate is not None and self.rate <= 0:
            raise ValueError("rate must be > 0 (or None for unlimited)")
        if self.burst is not None and self.burst < 1:
            raise ValueError("burst must be >= 1 (or None for the default)")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None)")
        if self.weight <= 0:
            raise ValueError("weight must be > 0")


class _TokenBucket:
    """Classic token bucket over an injectable clock: ``burst`` capacity,
    ``rate`` tokens/second refill, one token per admission."""

    __slots__ = ("rate", "burst", "tokens", "last", "clock")

    def __init__(self, rate: float, burst: float,
                 clock: Callable[[], float]):
        self.rate = rate
        self.burst = burst
        self.tokens = burst          # a fresh tenant may burst immediately
        self.clock = clock
        self.last = clock()

    def try_take(self, n: float = 1.0) -> bool:
        now = self.clock()
        self.tokens = min(self.burst, self.tokens + (now - self.last)
                          * self.rate)
        self.last = now
        if self.tokens >= n:
            self.tokens -= n
            return True
        return False


@dataclasses.dataclass
class _Pending:
    """One admitted request waiting in its tenant's queue."""

    query: object
    fut: Future
    root: object                     # enqueue-time root TraceSpan
    qspan: object                    # open queue_wait child
    tenant: str


@dataclasses.dataclass
class _TenantState:
    """One tenant's queue + quota + DRR bookkeeping."""

    name: str
    policy: TenantPolicy
    queue: collections.deque = dataclasses.field(
        default_factory=collections.deque)
    bucket: _TokenBucket | None = None
    deficit: float = 0.0


def _drr_claim(states: list[_TenantState], max_batch: int) -> list[_Pending]:
    """Claim up to ``max_batch`` requests: priority lanes in ascending
    order, deficit round-robin within a lane (quantum = ``weight`` per
    round, cost 1 per request).  A tenant whose queue drains forfeits its
    remaining deficit — leftover credit never hoards across idle periods
    — while a tenant cut off by a full batch keeps its deficit for the
    next window.  Pure queue/deficit manipulation (no locks, no clock):
    the unit under ``tests/test_multitenant.py``'s DRR-weight tests."""
    batch: list[_Pending] = []
    lanes: dict[int, list[_TenantState]] = {}
    for st in states:
        if st.queue:
            lanes.setdefault(st.policy.priority, []).append(st)
    for prio in sorted(lanes):
        active = collections.deque(lanes[prio])
        while active and len(batch) < max_batch:
            st = active.popleft()
            st.deficit += st.policy.weight
            while st.queue and st.deficit >= 1.0 and len(batch) < max_batch:
                batch.append(st.queue.popleft())
                st.deficit -= 1.0
            if st.queue:
                active.append(st)
            else:
                st.deficit = 0.0
    return batch


class AsyncScheduler:
    """Background batcher turning independent ``submit_async`` callers —
    across tenants — into fused ``submit_many`` batches.  See the module
    docstring."""

    def __init__(self, service: QueryService, *, max_batch: int = 64,
                 max_wait_ms: float = 2.0, max_queue: int = 1024,
                 tenants: dict[str, TenantPolicy] | None = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if max_wait_ms < 0:
            raise ValueError("max_wait_ms must be >= 0")
        # weak: the service owns the scheduler, never the reverse.  The
        # batcher thread references only this object, so an IDLE dropped
        # service (tables, caches, executables and all) stays collectable
        # even if the owner forgot to call close() — the idle heartbeat
        # below notices the dead ref and lets the thread exit.  While
        # requests are pending, ``_keepalive`` pins the service so
        # in-flight futures always get served.
        self._service_ref = weakref.ref(service)
        self._keepalive: QueryService | None = None
        # strong on purpose: the registry never references the service,
        # so pinning it keeps counters/spans working without keeping the
        # service (tables, caches, executables) alive
        self._obs = service.obs
        self._max_batch = max_batch
        self._max_wait_s = max_wait_ms / 1e3
        self._max_queue = max_queue
        # declared tenant policies; a tenant first seen at submit time
        # gets the default policy (unlimited, weight 1, shared depth
        # bound) — "millions of callers" must not need pre-registration
        self._policies = dict(tenants) if tenants else {}
        for name, pol in self._policies.items():
            if not isinstance(pol, TenantPolicy):
                raise TypeError(f"tenants[{name!r}] must be a TenantPolicy")
        self._states: dict[str, _TenantState] = {}
        self._cv = threading.Condition()
        self._closed = False
        self._thread = threading.Thread(target=self._drain_loop,
                                        name="query-service-batcher",
                                        daemon=True)
        self._thread.start()

    # ---- caller side -----------------------------------------------------
    def _tenant_state(self, tenant: str) -> _TenantState:
        """The tenant's queue/quota state, created on first touch.
        Caller holds ``_cv``."""
        st = self._states.get(tenant)
        if st is None:
            pol = self._policies.get(tenant, TenantPolicy())
            bucket = None
            if pol.rate is not None:
                burst = pol.burst if pol.burst is not None \
                    else max(pol.rate, 1.0)
                # the injectable Observability clock, so quota-refill unit
                # tests drive a fake clock (real deployments tick
                # perf_counter either way)
                bucket = _TokenBucket(pol.rate, burst, self._obs.clock)
            st = self._states[tenant] = _TenantState(tenant, pol,
                                                     bucket=bucket)
        return st

    def _depth_locked(self) -> int:
        return sum(len(st.queue) for st in self._states.values())

    def submit_async(self, query, *, tenant: str | None = None) \
            -> Future[QueryResult]:
        """Admit one query into its tenant's queue; returns its future.
        Raises ``TenantAdmissionError`` when the tenant is over its
        queue-depth bound or token-bucket rate, ``ServiceClosedError``
        after ``close()``."""
        from repro.service.engine import (ServiceClosedError,
                                          TenantAdmissionError)
        tenant = DEFAULT_TENANT if tenant is None else str(tenant)
        fut: Future = Future()
        with self._cv:
            if self._closed:
                self._obs.inc("rejected_closed")
                self._obs.tenant_inc(tenant, "rejected_closed")
                raise ServiceClosedError(
                    "scheduler is closed; the async tier is stopped "
                    "(sync submit still works)")
            st = self._tenant_state(tenant)
            cap = st.policy.max_queue if st.policy.max_queue is not None \
                else self._max_queue
            if len(st.queue) >= cap:
                self._obs.inc("rejected")
                self._obs.tenant_inc(tenant, "rejected_depth")
                raise TenantAdmissionError(
                    tenant, "depth",
                    f"tenant {tenant!r} admission queue full ({cap} "
                    "requests pending); backpressure — retry later")
            if st.bucket is not None and not st.bucket.try_take():
                self._obs.inc("rejected")
                self._obs.tenant_inc(tenant, "rejected_rate")
                raise TenantAdmissionError(
                    tenant, "rate",
                    f"tenant {tenant!r} over its admission rate "
                    f"({st.policy.rate:g}/s, burst {st.bucket.burst:g}); "
                    "backpressure — retry later")
            # the request's trace starts HERE: queue time is part of its
            # latency, so the root opens at enqueue and the engine ends it
            # (the scheduler hands the root through submit_many(_traces=))
            root = self._obs.begin_request(via="async", tenant=tenant)
            qspan = self._obs.open_span(root, "queue_wait")
            st.queue.append(_Pending(query, fut, root, qspan, tenant))
            self._keepalive = self._service_ref()  # pin while work pends
            self._obs.inc("async_requests")
            self._obs.set_gauge("queue_depth", self._depth_locked())
            self._cv.notify_all()
        return fut

    def metrics(self) -> dict[str, int]:
        """Deprecated thin view over the shared registry (the engine's
        ``metrics()``/``metrics_v2()`` are the real read path).  NOTE:
        reading snapshots the registry, so it resets peak gauges just as
        the engine's ``metrics()`` does."""
        snap = self._obs.snapshot()
        c, g = snap["counters"], snap["gauges"]
        return {"async_requests": c.get("async_requests", 0),
                "async_batches": c.get("async_batches", 0),
                "rejected": c.get("rejected", 0),
                "rejected_closed": c.get("rejected_closed", 0),
                "queue_depth": g.get("queue_depth", 0),
                "queue_depth_peak": g.get("queue_depth_peak", 0)}

    def close(self, timeout: float | None = 10.0) -> None:
        """Stop the batcher.  Requests already queued are drained and
        answered first; anything still pending after `timeout` fails with
        ``ServiceClosedError`` — future resolved AND root span ended, so
        nothing leaks from the trace registry."""
        from repro.service.engine import ServiceClosedError
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._thread.join(timeout)
        with self._cv:
            leftovers: list[_Pending] = []
            for st in self._states.values():
                leftovers.extend(st.queue)
                st.queue.clear()
            self._obs.set_gauge("queue_depth", 0)
        for p in leftovers:          # join timed out mid-drain
            err = ServiceClosedError("scheduler closed before the request "
                                     "could be served")
            self._obs.inc("rejected_closed")
            self._obs.tenant_inc(p.tenant, "rejected_closed")
            self._end_root(p, err)
            _resolve(p.fut, error=err)

    # ---- batcher side ----------------------------------------------------
    def _end_root(self, p: _Pending, error: BaseException) -> None:
        """End an admitted request's root on an abnormal exit path (close
        drain, dead service, whole-batch engine failure).  The normal
        path ends roots in ``submit_many``; this one closes the still-open
        ``queue_wait`` child (if any), annotates the error, and records
        the root so failed requests are visible in latency histograms and
        trace retention instead of leaking open forever."""
        root, qspan = p.root, p.qspan
        if root is NULL_SPAN or root.closed:
            return
        if qspan is not NULL_SPAN and not qspan.closed:
            self._obs.close_span(qspan)
        root.note(error=type(error).__name__)
        self._obs.end_request(root, tenant=p.tenant)

    def _drain_loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            try:
                self._serve(batch)
            finally:
                with self._cv:
                    if not self._depth_locked():  # idle: unpin the service
                        self._keepalive = None

    def _next_batch(self) -> list[_Pending] | None:
        """Block until work arrives, hold the formation window open, then
        claim up to ``max_batch`` requests across tenant queues (priority
        lanes, DRR within a lane).  None means closed + drained (or the
        owning service was garbage-collected)."""
        with self._cv:
            while not self._depth_locked():
                if self._closed or self._service_ref() is None:
                    return None
                # bounded wait: the heartbeat re-checks service liveness
                self._cv.wait(timeout=1.0)
            # formation window: wait for co-arriving callers (skipped when
            # the queue is already a full batch, or on shutdown).
            # time.monotonic (not the injectable obs clock) on purpose:
            # this is a REAL-TIME wait bound for Condition.wait, and a
            # test-injected fake clock must not be able to hang the window
            bspan = self._obs.open_span(None, "batch_form")
            deadline = time.monotonic() + self._max_wait_s
            while self._depth_locked() < self._max_batch \
                    and not self._closed:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cv.wait(remaining)
            batch = _drr_claim(list(self._states.values()), self._max_batch)
            self._obs.set_gauge("queue_depth", self._depth_locked())
            self._obs.inc("async_batches")
        # annotate BEFORE closing: close_span folds the span into
        # histograms/export, and a closed span rejects late notes
        bspan.note(claimed=len(batch),
                   tenants=len({p.tenant for p in batch}))
        self._obs.close_span(bspan)
        for p in batch:
            # queue time ends when the batcher claims the request; the
            # shared formation window rides along INSIDE every member's
            # queue_wait (it overlaps the wait, so attaching it to the
            # request root would break root ≥ Σ direct children)
            self._obs.close_span(p.qspan)
            if bspan is not NULL_SPAN and p.qspan is not NULL_SPAN:
                p.qspan.children.append(bspan)
        return batch

    def _serve(self, batch: list[_Pending]) -> None:
        """One shared pipeline run for the whole window; per-request
        fan-out of answers and captured errors onto the futures."""
        from repro.service.engine import ServiceClosedError
        service = self._service_ref()
        if service is None:
            err = ServiceClosedError(
                "QueryService was garbage-collected before the request "
                "could be served")
            for p in batch:
                self._end_root(p, err)
                _resolve(p.fut, error=err)
            return
        try:
            # hand the enqueue-time roots + tenants over through the
            # thread-local (not a kwarg: submit_many's public signature
            # stays wrappable); submit_many consumes it on this thread
            service._trace_handoff.traces = [p.root for p in batch]
            service._trace_handoff.tenants = [p.tenant for p in batch]
            results = service.submit_many([p.query for p in batch])
        except BaseException as e:  # engine bug — fail loudly, hang nobody
            service._trace_handoff.traces = None
            service._trace_handoff.tenants = None
            for p in batch:
                self._end_root(p, e)
                _resolve(p.fut, error=e)
            return
        for p, res in zip(batch, results):
            if res.error is not None:
                _resolve(p.fut, error=res.error)
            else:
                _resolve(p.fut, result=res)
