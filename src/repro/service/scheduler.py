"""Async submission tier: cross-caller batch formation for QueryService.

``QueryService.submit_many`` already fuses everything ONE caller hands it
— requests sharing a fingerprint dedup to one execution, and distinct
fingerprints whose op-graph DAGs overlap on content-addressed subplans
compile into one multi-query XLA program.  What it cannot do is fuse
across *callers*: a dashboard fleet where every client submits its own
single query gets N independent pipelines and N compiles.

``AsyncScheduler`` closes that gap with the classic batch-formation
pattern:

* ``submit_async(query) -> Future[QueryResult]`` appends the request to a
  bounded admission queue and returns immediately.  A full queue rejects
  with ``AdmissionError`` — backpressure the caller can see and retry —
  rather than growing without bound under overload.
* A background batcher thread drains the queue on a window: it wakes on
  the first enqueue, then waits up to ``max_wait_ms`` for co-arriving
  requests (or until ``max_batch`` are pending), and hands the whole
  window to the engine's shared batch pipeline
  (``QueryService._serve_batch`` via ``submit_many``) in one call.  There
  the op-graph IR's ``subplan_keys()`` union-find forms fusion groups
  exactly as for a single-caller batch — so N callers × one query each
  still share subplan work and compiled programs.
* Results fan back out per request: each future resolves to its own
  ``QueryResult`` (output names included), and a request whose
  admission/parse/serve failed gets ITS exception set on ITS future —
  batch-mates are never aborted (the engine's per-request fault
  isolation).

Observability: the scheduler books its counters (``async_requests``,
``async_batches``, ``rejected``) and the ``queue_depth`` gauge straight
into the service's ``Observability`` registry — ``queue_depth_peak`` is
a PEAK GAUGE there: each ``metrics()`` snapshot reports the high-water
mark since the previous snapshot, then resets it to the current depth
(not a forever-high counter).  Each request's root ``TraceSpan`` is
opened at enqueue with a ``queue_wait`` child closed when the batcher
claims it, so queue time is visible per request and as a histogram; the
formation window records a shared ``batch_form`` span.  The scheduler
holds the registry strongly (it never references the service, so the
drop-the-service GC guarantee below is unaffected).

Latency/throughput trade-off: ``max_wait_ms`` is the most a lone request
waits for company; under load the window closes early at ``max_batch``,
so the added latency shrinks exactly when batching pays most.
"""

from __future__ import annotations

import collections
import threading
import time
import weakref
from concurrent.futures import Future, InvalidStateError
from typing import TYPE_CHECKING

from repro.service.observability import NULL_SPAN

if TYPE_CHECKING:  # import cycle guard: engine lazily imports this module
    from repro.service.engine import QueryResult, QueryService


def _resolve(fut: Future, result=None, error: BaseException | None = None):
    """Set a future's outcome, tolerating a caller-side cancel race."""
    try:
        if error is not None:
            fut.set_exception(error)
        else:
            fut.set_result(result)
    except InvalidStateError:
        pass  # the caller cancelled while we were serving — drop the answer


class AsyncScheduler:
    """Background batcher turning independent ``submit_async`` callers
    into fused ``submit_many`` batches.  See the module docstring."""

    def __init__(self, service: QueryService, *, max_batch: int = 64,
                 max_wait_ms: float = 2.0, max_queue: int = 1024):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if max_wait_ms < 0:
            raise ValueError("max_wait_ms must be >= 0")
        # weak: the service owns the scheduler, never the reverse.  The
        # batcher thread references only this object, so an IDLE dropped
        # service (tables, caches, executables and all) stays collectable
        # even if the owner forgot to call close() — the idle heartbeat
        # below notices the dead ref and lets the thread exit.  While
        # requests are pending, ``_keepalive`` pins the service so
        # in-flight futures always get served.
        self._service_ref = weakref.ref(service)
        self._keepalive: QueryService | None = None
        # strong on purpose: the registry never references the service,
        # so pinning it keeps counters/spans working without keeping the
        # service (tables, caches, executables) alive
        self._obs = service.obs
        self._max_batch = max_batch
        self._max_wait_s = max_wait_ms / 1e3
        self._max_queue = max_queue
        # (query, future, root trace span, open queue_wait span)
        self._queue: collections.deque[tuple] = collections.deque()
        self._cv = threading.Condition()
        self._closed = False
        self._thread = threading.Thread(target=self._drain_loop,
                                        name="query-service-batcher",
                                        daemon=True)
        self._thread.start()

    # ---- caller side -----------------------------------------------------
    def submit_async(self, query) -> Future[QueryResult]:
        """Enqueue one query; returns its future.  Raises
        ``AdmissionError`` when the admission queue is full."""
        from repro.service.engine import AdmissionError
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            if len(self._queue) >= self._max_queue:
                self._obs.inc("rejected")
                raise AdmissionError(
                    f"admission queue full ({self._max_queue} requests "
                    "pending); backpressure — retry later")
            # the request's trace starts HERE: queue time is part of its
            # latency, so the root opens at enqueue and the engine ends it
            # (the scheduler hands the root through submit_many(_traces=))
            root = self._obs.begin_request(via="async")
            qspan = self._obs.open_span(root, "queue_wait")
            self._queue.append((query, fut, root, qspan))
            self._keepalive = self._service_ref()  # pin while work pends
            self._obs.inc("async_requests")
            self._obs.set_gauge("queue_depth", len(self._queue))
            self._cv.notify_all()
        return fut

    def metrics(self) -> dict[str, int]:
        """Deprecated thin view over the shared registry (the engine's
        ``metrics()``/``metrics_v2()`` are the real read path).  NOTE:
        reading snapshots the registry, so it resets peak gauges just as
        the engine's ``metrics()`` does."""
        snap = self._obs.snapshot()
        c, g = snap["counters"], snap["gauges"]
        return {"async_requests": c.get("async_requests", 0),
                "async_batches": c.get("async_batches", 0),
                "rejected": c.get("rejected", 0),
                "queue_depth": g.get("queue_depth", 0),
                "queue_depth_peak": g.get("queue_depth_peak", 0)}

    def close(self, timeout: float | None = 10.0) -> None:
        """Stop the batcher.  Requests already queued are drained and
        answered first; anything still pending after `timeout` fails with
        ``RuntimeError``."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._thread.join(timeout)
        with self._cv:
            leftovers = list(self._queue)
            self._queue.clear()
            self._obs.set_gauge("queue_depth", 0)
        for _, fut, _root, _qspan in leftovers:  # join timed out mid-drain
            _resolve(fut, error=RuntimeError("scheduler closed before the "
                                             "request could be served"))

    # ---- batcher side ----------------------------------------------------
    def _drain_loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            try:
                self._serve(batch)
            finally:
                with self._cv:
                    if not self._queue:      # idle again: unpin the service
                        self._keepalive = None

    def _next_batch(self) -> list[tuple] | None:
        """Block until work arrives, hold the formation window open, then
        claim up to ``max_batch`` requests.  None means closed + drained
        (or the owning service was garbage-collected)."""
        with self._cv:
            while not self._queue:
                if self._closed or self._service_ref() is None:
                    return None
                # bounded wait: the heartbeat re-checks service liveness
                self._cv.wait(timeout=1.0)
            # formation window: wait for co-arriving callers (skipped when
            # the queue is already a full batch, or on shutdown).
            # time.monotonic (not the injectable obs clock) on purpose:
            # this is a REAL-TIME wait bound for Condition.wait, and a
            # test-injected fake clock must not be able to hang the window
            bspan = self._obs.open_span(None, "batch_form")
            deadline = time.monotonic() + self._max_wait_s
            while len(self._queue) < self._max_batch and not self._closed:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cv.wait(remaining)
            n = min(len(self._queue), self._max_batch)
            batch = [self._queue.popleft() for _ in range(n)]
            self._obs.set_gauge("queue_depth", len(self._queue))
            self._obs.inc("async_batches")
        self._obs.close_span(bspan)
        bspan.note(claimed=n)
        for _, _, _root, qspan in batch:
            # queue time ends when the batcher claims the request; the
            # shared formation window rides along INSIDE every member's
            # queue_wait (it overlaps the wait, so attaching it to the
            # request root would break root ≥ Σ direct children)
            self._obs.close_span(qspan)
            if bspan is not NULL_SPAN and qspan is not NULL_SPAN:
                qspan.children.append(bspan)
        return batch

    def _serve(self, batch: list[tuple]) -> None:
        """One shared pipeline run for the whole window; per-request
        fan-out of answers and captured errors onto the futures."""
        service = self._service_ref()
        if service is None:
            for _, fut, _root, _qspan in batch:
                _resolve(fut, error=RuntimeError(
                    "QueryService was garbage-collected before the "
                    "request could be served"))
            return
        try:
            # hand the enqueue-time roots over through the thread-local
            # (not a kwarg: submit_many's public signature stays
            # wrappable); submit_many consumes it on this same thread
            service._trace_handoff.traces = [r for _, _, r, _ in batch]
            results = service.submit_many([q for q, _, _, _ in batch])
        except BaseException as e:  # engine bug — fail loudly, hang nobody
            service._trace_handoff.traces = None
            for _, fut, _root, _qspan in batch:
                _resolve(fut, error=e)
            return
        for (_, fut, _root, _qspan), res in zip(batch, results):
            if res.error is not None:
                _resolve(fut, error=res.error)
            else:
                _resolve(fut, result=res)
