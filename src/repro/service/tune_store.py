"""Persistent tuned-kernel-config store: autotuning survives restarts.

A measured kernel search (``repro.kernels.autotune.KernelTuner``) costs
seconds per (kernel, shape-bucket) — far too much to repeat on every
process start.  This store persists the winners under the same
``cache_dir`` as the plan store, with the same discipline:

* one JSON entry per (kernel, shape bucket, backend), living in a
  directory scoped by the serving topology (``(axis_names,
  shard_counts)``, ``()`` locally) — services sharded differently tuned
  against different per-shard shapes, so their entries never alias::

      <root>/tune/<topology-hash>/<key-hash>.json

* a header the loader verifies before trusting the body:
  ``format_version`` (schema bumps can never mis-parse old entries),
  the full key fields (kernel/shape/backend/topology — a hand-moved file
  whose name happens to match is still rejected), and
  ``payload_sha256`` over the canonical payload encoding (truncation or
  bit-flips fail closed);

* corruption-tolerant loads: ANY failure counts
  ``tune_persist_corrupt_skipped``, evicts the damaged file best-effort
  (own directory only — ``load_all`` during import/export never empties
  a foreign store), and returns None so the caller simply re-tunes;

* atomic, best-effort writes (temp file + ``os.replace``): a read-only
  or full disk counts ``tune_persist_write_errors`` and degrades the
  service to default/in-memory configs — persistence is an optimisation,
  never a request-path dependency.

Invalidation is structural, not manual: entries key off the SAME
power-of-two shape buckets as the plan cache, so data growth inside a
bucket keeps hitting the tuned entry, while crossing a bucket boundary
looks up (and, cold, re-tunes) the next bucket's entry.  A
``format_version`` bump or topology change orphans old entries without
ever serving them.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import threading
from pathlib import Path

from repro.kernels.autotune import KernelConfig

TUNE_FORMAT_VERSION = 1


def _canonical_body(payload: dict) -> bytes:
    return json.dumps(payload, sort_keys=True,
                      separators=(",", ":")).encode()


def _topology_tag(topology: tuple) -> str:
    return hashlib.sha256(repr(tuple(topology)).encode()).hexdigest()[:16]


class TuneStore:
    """Versioned, checksummed, corruption-tolerant tuned-config
    persistence.  Thread-safe: a lock guards only the counters."""

    def __init__(self, root, topology: tuple = ()):
        self.root = Path(root)
        self.topology = tuple(topology)
        self.tune_dir = self.root / "tune" / _topology_tag(self.topology)
        self._lock = threading.Lock()
        self.counters = {
            "tune_persist_hits": 0,
            "tune_persist_misses": 0,
            "tune_persist_writes": 0,
            "tune_persist_corrupt_skipped": 0,
            "tune_persist_write_errors": 0,
        }
        try:
            self.tune_dir.mkdir(parents=True, exist_ok=True)
        except OSError:
            # unwritable root: loads miss, saves count errors — the
            # service degrades to default configs, never crashes
            pass
        try:
            self._entries = sum(1 for _ in self.tune_dir.glob("*.json"))
        except OSError:
            self._entries = 0

    # ---- keys ------------------------------------------------------------
    def _key_fields(self, kernel: str, shape, backend: str) -> dict:
        return {
            "kernel": kernel,
            "shape": [int(s) for s in shape],
            "backend": backend,
            "topology": [list(part) for part in self.topology],
        }

    def _path(self, kernel: str, shape, backend: str) -> Path:
        ident = repr((kernel, tuple(int(s) for s in shape), backend,
                      self.topology))
        return self.tune_dir / (
            hashlib.sha256(ident.encode()).hexdigest()[:32] + ".json")

    def __len__(self) -> int:
        with self._lock:
            return self._entries

    # ---- load ------------------------------------------------------------
    def load(self, kernel: str, shape, backend: str) -> KernelConfig | None:
        """The persisted config for one tune key, or None (re-tune).
        Damaged entries are evicted and counted, never raised."""
        cfg, corrupt = self._load(self._path(kernel, shape, backend),
                                  self._key_fields(kernel, shape, backend))
        with self._lock:
            if cfg is not None:
                self.counters["tune_persist_hits"] += 1
            else:
                self.counters["tune_persist_misses"] += 1
                if corrupt:
                    self.counters["tune_persist_corrupt_skipped"] += 1
        return cfg

    def _load(self, path: Path, key_fields: dict | None, *,
              evict: bool = True) -> tuple[KernelConfig | None, bool]:
        """(config, was_corrupt) — counter-free core shared by ``load``
        and ``load_all``.  ``evict`` deletes damaged entries in the
        store's OWN directory; imports from a foreign directory skip in
        place instead (a mismatch there is the reader's, not damage)."""
        try:
            raw = path.read_bytes()
        except OSError:
            return None, False
        try:
            doc = json.loads(raw)
            if doc["format_version"] != TUNE_FORMAT_VERSION:
                raise ValueError(
                    f"format_version {doc['format_version']} != "
                    f"{TUNE_FORMAT_VERSION}")
            if key_fields is not None:
                for field, want in key_fields.items():
                    if doc[field] != want:
                        raise ValueError(f"entry {field} mismatch")
            payload = doc["payload"]
            if hashlib.sha256(_canonical_body(payload)).hexdigest() \
                    != doc["payload_sha256"]:
                raise ValueError("payload checksum mismatch")
            fields = {f.name for f in dataclasses.fields(KernelConfig)}
            raw_cfg = payload["config"]
            if set(raw_cfg) != fields:
                raise ValueError("config field mismatch")
            return KernelConfig(**{k: int(v) for k, v in raw_cfg.items()}), \
                False
        except Exception:
            if evict:
                try:
                    path.unlink()
                except OSError:
                    pass
                else:
                    with self._lock:
                        self._entries = max(0, self._entries - 1)
            return None, True

    def load_all(self):
        """Yield ((kernel, shape, backend), config) for every valid
        entry — warm starts and cache import/export.  Unreadable entries
        are skipped in place, NOT evicted (the directory may be a foreign
        store being imported)."""
        try:
            paths = sorted(self.tune_dir.glob("*.json"))
        except OSError:
            return
        for path in paths:
            cfg, corrupt = self._load(path, None, evict=False)
            if cfg is None:
                if corrupt:
                    with self._lock:
                        self.counters["tune_persist_corrupt_skipped"] += 1
                continue
            try:
                doc = json.loads(path.read_bytes())
                key = (doc["kernel"],
                       tuple(int(s) for s in doc["shape"]),
                       doc["backend"])
            except Exception:
                with self._lock:
                    self.counters["tune_persist_corrupt_skipped"] += 1
                continue
            yield key, cfg

    # ---- save ------------------------------------------------------------
    def save(self, kernel: str, shape, backend: str, config: KernelConfig,
             *, measurements: dict | None = None) -> bool:
        """Persist one winner (atomically).  Returns False — without
        raising — when the write fails: tuning degrades to in-memory."""
        payload = {
            "config": dataclasses.asdict(config),
            "measurements": {k: float(v)
                             for k, v in (measurements or {}).items()},
        }
        body = _canonical_body(payload)
        doc = {
            "format_version": TUNE_FORMAT_VERSION,
            **self._key_fields(kernel, shape, backend),
            "payload_sha256": hashlib.sha256(body).hexdigest(),
            "payload": payload,
        }
        path = self._path(kernel, shape, backend)
        tmp = None
        try:
            existed = path.exists()
            fd, tmp = tempfile.mkstemp(dir=str(self.tune_dir),
                                       prefix=f".{path.stem[:16]}.",
                                       suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1)
            os.replace(tmp, path)        # atomic: readers never see a torn
            tmp = None                   # entry, only old or new
        except OSError:
            with self._lock:
                self.counters["tune_persist_write_errors"] += 1
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
            return False
        with self._lock:
            self.counters["tune_persist_writes"] += 1
            if not existed:
                self._entries += 1
        return True

    # ---- observability ---------------------------------------------------
    def metrics(self) -> dict[str, int]:
        with self._lock:
            out = dict(self.counters)
        out["tune_persist_entries"] = len(self)
        return out


TUNE_PERSIST_ZEROS = {
    "tune_persist_hits": 0, "tune_persist_misses": 0,
    "tune_persist_writes": 0, "tune_persist_corrupt_skipped": 0,
    "tune_persist_write_errors": 0, "tune_persist_entries": 0,
}
