"""QueryService: the concurrent SQL serving front door.

The paper's zero-materialisation plans (0MA / Opt⁺) have a *static*
dataflow — no intermediate shape depends on the data — which is exactly
what lets them be compiled once and served many times.  ``QueryService``
turns the repo's one-shot pipeline (parse → classify → rewrite → jit →
run) into a serving engine:

    svc = QueryService(db, schema)
    res = svc.submit("SELECT MIN(s.s_acctbal) FROM supplier s ...")
    res.values, res.stats          # answer + per-query ServeStats
    svc.metrics()                  # cache hit/miss/eviction counters

Request path (shared by sync ``submit``/``submit_many`` and the async
scheduler — one internal pipeline, ``_serve_batch``):

  1. ADMIT: parse SQL → AggQuery (skipped for AggQuery submissions);
     admission fails — with the relation named — if a query touches a
     schema relation with no loaded table.  Failures are captured PER
     REQUEST: in a batch, a malformed query's error attaches to its own
     ``QueryResult.error`` (or its future) and never aborts batch-mates;
     ``submit`` re-raises it for the single-query caller.
  2. canonicalise → fingerprint (alias/variable-name invariant);
  3. PLAN-UNIT: plan cache L1: fingerprint → PhysicalPlan (an op-graph
     DAG), built outside the lock behind a per-fingerprint in-flight
     event; planning failures attach to the unit's requests only;
  4. shape bucket: power-of-two-padded capacities of the scanned
     relations; tables are padded (``Table.pad_to``) to their bucket, so
     data growth inside a bucket re-uses compiled programs.  Padding is
     device work and runs outside the lock too, against an immutable
     snapshot of the scanned tables;
  5. FUSION-GROUP + SERVE: plan cache L2: (fingerprint, bucket) → jitted
     executable; run; results renamed back to the request's output names.

Micro-batching: ``submit_many`` groups requests sharing a fingerprint and
runs each group's executable once, fanning the answer out per request
(each with its own name mapping).

Async serving: ``submit_async`` returns a ``Future[QueryResult]`` and
hands the query to a lazily-started background batcher
(``repro.service.scheduler.AsyncScheduler``) that drains its bounded
admission queue on a max_wait_ms/max_batch window — so N independent
callers each submitting ONE query still land in one ``_serve_batch``
call and fuse into the same multi-query XLA programs a single
``submit_many`` caller would get.  The queue rejects on overflow
(``AdmissionError`` backpressure); scheduler counters
(``async_requests``, ``async_batches``, ``queue_depth_peak``,
``rejected``) ride along in ``metrics()``.

Cross-fingerprint fusion: *different* fingerprints whose plan DAGs share
at least one non-trivial subplan (``PhysicalPlan.subplan_keys``: a join
node or a filtered scan with an equal content key) are grouped — union-find
over shared keys, so overlap is transitive — and compiled into ONE
multi-query XLA program (``Executor.compile_multi``) whose trace memo runs
every shared sub-DAG once.  Unlike PR 2's whole-prefix equality, this
fuses across *different join shapes*: a 3-way and a 5-way dashboard query
sharing only their filtered dimension scans and first semi-joins still
compile together.  Fused executables are cached by the merged-graph
signature (sorted member graph keys) + shape bucket; ``metrics()`` exposes
``fused_*`` plus ``partial_fusions`` (fused runs whose members do NOT all
share one whole prefix — fusions the prefix rule would have missed) and
``subplan_saved`` (subplan executions avoided by the shared trace memo).

Thread safety: the internal lock guards only cache and database mutation —
query planning, table padding, XLA compiles, and query execution all run
outside it, coordinated by per-key in-flight events so concurrent cold
requests for the same artefact build it once.  ``metrics()`` and
``update_table`` never wait behind planning, padding, a long compile, or
an eager baseline run.

Warm starts: ``QueryService(db, schema, cache_dir=...)`` persists every
shareable plan to a ``PlanStore`` under ``cache_dir`` and points JAX's
persistent compilation cache at ``cache_dir/xla`` — so a NEW process over
the same schema replays known query structures with zero plan rebuilds
(``plan_builds`` stays 0; the disk level answers, ``persist_hits``
counting) and pulls previously compiled XLA binaries from disk instead of
recompiling.  Plan lookup order is memory → disk → plan; disk failures of
any kind (corrupt entries, read-only volumes) degrade to memory-only
caching and never attach an error to a request.  ``export_cache`` /
``import_cache`` move a warm cache between directories (e.g. to seed a
fleet from one warmed pod).

Kernel autotuning: ``autotune()`` runs a measured config search
(``repro.kernels.autotune``) per (kernel, shape bucket, backend) over the
loaded tables' buckets — pallas block shapes, the XLA dense-domain
dispatch crossover — gating every candidate on bitwise equality with the
untuned answer, then drops compiled executables so the next serve
re-traces with the winners.  Tuned configs key off the SAME shape buckets
as the executable cache, so within-bucket growth never retunes.  With a
``cache_dir`` the winners persist in a ``TuneStore`` beside the plans
(same versioned/checksummed/corruption-tolerant discipline) and load at
construction: a warm-started process reports ``tune_searches == 0`` —
the tuning analogue of ``plan_builds == 0`` — and ``export_cache`` /
``import_cache`` ship tuned configs along with the plans.

Serving beyond one device: ``QueryService(db, schema, mesh=...)`` puts
the whole front door on a device mesh.  The jit executor becomes
``repro.core.distributed.DistributedExecutor`` — the SAME op-graph
interpreter lowered into one ``shard_map`` ring program per compile — so
admission, fingerprinting, the plan cache, fusion grouping, async
batching, fault isolation, persistence and tracing all flow through the
code paths above unchanged.  What the mesh changes is shapes and keys:
tables pad to per-shard power-of-two buckets
(``sharded_bucket_capacity`` — growth on one shard never recompiles the
mesh program) and padded views are placed row-sharded over the mesh;
exec/fused cache keys and the persistent store fingerprint carry the
shard topology ``(axis_names, shard_counts)`` so programs lowered for
different meshes never alias; ``metrics_v2()`` gains mesh gauges and the
``run`` span a ``ring_sweep`` child.  Answers are bitwise-equal to a
single-device service padded to the same capacities (construct one with
``min_bucket = n_shards * min_bucket`` for a power-of-two mesh).
Eager-fallback (ref/opt) plans keep running locally on the unpadded
tables — materialising baselines are not a mesh workload.

Observability: every request carries a ``TraceSpan`` tree (admit/parse →
queue-wait → fingerprint → plan → pad → compile → run) recorded through
``repro.service.observability`` — the ONLY timing source in this package
(``scripts/lint.py`` enforces it).  Spans aggregate into streaming
latency histograms; ``metrics_v2()`` returns the structured
``{"counters", "gauges", "histograms"}`` snapshot (service counters read
under ONE lock, so invariants like ``fused_queries <= requests`` hold in
every snapshot), ``metrics()`` keeps the old flat dict as a deprecated
view, ``export_trace(path)`` writes Chrome-trace/Perfetto JSON, and
``explain(query)`` names the cache level that answered one request.
Construct with ``tracing=False`` to drop every span (per-stage
``ServeStats`` timings read 0.0 then — counters keep working); answers
are bitwise-identical either way.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
from concurrent.futures import Future
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.executor import (
    ExecStats,
    Executor,
    shared_subplan_savings,
)
from repro.core.plan import MaterializeJoinOp, PhysicalPlan, segment_plan
from repro.core.rewrite import plan_query
from repro.core.sql import parse_sql
from repro.core.stats import FUSION_COST_DISPARITY, StatsCatalog
from repro.service.fingerprint import CanonicalQuery, canonicalize
from repro.service.observability import (DEFAULT_TENANT, NULL_SPAN,
                                         Observability, TraceSpan)
from repro.service.plan_cache import LRUCache, PlanCache, ShapeBucket
from repro.kernels.autotune import KernelTuner
from repro.service.plan_store import (
    PlanStore,
    enable_executable_cache,
    schema_fingerprint,
    store_fingerprint,
)
from repro.service.stats_store import STATS_PERSIST_ZEROS, StatsStore
from repro.service.tune_store import TUNE_PERSIST_ZEROS, TuneStore
from repro.tables.table import Schema, Table, bucket_capacity


class AdmissionError(ValueError):
    """A request the service refused at the door: a relation it cannot
    serve (present in the schema but with no table loaded, or unknown
    entirely), or async-tier backpressure (see the subclasses)."""


class TenantAdmissionError(AdmissionError):
    """Async admission rejected a request under its tenant's policy.
    ``tenant`` names the offender; ``kind`` is ``"rate"`` (token bucket
    empty) or ``"depth"`` (the tenant's queue is at its bound) — retry
    loops can back off differently for the two causes."""

    def __init__(self, tenant: str, kind: str, message: str):
        super().__init__(message)
        self.tenant = tenant
        self.kind = kind


class ServiceClosedError(AdmissionError, RuntimeError):
    """The async tier is stopped (``close()`` ran, or the service was
    garbage-collected): typed so retry loops written against
    ``AdmissionError`` backpressure survive shutdown.  Also a
    ``RuntimeError`` for callers of the pre-typed contract.  Counted as
    ``rejected_closed``, never ``rejected`` — shutdown is not
    backpressure."""


@dataclasses.dataclass
class ServeStats:
    """Per-request serving telemetry."""

    fingerprint: str = ""
    mode: str = ""
    plan_cache_hit: bool = False
    exec_cache_hit: bool = False
    shared_execution: bool = False   # answered by a batch-mate's run
    fused: bool = False              # answered by a multi-query program
    fused_group_size: int = 0        # distinct fingerprints in that program
    bucket: ShapeBucket = ()
    plan_source: str = ""            # memory | disk | built (cache level)
    exec_source: str = ""            # exec_cache | compiled | fused_cache |
                                     # fused_compiled | eager
    parse_s: float = 0.0
    queue_s: float = 0.0             # async admission-queue wait
    plan_s: float = 0.0
    compile_s: float = 0.0
    run_s: float = 0.0
    total_s: float = 0.0
    exec_stats: ExecStats | None = None  # eager (ref/opt) plans only
    trace: TraceSpan | None = dataclasses.field(default=None, repr=False)


@dataclasses.dataclass
class QueryResult:
    """One request's answer.  ``error`` is the per-request failure slot:
    in a batch, a malformed query gets its admission/parse/serve exception
    here while its batch-mates' results stay intact (``values`` is empty
    iff ``error`` is set).  ``submit`` re-raises it; the async scheduler
    moves it onto the request's future."""

    values: dict[str, Any]
    stats: ServeStats
    error: BaseException | None = None

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclasses.dataclass
class _Request:
    canon: CanonicalQuery | None
    stats: ServeStats
    error: BaseException | None = None   # captured per-request failure
    unit: "_Unit | None" = None          # back-pointer set by _plan_unit
    trace: Any = NULL_SPAN               # this request's root TraceSpan
    tenant: str = DEFAULT_TENANT         # owning tenant (metrics rollup)


@dataclasses.dataclass
class _Unit:
    """One fingerprint's worth of a batch: the requests sharing it, their
    cached plan, the plan's fusion identity, and (once served) the
    canonical result dict."""

    group: list[_Request]
    plan: PhysicalPlan
    plan_hit: bool
    plan_s: float
    eager: bool                       # materialising plan → eager fallback
    prefix_key: str | None            # whole-prefix identity (diagnostics)
    subplans: frozenset               # non-trivial subplan content keys
    sig: str                          # member signature for the fused cache
    plan_source: str = "memory"       # memory | disk | built
    results: dict = dataclasses.field(default_factory=dict)
    served_sig: str = ""              # fusion-group signature it ran under
                                      # ("" = served solo) — the feedback key

    @property
    def canon(self) -> CanonicalQuery:
        return self.group[0].canon


class QueryService:
    def __init__(self, db: dict[str, Table], schema: Schema, *,
                 mode: str = "auto", use_fkpk: bool = False,
                 freq_dtype=jnp.int32, backend: str = "xla",
                 interpret: bool = True, dense_domain: bool = False,
                 plan_capacity: int = 256, exec_capacity: int = 512,
                 fused_capacity: int = 128, padded_capacity: int = 64,
                 min_bucket: int = 8, async_max_batch: int = 64,
                 async_max_wait_ms: float = 2.0,
                 async_max_queue: int = 1024,
                 cache_dir: str | None = None,
                 clock: Callable[[], float] | None = None,
                 tracing: bool = True,
                 profile_annotations: bool = False,
                 mesh: "jax.sharding.Mesh | None" = None,
                 data_axes: tuple[str, ...] | None = None,
                 mesh_presort: bool = False,
                 fusion_disparity: float | None = None,
                 tenants: "dict[str, Any] | None" = None):
        self._db = dict(db)
        self.schema = schema
        self.mode = mode
        self.use_fkpk = use_fkpk
        self.min_bucket = min_bucket
        # fusion-admission cost gate: a plan never joins a fusion group
        # whose max estimated cost is >= this multiple of its own.  None →
        # the calibrated default from core.stats; float("inf") disables
        # the gate (the ungated baseline benchmarks compare against).
        self.fusion_disparity = (FUSION_COST_DISPARITY
                                 if fusion_disparity is None
                                 else float(fusion_disparity))
        # mesh serving: same pipeline, distributed jit executor (below),
        # topology-aware cache keys, per-shard buckets, sharded views.
        # min_bucket is PER SHARD on a mesh.
        self._mesh = mesh
        # the one timing source for the whole serving tier: counters,
        # gauges, per-stage histograms, and per-request span trees.
        # tracing=False keeps counters/gauges but makes every span a no-op
        # (no clock reads on the hot path — the overhead baseline).
        self.obs = Observability(clock, enabled=tracing)
        # root-span handoff from the async batcher to submit_many (see
        # there) — thread-local, so concurrent sync callers never see it
        self._trace_handoff = threading.local()
        self.obs.register_counters([
            "requests", "batches", "dedup_saved", "compiles",
            "eager_requests",
            "plan_builds",            # plan_query pipeline actually ran
                                      # (0 in a fully warm-started process)
            "request_errors",         # per-request captured failures
            "bucket_invalidations",
            # cross-fingerprint fusion
            "fused_batches",          # fused program executions
            "fused_queries",          # distinct fingerprints they answered
            "fused_compiles",         # of "compiles", how many were fused
            "partial_fusions",        # fused runs beyond whole-prefix rule
            "subplan_saved",          # subplan executions avoided
            "compile_s_total",        # float: total seconds compiling
            # async tier (bumped by the scheduler once it starts).
            # rejected = tenant backpressure (rate/depth);
            # rejected_closed = shutdown — counted apart on purpose
            "async_requests", "async_batches", "rejected",
            "rejected_closed",
            # cost-calibrated planning
            "stat_refreshes",         # full per-table stats computes ran
                                      # (0 in a fully warm-started process)
            "fusion_cost_rejects",    # members kept out of a fusion group
                                      # by the cost-disparity gate
            "fusion_demotions",       # members kept out by serve-time
                                      # feedback (a regressed fusion)
        ])
        self.obs.set_gauge("queue_depth", 0)
        self.obs.register_peak_gauge("queue_depth_peak", "queue_depth")
        if mesh is not None:
            from repro.core.distributed import DistributedExecutor

            axes = tuple(data_axes) if data_axes is not None \
                else tuple(mesh.axis_names)
            self._jit_executor = DistributedExecutor(
                schema, mesh, data_axes=axes, freq_dtype=freq_dtype,
                presort=mesh_presort, dense_domain=dense_domain,
                profile_annotations=profile_annotations)
            # the shape-relevant mesh identity, folded into every
            # executable-cache key and the persistent store fingerprint:
            # a ring program compiled for one mesh shape must never answer
            # a service sharded differently
            self._topo = self._jit_executor.topology()
            self._row_sharding = self._jit_executor.row_sharding()
            self.obs.set_gauge("mesh_devices", self._jit_executor.n_shards)
            for a, n in zip(*self._topo):
                self.obs.set_gauge(f"mesh_shard_count_{a}", n)
        else:
            self._jit_executor = Executor(
                self._db, schema, freq_dtype, backend, interpret,
                dense_domain=dense_domain,
                profile_annotations=profile_annotations)
            self._topo = ()
            self._row_sharding = None
        store = None
        tune_store = None
        if cache_dir is not None:
            # the store identity covers schema AND planner configuration
            # AND shard topology: plans are planner output, so a store
            # warmed under another mode/use_fkpk must never serve this
            # service, and a mesh config's warm-start state (incl. the XLA
            # executable cache beside it) stays disjoint per topology
            store = PlanStore(cache_dir,
                              store_fingerprint(schema, mode, use_fkpk,
                                                topology=self._topo))
            # executables warm-start through JAX's own persistent
            # compilation cache (process-global; see plan_store docs)
            enable_executable_cache(store.root / "xla")
            # tuned kernel configs persist beside the plans, scoped by the
            # same topology (per-shard buckets tune differently)
            tune_store = TuneStore(cache_dir, topology=self._topo)
        self.cache = PlanCache(plan_capacity, exec_capacity, fused_capacity,
                               padded_capacity, store=store)
        # kernel autotuning: the tuner resolves configs table → store →
        # measured search; a warm start installs every persisted entry NOW
        # so serving (and ``autotune()``) re-measures nothing
        # (``tune_searches == 0``).  The executor reads the table at trace
        # time, so installed configs take effect on the next compile.
        self.tuner = KernelTuner(tune_store, backend=backend,
                                 interpret=interpret)
        self.tuner.load_persisted()
        self._jit_executor.tuning = self.tuner.table
        # cost-calibrated planning: one statistics catalog feeds the gated
        # rewrite passes, the fusion-admission cost gate, and the serve-time
        # feedback loop.  Stats are derived state, so they persist under the
        # same cache_dir discipline as plans/tunings — scoped by SCHEMA only
        # (statistics describe the data, not the planner configuration, so
        # every mode/use_fkpk/topology variant shares them).  A warm restart
        # over identical data loads every table from disk and reports
        # ``stat_refreshes == 0``.
        self.stats = StatsCatalog(schema)
        self.stats_store = (StatsStore(cache_dir, schema_fingerprint(schema))
                            if cache_dir is not None else None)
        # live content tokens per relation — refreshed on update_table; the
        # store key composites each table's token with its FK destinations'
        # (orphan counts read both sides of a declared FK)
        self._tokens: dict[str, str] = {
            name: t.content_token() for name, t in self._db.items()}
        for name in sorted(self._db):
            self._refresh_stats(name)
        if self.stats_store is not None:
            fb = self.stats_store.load_feedback()
            if fb is not None:
                self.stats.load_feedback(fb)
        # fingerprint → last fusion-admission decision payload, for
        # ``explain`` (bounded like _segments below)
        self._fusion_decisions: dict[str, dict] = {}
        # fingerprint → (eager, prefix_key, subplans, sig): the fusion
        # identity is a pure function of the canonical structure, so
        # memoise it across batches (bounded: cleared when it outgrows the
        # plan cache several times over)
        self._segments: dict[str, tuple] = {}
        # guards cache + db mutation ONLY; planning, padding, compiles and
        # execution run outside it, serialised per cache key by these
        # in-flight events
        self._lock = threading.RLock()
        self._inflight: dict[tuple, threading.Event] = {}
        # async tier: started lazily on the first submit_async.
        # ``tenants`` maps tenant name -> TenantPolicy (quota / queue
        # bound / DRR weight / priority lane); unlisted tenants get the
        # unlimited default policy on first touch.
        self._async_opts = (async_max_batch, async_max_wait_ms,
                            async_max_queue)
        self._tenant_policies = dict(tenants) if tenants else {}
        self._scheduler = None
        self._async_closed = False

    # ---- data plane ------------------------------------------------------
    def update_table(self, name: str, table: Table) -> None:
        """Swap in new data for one relation.  Growth inside the relation's
        shape bucket keeps every compiled executable valid; crossing a
        bucket boundary invalidates only the executables that scan it."""
        if name not in self.schema.relations:
            raise KeyError(f"unknown relation {name!r}")
        want = set(self.schema.relations[name].column_names())
        have = set(table.columns)
        if want != have:
            raise ValueError(f"table {name!r} columns {sorted(have)} != "
                             f"schema columns {sorted(want)}")
        old = self._db.get(name)
        if old is not None:
            # shape buckets key on capacity only; a dtype change would turn
            # an exec-cache "hit" into a silent re-trace inside jax.jit
            # (uncounted compile), so reject it up front
            for col in want:
                if table.columns[col].dtype != old.columns[col].dtype:
                    raise ValueError(
                        f"table {name!r} column {col!r} dtype "
                        f"{table.columns[col].dtype} != existing "
                        f"{old.columns[col].dtype}; keep dtypes stable so "
                        "cached executables stay valid")
            if table.freq.dtype != old.freq.dtype:
                raise ValueError(
                    f"table {name!r} freq dtype {table.freq.dtype} != "
                    f"existing {old.freq.dtype}")
        with self._lock:
            old_bucket = self._bucket_cap(self._db[name].capacity) \
                if name in self._db else None
            self._db[name] = table
            self.cache.drop_padded(name)
            new_bucket = self._bucket_cap(table.capacity)
            if old_bucket != new_bucket:
                n = self.cache.invalidate_relation(name)
                self.obs.inc("bucket_invalidations", n)
        # statistics follow the data: refresh this table, plus every table
        # whose FK points AT it (their orphan counts read the new data).
        # Outside the lock — stats computes touch device arrays and the
        # catalog has its own synchronisation.
        self._tokens[name] = table.content_token()
        self._refresh_stats(name)
        for fk in self.schema.foreign_keys:
            if fk.dst == name and fk.src in self._db:
                self._refresh_stats(fk.src)
        # cached plans whose gating decisions consulted now-changed
        # statistics must re-plan: the same fingerprint may deserve a
        # different graph under the new data distribution
        with self._lock:
            self.cache.plans.invalidate_items(
                lambda fp, plan: not self._decisions_valid(plan))

    # ---- statistics ------------------------------------------------------
    def _stats_store_token(self, name: str) -> str:
        """Composite content token keying ``name``'s persisted stats: its
        own data version plus its FK destinations' (orphan counts depend on
        both sides).  Any change to either side forces a fresh compute."""
        parts = [self._tokens[name]]
        for fk in sorted(self.schema.foreign_keys,
                         key=lambda f: (f.src, f.src_col)):
            if fk.src == name and fk.dst in self._tokens:
                parts.append(self._tokens[fk.dst])
        if len(parts) == 1:
            return parts[0]
        return hashlib.sha256("\n".join(parts).encode()).hexdigest()

    def _refresh_stats(self, name: str) -> None:
        """Bring ``name``'s catalog entry up to date: persisted stats at
        the current composite token install without recomputation; a miss
        computes fresh (counted ``stat_refreshes``) and writes back."""
        token = self._stats_store_token(name)
        if self.stats_store is not None:
            stats = self.stats_store.load(name, token)
            if stats is not None:
                self.stats.install(stats)
                return
        stats = self.stats.refresh(name, self._db[name], self._db)
        self.obs.inc("stat_refreshes")
        if self.stats_store is not None:
            # keyed by the composite token (the staleness discipline); the
            # payload keeps the table's OWN token, so a warm install puts
            # exactly what a cold compute would into the catalog
            self.stats_store.save(stats, token=token)

    def _decisions_valid(self, plan: PhysicalPlan) -> bool:
        """True iff every statistic a plan's gating decisions consulted
        still matches the live catalog.  Plans that consulted nothing
        (``stats=None`` planning, or no stats-gated pass fired) are always
        valid — their graph is stats-independent."""
        depends: dict[str, str] = {}
        for d in getattr(plan, "decisions", ()):
            depends.update(dict(d.depends))
        return not depends or self.stats.validate_depends(depends)

    def _bucket_cap(self, n_rows: int) -> int:
        """The shape bucket an n-row table pads to: power-of-two locally,
        per-shard power-of-two blocks on a mesh (``min_bucket`` bounds the
        PER-SHARD block there, so growth confined to one shard's bucket
        reuses the compiled mesh program bit-for-bit)."""
        if self._mesh is not None:
            return self._jit_executor.shard_capacity(n_rows,
                                                     self.min_bucket)
        return bucket_capacity(n_rows, self.min_bucket)

    def _snapshot(self, rels) -> tuple[ShapeBucket, dict[str, Table]]:
        """Shape bucket + bucket-padded table views for `rels`.

        The raw tables and the bucket are captured under ONE lock
        acquisition so they describe the same database state: a concurrent
        bucket-crossing ``update_table`` can never pair a stale-bucket
        cache key with fresh-shaped inputs (which would make the cached
        jitted fn silently retrace inside ``jax.jit``).  Tables are
        immutable, so the snapshot stays consistent after release — which
        is what lets the padding itself (``Table.pad_to``, device work)
        run OUTSIDE the lock, serialised per (relation, capacity) by
        in-flight events exactly like compiles."""
        with self._lock:
            base = {rel: self._db[rel] for rel in rels}
            bucket: ShapeBucket = tuple(
                (rel, self._bucket_cap(base[rel].capacity))
                for rel in rels)
        sub_db = {rel: self._padded_view(rel, base[rel], cap)
                  for rel, cap in bucket}
        return bucket, sub_db

    def _padded_view(self, rel: str, table: Table, cap: int) -> Table:
        """`table` padded to `cap`, from the bounded padded-view cache.
        Entries are tagged with their source table; a tag mismatch (the
        relation was swapped after our snapshot) pads fresh but only
        caches the view while it still describes the live table.  On a
        mesh the view is additionally placed row-sharded over the data
        axes — also device work, also cached."""
        entry, _ = self._get_or_build(
            self.cache.padded, rel,
            lambda: (table, self._pad_table(table, cap)),
            flight_key=("pad", rel, cap),
            valid=lambda e: e[0] is table,
            cache_if=lambda e: self._db.get(rel) is table)
        return entry[1]

    def _pad_table(self, table: Table, cap: int) -> Table:
        padded = table.pad_to(cap)
        if self._row_sharding is not None:
            from repro.core.distributed import shard_table

            padded = shard_table(padded, self._row_sharding)
        return padded

    # ---- request plane ---------------------------------------------------
    def submit(self, query, *, tenant: str | None = None) -> QueryResult:
        """Serve one query (SQL text or AggQuery).  Raises the captured
        error for a single-query caller (batch callers get it attached to
        the request's ``QueryResult.error`` instead).  ``tenant`` rolls
        the request into that tenant's counters/latency histogram."""
        res = self.submit_many([query], tenant=tenant)[0]
        if res.error is not None:
            raise res.error
        return res

    def submit_many(self, queries, *, tenant: str | None = None) \
            -> list[QueryResult]:
        """Serve a batch of concurrent requests.

        Requests sharing a fingerprint are answered by one executable
        invocation; fingerprints whose plan DAGs overlap on any non-trivial
        subplan are fused into one multi-query program compiled and run
        once, with every shared sub-DAG computed a single time.

        Fault isolation is per request: an admission/parse/planning/serve
        failure attaches to the offending request's ``QueryResult.error``
        and never aborts its batch-mates.

        The async scheduler hands over the root spans it opened at
        enqueue time (so queue-wait is part of each request's tree) and
        each request's tenant through the ``_trace_handoff`` thread-local
        — a side channel, not a parameter, so the public signature stays
        wrappable (tests monkeypatch ``submit_many``); sync callers get a
        fresh root per query here, rolled up under ``tenant`` (default:
        the shared default tenant)."""
        queries = list(queries)          # accept any iterable
        _traces = getattr(self._trace_handoff, "traces", None)
        _tenants = getattr(self._trace_handoff, "tenants", None)
        self._trace_handoff.traces = None
        self._trace_handoff.tenants = None
        if not queries:
            return []                    # no work: don't count a batch
        tenant = DEFAULT_TENANT if tenant is None else str(tenant)
        if _tenants is None or len(_tenants) != len(queries):
            _tenants = [tenant] * len(queries)
        if _traces is None or len(_traces) != len(queries):
            _traces = [self.obs.begin_request(tenant=ten)
                       for ten in _tenants]
        # every submission counts, admitted or not — request_errors /
        # requests is then a meaningful error rate
        self.obs.inc("requests", len(queries))
        reqs = [self._try_admit(q, t, ten)
                for q, t, ten in zip(queries, _traces, _tenants)]
        served = self._serve_batch([r for r in reqs if r.error is None])
        out = []
        errors = 0
        for r in reqs:
            res = served.get(id(r))
            if res is None:              # admission/parse failure
                res = QueryResult({}, r.stats, error=r.error)
            self.obs.tenant_inc(r.tenant, "requests")
            if res.error is not None:
                errors += 1
                r.trace.note(error=type(res.error).__name__)
                self.obs.tenant_inc(r.tenant, "errors")
            elif res.stats.fused:
                self.obs.tenant_inc(r.tenant, "fused")
            if r.trace is not NULL_SPAN:
                r.stats.trace = r.trace
            self.obs.end_request(r.trace, tenant=r.tenant)
            out.append(res)
        if errors:
            self.obs.inc("request_errors", errors)
        return out

    def submit_async(self, query, *, tenant: str | None = None) \
            -> Future[QueryResult]:
        """Queue one query for background batch formation; returns a
        ``concurrent.futures.Future`` resolving to its ``QueryResult``
        (or raising its captured per-request error).

        Queries from independent callers that land in the same batching
        window are served by ONE ``_serve_batch`` call, so they dedup,
        fuse, and share compiled programs exactly as if a single caller
        had handed them to ``submit_many`` — across tenants too: quota
        accounting is per tenant, the compiled program is shared.  Raises
        ``TenantAdmissionError`` when ``tenant`` is over its queue-depth
        bound or token-bucket rate (backpressure; the error names the
        tenant and the cause), ``ServiceClosedError`` after ``close()``."""
        sch = self._scheduler
        if sch is None:
            from repro.service.scheduler import AsyncScheduler
            with self._lock:
                if self._async_closed:
                    self.obs.inc("rejected_closed")
                    raise ServiceClosedError(
                        "service closed: the async tier is stopped "
                        "(sync submit still works)")
                if self._scheduler is None:
                    max_batch, max_wait_ms, max_queue = self._async_opts
                    self._scheduler = AsyncScheduler(
                        self, max_batch=max_batch, max_wait_ms=max_wait_ms,
                        max_queue=max_queue,
                        tenants=self._tenant_policies)
                sch = self._scheduler
        return sch.submit_async(query, tenant=tenant)

    def close(self, timeout: float | None = 10.0) -> None:
        """Stop the async batcher (if started), draining queued requests.
        Terminal for the async tier — later ``submit_async`` calls raise —
        while sync submission keeps working."""
        with self._lock:
            self._async_closed = True
            sch = self._scheduler
        if sch is not None:
            sch.close(timeout=timeout)

    # ---- kernel autotuning ----------------------------------------------
    @property
    def tune_store(self) -> TuneStore | None:
        """The persistent tuned-config store (None without
        ``cache_dir``)."""
        return self.tuner.store

    def autotune(self, kernels=("freq_join", "semi_join", "segment_sum"),
                 *, row: Callable[..., Any] | None = None) -> dict[str, Any]:
        """Tune the kernels for this service's loaded tables.

        Runs the measured config search for every (kernel, shape-bucket)
        combination the current tables can produce — join kernels over
        (parent bucket × child bucket) pairs, the segmented sum per
        bucket — skipping any combination already resolved by the
        in-memory table or the persistent store (so a warm-started
        service measures nothing and this call is cheap to repeat).
        Every candidate is gated on bitwise equality with the untuned
        answer inside the search itself; a fresh install then drops the
        compiled executables so the next serve re-traces with the tuned
        configs.  ``row`` (a ``Recorder.row``-shaped sink) receives the
        per-candidate timing trajectory.  Returns a summary dict."""
        with self._lock:
            caps = sorted({self._bucket_cap(t.capacity)
                           for t in self._db.values()})
        before = self.tuner.metrics()
        prev_row = self.tuner.row
        if row is not None:
            self.tuner.row = row
        try:
            for kernel in kernels:
                if kernel == "segment_sum":
                    for b in caps:
                        self.tuner.ensure(kernel, (b,))
                else:
                    for bp in caps:
                        for bc in caps:
                            self.tuner.ensure(kernel, (bp, bc))
        finally:
            self.tuner.row = prev_row
        after = self.tuner.metrics()
        installed = after["tune_installs"] - before["tune_installs"]
        invalidated = 0
        if installed:
            # tuned configs are trace-time constants: compiled programs
            # predate them, so drop the executable levels (plans are
            # config-free and survive)
            with self._lock:
                invalidated = (
                    self.cache.execs.invalidate_if(lambda k: True)
                    + self.cache.fused.invalidate_if(lambda k: True))
        return {
            "buckets": caps,
            "searches": after["tune_searches"] - before["tune_searches"],
            "installed": installed,
            "gate_rejects": (after["tune_gate_rejects"]
                             - before["tune_gate_rejects"]),
            "entries": after["tune_entries"],
            "invalidated_executables": invalidated,
        }

    # ---- cache persistence ----------------------------------------------
    @property
    def plan_store(self) -> PlanStore | None:
        """The persistent plan level (None without ``cache_dir``)."""
        return self.cache.store

    def export_cache(self, path) -> int:
        """Write this service's plan cache to a fresh ``PlanStore`` at
        `path`: every serialisable in-memory plan, plus any entries already
        persisted in this service's own store that memory has evicted.
        Returns the number of plans exported.  Use to seed warm starts on
        other machines (ship the directory; ``cache_dir=path`` or
        ``import_cache`` consumes it)."""
        dest = PlanStore(path, store_fingerprint(self.schema, self.mode,
                                                 self.use_fkpk,
                                                 topology=self._topo))
        with self._lock:
            plans = self.cache.plans.items()
        exported = set()
        for fp, plan in plans:
            if dest.save(fp, plan):          # skips opaque/unserialisable
                exported.add(fp)
        own = self.cache.store
        if own is not None and own.root.resolve() != dest.root.resolve():
            for fp, plan in own.load_all():
                if fp not in exported and dest.save(fp, plan):
                    exported.add(fp)
        # tuned kernel configs ship with the plans: everything in the
        # in-memory table, plus store entries memory never loaded
        tdest = TuneStore(path, topology=self._topo)
        tuned = set()
        for (kernel, shape, backend), cfg in self.tuner.table.entries():
            if tdest.save(kernel, shape, backend, cfg):
                tuned.add((kernel, shape, backend))
        town = self.tuner.store
        if town is not None \
                and town.root.resolve() != tdest.root.resolve():
            for key, cfg in town.load_all():
                if key not in tuned:
                    tdest.save(*key, cfg)
        return len(exported)

    def import_cache(self, path) -> int:
        """Pre-warm the in-memory plan cache from a ``PlanStore`` at
        `path` (and write the entries through to this service's own store,
        when it has one).  Returns the number of plans imported.  Corrupt
        or schema-mismatched entries are skipped, never raised."""
        src = PlanStore(path, store_fingerprint(self.schema, self.mode,
                                                self.use_fkpk,
                                                topology=self._topo))
        n = 0
        own = self.cache.store
        write_through = own is not None \
            and own.root.resolve() != src.root.resolve()
        for fp, plan in src.load_all():
            with self._lock:
                self.cache.plans.put(fp, plan)
            if write_through:
                own.save(fp, plan)
            n += 1
        # tuned kernel configs ride along: install into the live table
        # (they take effect on the next compile) and write through to our
        # own store when we have one
        tsrc = TuneStore(path, topology=self._topo)
        town = self.tuner.store
        t_through = town is not None \
            and town.root.resolve() != tsrc.root.resolve()
        for (kernel, shape, backend), cfg in tsrc.load_all():
            self.tuner.table.install(kernel, shape, backend, cfg)
            if t_through:
                town.save(kernel, shape, backend, cfg)
        return n

    def _serve_batch(self, reqs: list[_Request]) -> dict[int, QueryResult]:
        """The batch pipeline: fingerprint-group → plan-unit →
        fusion-group → serve → per-request results, keyed by request id.
        Shared by sync ``submit_many`` and the async scheduler; errors
        attach to the affected requests, never to the batch."""
        if not reqs:
            return {}
        groups: dict[str, list[_Request]] = {}
        for r in reqs:
            groups.setdefault(r.canon.fingerprint, []).append(r)
        self.obs.inc("batches")
        dedup = sum(len(g) - 1 for g in groups.values())
        if dedup:
            self.obs.inc("dedup_saved", dedup)

        units = []
        for group in groups.values():
            try:
                units.append(self._plan_unit(group))
            except Exception as e:       # planning failed: this unit only
                for r in group:
                    r.error = e

        eagers, singles, fused_groups = self._fusion_groups(units)
        for u in eagers:
            self._try_serve(self._serve_eager, u)
        for u in singles:
            self._try_serve(self._serve_single, u)
        for us in fused_groups:
            try:
                self._serve_fused(us)
            except Exception:
                # the fused program failed as a whole — fall back to
                # serving each member singly, so only the member(s) that
                # actually cannot serve carry an error
                for u in us:
                    u.served_sig = ""       # it is a solo serve after all
                    self._try_serve(self._serve_single, u)

        # close the loop: observed serve times feed the catalog per
        # (fingerprint, fusion-group signature) — "" is the solo baseline —
        # so the grouper demotes fusions that keep regressing a member.
        # One atomic feedback write-back per observing batch.
        observed = False
        for u in units:
            if u.results and all(r.error is None for r in u.group):
                self.stats.observe_serve(u.canon.fingerprint, u.served_sig,
                                         u.group[0].stats.run_s)
                observed = True
        if observed and self.stats_store is not None:
            self.stats_store.save_feedback(self.stats.feedback_payload())

        results: dict[int, QueryResult] = {}
        for group in groups.values():
            for i, r in enumerate(group):
                if r.error is not None:
                    results[id(r)] = QueryResult({}, r.stats, error=r.error)
                    continue
                r.stats.shared_execution = i > 0
                r.stats.queue_s = r.trace.child_duration("queue_wait")
                r.stats.total_s = (r.stats.parse_s + r.stats.plan_s
                                   + r.stats.compile_s + r.stats.run_s)
                results[id(r)] = QueryResult(
                    r.canon.rename_results(r.unit.results), r.stats)
        return results

    def _try_admit(self, query, trace=NULL_SPAN,
                   tenant: str = DEFAULT_TENANT) -> _Request:
        """Admission with per-request error capture."""
        try:
            return self._admit(query, trace, tenant)
        except Exception as e:
            return _Request(canon=None, stats=ServeStats(), error=e,
                            trace=trace, tenant=tenant)

    def _try_serve(self, serve: Callable, u: _Unit) -> None:
        """Run one unit's serve step, attaching a failure to that unit's
        requests instead of propagating it into batch-mates."""
        try:
            serve(u)
        except Exception as e:
            for r in u.group:
                r.error = e

    def _admit(self, query, trace=NULL_SPAN,
               tenant: str = DEFAULT_TENANT) -> _Request:
        stats = ServeStats()
        if isinstance(query, str):
            with self.obs.span(trace, "parse") as sp:
                query = parse_sql(query, self.schema)
            stats.parse_s = sp.duration_s
        for atom in query.atoms:
            if atom.rel not in self.schema.relations:
                raise AdmissionError(
                    f"query references relation {atom.rel!r}, which is not "
                    "in the schema")
            if atom.rel not in self._db:
                raise AdmissionError(
                    f"query references relation {atom.rel!r}, which has no "
                    f"table loaded; call update_table({atom.rel!r}, table) "
                    "first")
        with self.obs.span(trace, "fingerprint"):
            canon = canonicalize(query)
        stats.fingerprint = canon.fingerprint
        trace.note(fingerprint=canon.fingerprint)
        return _Request(canon, stats, trace=trace, tenant=tenant)

    def _plan_unit(self, group: list[_Request]) -> _Unit:
        """Plan lookup for one fingerprint group: memory (plan-cache L1) →
        disk (persistent ``PlanStore``, warm starts) → ``plan_query``.
        Runs WITHOUT the service lock: both the disk load and the rewrite
        pipeline execute behind a per-fingerprint in-flight event like any
        other cache build, so a slow plan never blocks
        ``metrics()``/``update_table`` or unrelated fingerprints.  Opaque
        (unshareable) fingerprints are process-salted, so they bypass the
        store entirely; freshly built shareable plans are written back
        best-effort (a failed write degrades to memory-only caching)."""
        canon = group[0].canon
        roots = [r.trace for r in group]
        source = "memory"                # overwritten when build() runs

        def build():
            nonlocal source
            if canon.shareable:
                plan = self.cache.load_persistent(canon.fingerprint)
                if plan is not None:
                    # a persisted plan is only trusted if the statistics
                    # its gating decisions consulted still describe the
                    # live data; otherwise re-plan under current stats
                    if self._decisions_valid(plan):
                        source = "disk"
                        return plan
            plan = plan_query(canon.query, self.schema, mode=self.mode,
                              use_fkpk=self.use_fkpk, stats=self.stats)
            source = "built"
            self.obs.inc("plan_builds")
            if canon.shareable:
                self.cache.save_persistent(canon.fingerprint, plan)
            return plan

        with self.obs.span(roots, "plan",
                           fingerprint=canon.fingerprint) as sp:
            plan, plan_hit = self._get_or_build(
                self.cache.plans, canon.fingerprint, build)
            sp.note(source="memory" if plan_hit else source, hit=plan_hit)
        plan_s = sp.duration_s
        with self._lock:
            seg = self._segments.get(canon.fingerprint)
        if seg is None:
            eager = any(isinstance(op, MaterializeJoinOp) for op in plan.ops)
            if eager:
                seg = (True, None, frozenset(), canon.fingerprint)
            else:
                # opaque-selection plans key their scans on callable
                # identity, which can be recycled after GC — their member
                # signature falls back to the (salted, process-unique)
                # fingerprint so a fused cache entry can never alias them
                gk = plan.graph_key() if canon.shareable else None
                seg = (False, segment_plan(plan).prefix_key,
                       plan.subplan_keys(),
                       gk if gk is not None else canon.fingerprint)
            with self._lock:
                if len(self._segments) > 4 * self.cache.plans.capacity:
                    self._segments.clear()
                self._segments[canon.fingerprint] = seg
        eager, prefix_key, subplans, sig = seg
        unit = _Unit(group, plan, plan_hit, plan_s, eager, prefix_key,
                     subplans, sig,
                     plan_source="memory" if plan_hit else source)
        for r in group:
            r.unit = unit
        return unit

    def _fusion_groups(self, units: list[_Unit]):
        """Partition a batch: eager fallbacks, lone jittable units, and
        fusion groups — connected components of the "shares a non-trivial
        subplan key" relation (union-find over key owners)."""
        eagers = [u for u in units if u.eager]
        jit_units = [u for u in units if not u.eager]
        singles = [u for u in jit_units if not u.subplans]
        fusable = [u for u in jit_units if u.subplans]

        parent = list(range(len(fusable)))

        def find(i: int) -> int:
            while parent[i] != i:
                parent[i] = parent[parent[i]]
                i = parent[i]
            return i

        owner: dict = {}
        for i, u in enumerate(fusable):
            for k in u.subplans:
                j = owner.setdefault(k, i)
                if j != i:
                    parent[find(i)] = find(j)
        comps: dict[int, list[_Unit]] = {}
        for i, u in enumerate(fusable):
            comps.setdefault(find(i), []).append(u)
        fused_groups = []
        for comp in comps.values():
            if len(comp) == 1:
                singles.append(comp[0])
                continue
            groups, solos = self._admit_fusion(comp)
            singles.extend(solos)
            fused_groups.extend(groups)
        return eagers, singles, fused_groups

    def _admit_fusion(self, comp: list[_Unit]
                      ) -> tuple[list[list[_Unit]], list[_Unit]]:
        """Admission gate for one candidate fusion group: subplan sharing
        makes a fusion *possible*, the cost model and serve-time feedback
        decide whether it is *worth it*.  Returns (fused groups, solos).

        Two gates, in order:

        1. cost disparity — members partition into cost-compatible BANDS:
           walking members by ascending estimated (padded-shape) cost, a
           member opens a new band when it costs ≥ ``fusion_disparity`` ×
           the current band's minimum.  A cheap lookup fused with a heavy
           dashboard inherits the dashboard's latency for no savings it
           can notice — but cost-similar members still fuse among
           themselves, so the gate never forfeits compatible sharing.
           Members stranded in a singleton band serve solo and count
           ``fusion_cost_rejects``.
        2. feedback demotion — a (fingerprint, group-signature) pair the
           catalog has observed regressing vs. the member's solo baseline
           is evicted from its band; the signature shrinks and the check
           repeats until the band is stable (``fusion_demotions``).
        """
        rels = sorted({rel for u in comp for rel in u.plan.scanned_rels()})
        with self._lock:
            rows = {rel: self._bucket_cap(self._db[rel].capacity)
                    for rel in rels if rel in self._db}
        costs = {id(u): self.stats.estimate_plan_cost(u.plan, rows=rows)
                 for u in comp}
        cmin = min(costs.values())
        cmax = max(costs.values())
        bands: list[list[_Unit]] = []
        for u in sorted(comp, key=lambda u: costs[id(u)]):
            if bands and costs[id(u)] < self.fusion_disparity * max(
                    costs[id(bands[-1][0])], 1.0):
                bands[-1].append(u)
            else:
                bands.append([u])
        groups: list[list[_Unit]] = []
        solos: list[_Unit] = []
        for band in bands:
            if len(band) == 1:
                u = band[0]
                c = costs[id(u)]
                solos.append(u)
                self.obs.inc("fusion_cost_rejects")
                self._note_fusion(
                    u, admitted=False, cost=c, group_max_cost=cmax,
                    reason=(f"cost disparity >= {self.fusion_disparity:g}x:"
                            f" member cost {c:.0f} incompatible with the "
                            f"rest of its component (costs {cmin:.0f}.."
                            f"{cmax:.0f})"))
                continue
            keep = band
            while len(keep) > 1:
                keep.sort(key=lambda u: u.canon.fingerprint)
                sig = hashlib.sha256(
                    repr(tuple(u.sig for u in keep)).encode()).hexdigest()
                demoted = [u for u in keep
                           if self.stats.is_demoted(u.canon.fingerprint,
                                                    sig)]
                if not demoted:
                    for u in keep:
                        self._note_fusion(
                            u, admitted=True, cost=costs[id(u)],
                            group_max_cost=cmax, signature=sig,
                            reason=f"admitted (group of {len(keep)})")
                    break
                for u in demoted:
                    keep.remove(u)
                    solos.append(u)
                    self.obs.inc("fusion_demotions")
                    self._note_fusion(
                        u, admitted=False, cost=costs[id(u)],
                        group_max_cost=cmax, signature=sig,
                        reason=("demoted by serve-time feedback: fused "
                                "EWMA regressed vs solo baseline"))
            if len(keep) > 1:
                groups.append(keep)
            else:
                solos.extend(keep)
        return groups, solos

    def _note_fusion(self, u: _Unit, *, admitted: bool, reason: str,
                     cost: float, group_max_cost: float,
                     signature: str = "") -> None:
        """Record the last fusion-admission decision per fingerprint for
        ``explain`` (bounded like ``_segments``)."""
        with self._lock:
            if len(self._fusion_decisions) > 4 * self.cache.plans.capacity:
                self._fusion_decisions.clear()
            self._fusion_decisions[u.canon.fingerprint] = {
                "admitted": admitted, "reason": reason, "cost": cost,
                "group_max_cost": group_max_cost,
                "disparity": self.fusion_disparity,
                "signature": signature,
            }

    # ---- execution -------------------------------------------------------
    _MISSING = object()

    def _get_or_build(self, cache: LRUCache, key, build: Callable, *,
                      flight_key: tuple | None = None,
                      valid: Callable | None = None,
                      cache_if: Callable | None = None):
        """Cache access with the lock held only around the cache itself: a
        miss releases the lock, builds (compile / plan rewrite / padding),
        and re-inserts, while concurrent requests for the SAME key wait on
        an in-flight event instead of building twice (and requests for
        other keys — or ``metrics()``/``update_table`` — proceed
        untouched).

        ``valid`` lets a caller reject a cached entry (treated as a miss
        to rebuild, counted as neither hit nor eviction); ``cache_if``
        gates insertion of a freshly built value (evaluated under the
        lock) for builds that may already be stale by the time they
        finish.  Exactly one hit or miss is counted per logical access,
        however many times the wait loop spins."""
        fk = (id(cache), key) if flight_key is None else flight_key
        while True:
            with self._lock:
                value = cache.peek(key, self._MISSING)
                if value is not self._MISSING and (valid is None
                                                   or valid(value)):
                    cache.note_hit(key)
                    return value, True
                ev = self._inflight.get(fk)
                if ev is None:
                    ev = threading.Event()
                    self._inflight[fk] = ev
                    break
            ev.wait()
        try:
            value = build()
            with self._lock:
                cache.misses += 1
                if cache_if is None or cache_if(value):
                    cache.put(key, value)
            return value, False
        finally:
            with self._lock:
                self._inflight.pop(fk, None)
            ev.set()

    def _invoke(self, fn: Callable, sub_db: dict[str, Table], run_span):
        """Execute one ready program to completion.  On a mesh, the
        execution is additionally wrapped in a ``ring_sweep`` child span of
        the request's ``run`` span — the collective sweep is the mesh
        path's distinguishing cost and deserves its own timing row."""
        if self._mesh is not None:
            axes, counts = self._topo
            with self.obs.span(run_span, "ring_sweep",
                               axes="×".join(axes),
                               shards=self._jit_executor.n_shards):
                results = fn(sub_db)
                jax.block_until_ready(results)
            return results
        results = fn(sub_db)
        jax.block_until_ready(results)
        return results

    def _finish_unit(self, u: _Unit, results: dict, *, exec_hit: bool,
                     bucket: ShapeBucket, compile_s: float, run_s: float,
                     fused_size: int = 0, exec_source: str = "") -> None:
        u.results = results
        for r in u.group:
            r.stats.mode = u.plan.mode
            r.stats.plan_cache_hit = u.plan_hit
            r.stats.exec_cache_hit = exec_hit
            r.stats.fused = fused_size > 1
            r.stats.fused_group_size = fused_size
            r.stats.bucket = bucket
            r.stats.plan_source = u.plan_source
            r.stats.exec_source = exec_source
            r.stats.plan_s = u.plan_s
            r.stats.compile_s = compile_s
            r.stats.run_s = run_s

    def _serve_single(self, u: _Unit) -> None:
        """The classic path: one fingerprint, one executable."""
        roots = [r.trace for r in u.group]
        with self.obs.span(roots, "pad"):
            bucket, sub_db = self._snapshot(u.plan.scanned_rels())
        fn, exec_hit, compile_s = self._executable(u.canon, u.plan, bucket,
                                                   sub_db, roots)
        with self.obs.span(roots, "run") as rsp:
            results = self._invoke(fn, sub_db, rsp)
        self._finish_unit(u, results, exec_hit=exec_hit, bucket=bucket,
                          compile_s=compile_s, run_s=rsp.duration_s,
                          exec_source="exec_cache" if exec_hit
                          else "compiled")

    def _serve_fused(self, units: list[_Unit]) -> None:
        """Compile and run several subplan-sharing fingerprints as ONE
        program: each shared sub-DAG executes once, every member's
        remaining ops fold the shared vectors into its own answer."""
        units.sort(key=lambda u: u.canon.fingerprint)
        plans = [u.plan for u in units]
        # one set of spans shared by EVERY member request's trace tree —
        # a fused batch has exactly one pad/compile/run, so exactly one
        # span each, fanned out to all roots (export dedups by identity)
        roots = [r.trace for u in units for r in u.group]
        rels = sorted({rel for p in plans for rel in p.scanned_rels()})
        with self.obs.span(roots, "pad"):
            bucket, sub_db = self._snapshot(rels)
        signature = hashlib.sha256(
            repr(tuple(u.sig for u in units)).encode()).hexdigest()
        for u in units:
            # the feedback key this serve will be observed under — matches
            # the signature _admit_fusion computes for the same member set
            u.served_sig = signature
        compile_s = 0.0

        def build():
            nonlocal compile_s
            with self.obs.span(roots, "compile", cold=True, fused=True,
                               members=len(units)) as sp:
                fn = self._jit_executor.compile_multi(plans)
                jax.block_until_ready(fn(sub_db))
            compile_s = sp.duration_s
            self.obs.inc("compiles")
            self.obs.inc("fused_compiles")
            self.obs.inc("compile_s_total", compile_s)
            return fn

        fn, exec_hit = self._get_or_build(
            self.cache.fused,
            PlanCache.fused_key(signature, bucket, self._topo), build)
        with self.obs.span(roots, "run", fused=True) as rsp:
            outs = self._invoke(fn, sub_db, rsp)

        self.obs.inc("fused_batches")
        self.obs.inc("fused_queries", len(units))
        self.obs.inc("subplan_saved", shared_subplan_savings(plans))
        if len({u.prefix_key for u in units}) > 1:
            # members do NOT all share one whole prefix: this fusion is
            # beyond PR 2's equal-prefix rule (different join shapes)
            self.obs.inc("partial_fusions")
        for u, results in zip(units, outs):
            self._finish_unit(u, results, exec_hit=exec_hit, bucket=bucket,
                              compile_s=compile_s, run_s=rsp.duration_s,
                              fused_size=len(units),
                              exec_source="fused_cache" if exec_hit
                              else "fused_compiled")

    def _executable(self, canon: CanonicalQuery, plan: PhysicalPlan,
                    bucket: ShapeBucket, sub_db: dict[str, Table],
                    parents=(),
                    ) -> tuple[Callable, bool, float]:
        compile_s = 0.0

        def build():
            nonlocal compile_s
            with self.obs.span(parents, "compile", cold=True, fused=False,
                               fingerprint=canon.fingerprint) as sp:
                fn = self._jit_executor.compile(plan)
                # trace + compile now, against the snapshot's bucket
                # shapes, so the cache entry is a ready-to-run program and
                # `run_s` is pure execution
                jax.block_until_ready(fn(sub_db))
            compile_s = sp.duration_s
            self.obs.inc("compiles")
            self.obs.inc("compile_s_total", compile_s)
            return fn

        fn, hit = self._get_or_build(
            self.cache.execs,
            PlanCache.exec_key(canon.fingerprint, bucket, self._topo),
            build)
        return fn, hit, compile_s

    def _serve_eager(self, u: _Unit) -> None:
        """Fallback for non-jittable (materialising) plans: serve eagerly
        with the paper's per-step ExecStats attached."""
        base = self._jit_executor
        roots = [r.trace for r in u.group]
        self.obs.inc("eager_requests", len(u.group))
        with self._lock:
            # snapshot the scanned tables under the lock (tables are
            # immutable): execution then runs unlocked over a consistent
            # database state even if update_table swaps relations mid-run
            sub_db = {rel: self._db[rel] for rel in u.plan.scanned_rels()}
        ex = Executor(sub_db, self.schema, base.freq_dtype, base.backend,
                      base.interpret, dense_domain=base.dense_domain,
                      tuning=base.tuning)
        stats = ExecStats()
        with self.obs.span(roots, "run", eager=True) as rsp:
            results = ex.execute(u.plan, stats)
            # the executor's "__stats__" sentinel is bookkeeping, not an
            # answer column: it travels via ServeStats.exec_stats only
            results.pop("__stats__", None)
            jax.block_until_ready(list(results.values()))
        self._finish_unit(u, results, exec_hit=False, bucket=(),
                          compile_s=0.0, run_s=rsp.duration_s,
                          exec_source="eager")
        for r in u.group:
            r.stats.exec_stats = stats

    # ---- observability ---------------------------------------------------
    def metrics_v2(self) -> dict[str, Any]:
        """Structured metrics: ``{"counters", "gauges", "histograms",
        "tenants"}``.  ``"tenants"`` maps every tenant seen so far to its
        requests/errors/fused counts, rejections split by cause
        (rate/depth/closed), fused-share, and request-latency
        p50/p95/p99 — starvation is visible per tenant, not inferred.

        The service counters (requests/compiles/fused_*/async_*/...) come
        from ONE lock acquisition inside ``Observability.snapshot`` — so
        cross-counter invariants that hold in program order (a request is
        counted before anything it causes) hold in every snapshot too;
        ``fused_queries > requests`` can no longer be observed.  Cache
        counters are added under the service lock, persistent-store
        counters last under the store's own lock (its disk I/O never
        stalls the hot path and no locks nest).  Histograms carry
        per-stage p50/p95/p99 (parse/plan/pad/compile/run/queue_wait/
        request/...).  Peak gauges (``queue_depth_peak``) reset to the
        current value on read."""
        snap = self.obs.snapshot()
        with self._lock:
            snap["counters"].update(self.cache.metrics())
            snap["gauges"]["padded_relations"] = len(self.cache.padded)
        snap["counters"].update(self.cache.persist_metrics())
        snap["counters"].update(self.tuner.metrics())
        snap["counters"].update(
            self.tuner.store.metrics() if self.tuner.store is not None
            else dict(TUNE_PERSIST_ZEROS))
        snap["counters"].update(
            self.stats_store.metrics() if self.stats_store is not None
            else dict(STATS_PERSIST_ZEROS))
        snap["gauges"]["stats_feedback_records"] = self.stats.feedback_len()
        return snap

    def metrics(self) -> dict[str, Any]:
        """Deprecated flat view of ``metrics_v2()`` (counters and gauges
        merged into one dict — the pre-observability shape)."""
        v2 = self.metrics_v2()
        out = dict(v2["counters"])
        out.update(v2["gauges"])
        return out

    def export_trace(self, path) -> int:
        """Write the retained request traces as Chrome-trace JSON —
        loadable in Perfetto (https://ui.perfetto.dev) or
        ``chrome://tracing``.  Returns the number of events written."""
        return self.obs.export_chrome_trace(path)

    def explain(self, query) -> dict[str, Any]:
        """Serve `query` once and report HOW it was answered: the cache
        level that supplied the plan and the executable, fusion-group
        membership, the content-addressed graph/subplan keys, and the
        per-stage timings.  ``["text"]`` is a rendered report."""
        res = self.submit(query)
        st = res.stats
        fp = st.fingerprint
        with self._lock:
            seg = self._segments.get(fp)
        eager, prefix_key, subplans, sig = seg if seg is not None \
            else (False, None, frozenset(), fp)
        with self._lock:
            levels = self.cache.describe(fp, st.bucket, signature=sig,
                                         topo=self._topo)
            plan = self.cache.plans.peek(fp)
            fusion_admission = self._fusion_decisions.get(fp)
        decisions = list(plan.decisions) if plan is not None else []
        if self._mesh is not None:
            axes, counts = self._topo
            sharding = {
                "data_axes": list(axes),
                "shard_counts": dict(zip(axes, counts)),
                "devices": self._jit_executor.n_shards,
                # every scanned relation is row-sharded over the data
                # axes; bucket capacities are per-shard blocks × shards
                "placement": {rel: f"rows over {'×'.join(axes)} "
                                   f"({cap // self._jit_executor.n_shards}"
                                   f" rows/shard)"
                              for rel, cap in st.bucket},
            }
        else:
            sharding = None
        report = {
            "fingerprint": fp,
            "mode": st.mode,
            "eager": eager,
            "plan_source": st.plan_source,
            "exec_source": st.exec_source,
            "cache_levels": levels,
            "fused": st.fused,
            "fused_group_size": st.fused_group_size,
            "graph_key": sig,
            "prefix_key": prefix_key,
            "subplan_keys": sorted(subplans, key=repr),
            "bucket": st.bucket,
            "topology": self._topo,
            "sharding": sharding,
            # the machine-readable planning trace: every gated rewrite
            # pass's applied/skipped verdict with the gate values and the
            # statistics tokens it consulted
            "decisions": [d.to_payload() for d in decisions],
            # the last fusion-admission verdict for this fingerprint (None
            # until it has been a fusion candidate)
            "fusion_admission": fusion_admission,
            "timings_s": {"parse": st.parse_s, "queue": st.queue_s,
                          "plan": st.plan_s, "compile": st.compile_s,
                          "run": st.run_s, "total": st.total_s},
        }
        lines = [f"query {fp[:16]}… mode={st.mode}"
                 + (" (eager fallback)" if eager else ""),
                 f"  plan:  {st.plan_source}"
                 f" (in-memory={levels['plan_in_memory']},"
                 f" on-disk={levels['plan_on_disk']})",
                 f"  exec:  {st.exec_source}"
                 f" (in-memory={levels.get('exec_in_memory', False)})",
                 f"  fused: {st.fused}"
                 + (f" (group of {st.fused_group_size})" if st.fused
                    else ""),
                 f"  graph_key: {sig[:32]}",
                 f"  shared subplans: {len(subplans)}",]
        if decisions:
            lines.append("  planning decisions:")
            lines.extend(f"    {d.describe()}" for d in decisions)
        if fusion_admission is not None:
            fa = fusion_admission
            lines.append("  fusion admission: "
                         + ("admitted" if fa["admitted"] else "rejected")
                         + f" — {fa['reason']}")
        lines += [
                 "  sharding: " + (
                     f"rows over {'×'.join(sharding['data_axes'])} "
                     f"({sharding['devices']} shards)"
                     if sharding is not None else "single-device"),
                 "  timings: " + " ".join(
                     f"{k}={v * 1e3:.2f}ms"
                     for k, v in report["timings_s"].items())]
        report["text"] = "\n".join(lines)
        return report
