"""QueryService: the concurrent SQL serving front door.

The paper's zero-materialisation plans (0MA / Opt⁺) have a *static*
dataflow — no intermediate shape depends on the data — which is exactly
what lets them be compiled once and served many times.  ``QueryService``
turns the repo's one-shot pipeline (parse → classify → rewrite → jit →
run) into a serving engine:

    svc = QueryService(db, schema)
    res = svc.submit("SELECT MIN(s.s_acctbal) FROM supplier s ...")
    res.values, res.stats          # answer + per-query ServeStats
    svc.metrics()                  # cache hit/miss/eviction counters

Request path:

  1. parse SQL → AggQuery (skipped for AggQuery submissions);
  2. canonicalise → fingerprint (alias/variable-name invariant);
  3. plan cache L1: fingerprint → PhysicalPlan;
  4. shape bucket: power-of-two-padded capacities of the scanned
     relations; tables are padded (``Table.pad_to``) to their bucket, so
     data growth inside a bucket re-uses compiled programs;
  5. plan cache L2: (fingerprint, bucket) → jitted executable;
  6. run; results renamed back to the request's output names.

Micro-batching: ``submit_many`` groups requests sharing a fingerprint and
runs each group's executable once, fanning the answer out per request
(each with its own name mapping) — under a read-heavy dashboard workload
identical queries are the common case, and the marginal cost of the
duplicates drops to a dict rename.  Plans that fall outside the jittable
fragment (unguarded/cyclic → ref) are still served, eagerly, with the
paper's ExecStats attached.

Cross-fingerprint fusion: *different* fingerprints whose plans share a
scan/semi-join prefix (``segment_plan``: same relations, selections, join
shape, and guard rooting) are compiled into ONE multi-query XLA program
(``Executor.compile_multi``) that runs the shared prefix once and fans the
root frequency vector out to each member's aggregate suffix.  A dashboard
firing N distinct aggregates over the same dimension joins costs one
compile and one prefix execution instead of N.  Fused executables live in
a prefix-keyed cache level; ``metrics()`` exposes ``fused_*`` counters.

Thread safety: submissions serialise on an internal lock (Python-side
bookkeeping is cheap; the work lives in XLA dispatch), so concurrent
callers can share one service.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.executor import ExecStats, Executor
from repro.core.plan import MaterializeJoinOp, PhysicalPlan, segment_plan
from repro.core.rewrite import plan_query
from repro.core.sql import parse_sql
from repro.service.fingerprint import CanonicalQuery, canonicalize
from repro.service.plan_cache import PlanCache, ShapeBucket
from repro.tables.table import Schema, Table, bucket_capacity


@dataclasses.dataclass
class ServeStats:
    """Per-request serving telemetry."""

    fingerprint: str = ""
    mode: str = ""
    plan_cache_hit: bool = False
    exec_cache_hit: bool = False
    shared_execution: bool = False   # answered by a batch-mate's run
    fused: bool = False              # answered by a multi-query program
    fused_group_size: int = 0        # distinct fingerprints in that program
    bucket: ShapeBucket = ()
    parse_s: float = 0.0
    plan_s: float = 0.0
    compile_s: float = 0.0
    run_s: float = 0.0
    total_s: float = 0.0
    exec_stats: ExecStats | None = None  # eager (ref/opt) plans only


@dataclasses.dataclass
class QueryResult:
    values: dict[str, Any]
    stats: ServeStats


@dataclasses.dataclass
class _Request:
    canon: CanonicalQuery
    stats: ServeStats


@dataclasses.dataclass
class _Unit:
    """One fingerprint's worth of a batch: the requests sharing it, their
    cached plan, and (once served) the canonical result dict."""

    group: list[_Request]
    plan: PhysicalPlan
    plan_hit: bool
    plan_s: float
    eager: bool                       # materialising plan → eager fallback
    prefix_key: str | None            # shareable-prefix identity (jittable)
    results: dict = dataclasses.field(default_factory=dict)

    @property
    def canon(self) -> CanonicalQuery:
        return self.group[0].canon


class QueryService:
    def __init__(self, db: dict[str, Table], schema: Schema, *,
                 mode: str = "auto", use_fkpk: bool = False,
                 freq_dtype=jnp.int32, backend: str = "xla",
                 interpret: bool = True, dense_domain: bool = False,
                 plan_capacity: int = 256, exec_capacity: int = 512,
                 fused_capacity: int = 128, min_bucket: int = 8):
        self._db = dict(db)
        self.schema = schema
        self.mode = mode
        self.use_fkpk = use_fkpk
        self.min_bucket = min_bucket
        self.cache = PlanCache(plan_capacity, exec_capacity, fused_capacity)
        self._jit_executor = Executor(self._db, schema, freq_dtype, backend,
                                      interpret, dense_domain=dense_domain)
        self._padded: dict[str, Table] = {}
        # fingerprint → (eager, prefix_key): segmentation is a pure function
        # of the canonical structure, so memoise it across batches (bounded:
        # cleared when it outgrows the plan cache several times over)
        self._segments: dict[str, tuple[bool, str | None]] = {}
        self._lock = threading.RLock()
        self._counters = {
            "requests": 0, "batches": 0, "dedup_saved": 0,
            "compiles": 0, "eager_requests": 0,
            "bucket_invalidations": 0,
            # cross-fingerprint fusion
            "fused_batches": 0,       # fused program executions
            "fused_queries": 0,       # distinct fingerprints they answered
            "fused_compiles": 0,      # of "compiles", how many were fused
            "fused_prefix_saved": 0,  # prefix executions avoided
        }
        self._compile_s_total = 0.0

    # ---- data plane ------------------------------------------------------
    def update_table(self, name: str, table: Table) -> None:
        """Swap in new data for one relation.  Growth inside the relation's
        shape bucket keeps every compiled executable valid; crossing a
        bucket boundary invalidates only the executables that scan it."""
        if name not in self.schema.relations:
            raise KeyError(f"unknown relation {name!r}")
        want = set(self.schema.relations[name].column_names())
        have = set(table.columns)
        if want != have:
            raise ValueError(f"table {name!r} columns {sorted(have)} != "
                             f"schema columns {sorted(want)}")
        old = self._db.get(name)
        if old is not None:
            # shape buckets key on capacity only; a dtype change would turn
            # an exec-cache "hit" into a silent re-trace inside jax.jit
            # (uncounted compile), so reject it up front
            for col in want:
                if table.columns[col].dtype != old.columns[col].dtype:
                    raise ValueError(
                        f"table {name!r} column {col!r} dtype "
                        f"{table.columns[col].dtype} != existing "
                        f"{old.columns[col].dtype}; keep dtypes stable so "
                        "cached executables stay valid")
            if table.freq.dtype != old.freq.dtype:
                raise ValueError(
                    f"table {name!r} freq dtype {table.freq.dtype} != "
                    f"existing {old.freq.dtype}")
        with self._lock:
            old_bucket = bucket_capacity(self._db[name].capacity,
                                         self.min_bucket) \
                if name in self._db else None
            self._db[name] = table
            self._padded.pop(name, None)
            new_bucket = bucket_capacity(table.capacity, self.min_bucket)
            if old_bucket != new_bucket:
                n = self.cache.invalidate_relation(name)
                self._counters["bucket_invalidations"] += n

    def _padded_view(self, rel: str) -> Table:
        tab = self._padded.get(rel)
        if tab is None:
            raw = self._db[rel]
            tab = raw.pad_to(bucket_capacity(raw.capacity, self.min_bucket))
            self._padded[rel] = tab
        return tab

    def _bucket_for(self, plan: PhysicalPlan) -> ShapeBucket:
        return tuple(
            (rel, bucket_capacity(self._db[rel].capacity, self.min_bucket))
            for rel in plan.scanned_rels())

    # ---- request plane ---------------------------------------------------
    def submit(self, query) -> QueryResult:
        """Serve one query (SQL text or AggQuery)."""
        return self.submit_many([query])[0]

    def submit_many(self, queries) -> list[QueryResult]:
        """Serve a batch of concurrent requests.

        Requests sharing a fingerprint are answered by one executable
        invocation; fingerprints sharing a plan prefix (same scans,
        selections, and join sweep — only the aggregates differ) are fused
        into one multi-query program compiled and run once."""
        with self._lock:
            reqs = [self._admit(q) for q in queries]
            groups: dict[str, list[_Request]] = {}
            for r in reqs:
                groups.setdefault(r.canon.fingerprint, []).append(r)
            self._counters["requests"] += len(reqs)
            self._counters["batches"] += 1
            for group in groups.values():
                self._counters["dedup_saved"] += len(group) - 1

            units = [self._plan_unit(group) for group in groups.values()]

            # partition: eager fallbacks run alone; jittable units group by
            # (query-level prefix candidate, plan-level prefix identity)
            fusable: dict[tuple[str, str], list[_Unit]] = {}
            for u in units:
                if u.eager:
                    self._serve_eager(u)
                elif u.prefix_key is None:
                    self._serve_single(u)
                else:
                    key = (u.canon.prefix_fingerprint, u.prefix_key)
                    fusable.setdefault(key, []).append(u)
            for (_pfp, prefix_key), us in fusable.items():
                if len(us) == 1:
                    self._serve_single(us[0])
                else:
                    self._serve_fused(prefix_key, us)

            results: dict[int, QueryResult] = {}
            for u in units:
                for i, r in enumerate(u.group):
                    r.stats.shared_execution = i > 0
                    r.stats.total_s = (r.stats.parse_s + r.stats.plan_s
                                       + r.stats.compile_s + r.stats.run_s)
                    results[id(r)] = QueryResult(
                        r.canon.rename_results(u.results), r.stats)
            return [results[id(r)] for r in reqs]

    def _admit(self, query) -> _Request:
        stats = ServeStats()
        if isinstance(query, str):
            t0 = time.perf_counter()
            query = parse_sql(query, self.schema)
            stats.parse_s = time.perf_counter() - t0
        canon = canonicalize(query)
        stats.fingerprint = canon.fingerprint
        return _Request(canon, stats)

    def _plan_unit(self, group: list[_Request]) -> _Unit:
        """L1 plan-cache lookup + segmentation for one fingerprint group."""
        canon = group[0].canon
        t0 = time.perf_counter()
        plan, plan_hit = self.cache.get_plan(
            canon.fingerprint,
            lambda: plan_query(canon.query, self.schema, mode=self.mode,
                               use_fkpk=self.use_fkpk))
        plan_s = time.perf_counter() - t0
        seg = self._segments.get(canon.fingerprint)
        if seg is None:
            eager = any(isinstance(op, MaterializeJoinOp) for op in plan.ops)
            prefix_key = None if eager else segment_plan(plan).prefix_key
            if len(self._segments) > 4 * self.cache.plans.capacity:
                self._segments.clear()
            self._segments[canon.fingerprint] = seg = (eager, prefix_key)
        eager, prefix_key = seg
        return _Unit(group, plan, plan_hit, plan_s, eager, prefix_key)

    def _finish_unit(self, u: _Unit, results: dict, *, exec_hit: bool,
                     bucket: ShapeBucket, compile_s: float, run_s: float,
                     fused_size: int = 0) -> None:
        u.results = results
        for r in u.group:
            r.stats.mode = u.plan.mode
            r.stats.plan_cache_hit = u.plan_hit
            r.stats.exec_cache_hit = exec_hit
            r.stats.fused = fused_size > 1
            r.stats.fused_group_size = fused_size
            r.stats.bucket = bucket
            r.stats.plan_s = u.plan_s
            r.stats.compile_s = compile_s
            r.stats.run_s = run_s

    def _serve_single(self, u: _Unit) -> None:
        """The classic path: one fingerprint, one executable."""
        bucket = self._bucket_for(u.plan)
        fn, exec_hit, compile_s = self._executable(u.canon, u.plan, bucket)
        sub_db = {rel: self._padded_view(rel)
                  for rel in u.plan.scanned_rels()}
        t0 = time.perf_counter()
        results = fn(sub_db)
        jax.block_until_ready(results)
        run_s = time.perf_counter() - t0
        self._finish_unit(u, results, exec_hit=exec_hit, bucket=bucket,
                          compile_s=compile_s, run_s=run_s)

    def _serve_fused(self, prefix_key: str, units: list[_Unit]) -> None:
        """Compile and run several prefix-sharing fingerprints as ONE
        program: the shared scan/semi-join prefix executes once, each
        member's aggregate suffix folds the same root frequency vector."""
        units.sort(key=lambda u: u.canon.fingerprint)
        members = tuple(u.canon.fingerprint for u in units)
        plans = [u.plan for u in units]
        rels = sorted({rel for p in plans for rel in p.scanned_rels()})
        bucket: ShapeBucket = tuple(
            (rel, bucket_capacity(self._db[rel].capacity, self.min_bucket))
            for rel in rels)
        compile_s = 0.0

        def build():
            nonlocal compile_s
            t0 = time.perf_counter()
            fn = self._jit_executor.compile_multi(plans)
            sub = {rel: self._padded_view(rel) for rel in rels}
            jax.block_until_ready(fn(sub))
            compile_s = time.perf_counter() - t0
            self._counters["compiles"] += 1
            self._counters["fused_compiles"] += 1
            self._compile_s_total += compile_s
            return fn

        fn, exec_hit = self.cache.get_fused(prefix_key, members, bucket,
                                            build)
        sub_db = {rel: self._padded_view(rel) for rel in rels}
        t0 = time.perf_counter()
        outs = fn(sub_db)
        jax.block_until_ready(outs)
        run_s = time.perf_counter() - t0

        self._counters["fused_batches"] += 1
        self._counters["fused_queries"] += len(units)
        self._counters["fused_prefix_saved"] += len(units) - 1
        for u, results in zip(units, outs):
            self._finish_unit(u, results, exec_hit=exec_hit, bucket=bucket,
                              compile_s=compile_s, run_s=run_s,
                              fused_size=len(units))

    def _executable(self, canon: CanonicalQuery, plan: PhysicalPlan,
                    bucket: ShapeBucket) -> tuple[Callable, bool, float]:
        compile_s = 0.0

        def build():
            nonlocal compile_s
            t0 = time.perf_counter()
            fn = self._jit_executor.compile(plan)
            # trace + compile now, against the bucket shapes, so the cache
            # entry is a ready-to-run program and `run_s` is pure execution
            sub_db = {rel: self._padded_view(rel)
                      for rel in plan.scanned_rels()}
            jax.block_until_ready(fn(sub_db))
            compile_s = time.perf_counter() - t0
            self._counters["compiles"] += 1
            self._compile_s_total += compile_s
            return fn

        fn, hit = self.cache.get_executable(canon.fingerprint, bucket, build)
        return fn, hit, compile_s

    def _serve_eager(self, u: _Unit) -> None:
        """Fallback for non-jittable (materialising) plans: serve eagerly
        with the paper's per-step ExecStats attached."""
        self._counters["eager_requests"] += len(u.group)
        # the jit executor shares self._db (update_table mutates in place)
        # and was never configured with eager-only options, so it serves
        # the eager surface too
        stats = ExecStats()
        t0 = time.perf_counter()
        results = self._jit_executor.execute(u.plan, stats)
        # the executor's "__stats__" sentinel is bookkeeping, not an answer
        # column: it travels via ServeStats.exec_stats only
        results.pop("__stats__", None)
        jax.block_until_ready(list(results.values()))
        run_s = time.perf_counter() - t0
        self._finish_unit(u, results, exec_hit=False, bucket=(),
                          compile_s=0.0, run_s=run_s)
        for r in u.group:
            r.stats.exec_stats = stats

    # ---- observability ---------------------------------------------------
    def metrics(self) -> dict[str, Any]:
        with self._lock:
            out = dict(self._counters)
            out.update(self.cache.metrics())
            out["compile_s_total"] = self._compile_s_total
            out["padded_relations"] = len(self._padded)
            return out
