"""QueryService: the concurrent SQL serving front door.

The paper's zero-materialisation plans (0MA / Opt⁺) have a *static*
dataflow — no intermediate shape depends on the data — which is exactly
what lets them be compiled once and served many times.  ``QueryService``
turns the repo's one-shot pipeline (parse → classify → rewrite → jit →
run) into a serving engine:

    svc = QueryService(db, schema)
    res = svc.submit("SELECT MIN(s.s_acctbal) FROM supplier s ...")
    res.values, res.stats          # answer + per-query ServeStats
    svc.metrics()                  # cache hit/miss/eviction counters

Request path:

  1. parse SQL → AggQuery (skipped for AggQuery submissions); admission
     fails fast — with the relation named — if a query touches a schema
     relation with no loaded table;
  2. canonicalise → fingerprint (alias/variable-name invariant);
  3. plan cache L1: fingerprint → PhysicalPlan (an op-graph DAG);
  4. shape bucket: power-of-two-padded capacities of the scanned
     relations; tables are padded (``Table.pad_to``) to their bucket, so
     data growth inside a bucket re-uses compiled programs;
  5. plan cache L2: (fingerprint, bucket) → jitted executable;
  6. run; results renamed back to the request's output names.

Micro-batching: ``submit_many`` groups requests sharing a fingerprint and
runs each group's executable once, fanning the answer out per request
(each with its own name mapping).

Cross-fingerprint fusion: *different* fingerprints whose plan DAGs share
at least one non-trivial subplan (``PhysicalPlan.subplan_keys``: a join
node or a filtered scan with an equal content key) are grouped — union-find
over shared keys, so overlap is transitive — and compiled into ONE
multi-query XLA program (``Executor.compile_multi``) whose trace memo runs
every shared sub-DAG once.  Unlike PR 2's whole-prefix equality, this
fuses across *different join shapes*: a 3-way and a 5-way dashboard query
sharing only their filtered dimension scans and first semi-joins still
compile together.  Fused executables are cached by the merged-graph
signature (sorted member graph keys) + shape bucket; ``metrics()`` exposes
``fused_*`` plus ``partial_fusions`` (fused runs whose members do NOT all
share one whole prefix — fusions the prefix rule would have missed) and
``subplan_saved`` (subplan executions avoided by the shared trace memo).

Thread safety: the internal lock guards only cache and database mutation —
XLA compiles and query execution run outside it, coordinated by per-key
in-flight events so concurrent cold requests for the same executable
compile it once.  ``metrics()`` and ``update_table`` never wait behind a
long compile or an eager baseline run.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.executor import (
    ExecStats,
    Executor,
    shared_subplan_savings,
)
from repro.core.plan import MaterializeJoinOp, PhysicalPlan, segment_plan
from repro.core.rewrite import plan_query
from repro.core.sql import parse_sql
from repro.service.fingerprint import CanonicalQuery, canonicalize
from repro.service.plan_cache import LRUCache, PlanCache, ShapeBucket
from repro.tables.table import Schema, Table, bucket_capacity


class AdmissionError(ValueError):
    """A request referenced a relation the service cannot serve (present
    in the schema but with no table loaded, or unknown entirely)."""


@dataclasses.dataclass
class ServeStats:
    """Per-request serving telemetry."""

    fingerprint: str = ""
    mode: str = ""
    plan_cache_hit: bool = False
    exec_cache_hit: bool = False
    shared_execution: bool = False   # answered by a batch-mate's run
    fused: bool = False              # answered by a multi-query program
    fused_group_size: int = 0        # distinct fingerprints in that program
    bucket: ShapeBucket = ()
    parse_s: float = 0.0
    plan_s: float = 0.0
    compile_s: float = 0.0
    run_s: float = 0.0
    total_s: float = 0.0
    exec_stats: ExecStats | None = None  # eager (ref/opt) plans only


@dataclasses.dataclass
class QueryResult:
    values: dict[str, Any]
    stats: ServeStats


@dataclasses.dataclass
class _Request:
    canon: CanonicalQuery
    stats: ServeStats


@dataclasses.dataclass
class _Unit:
    """One fingerprint's worth of a batch: the requests sharing it, their
    cached plan, the plan's fusion identity, and (once served) the
    canonical result dict."""

    group: list[_Request]
    plan: PhysicalPlan
    plan_hit: bool
    plan_s: float
    eager: bool                       # materialising plan → eager fallback
    prefix_key: str | None            # whole-prefix identity (diagnostics)
    subplans: frozenset               # non-trivial subplan content keys
    sig: str                          # member signature for the fused cache
    results: dict = dataclasses.field(default_factory=dict)

    @property
    def canon(self) -> CanonicalQuery:
        return self.group[0].canon


class QueryService:
    def __init__(self, db: dict[str, Table], schema: Schema, *,
                 mode: str = "auto", use_fkpk: bool = False,
                 freq_dtype=jnp.int32, backend: str = "xla",
                 interpret: bool = True, dense_domain: bool = False,
                 plan_capacity: int = 256, exec_capacity: int = 512,
                 fused_capacity: int = 128, min_bucket: int = 8):
        self._db = dict(db)
        self.schema = schema
        self.mode = mode
        self.use_fkpk = use_fkpk
        self.min_bucket = min_bucket
        self.cache = PlanCache(plan_capacity, exec_capacity, fused_capacity)
        self._jit_executor = Executor(self._db, schema, freq_dtype, backend,
                                      interpret, dense_domain=dense_domain)
        self._padded: dict[str, Table] = {}
        # fingerprint → (eager, prefix_key, subplans, sig): the fusion
        # identity is a pure function of the canonical structure, so
        # memoise it across batches (bounded: cleared when it outgrows the
        # plan cache several times over)
        self._segments: dict[str, tuple] = {}
        # guards cache + db mutation ONLY; compiles and execution run
        # outside it, serialised per cache key by these in-flight events
        self._lock = threading.RLock()
        self._inflight: dict[tuple, threading.Event] = {}
        self._counters = {
            "requests": 0, "batches": 0, "dedup_saved": 0,
            "compiles": 0, "eager_requests": 0,
            "bucket_invalidations": 0,
            # cross-fingerprint fusion
            "fused_batches": 0,       # fused program executions
            "fused_queries": 0,       # distinct fingerprints they answered
            "fused_compiles": 0,      # of "compiles", how many were fused
            "partial_fusions": 0,     # fused runs beyond whole-prefix rule
            "subplan_saved": 0,       # subplan executions avoided
        }
        self._compile_s_total = 0.0

    # ---- data plane ------------------------------------------------------
    def update_table(self, name: str, table: Table) -> None:
        """Swap in new data for one relation.  Growth inside the relation's
        shape bucket keeps every compiled executable valid; crossing a
        bucket boundary invalidates only the executables that scan it."""
        if name not in self.schema.relations:
            raise KeyError(f"unknown relation {name!r}")
        want = set(self.schema.relations[name].column_names())
        have = set(table.columns)
        if want != have:
            raise ValueError(f"table {name!r} columns {sorted(have)} != "
                             f"schema columns {sorted(want)}")
        old = self._db.get(name)
        if old is not None:
            # shape buckets key on capacity only; a dtype change would turn
            # an exec-cache "hit" into a silent re-trace inside jax.jit
            # (uncounted compile), so reject it up front
            for col in want:
                if table.columns[col].dtype != old.columns[col].dtype:
                    raise ValueError(
                        f"table {name!r} column {col!r} dtype "
                        f"{table.columns[col].dtype} != existing "
                        f"{old.columns[col].dtype}; keep dtypes stable so "
                        "cached executables stay valid")
            if table.freq.dtype != old.freq.dtype:
                raise ValueError(
                    f"table {name!r} freq dtype {table.freq.dtype} != "
                    f"existing {old.freq.dtype}")
        with self._lock:
            old_bucket = bucket_capacity(self._db[name].capacity,
                                         self.min_bucket) \
                if name in self._db else None
            self._db[name] = table
            self._padded.pop(name, None)
            new_bucket = bucket_capacity(table.capacity, self.min_bucket)
            if old_bucket != new_bucket:
                n = self.cache.invalidate_relation(name)
                self._counters["bucket_invalidations"] += n

    def _snapshot(self, rels) -> tuple[ShapeBucket, dict[str, Table]]:
        """Shape bucket + bucket-padded table views for `rels`, taken under
        ONE lock acquisition so they describe the same database state: a
        concurrent bucket-crossing ``update_table`` can never pair a
        stale-bucket cache key with fresh-shaped inputs (which would make
        the cached jitted fn silently retrace inside ``jax.jit``).  Tables
        are immutable, so the snapshot stays consistent after release."""
        with self._lock:
            bucket: ShapeBucket = tuple(
                (rel, bucket_capacity(self._db[rel].capacity,
                                      self.min_bucket))
                for rel in rels)
            sub_db: dict[str, Table] = {}
            for rel, cap in bucket:
                tab = self._padded.get(rel)
                if tab is None:
                    self._padded[rel] = tab = self._db[rel].pad_to(cap)
                sub_db[rel] = tab
            return bucket, sub_db

    # ---- request plane ---------------------------------------------------
    def submit(self, query) -> QueryResult:
        """Serve one query (SQL text or AggQuery)."""
        return self.submit_many([query])[0]

    def submit_many(self, queries) -> list[QueryResult]:
        """Serve a batch of concurrent requests.

        Requests sharing a fingerprint are answered by one executable
        invocation; fingerprints whose plan DAGs overlap on any non-trivial
        subplan are fused into one multi-query program compiled and run
        once, with every shared sub-DAG computed a single time."""
        reqs = [self._admit(q) for q in queries]
        with self._lock:
            groups: dict[str, list[_Request]] = {}
            for r in reqs:
                groups.setdefault(r.canon.fingerprint, []).append(r)
            self._counters["requests"] += len(reqs)
            self._counters["batches"] += 1
            for group in groups.values():
                self._counters["dedup_saved"] += len(group) - 1
            units = [self._plan_unit(group) for group in groups.values()]

        eagers, singles, fused_groups = self._fusion_groups(units)
        for u in eagers:
            self._serve_eager(u)
        for u in singles:
            self._serve_single(u)
        for us in fused_groups:
            self._serve_fused(us)

        results: dict[int, QueryResult] = {}
        for u in units:
            for i, r in enumerate(u.group):
                r.stats.shared_execution = i > 0
                r.stats.total_s = (r.stats.parse_s + r.stats.plan_s
                                   + r.stats.compile_s + r.stats.run_s)
                results[id(r)] = QueryResult(
                    r.canon.rename_results(u.results), r.stats)
        return [results[id(r)] for r in reqs]

    def _admit(self, query) -> _Request:
        stats = ServeStats()
        if isinstance(query, str):
            t0 = time.perf_counter()
            query = parse_sql(query, self.schema)
            stats.parse_s = time.perf_counter() - t0
        for atom in query.atoms:
            if atom.rel not in self.schema.relations:
                raise AdmissionError(
                    f"query references relation {atom.rel!r}, which is not "
                    "in the schema")
            if atom.rel not in self._db:
                raise AdmissionError(
                    f"query references relation {atom.rel!r}, which has no "
                    f"table loaded; call update_table({atom.rel!r}, table) "
                    "first")
        canon = canonicalize(query)
        stats.fingerprint = canon.fingerprint
        return _Request(canon, stats)

    def _plan_unit(self, group: list[_Request]) -> _Unit:
        """L1 plan-cache lookup + fusion identity for one fingerprint
        group.  Caller holds the lock."""
        canon = group[0].canon
        t0 = time.perf_counter()
        plan, plan_hit = self.cache.get_plan(
            canon.fingerprint,
            lambda: plan_query(canon.query, self.schema, mode=self.mode,
                               use_fkpk=self.use_fkpk))
        plan_s = time.perf_counter() - t0
        seg = self._segments.get(canon.fingerprint)
        if seg is None:
            eager = any(isinstance(op, MaterializeJoinOp) for op in plan.ops)
            if eager:
                seg = (True, None, frozenset(), canon.fingerprint)
            else:
                # opaque-selection plans key their scans on callable
                # identity, which can be recycled after GC — their member
                # signature falls back to the (salted, process-unique)
                # fingerprint so a fused cache entry can never alias them
                gk = plan.graph_key() if canon.shareable else None
                seg = (False, segment_plan(plan).prefix_key,
                       plan.subplan_keys(),
                       gk if gk is not None else canon.fingerprint)
            if len(self._segments) > 4 * self.cache.plans.capacity:
                self._segments.clear()
            self._segments[canon.fingerprint] = seg
        eager, prefix_key, subplans, sig = seg
        return _Unit(group, plan, plan_hit, plan_s, eager, prefix_key,
                     subplans, sig)

    def _fusion_groups(self, units: list[_Unit]):
        """Partition a batch: eager fallbacks, lone jittable units, and
        fusion groups — connected components of the "shares a non-trivial
        subplan key" relation (union-find over key owners)."""
        eagers = [u for u in units if u.eager]
        jit_units = [u for u in units if not u.eager]
        singles = [u for u in jit_units if not u.subplans]
        fusable = [u for u in jit_units if u.subplans]

        parent = list(range(len(fusable)))

        def find(i: int) -> int:
            while parent[i] != i:
                parent[i] = parent[parent[i]]
                i = parent[i]
            return i

        owner: dict = {}
        for i, u in enumerate(fusable):
            for k in u.subplans:
                j = owner.setdefault(k, i)
                if j != i:
                    parent[find(i)] = find(j)
        comps: dict[int, list[_Unit]] = {}
        for i, u in enumerate(fusable):
            comps.setdefault(find(i), []).append(u)
        fused_groups = []
        for comp in comps.values():
            if len(comp) == 1:
                singles.append(comp[0])
            else:
                fused_groups.append(comp)
        return eagers, singles, fused_groups

    # ---- execution -------------------------------------------------------
    def _get_or_build(self, cache: LRUCache, key, build: Callable):
        """Executable-cache access with the lock held only around the cache
        itself: a miss releases the lock, compiles, and re-inserts, while
        concurrent requests for the SAME key wait on an in-flight event
        instead of compiling twice (and requests for other keys — or
        ``metrics()``/``update_table`` — proceed untouched)."""
        flight_key = (id(cache), key)
        while True:
            with self._lock:
                if key in cache:
                    return cache.get(key), True
                ev = self._inflight.get(flight_key)
                if ev is None:
                    ev = threading.Event()
                    self._inflight[flight_key] = ev
                    break
            ev.wait()
        try:
            value = build()
            with self._lock:
                cache.misses += 1
                cache.put(key, value)
            return value, False
        finally:
            with self._lock:
                self._inflight.pop(flight_key, None)
            ev.set()

    def _finish_unit(self, u: _Unit, results: dict, *, exec_hit: bool,
                     bucket: ShapeBucket, compile_s: float, run_s: float,
                     fused_size: int = 0) -> None:
        u.results = results
        for r in u.group:
            r.stats.mode = u.plan.mode
            r.stats.plan_cache_hit = u.plan_hit
            r.stats.exec_cache_hit = exec_hit
            r.stats.fused = fused_size > 1
            r.stats.fused_group_size = fused_size
            r.stats.bucket = bucket
            r.stats.plan_s = u.plan_s
            r.stats.compile_s = compile_s
            r.stats.run_s = run_s

    def _serve_single(self, u: _Unit) -> None:
        """The classic path: one fingerprint, one executable."""
        bucket, sub_db = self._snapshot(u.plan.scanned_rels())
        fn, exec_hit, compile_s = self._executable(u.canon, u.plan, bucket,
                                                   sub_db)
        t0 = time.perf_counter()
        results = fn(sub_db)
        jax.block_until_ready(results)
        run_s = time.perf_counter() - t0
        self._finish_unit(u, results, exec_hit=exec_hit, bucket=bucket,
                          compile_s=compile_s, run_s=run_s)

    def _serve_fused(self, units: list[_Unit]) -> None:
        """Compile and run several subplan-sharing fingerprints as ONE
        program: each shared sub-DAG executes once, every member's
        remaining ops fold the shared vectors into its own answer."""
        units.sort(key=lambda u: u.canon.fingerprint)
        plans = [u.plan for u in units]
        rels = sorted({rel for p in plans for rel in p.scanned_rels()})
        bucket, sub_db = self._snapshot(rels)
        signature = hashlib.sha256(
            repr(tuple(u.sig for u in units)).encode()).hexdigest()
        compile_s = 0.0

        def build():
            nonlocal compile_s
            t0 = time.perf_counter()
            fn = self._jit_executor.compile_multi(plans)
            jax.block_until_ready(fn(sub_db))
            compile_s = time.perf_counter() - t0
            with self._lock:
                self._counters["compiles"] += 1
                self._counters["fused_compiles"] += 1
                self._compile_s_total += compile_s
            return fn

        fn, exec_hit = self._get_or_build(
            self.cache.fused, PlanCache.fused_key(signature, bucket), build)
        t0 = time.perf_counter()
        outs = fn(sub_db)
        jax.block_until_ready(outs)
        run_s = time.perf_counter() - t0

        with self._lock:
            self._counters["fused_batches"] += 1
            self._counters["fused_queries"] += len(units)
            self._counters["subplan_saved"] += shared_subplan_savings(plans)
            if len({u.prefix_key for u in units}) > 1:
                # members do NOT all share one whole prefix: this fusion is
                # beyond PR 2's equal-prefix rule (different join shapes)
                self._counters["partial_fusions"] += 1
        for u, results in zip(units, outs):
            self._finish_unit(u, results, exec_hit=exec_hit, bucket=bucket,
                              compile_s=compile_s, run_s=run_s,
                              fused_size=len(units))

    def _executable(self, canon: CanonicalQuery, plan: PhysicalPlan,
                    bucket: ShapeBucket, sub_db: dict[str, Table],
                    ) -> tuple[Callable, bool, float]:
        compile_s = 0.0

        def build():
            nonlocal compile_s
            t0 = time.perf_counter()
            fn = self._jit_executor.compile(plan)
            # trace + compile now, against the snapshot's bucket shapes, so
            # the cache entry is a ready-to-run program and `run_s` is pure
            # execution
            jax.block_until_ready(fn(sub_db))
            compile_s = time.perf_counter() - t0
            with self._lock:
                self._counters["compiles"] += 1
                self._compile_s_total += compile_s
            return fn

        fn, hit = self._get_or_build(
            self.cache.execs,
            PlanCache.exec_key(canon.fingerprint, bucket), build)
        return fn, hit, compile_s

    def _serve_eager(self, u: _Unit) -> None:
        """Fallback for non-jittable (materialising) plans: serve eagerly
        with the paper's per-step ExecStats attached."""
        base = self._jit_executor
        with self._lock:
            self._counters["eager_requests"] += len(u.group)
            # snapshot the scanned tables under the lock (tables are
            # immutable): execution then runs unlocked over a consistent
            # database state even if update_table swaps relations mid-run
            sub_db = {rel: self._db[rel] for rel in u.plan.scanned_rels()}
        ex = Executor(sub_db, self.schema, base.freq_dtype, base.backend,
                      base.interpret, dense_domain=base.dense_domain)
        stats = ExecStats()
        t0 = time.perf_counter()
        results = ex.execute(u.plan, stats)
        # the executor's "__stats__" sentinel is bookkeeping, not an answer
        # column: it travels via ServeStats.exec_stats only
        results.pop("__stats__", None)
        jax.block_until_ready(list(results.values()))
        run_s = time.perf_counter() - t0
        self._finish_unit(u, results, exec_hit=False, bucket=(),
                          compile_s=0.0, run_s=run_s)
        for r in u.group:
            r.stats.exec_stats = stats

    # ---- observability ---------------------------------------------------
    def metrics(self) -> dict[str, Any]:
        with self._lock:
            out = dict(self._counters)
            out.update(self.cache.metrics())
            out["compile_s_total"] = self._compile_s_total
            out["padded_relations"] = len(self._padded)
            return out
