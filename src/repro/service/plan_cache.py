"""Plan cache: fingerprint → plan, (fingerprint, bucket) → jit, and a
prefix-keyed level for fused multi-query programs.

Level 1 amortises the front half of the pipeline (GYO classification,
guard re-rooting, rule rewrites): one ``PhysicalPlan`` per query structure.
Level 2 amortises the expensive half (XLA trace + compile): one executable
per (structure, shape bucket).  Buckets are tuples of
``(relation, padded_capacity)`` over the relations the plan scans, with
capacities rounded up to powers of two (``bucket_capacity``) — so tables
growing inside their bucket re-use the compiled program bit-for-bit.
Level 3 caches *fused* executables — one XLA program answering several
distinct fingerprints whose plan DAGs overlap on shared subplans — keyed
by (merged-graph signature, bucket), so a repeating dashboard workload
recompiles nothing.  The signature hashes the sorted member graph keys
(``PhysicalPlan.graph_key``), so any request order for the same query set
hits the same compiled program.

A fourth, data-plane level caches the bucket-padded table *views*
(``Table.pad_to`` output) per relation, entries tagged with their source
table so a view is never served against swapped-in data: ``update_table``
calls ``drop_padded`` and the engine re-validates the tag on every read.
Padding is device work, so bounding this level (LRU) keeps a service that
has touched many relations from pinning every padded copy forever.

Below all the LRU levels sits an optional PERSISTENT level
(``repro.service.plan_store.PlanStore``): a plan that misses the in-memory
``plans`` LRU is looked up on disk before being re-planned, and freshly
built plans are written back — so plan structures survive process
restarts.  The store is strictly a lower level: it never affects LRU
bookkeeping, its failures degrade to memory-only caching, and its
``persist_*`` counters ride along in ``metrics()``.

All levels are bounded LRU with hit/miss/eviction counters; ``metrics()``
flattens them into the dict the serving engine exposes.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Hashable

ShapeBucket = tuple[tuple[str, int], ...]


class LRUCache:
    """Ordered-dict LRU with counters.  Single-threaded by design: the
    serving engine serialises cache access (JAX dispatch is where the
    concurrency lives, not the Python bookkeeping)."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self._d: OrderedDict[Hashable, Any] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:
        return key in self._d

    def get(self, key, default=None):
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return default

    def peek(self, key, default=None):
        """Read without touching counters or LRU order — for callers that
        must validate the entry before deciding whether this was really a
        hit (see the serving engine's ``_get_or_build``)."""
        return self._d.get(key, default)

    def note_hit(self, key) -> None:
        """Record the hit a prior ``peek`` deferred: one counter bump and
        an LRU refresh."""
        self._d.move_to_end(key)
        self.hits += 1

    def put(self, key, value) -> None:
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = value
        if len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def get_or_create(self, key, factory: Callable[[], Any]):
        """Return (value, hit) — counting exactly one hit or miss."""
        if key in self._d:
            return self.get(key), True
        value = factory()
        self.misses += 1
        self.put(key, value)
        return value, False

    def items(self) -> list[tuple[Hashable, Any]]:
        """Snapshot of (key, value) pairs, LRU-oldest first — for cache
        export; no counters touched."""
        return list(self._d.items())

    def invalidate_if(self, pred: Callable[[Hashable], bool]) -> int:
        """Drop entries whose key matches; returns the count (not counted
        as evictions — these are correctness invalidations, not pressure)."""
        doomed = [k for k in self._d if pred(k)]
        for k in doomed:
            del self._d[k]
        return len(doomed)

    def invalidate_items(self,
                         pred: Callable[[Hashable, Any], bool]) -> int:
        """Like ``invalidate_if`` but the predicate sees the VALUE too —
        for invalidations keyed on entry content (e.g. a cached plan whose
        decision trace consulted statistics that have since changed)."""
        doomed = [k for k, v in self._d.items() if pred(k, v)]
        for k in doomed:
            del self._d[k]
        return len(doomed)

    def counters(self) -> dict[str, int]:
        return {"size": len(self._d), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}


class PlanCache:
    """Four levels:

    * ``plans`` — fingerprint → PhysicalPlan;
    * ``execs`` — (fingerprint, topology, ShapeBucket) → single-query
      executable, where topology is ``(axis_names, shard_counts)`` for a
      mesh-lowered program and ``()`` locally;
    * ``fused`` — (merged-graph signature, topology, ShapeBucket) → fused
      multi-query executable.  The signature content-addresses the whole
      member set (sorted graph keys), so it is order-invariant and safe
      across structurally-identical query sets;
    * ``padded`` — relation name → (source Table, bucket-padded view).
      The source-table tag is the consistency check: readers compare it
      against their own database snapshot and ignore (then overwrite)
      entries padded from data that has since been swapped out.

    Plus the optional persistent level under ``plans``: ``store`` (a
    ``PlanStore`` or None), consulted via ``load_persistent`` /
    ``save_persistent`` when the in-memory level misses.
    """

    def __init__(self, plan_capacity: int = 256, exec_capacity: int = 512,
                 fused_capacity: int = 128, padded_capacity: int = 64,
                 store=None):
        self.plans = LRUCache(plan_capacity)
        self.execs = LRUCache(exec_capacity)
        self.fused = LRUCache(fused_capacity)
        self.padded = LRUCache(padded_capacity)
        self.store = store

    def load_persistent(self, fingerprint: str):
        """Disk-level plan lookup (None without a store / on any miss).
        Corrupt entries are skipped and evicted by the store itself."""
        if self.store is None:
            return None
        return self.store.load(fingerprint)

    def save_persistent(self, fingerprint: str, plan) -> bool:
        """Best-effort disk write-back of a freshly built plan."""
        if self.store is None:
            return False
        return self.store.save(fingerprint, plan)

    # single source of the executable-cache key shapes: the serving engine
    # accesses the LRUs directly (to keep builds outside its lock) but
    # builds its keys here, and ``invalidate_relation`` relies on the
    # bucket sitting last.  ``topo`` is the shard topology the executable
    # was lowered for — ``(axis_names, shard_counts)`` on a mesh service,
    # ``()`` on a single device: the same fingerprint served at the same
    # bucket compiles to a DIFFERENT program per mesh shape (ring length,
    # collective layout), so topologies must occupy distinct entries.
    @staticmethod
    def exec_key(fingerprint: str, bucket: ShapeBucket,
                 topo: tuple = ()) -> tuple:
        return (fingerprint, topo, bucket)

    @staticmethod
    def fused_key(signature: str, bucket: ShapeBucket,
                  topo: tuple = ()) -> tuple:
        return (signature, topo, bucket)

    def get_executable(self, fingerprint: str, bucket: ShapeBucket,
                       factory: Callable[[], Callable],
                       topo: tuple = ()) -> tuple[Callable, bool]:
        return self.execs.get_or_create(
            self.exec_key(fingerprint, bucket, topo), factory)

    def invalidate_relation(self, rel: str) -> int:
        """Drop executables whose bucket pins `rel` to a now-stale capacity.
        Called when a table's data outgrows its bucket; plans (shape-free)
        survive.  Both key builders above place the bucket last."""
        def stale(key) -> bool:
            bucket = key[-1]
            return any(r == rel for r, _ in bucket)

        return (self.execs.invalidate_if(stale)
                + self.fused.invalidate_if(stale))

    def drop_padded(self, rel: str) -> None:
        """Forget the padded view for `rel` (its source table was swapped).
        Not an eviction: the entry is simply stale."""
        self.padded.invalidate_if(lambda k: k == rel)

    def describe(self, fingerprint: str, bucket: ShapeBucket | None = None,
                 signature: str | None = None,
                 topo: tuple = ()) -> dict[str, bool]:
        """Hit-level attribution for one fingerprint — which cache levels
        could answer it RIGHT NOW.  Counter-free and LRU-order-free
        (``peek`` semantics): this is an inspection surface for
        ``QueryService.explain``, not a lookup."""
        out = {
            "plan_in_memory": fingerprint in self.plans,
            "plan_on_disk": (self.store.has(fingerprint)
                             if self.store is not None else False),
        }
        if bucket is not None:
            out["exec_in_memory"] = \
                self.exec_key(fingerprint, bucket, topo) in self.execs
            if signature is not None:
                out["fused_in_memory"] = \
                    self.fused_key(signature, bucket, topo) in self.fused
        return out

    def metrics(self) -> dict[str, int]:
        """The LRU levels' counters.  The persistent level reports via
        ``persist_metrics()`` — kept separate because it touches the disk
        (entry count) and synchronises on the store's own lock, so callers
        holding a hot-path lock (the serving engine) can collect it
        outside."""
        out = {}
        for level, cache in (("plan", self.plans), ("exec", self.execs),
                             ("fused", self.fused), ("padded", self.padded)):
            for k, v in cache.counters().items():
                out[f"{level}_{k}"] = v
        return out

    def persist_metrics(self) -> dict[str, int]:
        from repro.service.plan_store import PERSIST_ZEROS

        return (self.store.metrics() if self.store is not None
                else dict(PERSIST_ZEROS))
