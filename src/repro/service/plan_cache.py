"""Two-level plan cache: fingerprint → plan, (fingerprint, bucket) → jit.

Level 1 amortises the front half of the pipeline (GYO classification,
guard re-rooting, rule rewrites): one ``PhysicalPlan`` per query structure.
Level 2 amortises the expensive half (XLA trace + compile): one executable
per (structure, shape bucket).  Buckets are tuples of
``(relation, padded_capacity)`` over the relations the plan scans, with
capacities rounded up to powers of two (``bucket_capacity``) — so tables
growing inside their bucket re-use the compiled program bit-for-bit.

Both levels are bounded LRU with hit/miss/eviction counters; ``metrics()``
flattens them into the dict the serving engine exposes.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Hashable

from repro.core.plan import PhysicalPlan

ShapeBucket = tuple[tuple[str, int], ...]


class LRUCache:
    """Ordered-dict LRU with counters.  Single-threaded by design: the
    serving engine serialises cache access (JAX dispatch is where the
    concurrency lives, not the Python bookkeeping)."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self._d: OrderedDict[Hashable, Any] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:
        return key in self._d

    def get(self, key, default=None):
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return default

    def put(self, key, value) -> None:
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = value
        if len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def get_or_create(self, key, factory: Callable[[], Any]):
        """Return (value, hit) — counting exactly one hit or miss."""
        if key in self._d:
            return self.get(key), True
        value = factory()
        self.misses += 1
        self.put(key, value)
        return value, False

    def invalidate_if(self, pred: Callable[[Hashable], bool]) -> int:
        """Drop entries whose key matches; returns the count (not counted
        as evictions — these are correctness invalidations, not pressure)."""
        doomed = [k for k in self._d if pred(k)]
        for k in doomed:
            del self._d[k]
        return len(doomed)

    def counters(self) -> dict[str, int]:
        return {"size": len(self._d), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}


class PlanCache:
    """fingerprint → PhysicalPlan, (fingerprint, ShapeBucket) → executable."""

    def __init__(self, plan_capacity: int = 256, exec_capacity: int = 512):
        self.plans = LRUCache(plan_capacity)
        self.execs = LRUCache(exec_capacity)

    def get_plan(self, fingerprint: str,
                 factory: Callable[[], PhysicalPlan]) -> tuple[PhysicalPlan, bool]:
        return self.plans.get_or_create(fingerprint, factory)

    def get_executable(self, fingerprint: str, bucket: ShapeBucket,
                       factory: Callable[[], Callable]) -> tuple[Callable, bool]:
        return self.execs.get_or_create((fingerprint, bucket), factory)

    def invalidate_relation(self, rel: str) -> int:
        """Drop executables whose bucket pins `rel` to a now-stale capacity.
        Called when a table's data outgrows its bucket; plans (shape-free)
        survive."""
        return self.execs.invalidate_if(
            lambda key: any(r == rel for r, _ in key[1]))

    def metrics(self) -> dict[str, int]:
        out = {}
        for level, cache in (("plan", self.plans), ("exec", self.execs)):
            for k, v in cache.counters().items():
                out[f"{level}_{k}"] = v
        return out
