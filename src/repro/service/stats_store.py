"""Persistent statistics store: warm restarts skip stats recomputation.

Table statistics are *derived* state — a pure function of one table's data
version — so they persist under the same ``cache_dir`` discipline as plans
(PR 5) and tuned kernel configs (PR 8): versioned, checksummed, atomic,
corruption-tolerant.  Entries are keyed by (relation, content token): the
engine passes a composite token covering the table's own
``Table.content_token()`` PLUS those of its FK-destination tables (orphan
counts read both sides of each declared FK), so a warm restart over
identical data loads every table's stats straight from disk
(``stat_refreshes == 0``) while ANY data change on either side misses
the token and forces a fresh compute — stale statistics are structurally
impossible, not merely unlikely.

The serve-time feedback table (EWMA solo/fused serve times per
(fingerprint, fusion-group signature)) persists as one additional entry
per store, rewritten atomically after each observing batch, so a
restarted service remembers which fusions regressed and keeps them
demoted from the first request.

Store layout (``<sfp>`` scopes by schema structure, exactly like the plan
store — differently-schema'd services sharing a ``cache_dir`` never read
each other's statistics)::

    <root>/stats/<sfp>/<relation>.json      stats @ one content token
    <root>/stats/<sfp>/__feedback__.json    serve-time feedback snapshot

Each entry carries ``format_version`` / ``schema_fingerprint`` /
``payload_sha256`` headers verified before the body is trusted; the
per-table entries additionally embed their key fields (relation, token)
so a hand-moved file can never impersonate another table's statistics.
Damaged entries in our own directory are evicted best-effort and counted
``stats_persist_corrupt_skipped``; write failures degrade the service to
in-memory statistics (``stats_persist_write_errors``), never fail a
request.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading
from pathlib import Path

from repro.core.stats import TableStats

STATS_FORMAT_VERSION = 1

_FEEDBACK_KEY = "__feedback__"


def _canonical_body(payload: dict) -> bytes:
    """Checksummed byte string: canonical JSON (sorted keys, compact)."""
    return json.dumps(payload, sort_keys=True,
                      separators=(",", ":")).encode()


class StatsStore:
    """Versioned, token-keyed, corruption-tolerant statistics persistence.

    Thread-safe: a lock guards the counters; file operations are atomic
    per entry (temp file + ``os.replace``)."""

    def __init__(self, root, schema_fp: str):
        self.root = Path(root)
        self.stats_dir = self.root / "stats" / schema_fp[:16]
        self.schema_fp = schema_fp
        self._lock = threading.Lock()
        self.counters = {
            "stats_persist_hits": 0,
            "stats_persist_misses": 0,
            "stats_persist_writes": 0,
            "stats_persist_corrupt_skipped": 0,
            "stats_persist_write_errors": 0,
        }
        try:
            self.stats_dir.mkdir(parents=True, exist_ok=True)
        except OSError:
            # unwritable root: loads miss, saves count errors — the
            # service degrades to in-memory statistics, never crashes
            pass
        try:
            self._entries = sum(1 for _ in self.stats_dir.glob("*.json"))
        except OSError:
            self._entries = 0

    # ---- paths -----------------------------------------------------------
    def _path(self, relation: str) -> Path:
        # relation names come from the schema, but never trust a name as a
        # path component — anything beyond [a-z0-9_] is re-hashed
        if not all(c.isalnum() or c == "_" for c in relation):
            relation = hashlib.sha256(relation.encode()).hexdigest()[:32]
        return self.stats_dir / f"{relation}.json"

    def __len__(self) -> int:
        with self._lock:
            return self._entries

    # ---- table stats -----------------------------------------------------
    def load(self, relation: str, token: str) -> TableStats | None:
        """Persisted stats for ``relation`` at data version ``token``, or
        None (compute fresh).  A valid entry whose token differs is a
        plain miss — the data changed, the entry is simply outdated (it
        will be overwritten by the next save), not corrupt."""
        doc, corrupt = self._read(self._path(relation))
        stats: TableStats | None = None
        stale = False
        if doc is not None:
            try:
                if doc["relation"] != relation:
                    raise ValueError("entry/relation mismatch")
                if doc["token"] != token:
                    stale = True
                else:
                    stats = TableStats.from_payload(doc["payload"])
                    if stats.relation != relation:
                        raise ValueError("payload/key mismatch")
            except Exception:
                stats = None
                corrupt = True
                self._evict(self._path(relation))
        with self._lock:
            if stats is not None:
                self.counters["stats_persist_hits"] += 1
            else:
                self.counters["stats_persist_misses"] += 1
                if corrupt and not stale:
                    self.counters["stats_persist_corrupt_skipped"] += 1
        return stats

    def save(self, stats: TableStats, token: str | None = None) -> bool:
        """Persist one table's stats (overwrites any previous version).
        ``token`` overrides the entry's KEY token — the engine passes its
        composite token here while the payload keeps the table's own
        ``content_token()`` (what decision traces compare against)."""
        return self._write(self._path(stats.relation), {
            "relation": stats.relation,
            "token": stats.token if token is None else token,
            "payload": stats.to_payload(),
        })

    # ---- feedback --------------------------------------------------------
    def load_feedback(self) -> dict | None:
        """The persisted feedback snapshot payload, or None.  Touches the
        hit/miss counters like any other entry."""
        doc, corrupt = self._read(self._path(_FEEDBACK_KEY))
        payload = None
        if doc is not None:
            try:
                if doc["relation"] != _FEEDBACK_KEY:
                    raise ValueError("entry/key mismatch")
                payload = doc["payload"]
            except Exception:
                corrupt = True
                self._evict(self._path(_FEEDBACK_KEY))
        with self._lock:
            if payload is not None:
                self.counters["stats_persist_hits"] += 1
            else:
                self.counters["stats_persist_misses"] += 1
                if corrupt:
                    self.counters["stats_persist_corrupt_skipped"] += 1
        return payload

    def save_feedback(self, payload: dict) -> bool:
        """Atomically replace the feedback snapshot."""
        return self._write(self._path(_FEEDBACK_KEY), {
            "relation": _FEEDBACK_KEY,
            "token": "",
            "payload": payload,
        })

    # ---- shared entry I/O ------------------------------------------------
    def _read(self, path: Path) -> tuple[dict | None, bool]:
        """(verified doc, was_corrupt).  ANY failure — unreadable file,
        bad JSON, header mismatch, checksum mismatch — evicts the entry
        (own directory: a bad entry must not be re-parsed per lookup) and
        reports corruption; a plain absence is (None, False)."""
        try:
            raw = path.read_bytes()
        except OSError:
            return None, False
        try:
            doc = json.loads(raw)
            if doc["format_version"] != STATS_FORMAT_VERSION:
                raise ValueError(
                    f"format_version {doc['format_version']} != "
                    f"{STATS_FORMAT_VERSION}")
            if doc["schema_fingerprint"] != self.schema_fp:
                raise ValueError("schema fingerprint mismatch")
            if hashlib.sha256(_canonical_body(doc["payload"])).hexdigest() \
                    != doc["payload_sha256"]:
                raise ValueError("payload checksum mismatch")
            return doc, False
        except Exception:
            self._evict(path)
            return None, True

    def _evict(self, path: Path) -> None:
        try:
            path.unlink()
        except OSError:
            pass
        else:
            with self._lock:
                self._entries = max(0, self._entries - 1)

    def _write(self, path: Path, fields: dict) -> bool:
        doc = {
            "format_version": STATS_FORMAT_VERSION,
            "schema_fingerprint": self.schema_fp,
            "payload_sha256": hashlib.sha256(
                _canonical_body(fields["payload"])).hexdigest(),
            **fields,
        }
        tmp = None
        try:
            existed = path.exists()
            fd, tmp = tempfile.mkstemp(dir=str(self.stats_dir),
                                       prefix=f".{path.stem[:16]}.",
                                       suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1)
            os.replace(tmp, path)        # atomic: readers see old or new,
            tmp = None                   # never a torn entry
        except (OSError, TypeError, ValueError):
            with self._lock:
                self.counters["stats_persist_write_errors"] += 1
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
            return False
        with self._lock:
            self.counters["stats_persist_writes"] += 1
            if not existed:
                self._entries += 1
        return True

    # ---- observability ---------------------------------------------------
    def metrics(self) -> dict[str, int]:
        with self._lock:
            out = dict(self.counters)
        out["stats_persist_entries"] = len(self)
        return out


STATS_PERSIST_ZEROS = {
    "stats_persist_hits": 0, "stats_persist_misses": 0,
    "stats_persist_writes": 0, "stats_persist_corrupt_skipped": 0,
    "stats_persist_write_errors": 0, "stats_persist_entries": 0,
}

__all__ = ["StatsStore", "STATS_PERSIST_ZEROS", "STATS_FORMAT_VERSION"]
