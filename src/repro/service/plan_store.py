"""Persistent plan store: cross-process warm starts for the serving tier.

The whole premise of serving guarded aggregate plans is that the evaluation
*structure* — not any materialised intermediate — is the reusable artefact.
In-process, the plan cache already keeps one ``PhysicalPlan`` per query
structure; this module extends that to process lifetimes: plans are
serialised (``repro.core.plan.plan_to_payload``) into a content-addressed
on-disk store keyed by query fingerprint, so a restarted service re-plans
nothing it has seen before.

Store layout (one directory per store; ``<sfp>`` is a prefix of the
store fingerprint — schema structure + planner configuration — so
differently-configured services share a ``cache_dir`` without collisions)::

    <root>/plans/<sfp>/<fingerprint>.json   one plan per query structure
    <root>/xla/...                          JAX persistent compilation
                                            cache (it keys on the HLO, so
                                            it is safely shared; see
                                            ``enable_executable_cache``)

Each entry is a JSON document with a header the loader verifies before
trusting the body:

* ``format_version``     — bumped whenever the payload schema changes; a
  mismatched entry is skipped (and evicted), never mis-parsed;
* ``schema_fingerprint`` — structural hash of the database schema the plan
  was built against (relations, column metadata, FK edges).  A store warmed
  against one schema can never serve plans into a service with another;
* ``payload_sha256``     — checksum of the canonical payload encoding; a
  truncated or bit-flipped entry fails verification.

Loads are corruption-tolerant by construction: ANY failure — unreadable
file, bad JSON, header mismatch, checksum mismatch, malformed payload —
counts ``persist_corrupt_skipped`` (for genuinely damaged entries), evicts
the file best-effort, and returns ``None`` so the caller simply re-plans.
Writes are atomic (temp file + ``os.replace``) and best-effort: a full or
read-only disk degrades the service to memory-only caching (counted in
``persist_write_errors``), it never fails a request.

Executable persistence rides on JAX's own compilation cache:
``enable_executable_cache`` points ``jax_compilation_cache_dir`` at the
store's ``xla/`` subdirectory with thresholds zeroed, so a warm-started
process that replays a known (graph_key, shape-bucket) trace gets its XLA
binary from disk instead of recompiling.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading
from pathlib import Path

from repro.core.plan import (
    PhysicalPlan,
    PlanNotSerialisable,
    plan_from_payload,
    plan_to_payload,
)
from repro.tables.table import Schema

FORMAT_VERSION = 1


def schema_fingerprint(schema: Schema) -> str:
    """Structural hash of a database schema: relation names, column
    metadata (order, uniqueness, domains) and FK edges.  Plans persisted
    under one schema fingerprint are only ever loaded into services whose
    schema hashes identically — column renames or domain changes silently
    invalidate the whole store rather than mis-resolving variables."""
    rels = tuple(sorted(
        (name, tuple((c.name, c.unique, c.domain) for c in rs.columns))
        for name, rs in schema.relations.items()))
    fks = tuple(sorted((fk.src, fk.src_col, fk.dst, fk.dst_col)
                       for fk in schema.foreign_keys))
    return hashlib.sha256(repr((rels, fks)).encode()).hexdigest()


def store_fingerprint(schema: Schema, mode: str = "auto",
                      use_fkpk: bool = False,
                      topology: tuple = ()) -> str:
    """The identity a service's store entries must match: schema structure
    PLUS planner configuration PLUS shard topology.  Persisted plans are
    *planner output* — a store warmed by a ``mode="ref"`` service must not
    hand materialising plans to an ``opt_plus`` service, and a
    ``use_fkpk=True`` store must not impose FK-trusting semi-joins on a
    service configured not to trust the declared FKs.  ``topology`` is the
    serving mesh's ``(axis_names, shard_counts)`` (``()`` on a single
    device): a mesh service's warm-start bookkeeping (and the XLA
    executable cache living beside its entries) describes programs lowered
    for that mesh shape, so differently-sharded services keep disjoint
    entry directories under one ``cache_dir`` and never leak state across
    configs."""
    return hashlib.sha256(repr((schema_fingerprint(schema), mode,
                                use_fkpk,
                                tuple(topology))).encode()).hexdigest()


def _canonical_body(payload: dict) -> bytes:
    """The byte string the checksum covers: a canonical JSON encoding of
    the payload (sorted keys, no whitespace) so the digest is stable across
    writers."""
    return json.dumps(payload, sort_keys=True,
                      separators=(",", ":")).encode()


def enable_executable_cache(path) -> bool:
    """Point JAX's persistent compilation cache at `path` (thresholds
    zeroed so every serving executable qualifies).  Best-effort and
    process-global: JAX has ONE compilation cache directory, so the last
    service to enable it wins — which is the common case of one service
    per process.  Returns False (and leaves JAX untouched) when the flags
    are unavailable or the directory cannot be created."""
    import jax

    try:
        os.makedirs(path, exist_ok=True)
    except OSError:
        return False
    try:
        jax.config.update("jax_compilation_cache_dir", str(path))
    except Exception:
        return False
    # thresholds and backend toggles are advisory — missing flags on an
    # older jax leave the cache enabled with its defaults
    for flag, value in (
            ("jax_persistent_cache_min_compile_time_secs", 0),
            ("jax_persistent_cache_min_entry_size_bytes", -1)):
        try:
            jax.config.update(flag, value)
        except Exception:
            pass
    # jax initialises its cache handle lazily ON FIRST COMPILE and never
    # re-reads the directory config afterwards — a service constructed
    # after any prior jit (tests, another service) would silently get no
    # persistence without this reset
    try:
        from jax.experimental.compilation_cache import (
            compilation_cache as cc,
        )
        cc.reset_cache()
    except Exception:
        pass
    return True


class PlanStore:
    """Versioned, content-addressed, corruption-tolerant plan persistence.

    Thread-safe: loads/saves for different fingerprints may run
    concurrently (the serving engine issues them from per-fingerprint
    in-flight builds); a lock guards only the counters."""

    def __init__(self, root, schema_fp: str):
        self.root = Path(root)
        # entries are scoped by the store fingerprint: two services with
        # different schemas or planner configs sharing one cache_dir get
        # disjoint directories (the per-entry header check below is then
        # belt and braces, catching hand-moved files)
        self.plans_dir = self.root / "plans" / schema_fp[:16]
        self.schema_fp = schema_fp
        self._lock = threading.Lock()
        self.counters = {
            "persist_hits": 0,            # usable entry loaded from disk
            "persist_misses": 0,          # no usable entry (absent/corrupt)
            "persist_writes": 0,          # entries persisted
            "persist_corrupt_skipped": 0,  # damaged entries skipped+evicted
            "persist_write_errors": 0,    # failed writes (degraded to
                                          # memory-only caching)
        }
        try:
            self.plans_dir.mkdir(parents=True, exist_ok=True)
        except OSError:
            # unwritable root: loads will miss, saves will count errors —
            # the service degrades to memory-only caching, never crashes
            pass
        # entry count: one directory scan at construction, then maintained
        # by save/evict — metrics() must never turn into a disk scan (it
        # is called on the serving hot path).  Approximate under
        # concurrent writers from OTHER processes, exact within this one.
        try:
            self._entries = sum(1 for _ in self.plans_dir.glob("*.json"))
        except OSError:
            self._entries = 0

    # ---- paths -----------------------------------------------------------
    def _path(self, fingerprint: str) -> Path:
        # fingerprints are sha256 hex for shareable queries; anything else
        # (defensive: a salted opaque fingerprint) is re-hashed into a safe
        # filename rather than trusted as a path component
        if not all(c in "0123456789abcdef" for c in fingerprint):
            fingerprint = hashlib.sha256(fingerprint.encode()).hexdigest()
        return self.plans_dir / f"{fingerprint}.json"

    def __len__(self) -> int:
        with self._lock:
            return self._entries

    def fingerprints(self) -> list[str]:
        """Fingerprints with an entry on disk (existence only — entries
        are verified at load time)."""
        try:
            return sorted(p.stem for p in self.plans_dir.glob("*.json"))
        except OSError:
            return []

    def has(self, fingerprint: str) -> bool:
        """Existence probe for hit-level attribution (``explain()``):
        does an entry file exist for `fingerprint`?  Touches no counters
        and performs no verification — a damaged entry still reports
        True until a real ``load`` evicts it."""
        try:
            return self._path(fingerprint).exists()
        except OSError:
            return False

    # ---- load ------------------------------------------------------------
    def load(self, fingerprint: str) -> PhysicalPlan | None:
        """Return the persisted plan, or None (re-plan).  Damaged entries
        are evicted and counted, never raised."""
        plan, corrupt = self._load(self._path(fingerprint), fingerprint)
        with self._lock:
            if plan is not None:
                self.counters["persist_hits"] += 1
            else:
                self.counters["persist_misses"] += 1
                if corrupt:
                    self.counters["persist_corrupt_skipped"] += 1
        return plan

    def _load(self, path: Path, fingerprint: str | None, *,
              evict: bool = True,
              ) -> tuple[PhysicalPlan | None, bool]:
        """(plan, was_corrupt) — counter-free core shared by ``load`` and
        ``load_all``.  ``was_corrupt`` distinguishes a damaged entry from a
        plain absence.  ``evict`` deletes damaged entries — right for the
        store's OWN directory (a bad entry must not be re-parsed on every
        lookup), wrong for a foreign directory being imported/exported
        (schema skew there is the reader's mismatch, not damage)."""
        try:
            raw = path.read_bytes()
        except OSError:
            return None, False
        try:
            doc = json.loads(raw)
            if doc["format_version"] != FORMAT_VERSION:
                raise ValueError(
                    f"format_version {doc['format_version']} != "
                    f"{FORMAT_VERSION}")
            if doc["schema_fingerprint"] != self.schema_fp:
                raise ValueError("schema fingerprint mismatch")
            if fingerprint is not None \
                    and doc["fingerprint"] != fingerprint:
                raise ValueError("entry/fingerprint mismatch")
            payload = doc["payload"]
            if hashlib.sha256(_canonical_body(payload)).hexdigest() \
                    != doc["payload_sha256"]:
                raise ValueError("payload checksum mismatch")
            return plan_from_payload(payload), False
        except Exception:
            # skip — and in our own directory, evict — without ever
            # crashing a request
            if evict:
                try:
                    path.unlink()
                except OSError:
                    pass
                else:
                    with self._lock:
                        self._entries = max(0, self._entries - 1)
            return None, True

    def load_all(self):
        """Yield (fingerprint, plan) for every valid entry — used by cache
        import/export, so it touches neither the hit/miss counters nor the
        files: unreadable entries are skipped in place, NOT evicted (the
        directory may belong to another service whose schema simply isn't
        ours — import must never empty a shared warm store)."""
        for fp in self.fingerprints():
            plan, corrupt = self._load(self._path(fp), fp, evict=False)
            if plan is not None:
                yield fp, plan
            elif corrupt:
                with self._lock:
                    self.counters["persist_corrupt_skipped"] += 1

    # ---- save ------------------------------------------------------------
    def save(self, fingerprint: str, plan: PhysicalPlan) -> bool:
        """Persist one plan.  Returns False — without raising — when the
        plan is not serialisable (opaque selections) or the write fails
        (read-only/full disk): persistence is an optimisation, never a
        request-path dependency."""
        try:
            payload = plan_to_payload(plan)
            body = _canonical_body(payload)
        except (PlanNotSerialisable, TypeError, ValueError):
            return False
        doc = {
            "format_version": FORMAT_VERSION,
            "schema_fingerprint": self.schema_fp,
            "fingerprint": fingerprint,
            "payload_sha256": hashlib.sha256(body).hexdigest(),
            "payload": payload,
        }
        path = self._path(fingerprint)
        tmp = None
        try:
            existed = path.exists()
            fd, tmp = tempfile.mkstemp(dir=str(self.plans_dir),
                                       prefix=f".{path.stem[:16]}.",
                                       suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1)
            os.replace(tmp, path)        # atomic: readers never see a torn
            tmp = None                   # entry, only old or new
        except OSError:
            with self._lock:
                self.counters["persist_write_errors"] += 1
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
            return False
        with self._lock:
            self.counters["persist_writes"] += 1
            if not existed:
                self._entries += 1
        return True

    # ---- observability ---------------------------------------------------
    def metrics(self) -> dict[str, int]:
        with self._lock:
            out = dict(self.counters)
        out["persist_entries"] = len(self)
        return out


PERSIST_ZEROS = {
    "persist_hits": 0, "persist_misses": 0, "persist_writes": 0,
    "persist_corrupt_skipped": 0, "persist_write_errors": 0,
    "persist_entries": 0,
}
