"""Serving tier: plan cache, shape-bucketed jit reuse, SQL front door.

Guarded aggregate plans are static-dataflow programs — compile once, serve
many.  This package owns everything between "SQL arrives" and "compiled
program runs": query fingerprinting (``fingerprint``), the multi-level
plan cache (``plan_cache``), the persistent cross-process plan store
(``plan_store``), the concurrent micro-batching engine (``engine``), the
async cross-caller batch former (``scheduler``), the persistent
tuned-kernel-config store (``tune_store``), the persistent statistics
store behind cost-calibrated planning (``stats_store``), and the tracing
+ metrics registry every request reports into (``observability``).
"""

from repro.service.engine import (
    AdmissionError,
    QueryResult,
    QueryService,
    ServeStats,
    ServiceClosedError,
    TenantAdmissionError,
)
from repro.service.fingerprint import (
    CanonicalQuery,
    canonicalize,
    fingerprint,
    prefix_fingerprint,
)
from repro.service.observability import (
    DEFAULT_TENANT,
    Histogram,
    Observability,
    TraceSpan,
)
from repro.service.plan_cache import LRUCache, PlanCache
from repro.service.plan_store import (
    PlanStore,
    enable_executable_cache,
    schema_fingerprint,
    store_fingerprint,
)
from repro.service.scheduler import AsyncScheduler, TenantPolicy
from repro.service.stats_store import StatsStore
from repro.service.tune_store import TuneStore

__all__ = [
    "AdmissionError",
    "AsyncScheduler",
    "DEFAULT_TENANT",
    "CanonicalQuery",
    "canonicalize",
    "enable_executable_cache",
    "fingerprint",
    "prefix_fingerprint",
    "Histogram",
    "LRUCache",
    "Observability",
    "PlanCache",
    "TraceSpan",
    "PlanStore",
    "QueryResult",
    "QueryService",
    "ServeStats",
    "ServiceClosedError",
    "StatsStore",
    "TenantAdmissionError",
    "TenantPolicy",
    "TuneStore",
    "schema_fingerprint",
    "store_fingerprint",
]
