"""Tracing + metrics subsystem for the serving tier.

The paper's argument is a *measured* one — guardedness wins because
materialisation cost dominates — so the serving tier built on top of it
has to be measurable too.  This module is the single timing source for
``repro.service``: every request carries a ``TraceSpan`` tree (admit →
queue-wait → fingerprint → plan → pad → compile → run), spans aggregate
into streaming log-bucketed latency histograms, and everything is read
back through one consistent snapshot.

Design constraints, in order:

* **Lock-cheap.**  One small lock guards counters/gauges/histograms;
  it is held only for O(1) dict/array updates, never across planning,
  padding, compiles, or execution.  Snapshots are taken under the same
  single lock, so counter invariants that hold in program order
  (``fused_queries`` bumps always follow the ``requests`` bump that
  admitted them) also hold in every snapshot — the cure for the
  three-locks-three-tearings ``metrics()`` of PRs 1–5.
* **No per-request allocation on the warm hot path** for aggregation:
  histograms are fixed log-spaced bucket arrays (8 buckets/decade from
  1 µs to 100 s); recording is a bisect + an integer increment.  Spans
  do allocate (one small object each) — they are the *trace*, bounded
  by ``max_traces`` completed request trees kept for export.
* **Injectable clock.**  Everything times through ``self.clock``
  (default ``time.perf_counter``), so tests drive a fake clock and the
  lint rule can forbid raw ``perf_counter`` calls elsewhere under
  ``src/repro/service/``.
* **Disableable.**  ``enabled=False`` replaces every span with a shared
  no-op singleton: no clock reads, no tree, no histogram traffic —
  the baseline the ≤ 3 % tracing-overhead gate compares against.
  Counters and gauges keep working either way (cache-hit accounting is
  correctness bookkeeping, not observability sugar).

Export surfaces:

* ``snapshot()``          — ``{"counters", "gauges", "histograms"}``
  (the structured ``metrics()`` v2 the engine exposes);
* ``export_chrome_trace(path)`` — Chrome-trace/Perfetto JSON of the
  retained request trees (open ``chrome://tracing`` or
  https://ui.perfetto.dev and load the file); spans shared by several
  requests (one fused compile serving a whole dashboard) are emitted
  exactly once.
"""

from __future__ import annotations

import bisect
import collections
import json
import os
import threading
import time
from typing import Any, Callable, Iterable

# The one sanctioned monotonic time source for the serving tier
# (scripts/lint.py forbids raw time.perf_counter elsewhere in
# src/repro/service/).
MONOTONIC: Callable[[], float] = time.perf_counter

# The tenant every request belongs to unless the caller says otherwise.
# Single-tenant deployments never have to mention tenants at all: the
# default tenant has no quota, weight 1, and the scheduler-wide queue
# bound, so pre-multi-tenant behaviour is preserved exactly.
DEFAULT_TENANT = "default"


def _strict_spans() -> bool:
    """Whether span-lifecycle misuse should raise instead of passing
    silently.  On under pytest (so a ``note()`` on a closed span is a
    loud test failure, not a silently-dropped Chrome-trace annotation);
    REPRO_STRICT_SPANS=0/1 overrides either way."""
    flag = os.environ.get("REPRO_STRICT_SPANS")
    if flag is not None:
        return flag not in ("", "0", "false", "no")
    return "PYTEST_CURRENT_TEST" in os.environ

# Log-spaced bucket upper bounds (seconds): 8 per decade, 1 µs … 100 s.
# Built once at import; every histogram shares the tuple, so a warmed
# service allocates nothing per observation.
_BUCKET_BOUNDS: tuple[float, ...] = tuple(
    10.0 ** (-6.0 + i / 8.0) for i in range(0, 8 * 8 + 1))


class Histogram:
    """Streaming latency histogram over fixed log-spaced buckets.

    ``record`` is a bisect + increment (no allocation); percentiles are
    estimated as the upper bound of the bucket containing the requested
    rank — an overestimate by at most one bucket width (~33 %/bucket at
    8 buckets per decade), which is the standard monitoring trade-off.
    Not thread-safe on its own: ``Observability`` serialises access.
    """

    __slots__ = ("counts", "count", "sum_s", "max_s")

    def __init__(self):
        self.counts = [0] * (len(_BUCKET_BOUNDS) + 1)  # +1: overflow
        self.count = 0
        self.sum_s = 0.0
        self.max_s = 0.0

    def record(self, seconds: float) -> None:
        self.counts[bisect.bisect_left(_BUCKET_BOUNDS, seconds)] += 1
        self.count += 1
        self.sum_s += seconds
        if seconds > self.max_s:
            self.max_s = seconds

    def percentile(self, q: float) -> float:
        """Upper-bound estimate of the q-quantile (q in [0, 1])."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                return _BUCKET_BOUNDS[i] if i < len(_BUCKET_BOUNDS) \
                    else self.max_s
        return self.max_s

    def snapshot(self) -> dict[str, Any]:
        """JSON-able summary: count/sum/max, p50/p95/p99, and the
        non-empty buckets as (upper_bound_s, count) pairs."""
        return {
            "count": self.count,
            "sum_s": self.sum_s,
            "max_s": self.max_s,
            "p50_s": self.percentile(0.50),
            "p95_s": self.percentile(0.95),
            "p99_s": self.percentile(0.99),
            "buckets": [
                (_BUCKET_BOUNDS[i] if i < len(_BUCKET_BOUNDS) else None, c)
                for i, c in enumerate(self.counts) if c],
        }


class TraceSpan:
    """One timed interval in a request's trace tree.

    Spans are created open (``t1 < 0``) and closed by ``Observability``;
    a span may be attached as a child of SEVERAL roots — that is how a
    fused batch records exactly one compile span shared by all members
    (the export dedups by object identity, so it renders once).
    """

    __slots__ = ("name", "t0", "t1", "tid", "args", "children")

    def __init__(self, name: str, t0: float, tid: int,
                 args: dict | None = None):
        self.name = name
        self.t0 = t0
        self.t1 = -1.0
        self.tid = tid
        self.args = args if args is not None else {}
        self.children: list[TraceSpan] = []

    @property
    def closed(self) -> bool:
        return self.t1 >= 0.0

    @property
    def duration_s(self) -> float:
        return max(0.0, self.t1 - self.t0) if self.closed else 0.0

    def note(self, **kv) -> None:
        """Attach key/value annotations (rendered as Chrome-trace args).
        Must happen while the span is open: ``close_span`` folds the span
        into histograms and (for roots) the export retention, so a late
        note races the reader.  Under tests a late note raises."""
        if self.closed and _strict_spans():
            raise RuntimeError(
                f"note() on closed span {self.name!r} ({kv!r}) — annotate "
                "before close_span/end_request")
        self.args.update(kv)

    def child_duration(self, name: str) -> float:
        """Total closed duration of direct children called `name`."""
        return sum(c.duration_s for c in self.children
                   if c.name == name and c.closed)

    def walk(self) -> Iterable["TraceSpan"]:
        yield self
        for c in self.children:
            yield from c.walk()

    def __repr__(self):  # pragma: no cover - debugging sugar
        state = f"{self.duration_s * 1e3:.3f}ms" if self.closed else "open"
        return f"TraceSpan({self.name!r}, {state}, {len(self.children)} kids)"


class _NullSpan:
    """Shared no-op span: what every tracing call returns when tracing is
    disabled.  Deliberately inert — no clock reads, no children, notes
    dropped — so the disabled service is the overhead baseline."""

    __slots__ = ()
    name = ""
    t0 = 0.0
    t1 = 0.0
    tid = 0
    closed = True
    duration_s = 0.0
    children: tuple = ()
    args: dict = {}

    def note(self, **kv) -> None:
        pass

    def child_duration(self, name: str) -> float:
        return 0.0

    def walk(self):
        return iter(())


NULL_SPAN = _NullSpan()


class _SpanCtx:
    """Context-manager wrapper for open_span/close_span pairs."""

    __slots__ = ("_obs", "span")

    def __init__(self, obs: "Observability", span):
        self._obs = obs
        self.span = span

    def __enter__(self):
        return self.span

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None and self.span is not NULL_SPAN:
            self.span.note(error=exc_type.__name__)
        self._obs.close_span(self.span)
        return False


class Observability:
    """Counters + gauges + histograms + bounded trace retention, all
    behind one lock.  See the module docstring for the contract."""

    def __init__(self, clock: Callable[[], float] | None = None, *,
                 enabled: bool = True, max_traces: int = 512):
        self.clock = clock if clock is not None else MONOTONIC
        self.enabled = enabled
        self._lock = threading.Lock()
        self._counters: dict[str, int | float] = {}
        self._gauges: dict[str, int | float] = {}
        # peak gauge name -> source gauge name; reset-to-current on read
        self._peaks: dict[str, str] = {}
        self._peak_values: dict[str, int | float] = {}
        self._hists: dict[str, Histogram] = {}
        self._traces: collections.deque[TraceSpan] = \
            collections.deque(maxlen=max_traces)
        # per-tenant accounting: counters (requests/errors/fused/
        # rejected_*) and a request-latency histogram per tenant.  Kept
        # separate from the flat counter namespace so tenant names can
        # never collide with service counters.
        self._tenant_counters: dict[str, dict[str, int | float]] = {}
        self._tenant_hists: dict[str, Histogram] = {}
        # roots opened via begin_request but not yet ended — the span-leak
        # detector: a request that dies on an abnormal path MUST still be
        # ended, so this reads 0 whenever the service is idle.
        self._open_requests = 0

    # ---- counters / gauges ----------------------------------------------
    def register_counters(self, names: Iterable[str]) -> None:
        """Pre-declare counters so they appear as 0 in every snapshot
        (metrics keys must exist before the first event — e.g. the async
        tier's counters before the scheduler lazily starts)."""
        with self._lock:
            for n in names:
                self._counters.setdefault(n, 0)

    def inc(self, name: str, n: int | float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def counter(self, name: str) -> int | float:
        with self._lock:
            return self._counters.get(name, 0)

    def tenant_inc(self, tenant: str, name: str, n: int | float = 1) -> None:
        """Bump a per-tenant counter (requests/errors/fused/rejected_*).
        Tenants materialise in ``snapshot()["tenants"]`` on first touch."""
        with self._lock:
            d = self._tenant_counters.setdefault(tenant, {})
            d[name] = d.get(name, 0) + n

    def tenant_counter(self, tenant: str, name: str) -> int | float:
        with self._lock:
            return self._tenant_counters.get(tenant, {}).get(name, 0)

    def open_requests(self) -> int:
        """Roots opened via ``begin_request`` but not yet ended — 0 on an
        idle service; anything else is a span leak."""
        with self._lock:
            return self._open_requests

    def set_gauge(self, name: str, value: int | float) -> None:
        """Set a gauge; any peak gauge tracking it ratchets up with it."""
        with self._lock:
            self._gauges[name] = value
            for peak, source in self._peaks.items():
                if source == name and value > self._peak_values.get(peak, 0):
                    self._peak_values[peak] = value

    def register_peak_gauge(self, name: str, source: str) -> None:
        """`name` reports the max value `source` reached since the last
        snapshot (and at least its current value) — a resettable
        high-water mark, not a forever-high counter."""
        with self._lock:
            self._peaks[name] = source
            self._peak_values.setdefault(name, self._gauges.get(source, 0))
            self._gauges.setdefault(source, 0)

    # ---- spans -----------------------------------------------------------
    def begin_request(self, name: str = "request", *, tenant: str | None
                      = None, **args) -> TraceSpan:
        """Open a trace root.  Close with ``end_request``.  ``tenant``
        stamps the owning tenant onto the root's args (visible in the
        Chrome-trace export) — pass the same tenant to ``end_request`` to
        land the latency in that tenant's histogram."""
        if not self.enabled:
            return NULL_SPAN
        if tenant is not None:
            args["tenant"] = tenant
        with self._lock:
            self._open_requests += 1
        return TraceSpan(name, self.clock(), threading.get_ident(), args)

    def end_request(self, root: TraceSpan, *, tenant: str | None = None) \
            -> None:
        """Close a root, record its latency histogram (and the tenant's,
        when given), retain the tree for export."""
        if root is NULL_SPAN or root.closed:
            return
        root.t1 = self.clock()
        with self._lock:
            self._open_requests -= 1
            self._observe_locked(root.name, root.duration_s)
            if tenant is not None:
                h = self._tenant_hists.get(tenant)
                if h is None:
                    h = self._tenant_hists[tenant] = Histogram()
                h.record(root.duration_s)
            self._traces.append(root)

    def open_span(self, parents, name: str, **args) -> TraceSpan:
        """Open a child span attached to one or many parent spans (many =
        a span shared by every member of a fused batch).  ``parents`` may
        be a span, an iterable of spans, or None (detached)."""
        if not self.enabled:
            return NULL_SPAN
        span = TraceSpan(name, self.clock(), threading.get_ident(), args)
        if parents is None:
            parents = ()
        elif isinstance(parents, (TraceSpan, _NullSpan)):
            parents = (parents,)
        seen: set[int] = set()
        for p in parents:
            if p is not NULL_SPAN and id(p) not in seen:
                seen.add(id(p))
                p.children.append(span)
        return span

    def close_span(self, span: TraceSpan) -> float:
        """Close a span and fold its duration into the stage histogram.
        Returns the duration (0.0 for the null span)."""
        if span is NULL_SPAN:
            return 0.0
        if not span.closed:
            span.t1 = self.clock()
        dur = span.duration_s
        with self._lock:
            self._observe_locked(span.name, dur)
        return dur

    def span(self, parents, name: str, **args) -> _SpanCtx:
        """``with obs.span(root, "plan") as sp: ...`` — open/close pair."""
        return _SpanCtx(self, self.open_span(parents, name, **args))

    def observe(self, stage: str, seconds: float) -> None:
        """Record a duration into a stage histogram without a span."""
        if not self.enabled:
            return
        with self._lock:
            self._observe_locked(stage, seconds)

    def _observe_locked(self, stage: str, seconds: float) -> None:
        h = self._hists.get(stage)
        if h is None:
            h = self._hists[stage] = Histogram()
        h.record(seconds)

    # ---- read side -------------------------------------------------------
    def snapshot(self) -> dict[str, Any]:
        """One consistent read of everything this registry owns, under one
        lock acquisition: ``{"counters", "gauges", "histograms",
        "tenants"}``.  Peak gauges report their high-water mark since the
        previous snapshot and reset to their source gauge's current value.
        ``"tenants"`` maps each tenant touched so far to its counters
        (requests/errors/fused/rejected split by cause), its fused-share,
        and its request-latency percentiles."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            gauges["open_requests"] = self._open_requests
            for peak, source in self._peaks.items():
                current = self._gauges.get(source, 0)
                gauges[peak] = max(self._peak_values.get(peak, 0), current)
                self._peak_values[peak] = current
            hists = {name: h.snapshot() for name, h in self._hists.items()}
            tenants: dict[str, Any] = {}
            for name in sorted(set(self._tenant_counters)
                               | set(self._tenant_hists)):
                c = self._tenant_counters.get(name, {})
                entry: dict[str, Any] = {
                    "requests": c.get("requests", 0),
                    "errors": c.get("errors", 0),
                    "fused": c.get("fused", 0),
                    "rejected_rate": c.get("rejected_rate", 0),
                    "rejected_depth": c.get("rejected_depth", 0),
                    "rejected_closed": c.get("rejected_closed", 0),
                }
                entry["rejected"] = (entry["rejected_rate"]
                                     + entry["rejected_depth"])
                entry["fused_share"] = (entry["fused"] / entry["requests"]
                                        if entry["requests"] else 0.0)
                h = self._tenant_hists.get(name)
                hsnap = h.snapshot() if h is not None else {
                    "count": 0, "p50_s": 0.0, "p95_s": 0.0, "p99_s": 0.0}
                for k in ("count", "p50_s", "p95_s", "p99_s"):
                    entry[k] = hsnap[k]
                tenants[name] = entry
        return {"counters": counters, "gauges": gauges, "histograms": hists,
                "tenants": tenants}

    def traces(self) -> list[TraceSpan]:
        """The retained completed request trees, oldest first."""
        with self._lock:
            return list(self._traces)

    # ---- export ----------------------------------------------------------
    def export_chrome_trace(self, path) -> int:
        """Write the retained traces as Chrome-trace JSON (the format
        chrome://tracing and Perfetto load).  Spans shared by several
        requests are emitted once.  Returns the number of events."""
        events = []
        seen: set[int] = set()
        for root in self.traces():
            for span in root.walk():
                if id(span) in seen or not span.closed:
                    continue
                seen.add(id(span))
                events.append({
                    "name": span.name,
                    "ph": "X",
                    "ts": span.t0 * 1e6,          # Chrome trace wants µs
                    "dur": span.duration_s * 1e6,
                    "pid": 1,
                    "tid": span.tid,
                    "cat": "serving",
                    "args": {k: repr(v) if not isinstance(
                        v, (str, int, float, bool, type(None))) else v
                        for k, v in span.args.items()},
                })
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(events)
