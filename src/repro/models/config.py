"""Unified model configuration covering all 10 assigned architectures."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | mamba2 | rwkv6 | hybrid
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 128

    # attention flavour
    qk_norm: bool = False
    sliding_window: int | None = None      # SWA width (mistral-style)
    local_global_ratio: int = 0            # gemma3: N local per 1 global
    local_window: int = 1024
    rope_theta: float = 10_000.0
    attn_chunk: int = 1024                 # flash-style KV chunk (train)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    # §Perf knob: re-shard the dispatch buffer to expert-major before the
    # expert einsum (True = baseline) or let SPMD propagate (False)
    dispatch_reshard: bool = True

    # SSM (Mamba2 / RWKV6)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_expand: int = 2
    conv_width: int = 4

    # hybrid (zamba2): one shared attention+MLP block every k layers
    shared_attn_every: int = 0

    # modality frontend (assignment: stubs for audio/vision)
    frontend: str = "tokens"               # tokens | vision_stub
    num_patches: int = 0                   # pixtral: prepended embeddings

    # numerics
    embed_scale: bool = False              # multiply embeddings by sqrt(d)
    dtype: str = "bfloat16"
    # roofline probes: fully unroll every lax.scan so cost_analysis counts
    # each iteration (a while body is otherwise counted once — DESIGN.md §9)
    probe_unroll: bool = False
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0
    tie_embeddings: bool = False

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def d_inner(self) -> int:              # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family in ("mamba2", "rwkv6")

    @property
    def subquadratic(self) -> bool:
        """Can serve 500k-token contexts (assignment: SSM/hybrid/linear)."""
        return self.family in ("mamba2", "rwkv6", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (roofline MODEL_FLOPS uses this)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        per_layer = 0
        if self.family in ("dense", "moe"):
            hd = self.d_head
            per_layer += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
            if self.family == "moe":
                per_layer += self.n_experts * 3 * d * f
                per_layer += d * self.n_experts          # router
                per_layer += self.n_shared_experts * 3 * d * f
            else:
                per_layer += 3 * d * f
            per_layer += 2 * d                            # norms
            n += L * per_layer
        elif self.family == "mamba2":
            di, st, h = self.d_inner, self.ssm_state, self.n_ssm_heads
            proj_in = d * (2 * di + 2 * st + h)
            per_layer = proj_in + self.conv_width * (di + 2 * st) \
                + di * d + 2 * h + d + di
            n += L * per_layer + L * 3 * d * f if f else L * per_layer
        elif self.family == "rwkv6":
            h = d // self.ssm_head_dim
            per_layer = 6 * d * d + 2 * d * f + 4 * d  # r,k,v,w,g,out + ffn
            n += L * per_layer
        elif self.family == "hybrid":
            di, st, h = self.d_inner, self.ssm_state, self.n_ssm_heads
            mamba_layer = d * (2 * di + 2 * st + h) \
                + self.conv_width * (di + 2 * st) + di * d + 2 * h + d + di
            hd = self.d_head
            shared = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d + 3 * d * f + 2 * d
            n += L * mamba_layer + shared
        return n

    def active_param_count(self) -> int:
        """MoE: params touched per token (6·N_active·D roofline)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_n = self.param_count() - self.n_layers * (
            self.n_experts * 3 * d * f)
        return dense_n + self.n_layers * (self.top_k * 3 * d * f)
