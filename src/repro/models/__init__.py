from repro.models.config import ModelConfig
from repro.models.model import (
    decode_state_specs,
    decode_step,
    forward,
    init_decode_state,
    init_params,
    prefill,
)

__all__ = [
    "ModelConfig",
    "init_params",
    "forward",
    "prefill",
    "decode_step",
    "init_decode_state",
    "decode_state_specs",
]
