"""Attention: GQA + RoPE + optional qk-norm / sliding-window / local:global.

Three execution paths, chosen by workload (see DESIGN.md §6):

  * train      — dense masked attention (S×S scores per layer, recomputed in
                 backward under the remat policy; a Pallas flash kernel is
                 the natural TPU upgrade and is tracked in EXPERIMENTS §Perf)
  * prefill    — chunked (flash-style online-softmax) scan over KV blocks;
                 no gradient flows, so the scan carries are free
  * decode     — one-token query against the KV cache; for sequence-parallel
                 long contexts the KV is sharded over `kv_seq` and XLA
                 reduces the partial softmax across shards

GQA with n_kv_heads < n_heads computes grouped einsums; kv_heads==1 (gemma3)
degenerates to MQA with fully replicated KV.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_norm, rope

NEG_INF = -1e30


def attention_init(key, cfg: ModelConfig, stacked: int | None = None):
    """Projection weights use the FUSED head layout [d, h·hd] so the TP
    ("model") axis shards h·hd — which is 16-divisible for every assigned
    arch even when the head count (9, 40, ...) is not."""
    ks = jax.random.split(key, 6)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    pre = (stacked,) if stacked is not None else ()
    lead = ("layers",) if stacked is not None else ()
    p = {
        "wq": dense_init(ks[0], pre + (d, h * hd)),
        "wk": dense_init(ks[1], pre + (d, kv * hd)),
        "wv": dense_init(ks[2], pre + (d, kv * hd)),
        "wo": dense_init(ks[3], pre + (h * hd, d), in_axis=-2),
    }
    s = {
        "wq": lead + ("embed", "heads_fused"),
        "wk": lead + ("embed", "heads_fused"),
        "wv": lead + ("embed", "heads_fused"),
        "wo": lead + ("heads_fused", "embed"),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros(pre + (hd,))
        p["k_norm"] = jnp.zeros(pre + (hd,))
        s["q_norm"] = lead + ("head_dim",)
        s["k_norm"] = lead + ("head_dim",)
    return p, s


def _qkv(p, cfg: ModelConfig, x, pos, dtype):
    """Project + (qk-norm) + rope. Returns q [B,S,KV,G,hd], k,v [B,S,KV,hd]."""
    b, s = x.shape[:2]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = h // kv
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(dtype))
    q = shard(q, "batch", "seq", "heads_fused").reshape(b, s, h, hd)
    k = shard(k, "batch", "seq", "heads_fused").reshape(b, s, kv, hd)
    v = shard(v, "batch", "seq", "heads_fused").reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    sin, cos = rope(pos, hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    q = q.reshape(b, s, kv, g, hd)
    return q, k, v


def _mask(q_pos, k_pos, window, is_global):
    """[Sq, Sk] bool: causal ∧ (global ∨ within window)."""
    causal = q_pos[:, None] >= k_pos[None, :]
    if window is None:
        return causal
    within = (q_pos[:, None] - k_pos[None, :]) < window
    return causal & jnp.where(is_global, True, within)


def attention_train(p, cfg: ModelConfig, x, pos, is_global, dtype):
    """Dense masked attention (training path)."""
    b, s, _ = x.shape
    hd = cfg.d_head
    q, k, v = _qkv(p, cfg, x, pos, dtype)
    window = (cfg.local_window if cfg.local_global_ratio
              else cfg.sliding_window)
    mask = _mask(pos[0], pos[0], window, is_global)
    scores = jnp.einsum("bqhgk,bshk->bhgqs", q, k) / jnp.sqrt(hd).astype(dtype)
    # kv_heads take "model" when divisible; otherwise q positions do
    # (context parallelism) — resolve_spec arbitrates per shape.
    scores = shard(scores, "batch", "kv_heads", None, "q_seq", None)
    scores = jnp.where(mask[None, None, None], scores.astype(jnp.float32),
                       NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs, v)
    out = out.reshape(b, s, cfg.n_heads * hd)
    return jnp.einsum("bse,ed->bsd", out, p["wo"].astype(dtype))


def attention_prefill(p, cfg: ModelConfig, x, pos, is_global, dtype):
    """Chunked online-softmax attention (inference prefill; no grad)."""
    b, s, _ = x.shape
    hd = cfg.d_head
    chunk = min(cfg.attn_chunk, s)
    assert s % chunk == 0, (s, chunk)
    q, k, v = _qkv(p, cfg, x, pos, dtype)
    kvh, g = q.shape[2], q.shape[3]
    window = (cfg.local_window if cfg.local_global_ratio
              else cfg.sliding_window)
    qp = pos[0]
    scale = 1.0 / jnp.sqrt(hd)

    def body(carry, idx):
        m, l, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(k, idx * chunk, chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, idx * chunk, chunk, axis=1)
        kp = qp[0] + idx * chunk + jnp.arange(chunk)
        msk = _mask(qp, kp, window, is_global)
        sc = jnp.einsum("bqhgk,bshk->bhgqs", q, kc).astype(jnp.float32) * scale
        sc = shard(sc, "batch", "kv_heads", None, "q_seq", None)
        sc = jnp.where(msk[None, None, None], sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(sc - m_new[..., None])
        l_new = l * alpha + pexp.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqs,bshk->bhgqk", pexp.astype(dtype), vc).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, s, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  jnp.arange(s // chunk),
                                  unroll=True if cfg.probe_unroll else 1)
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(dtype)
    out = jnp.moveaxis(out, 3, 1).reshape(b, s, cfg.n_heads * hd)
    return jnp.einsum("bse,ed->bsd", out, p["wo"].astype(dtype))


def attention_decode(p, cfg: ModelConfig, x, cache_k, cache_v, pos_scalar,
                     is_global, dtype):
    """One new token against the KV cache.

    x: [B, 1, D]; cache_k/v: [B, Smax, KV, hd] (updated in place at
    pos_scalar).  Long-context caches may be sharded over `kv_seq`.
    Returns (out [B,1,D], cache_k, cache_v).
    """
    b = x.shape[0]
    hd = cfg.d_head
    pos = jnp.full((b, 1), pos_scalar, jnp.int32)
    q, k, v = _qkv(p, cfg, x, pos, dtype)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), pos_scalar, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), pos_scalar, axis=1)
    cache_k = shard(cache_k, "batch", "kv_seq", "kv_heads", "kv_head_dim")
    cache_v = shard(cache_v, "batch", "kv_seq", "kv_heads", "kv_head_dim")

    smax = cache_k.shape[1]
    kp = jnp.arange(smax)
    window = (cfg.local_window if cfg.local_global_ratio
              else cfg.sliding_window)
    valid = kp <= pos_scalar
    if window is not None:
        within = (pos_scalar - kp) < window
        valid = valid & jnp.where(is_global, True, within)
    sc = jnp.einsum("bqhgk,bshk->bhgqs", q,
                    cache_k.astype(dtype)).astype(jnp.float32)
    sc = sc / jnp.sqrt(hd)
    sc = jnp.where(valid[None, None, None, None, :], sc, NEG_INF)
    probs = jax.nn.softmax(sc, axis=-1).astype(dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs, cache_v.astype(dtype))
    out = out.reshape(b, 1, cfg.n_heads * hd)
    y = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(dtype))
    return y, cache_k, cache_v
