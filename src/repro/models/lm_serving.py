"""LM batched serving: prefill + jitted decode loop with slot management.

Lives beside the model code it drives (everything here is a thin loop over
``repro.models``' prefill/decode_step).  Historically this was the
``repro.serving`` package — a name that now collides conceptually with
``repro.service``, the guarded-aggregate *query* serving tier; the old
import path remains as a deprecated re-export.

`ServeEngine` owns the per-slot KV/SSM caches for a fixed batch of request
slots (static shapes).  Requests of different lengths right-pad into slots;
finished slots are refilled (continuous-batching-lite: the decode step is
one jitted program, slot refill happens at step boundaries).  `serve_step`
— one token for every live slot — is the unit the dry-run lowers for the
decode_* shape cells.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (
    decode_step,
    init_decode_state,
    prefill,
)
from repro.models.config import ModelConfig


def greedy_generate(params, cfg: ModelConfig, prompts: np.ndarray,
                    max_new_tokens: int, extra: dict | None = None):
    """prompts: [B, S_prompt] int32.  Returns [B, max_new_tokens]."""
    b, s = prompts.shape
    cache = init_decode_state(cfg, batch=b, max_len=s + max_new_tokens)
    batch = {"tokens": jnp.asarray(prompts)}
    if extra:
        batch.update(extra)
    logits, cache = jax.jit(prefill, static_argnames=("cfg",))(
        params, cfg, batch, cache)
    step = jax.jit(decode_step, static_argnames=("cfg",))
    toks = []
    cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(max_new_tokens):
        toks.append(cur)
        logits, cache = step(params, cfg, cur, cache)
        cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return np.concatenate([np.asarray(t) for t in toks], axis=1)


@dataclasses.dataclass
class ServeEngine:
    """Fixed-slot, wave-synchronous batched serving.

    Requests queue up; a *wave* pads them to a common prompt length, runs
    one batched prefill and then jitted single-token decode steps until
    every slot finishes (EOS or budget).  The decode program — one token
    for `n_slots` live slots — is exactly the dry-run's `serve_step` unit.
    (Per-slot asynchronous positions would need scatter-based cache writes;
    tracked as future work in DESIGN.md.)
    """

    params: Any
    cfg: ModelConfig
    n_slots: int
    max_len: int

    def __post_init__(self):
        self._decode = jax.jit(decode_step, static_argnames=("cfg",))
        self._prefill = jax.jit(prefill, static_argnames=("cfg",))
        self._queue: list[tuple[int, np.ndarray]] = []
        self._next_req = 0

    def submit(self, prompt: np.ndarray) -> int:
        rid = self._next_req
        self._next_req += 1
        self._queue.append((rid, np.asarray(prompt, np.int32)))
        return rid

    def run_wave(self, eos: int | None = None, max_tokens: int = 64):
        """Serve up to n_slots queued requests to completion.
        Returns {request_id: generated tokens}."""
        if not self._queue:
            return {}
        wave = self._queue[:self.n_slots]
        self._queue = self._queue[self.n_slots:]
        plen = max(len(p) for _, p in wave)
        toks = np.zeros((self.n_slots, plen), np.int32)
        for i, (_, p) in enumerate(wave):
            toks[i, plen - len(p):] = p  # left-pad into the slot
        cache = init_decode_state(self.cfg, self.n_slots, self.max_len)
        logits, cache = self._prefill(self.params, self.cfg,
                                      {"tokens": jnp.asarray(toks)}, cache)
        cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        outs: dict[int, list[int]] = {rid: [] for rid, _ in wave}
        live = np.ones(len(wave), bool)
        for _ in range(max_tokens):
            for i, (rid, _) in enumerate(wave):
                if live[i]:
                    t = int(cur[i, 0])
                    outs[rid].append(t)
                    if eos is not None and t == eos:
                        live[i] = False
            if not live.any():
                break
            logits, cache = self._decode(self.params, self.cfg, cur, cache)
            cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return outs
