"""RWKV6 ("Finch") mixer: data-dependent per-channel decay linear attention.

Chunked parallel form with a `lax.scan` over chunks carrying the [h, K, V]
state.  All decay exponents are *pairwise differences* of a within-chunk
cumulative log-decay (≤ 0 on every masked entry), so the chunked form is
numerically safe in fp32 at any chunk length — no explicit exp(+cumsum)
ever appears (see DESIGN.md §10 for the deviation notes: static token-shift
mix instead of the LoRA-interpolated one; per-head RMS instead of
GroupNorm).

The per-token recurrence used for decode (and as the test oracle) is
    S_t = diag(w_t)·S_{t-1} + kᵀ_t v_t
    o_t = r_t · (S_{t-1} + diag(u)·kᵀ_t v_t)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rms_norm


def rwkv6_init(key, cfg: ModelConfig, stacked: int | None = None):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    hd = cfg.ssm_head_dim
    h = d // hd
    pre = (stacked,) if stacked is not None else ()
    lead = ("layers",) if stacked is not None else ()
    p = {
        "mu": 0.5 * jnp.ones(pre + (5, d)),       # token-shift mix r,k,v,w,g
        "wr": dense_init(ks[0], pre + (d, d)),
        "wk": dense_init(ks[1], pre + (d, d)),
        "wv": dense_init(ks[2], pre + (d, d)),
        "ww": dense_init(ks[3], pre + (d, d)) * 0.1,
        "w_bias": -6.0 * jnp.ones(pre + (d,)),    # decay ≈ exp(-exp(-6)) ≈ 1
        "wg": dense_init(ks[4], pre + (d, d)),
        "u": jnp.zeros(pre + (h, hd)),
        "norm_w": jnp.zeros(pre + (d,)),
        "ln1": jnp.zeros(pre + (d,)),
        "ln2": jnp.zeros(pre + (d,)),
        "wo": dense_init(ks[5], pre + (d, d)),
        # channel-mix FFN (RWKV flavour: r-sigmoid gate, squared relu)
        "ffn_wr": dense_init(ks[6], pre + (d, d)),
        "ffn_wk": dense_init(ks[7], pre + (d, cfg.d_ff)),
        "ffn_wv": dense_init(jax.random.fold_in(key, 9),
                             pre + (cfg.d_ff, d)),
        "ffn_mu": 0.5 * jnp.ones(pre + (2, d)),
    }
    s = {
        "mu": lead + (None, None),
        "wr": lead + ("embed", "ssm_inner"),
        "wk": lead + ("embed", "ssm_inner"),
        "wv": lead + ("embed", "ssm_inner"),
        "ww": lead + ("embed", "ssm_inner"),
        "w_bias": lead + (None,),
        "wg": lead + ("embed", "ssm_inner"),
        "u": lead + (None, None),
        "norm_w": lead + (None,),
        "ln1": lead + (None,),
        "ln2": lead + (None,),
        "wo": lead + ("ssm_inner", "embed"),
        "ffn_wr": lead + ("embed", None),
        "ffn_wk": lead + ("embed", "mlp"),
        "ffn_wv": lead + ("mlp", "embed"),
        "ffn_mu": lead + (None, None),
    }
    return p, s


def _token_shift(x, prev):
    """shift(x)[t] = x[t-1]; position 0 takes `prev` (decode carry)."""
    shifted = jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)
    return shifted


def _wkv_chunked(r, k, v, logw, u, chunk, state0, unroll=1):
    """r,k: [b,s,h,K]; v: [b,s,h,V]; logw: [b,s,h,K] (≤0); u: [h,K].

    Returns (o [b,s,h,V], final state [b,h,K,V])."""
    b, s, h, K = r.shape
    V = v.shape[-1]
    c = s // chunk

    def chunked(t, width):
        return t.reshape(b, c, chunk, h, width).transpose(1, 0, 2, 3, 4)

    rr, kk = chunked(r, K), chunked(k, K)
    vv = chunked(v, V)
    lw = chunked(logw, K)                            # [c,b,l,h,K]
    mask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])

    def body(S, inp):
        rc, kc, vc, lwc = inp                        # [b,l,h,K/V]
        W = jnp.cumsum(lwc, axis=1)                  # inclusive, ≤ 0 slope
        Wi = W - lwc                                 # exclusive (W_{i-1})
        # intra-chunk: pairwise decay differences are ≤ 0 where masked
        diff = Wi[:, :, None] - W[:, None, :]        # [b,i,j,h,K]
        diff = jnp.where(mask[None, :, :, None, None], diff, -jnp.inf)
        att = jnp.einsum("bihk,bjhk,bijhk->bijh", rc, kc, jnp.exp(diff))
        o = jnp.einsum("bijh,bjhv->bihv", att, vc)
        diag = jnp.einsum("bihk,hk,bihk->bih", rc, u, kc)
        o = o + diag[..., None] * vc
        # inter-chunk from carried state
        o = o + jnp.einsum("bihk,bhkv->bihv", rc * jnp.exp(Wi), S)
        # state update (all exponents ≤ 0)
        k_dec = kc * jnp.exp(W[:, -1:, :, :] - W)
        S_new = S * jnp.exp(W[:, -1])[..., None] \
            + jnp.einsum("bjhk,bjhv->bhkv", k_dec, vc)
        return S_new, o

    final, ys = jax.lax.scan(body, state0, (rr, kk, vv, lw),
                             unroll=unroll)
    o = ys.transpose(1, 0, 2, 3, 4)
    return o.reshape(b, s, h, V), final


def rwkv6_apply(p, cfg: ModelConfig, x, dtype, state=None):
    """One full RWKV block (time-mix + channel-mix, pre-norm residuals):
        h   = x + time_mix(LN1(x));   out = h + channel_mix(LN2(h))
    Returns (out, carry); carry = (wkv_state, last LN1 token, last LN2
    token) so prefill→decode is seamless."""
    b, s, d = x.shape
    hd = cfg.ssm_head_dim
    h = d // hd
    if state is None:
        wkv0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        prev_tok = jnp.zeros((b, d), dtype)
        prev_ffn = jnp.zeros((b, d), dtype)
    else:
        wkv0, prev_tok, prev_ffn = state

    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    sx = _token_shift(xn, prev_tok)
    mu = p["mu"].astype(dtype)
    xm = [xn + mu[i][None, None, :] * (sx - xn) for i in range(5)]
    r = jnp.einsum("bsd,de->bse", xm[0], p["wr"].astype(dtype))
    k = jnp.einsum("bsd,de->bse", xm[1], p["wk"].astype(dtype))
    v = jnp.einsum("bsd,de->bse", xm[2], p["wv"].astype(dtype))
    wlog = -jnp.exp(jnp.einsum("bsd,de->bse", xm[3],
                               p["ww"].astype(dtype)).astype(jnp.float32)
                    + p["w_bias"])                 # ≤ 0
    g = jnp.einsum("bsd,de->bse", xm[4], p["wg"].astype(dtype))

    chunk = min(cfg.ssm_chunk, s)
    pad = (-s) % chunk
    sp_ = s + pad

    def heads(t, fill=0.0):
        if pad:
            t = jnp.pad(t, ((0, 0), (0, pad), (0, 0)),
                        constant_values=fill)
        return t.reshape(b, sp_, h, hd)

    # state-preserving padding: k=r=v=0 (no ingest), logw=0 (decay 1)
    o, wkv = _wkv_chunked(heads(r).astype(jnp.float32),
                          heads(k).astype(jnp.float32),
                          heads(v).astype(jnp.float32),
                          heads(wlog), p["u"].astype(jnp.float32),
                          chunk, wkv0,
                          unroll=True if cfg.probe_unroll else 1)
    o = o.reshape(b, sp_, d)[:, :s].astype(dtype)
    o = rms_norm(o, p["norm_w"], cfg.norm_eps) * jax.nn.silu(g)
    y = jnp.einsum("bsd,de->bse", o, p["wo"].astype(dtype))
    x1 = x + y

    # channel mix
    x1n = rms_norm(x1, p["ln2"], cfg.norm_eps)
    sx2 = _token_shift(x1n, prev_ffn)
    fmu = p["ffn_mu"].astype(dtype)
    xr = x1n + fmu[0][None, None, :] * (sx2 - x1n)
    xk = x1n + fmu[1][None, None, :] * (sx2 - x1n)
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr,
                                   p["ffn_wr"].astype(dtype)))
    kk = jnp.square(jax.nn.relu(
        jnp.einsum("bsd,df->bsf", xk, p["ffn_wk"].astype(dtype))))
    kk = shard(kk, "batch", "seq", "mlp")
    ffn = rr * jnp.einsum("bsf,fd->bsd", kk, p["ffn_wv"].astype(dtype))
    out = x1 + ffn
    carry = (wkv, xn[:, -1, :], x1n[:, -1, :])
    return out, carry


def rwkv6_decode(p, cfg: ModelConfig, x, state, dtype):
    """One-token step via the exact recurrence. x: [b,1,d]."""
    b, _, d = x.shape
    hd = cfg.ssm_head_dim
    h = d // hd
    wkv, prev_tok, prev_ffn = state
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    sx = prev_tok[:, None, :]
    mu = p["mu"].astype(dtype)
    xm = [xn + mu[i][None, None, :] * (sx - xn) for i in range(5)]
    r = jnp.einsum("bsd,de->bse", xm[0], p["wr"].astype(dtype))
    k = jnp.einsum("bsd,de->bse", xm[1], p["wk"].astype(dtype))
    v = jnp.einsum("bsd,de->bse", xm[2], p["wv"].astype(dtype))
    wlog = -jnp.exp(jnp.einsum("bsd,de->bse", xm[3],
                               p["ww"].astype(dtype)).astype(jnp.float32)
                    + p["w_bias"])
    g = jnp.einsum("bsd,de->bse", xm[4], p["wg"].astype(dtype))

    rh = r.reshape(b, h, hd).astype(jnp.float32)
    kh = k.reshape(b, h, hd).astype(jnp.float32)
    vh = v.reshape(b, h, hd).astype(jnp.float32)
    wh = jnp.exp(wlog.reshape(b, h, hd))
    u = p["u"].astype(jnp.float32)
    kv = jnp.einsum("bhk,bhv->bhkv", kh, vh)
    o = jnp.einsum("bhk,bhkv->bhv", rh, wkv + u[None, :, :, None] * kv)
    wkv_new = wkv * wh[..., None] + kv
    o = o.reshape(b, 1, d).astype(dtype)
    o = rms_norm(o, p["norm_w"], cfg.norm_eps) * jax.nn.silu(g)
    y = jnp.einsum("bsd,de->bse", o, p["wo"].astype(dtype))
    x1 = x + y

    x1n = rms_norm(x1, p["ln2"], cfg.norm_eps)
    sx2 = prev_ffn[:, None, :]
    fmu = p["ffn_mu"].astype(dtype)
    xr = x1n + fmu[0][None, None, :] * (sx2 - x1n)
    xk = x1n + fmu[1][None, None, :] * (sx2 - x1n)
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr,
                                   p["ffn_wr"].astype(dtype)))
    kk = jnp.square(jax.nn.relu(
        jnp.einsum("bsd,df->bsf", xk, p["ffn_wk"].astype(dtype))))
    ffn = rr * jnp.einsum("bsf,fd->bsd", kk, p["ffn_wv"].astype(dtype))
    out = x1 + ffn
    return out, (wkv_new, xn[:, 0, :], x1n[:, 0, :])
