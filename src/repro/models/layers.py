"""Shared neural layers: init helpers, RMSNorm, RoPE, SwiGLU, embeddings.

Functional style: params are nested dicts of arrays; every init function
also returns a matching tree of PartitionSpec-producing logical axis tuples
(consumed by launch/dryrun for in_shardings).  Layer stacks store weights
with a leading [L] axis and run under `lax.scan` (compile time O(1) in
depth — essential for the 512-device dry-runs on this 1-core container).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard

Params = dict[str, Any]
Specs = dict[str, Any]  # mirrors Params with tuples of logical axis names


def dense_init(key, shape, in_axis: int = -2, dtype=jnp.float32):
    fan_in = shape[in_axis]
    scale = 1.0 / jnp.sqrt(fan_in)
    return jax.random.normal(key, shape, dtype) * scale


def rms_norm(x, weight, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dt)


def rope(pos, d_head, theta):
    """Rotary embedding tables: returns (sin, cos) of shape pos.shape+[d/2]."""
    half = d_head // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = pos.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x, sin, cos):
    """x: [..., n_heads, d_head]; sin/cos: broadcastable [..., d_head/2]."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------
def mlp_init(key, d_model, d_ff, stacked: int | None = None):
    ks = jax.random.split(key, 3)
    pre = (stacked,) if stacked is not None else ()
    p = {
        "wi": dense_init(ks[0], pre + (d_model, d_ff)),
        "wg": dense_init(ks[1], pre + (d_model, d_ff)),
        "wo": dense_init(ks[2], pre + (d_ff, d_model), in_axis=-2),
    }
    lead = ("layers",) if stacked is not None else ()
    s = {
        "wi": lead + ("embed", "mlp"),
        "wg": lead + ("embed", "mlp"),
        "wo": lead + ("mlp", "embed"),
    }
    return p, s


def mlp_apply(p, x, dtype):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dtype))
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dtype))
    h = jax.nn.silu(g) * h
    h = shard(h, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dtype))


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------
def embed_init(key, vocab, d_model, tie: bool):
    k1, k2 = jax.random.split(key)
    p = {"embedding": jax.random.normal(k1, (vocab, d_model)) * 0.02}
    s = {"embedding": ("vocab", "embed")}
    if not tie:
        p["unembed"] = dense_init(k2, (d_model, vocab))
        s["unembed"] = ("embed", "vocab")
    return p, s


def embed_apply(p, tokens, dtype):
    out = jnp.take(p["embedding"].astype(dtype), tokens, axis=0)
    return shard(out, "batch", "seq", "act_embed")


def unembed_apply(p, x, dtype, softcap: float = 0.0):
    """Logits stay in the compute dtype (bf16): the loss upcasts its own
    block-local math to f32, while the logits *gradient* — which feeds the
    embedding-gradient all-reduce and the unembedding all-gather, both ×M
    microbatches — moves at half the bytes (EXPERIMENTS §Perf, LM cells)."""
    w = p.get("unembed")
    if w is None:
        w = p["embedding"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(dtype))
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    return shard(logits, "batch", "seq", "vocab")
