"""Model assembly: init / forward / prefill / decode for every family.

One decoder skeleton, pluggable mixers:

  dense   — [LN → attention → LN → SwiGLU] × L, scanned
  moe     — [LN → attention → LN → MoE(+shared)] × L, scanned
  rwkv6   — [RWKV block (time-mix + channel-mix)] × L, scanned
  mamba2  — [LN → Mamba2 mixer] × L, scanned
  hybrid  — zamba2: groups of `shared_attn_every` Mamba2 layers, each group
            preceded by ONE weight-shared attention+MLP block (7 cache
            instances for 38 layers)

Homogeneous stacks run under `lax.scan` over stacked [L, ...] weights so
HLO size (and 512-device dry-run compile time) is depth-independent.
Training wraps the scan body in `jax.checkpoint` (policy from the caller).

Decode state is a pytree of stacked per-layer caches updated inside the
same scan. `prefill` returns the populated caches for every family.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rk
from repro.models.config import ModelConfig
from repro.models.layers import (
    embed_apply,
    embed_init,
    mlp_apply,
    mlp_init,
    rms_norm,
    unembed_apply,
)

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


# ==========================================================================
# init
# ==========================================================================
def init_params(key, cfg: ModelConfig):
    """Returns (params, specs): specs mirror params with logical-axis tuples."""
    keys = jax.random.split(key, 8)
    p: dict[str, Any] = {}
    s: dict[str, Any] = {}
    p["embed"], s["embed"] = embed_init(keys[0], cfg.vocab_size, cfg.d_model,
                                        cfg.tie_embeddings)
    p["final_norm"] = jnp.zeros((cfg.d_model,))
    s["final_norm"] = (None,)
    L = cfg.n_layers

    if cfg.family in ("dense", "moe"):
        ap, asx = attn.attention_init(keys[1], cfg, stacked=L)
        if cfg.family == "moe":
            mp, msx = moe_mod.moe_init(keys[2], cfg, stacked=L)
        else:
            mp, msx = mlp_init(keys[2], cfg.d_model, cfg.d_ff, stacked=L)
        p["layers"] = {"attn": ap, "mlp": mp,
                       "ln1": jnp.zeros((L, cfg.d_model)),
                       "ln2": jnp.zeros((L, cfg.d_model))}
        s["layers"] = {"attn": asx, "mlp": msx,
                       "ln1": ("layers", None), "ln2": ("layers", None)}
    elif cfg.family == "rwkv6":
        mp, msx = rk.rwkv6_init(keys[1], cfg, stacked=L)
        p["layers"] = {"mixer": mp}
        s["layers"] = {"mixer": msx}
    elif cfg.family == "mamba2":
        mp, msx = m2.mamba2_init(keys[1], cfg, stacked=L)
        p["layers"] = {"mixer": mp, "ln1": jnp.zeros((L, cfg.d_model))}
        s["layers"] = {"mixer": msx, "ln1": ("layers", None)}
    elif cfg.family == "hybrid":
        mp, msx = m2.mamba2_init(keys[1], cfg, stacked=L)
        p["layers"] = {"mixer": mp, "ln1": jnp.zeros((L, cfg.d_model))}
        s["layers"] = {"mixer": msx, "ln1": ("layers", None)}
        ap, asx = attn.attention_init(keys[2], cfg, stacked=None)
        fp, fsx = mlp_init(keys[3], cfg.d_model, cfg.d_ff, stacked=None)
        p["shared"] = {"attn": ap, "mlp": fp,
                       "ln1": jnp.zeros((cfg.d_model,)),
                       "ln2": jnp.zeros((cfg.d_model,))}
        s["shared"] = {"attn": asx, "mlp": fsx,
                       "ln1": (None,), "ln2": (None,)}
    else:
        raise ValueError(cfg.family)
    return p, s


def _is_global_pattern(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer bool: layer uses global (non-windowed) attention."""
    L = cfg.n_layers
    if cfg.local_global_ratio:
        # gemma3: every (ratio+1)-th layer is global
        idx = jnp.arange(L)
        return (idx % (cfg.local_global_ratio + 1)) == cfg.local_global_ratio
    if cfg.sliding_window:
        return jnp.zeros((L,), bool)     # all windowed (SWA)
    return jnp.ones((L,), bool)          # all global


# ==========================================================================
# embedding / head shared by all paths
# ==========================================================================
def _embed_inputs(params, cfg: ModelConfig, batch, dtype):
    x = embed_apply(params["embed"], batch["tokens"], dtype)
    if cfg.frontend == "vision_stub":
        img = batch["image_embeds"].astype(dtype)
        x = jnp.concatenate([img, x], axis=1)
    if getattr(cfg, "embed_scale", False) or cfg.name.startswith("gemma"):
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32)).astype(dtype)
    return shard(x, "batch", "seq", "act_embed")


def _head(params, cfg: ModelConfig, x, dtype):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed_apply(params["embed"], x, dtype, cfg.logit_softcap)


# ==========================================================================
# transformer stacks (dense / moe)
# ==========================================================================
def _dense_stack(params, cfg, x, pos, mode, cache, policy):
    dtype = cfg.compute_dtype
    unroll = True if cfg.probe_unroll else 1
    is_global = _is_global_pattern(cfg)
    zero_aux = {"load_balance": jnp.zeros((), jnp.float32),
                "router_z": jnp.zeros((), jnp.float32),
                "dropped_frac": jnp.zeros((), jnp.float32)}

    def block(x, lp, ig, ck, cv, pos_scalar):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if mode == "train":
            a = attn.attention_train(lp["attn"], cfg, h, pos, ig, dtype)
        elif mode == "prefill":
            a = attn.attention_prefill(lp["attn"], cfg, h, pos, ig, dtype)
            # write the whole prefix into the cache
            q, k, v = attn._qkv(lp["attn"], cfg, h, pos, dtype)
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), 0, axis=1)
        else:  # decode
            a, ck, cv = attn.attention_decode(lp["attn"], cfg, h, ck, cv,
                                              pos_scalar, ig, dtype)
        x = x + a
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, aux = moe_mod.moe_apply(lp["mlp"], cfg, h2, dtype)
        else:
            y, aux = mlp_apply(lp["mlp"], h2, dtype), zero_aux
        return x + y, ck, cv, aux

    if mode == "train":
        def body(carry, xs):
            x, aux_acc = carry
            lp, ig = xs
            x, _, _, aux = block(x, lp, ig, None, None, None)
            aux_acc = jax.tree.map(lambda a, b: a + b, aux_acc, aux)
            return (x, aux_acc), None

        if policy is not None:
            body = jax.checkpoint(body, policy=policy)
        (x, aux), _ = jax.lax.scan(body, (x, zero_aux),
                                   (params["layers"], is_global),
                                   unroll=unroll)
        return x, None, aux

    # prefill / decode: caches ride the scan as xs/ys
    pos_scalar = cache["pos"]

    def body(x, xs):
        lp, ig, ck, cv = xs
        x, ck, cv, _aux = block(x, lp, ig, ck, cv, pos_scalar)
        return x, (ck, cv)

    x, (ck, cv) = jax.lax.scan(body, x,
                               (params["layers"], is_global,
                                cache["k"], cache["v"]),
                               unroll=unroll)
    new_len = pos_scalar + x.shape[1]
    new_cache = {"k": ck, "v": cv, "pos": new_len}
    return x, new_cache, zero_aux


# ==========================================================================
# rwkv6 / mamba2 stacks
# ==========================================================================
def _rwkv_stack(params, cfg, x, pos, mode, cache, policy):
    dtype = cfg.compute_dtype
    unroll = True if cfg.probe_unroll else 1

    if mode == "decode":
        def body(x, xs):
            lp, wkv, tok, ffn = xs
            y, (wkv, tok, ffn) = rk.rwkv6_decode(lp["mixer"], cfg, x,
                                                 (wkv, tok, ffn), dtype)
            return y, (wkv, tok, ffn)

        x, (wkv, tok, ffn) = jax.lax.scan(
            body, x, (params["layers"], cache["wkv"], cache["tok"],
                      cache["ffn"]), unroll=unroll)
        return x, {"wkv": wkv, "tok": tok, "ffn": ffn,
                   "pos": cache["pos"] + 1}, None

    def body(x, xs):
        lp = xs
        y, carry = rk.rwkv6_apply(lp["mixer"], cfg, x, dtype, state=None)
        return y, carry

    if mode == "train" and policy is not None:
        body = jax.checkpoint(body, policy=policy)
    x, (wkv, tok, ffn) = jax.lax.scan(body, x, params["layers"],
                                      unroll=unroll)
    new_cache = None
    if mode == "prefill":
        new_cache = {"wkv": wkv, "tok": tok, "ffn": ffn,
                     "pos": (cache["pos"] if cache else 0) + x.shape[1]}
    return x, new_cache, None


def _mamba_block(lp, cfg, x, mode, ssm, conv, dtype):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if mode == "decode":
        y, (ssm, conv) = m2.mamba2_decode(lp["mixer"], cfg, h, ssm, conv,
                                          dtype)
    else:
        y, (ssm, conv) = m2.mamba2_apply(lp["mixer"], cfg, h, dtype)
    return x + y, ssm, conv


def _mamba_stack(params, cfg, x, pos, mode, cache, policy):
    dtype = cfg.compute_dtype
    unroll = True if cfg.probe_unroll else 1

    if mode == "decode":
        def body(x, xs):
            lp, ssm, conv = xs
            x, ssm, conv = _mamba_block(lp, cfg, x, mode, ssm, conv, dtype)
            return x, (ssm, conv)

        x, (ssm, conv) = jax.lax.scan(
            body, x, (params["layers"], cache["ssm"], cache["conv"]),
            unroll=unroll)
        return x, {"ssm": ssm, "conv": conv, "pos": cache["pos"] + 1}, None

    def body(x, xs):
        lp = xs
        x, ssm, conv = _mamba_block(lp, cfg, x, mode, None, None, dtype)
        return x, (ssm, conv)

    if mode == "train" and policy is not None:
        body = jax.checkpoint(body, policy=policy)
    x, (ssm, conv) = jax.lax.scan(body, x, params["layers"],
                                  unroll=unroll)
    new_cache = None
    if mode == "prefill":
        new_cache = {"ssm": ssm, "conv": conv,
                     "pos": (cache["pos"] if cache else 0) + x.shape[1]}
    return x, new_cache, None


# ==========================================================================
# hybrid (zamba2) stack
# ==========================================================================
def _hybrid_groups(cfg: ModelConfig):
    every = cfg.shared_attn_every
    L = cfg.n_layers
    sizes = []
    done = 0
    while done < L:
        g = min(every, L - done)
        sizes.append(g)
        done += g
    return sizes  # one shared-attn application before each group


def _hybrid_stack(params, cfg, x, pos, mode, cache, policy):
    dtype = cfg.compute_dtype
    unroll = True if cfg.probe_unroll else 1
    sizes = _hybrid_groups(cfg)
    sp = params["shared"]
    off = 0
    new_k, new_v, new_ssm, new_conv = [], [], [], []
    pos_scalar = cache["pos"] if cache is not None else None

    for gi, gsz in enumerate(sizes):
        # ---- shared attention + MLP block (weights shared, cache per app)
        h = rms_norm(x, sp["ln1"], cfg.norm_eps)
        if mode == "train":
            a = attn.attention_train(sp["attn"], cfg, h, pos, True, dtype)
        elif mode == "prefill":
            a = attn.attention_prefill(sp["attn"], cfg, h, pos, True, dtype)
            q, k, v = attn._qkv(sp["attn"], cfg, h, pos, dtype)
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"][gi], k.astype(cache["k"].dtype), 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"][gi], v.astype(cache["v"].dtype), 0, axis=1)
            new_k.append(ck)
            new_v.append(cv)
        else:
            a, ck, cv = attn.attention_decode(
                sp["attn"], cfg, h, cache["k"][gi], cache["v"][gi],
                pos_scalar, True, dtype)
            new_k.append(ck)
            new_v.append(cv)
        x = x + a
        h2 = rms_norm(x, sp["ln2"], cfg.norm_eps)
        x = x + mlp_apply(sp["mlp"], h2, dtype)

        # ---- group of mamba2 layers
        lp_slice = jax.tree.map(lambda a: a[off:off + gsz], params["layers"])

        if mode == "decode":
            def body(x, xs):
                lp, ssm, conv = xs
                x, ssm, conv = _mamba_block(lp, cfg, x, mode, ssm, conv,
                                            dtype)
                return x, (ssm, conv)

            x, (ssm, conv) = jax.lax.scan(
                body, x, (lp_slice, cache["ssm"][off:off + gsz],
                          cache["conv"][off:off + gsz]), unroll=unroll)
            new_ssm.append(ssm)
            new_conv.append(conv)
        else:
            def body(x, xs):
                x, ssm, conv = _mamba_block(xs, cfg, x, mode, None, None,
                                            dtype)
                return x, (ssm, conv)

            b = jax.checkpoint(body, policy=policy) \
                if (mode == "train" and policy is not None) else body
            x, (ssm, conv) = jax.lax.scan(b, x, lp_slice, unroll=unroll)
            if mode == "prefill":
                new_ssm.append(ssm)
                new_conv.append(conv)
        off += gsz

    new_cache = None
    if mode in ("prefill", "decode"):
        base = pos_scalar if pos_scalar is not None else 0
        step = 1 if mode == "decode" else x.shape[1]
        if not sizes:  # L=0 probe models: pass the cache through
            new_cache = dict(cache, pos=base + step)
        else:
            new_cache = {
                "k": jnp.stack(new_k), "v": jnp.stack(new_v),
                "ssm": jnp.concatenate(new_ssm),
                "conv": jnp.concatenate(new_conv),
                "pos": base + step,
            }
    return x, new_cache, None


_STACKS = {
    "dense": _dense_stack,
    "moe": _dense_stack,
    "rwkv6": _rwkv_stack,
    "mamba2": _mamba_stack,
    "hybrid": _hybrid_stack,
}


# ==========================================================================
# public API
# ==========================================================================
def forward(params, cfg: ModelConfig, batch, *, remat: str = "none"):
    """Training/eval forward over a full sequence. Returns (logits, aux)."""
    dtype = cfg.compute_dtype
    x = _embed_inputs(params, cfg, batch, dtype)
    b, s = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    policy = REMAT_POLICIES[remat]
    x, _, aux = _STACKS[cfg.family](params, cfg, x, pos, "train", None,
                                    policy)
    logits = _head(params, cfg, x, dtype)
    return logits, aux


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    """Fresh decode caches (stacked over layers / app instances)."""
    L = cfg.n_layers
    kvd = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.family in ("dense", "moe"):
        kvh, hd = cfg.n_kv_heads, cfg.d_head
        return {
            "k": jnp.zeros((L, batch, max_len, kvh, hd), kvd),
            "v": jnp.zeros((L, batch, max_len, kvh, hd), kvd),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "rwkv6":
        hd = cfg.ssm_head_dim
        h = cfg.d_model // hd
        return {
            "wkv": jnp.zeros((L, batch, h, hd, hd), jnp.float32),
            "tok": jnp.zeros((L, batch, cfg.d_model), kvd),
            "ffn": jnp.zeros((L, batch, cfg.d_model), kvd),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "mamba2":
        return {
            "ssm": jnp.zeros((L, batch, cfg.n_ssm_heads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((L, batch, cfg.conv_width - 1,
                               cfg.d_inner + 2 * cfg.ssm_state), kvd),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "hybrid":
        n_apps = len(_hybrid_groups(cfg))
        kvh, hd = cfg.n_kv_heads, cfg.d_head
        return {
            "k": jnp.zeros((n_apps, batch, max_len, kvh, hd), kvd),
            "v": jnp.zeros((n_apps, batch, max_len, kvh, hd), kvd),
            "ssm": jnp.zeros((L, batch, cfg.n_ssm_heads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((L, batch, cfg.conv_width - 1,
                               cfg.d_inner + 2 * cfg.ssm_state), kvd),
            "pos": jnp.zeros((), jnp.int32),
        }
    raise ValueError(cfg.family)


def decode_state_specs(cfg: ModelConfig):
    """Logical-axis annotations for the decode caches (for in_shardings)."""
    if cfg.family in ("dense", "moe"):
        return {"k": ("stack", "batch", "kv_seq", "kv_heads", "kv_head_dim"),
                "v": ("stack", "batch", "kv_seq", "kv_heads", "kv_head_dim"),
                "pos": ()}
    if cfg.family == "rwkv6":
        return {"wkv": ("stack", "batch", "heads", None, None),
                "tok": ("stack", "batch", None),
                "ffn": ("stack", "batch", None),
                "pos": ()}
    if cfg.family == "mamba2":
        return {"ssm": ("stack", "batch", "heads", None, None),
                "conv": ("stack", "batch", None, "ssm_inner"),
                "pos": ()}
    if cfg.family == "hybrid":
        return {"k": ("stack", "batch", "kv_seq", "kv_heads", "head_dim"),
                "v": ("stack", "batch", "kv_seq", "kv_heads", "head_dim"),
                "ssm": ("stack", "batch", "heads", None, None),
                "conv": ("stack", "batch", None, "ssm_inner"),
                "pos": ()}
    raise ValueError(cfg.family)


def prefill(params, cfg: ModelConfig, batch, cache):
    """Run the prompt through the model, populating `cache`.
    Returns (last-token logits [B, V], cache)."""
    dtype = cfg.compute_dtype
    x = _embed_inputs(params, cfg, batch, dtype)
    b, s = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x, new_cache, _ = _STACKS[cfg.family](params, cfg, x, pos, "prefill",
                                          cache, None)
    logits = _head(params, cfg, x[:, -1:, :], dtype)
    return logits[:, 0], new_cache


def decode_step(params, cfg: ModelConfig, tokens, cache):
    """One decoding step. tokens: [B, 1]. Returns (logits [B, V], cache)."""
    dtype = cfg.compute_dtype
    x = embed_apply(params["embed"], tokens, dtype)
    if getattr(cfg, "embed_scale", False) or cfg.name.startswith("gemma"):
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32)).astype(dtype)
    pos = cache["pos"]
    x, new_cache, _ = _STACKS[cfg.family](params, cfg, x, None, "decode",
                                          cache, None)
    logits = _head(params, cfg, x, dtype)
    return logits[:, 0], new_cache
