"""Mixture-of-Experts layer: top-k router, capacity-bounded scatter
dispatch, per-expert SwiGLU, optional shared experts (Moonlight-style).

TPU/JAX shape discipline: dispatch is a static-capacity scatter into an
[E, C, D] buffer (tokens over capacity are dropped, the standard TPU MoE
trade-off), expert FFNs run as one batched einsum, and the combine is a
gather + weighted sum.  Experts shard over the `expert` logical axis
("data" on the production mesh — EP), expert FFN width over "model" (TP);
the token shuffle between batch-sharded activations and expert-sharded
buffers lowers to an all_to_all under SPMD.

Integration with the paper (DESIGN.md §4): expert load statistics are a
guarded COUNT(*) ... GROUP BY expert; `load_stats` computes them with the
same segmented-sum machinery as the query engine's FreqJoin pipeline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.config import ModelConfig
from repro.models.layers import dense_init


def moe_init(key, cfg: ModelConfig, stacked: int | None = None):
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    pre = (stacked,) if stacked is not None else ()
    lead = ("layers",) if stacked is not None else ()
    p = {
        "router": dense_init(ks[0], pre + (d, e)),
        "wi": dense_init(ks[1], pre + (e, d, f)),
        "wg": dense_init(ks[2], pre + (e, d, f)),
        "wo": dense_init(ks[3], pre + (e, f, d), in_axis=-2),
    }
    s = {
        "router": lead + ("embed", None),
        "wi": lead + ("experts", None, "expert_mlp"),
        "wg": lead + ("experts", None, "expert_mlp"),
        "wo": lead + ("experts", "expert_mlp", None),
    }
    if cfg.n_shared_experts:
        sk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi": dense_init(sk[0], pre + (d, f * cfg.n_shared_experts)),
            "wg": dense_init(sk[1], pre + (d, f * cfg.n_shared_experts)),
            "wo": dense_init(sk[2], pre + (f * cfg.n_shared_experts, d),
                             in_axis=-2),
        }
        s["shared"] = {
            "wi": lead + ("embed", "mlp"),
            "wg": lead + ("embed", "mlp"),
            "wo": lead + ("mlp", "embed"),
        }
    return p, s


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(8, (c + 7) // 8 * 8)


def moe_apply(p, cfg: ModelConfig, x, dtype):
    """x: [B, S, D] → [B, S, D], aux-loss dict."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(cfg, t)
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt, p["router"].astype(dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)           # [t, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert, capacity-dropped.
    # SORT-based (not one-hot cumsum): a [t·k, e] one-hot prefix sum is
    # counted/lowered as an O(N·w) reduce-window — at 1M tokens it alone
    # was 1.6e14 FLOPs/device and 1.6 GB (EXPERIMENTS §Dry-run note ²).
    # A stable argsort by expert gives identical first-come-first-served
    # positions in O(N log N), shardable, with no [N, e] intermediates.
    n_assign = t * k
    flat_e_all = expert_idx.reshape(n_assign)
    order = jnp.argsort(flat_e_all, stable=True)
    sorted_e = flat_e_all[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))    # [e]
    pos_sorted = jnp.arange(n_assign) - seg_start[sorted_e]
    pos = jnp.zeros((n_assign,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32)).reshape(t, k)
    keep = pos < cap

    # scatter tokens into the expert buffer.  Two SPMD-friendliness tricks:
    # (a) LINEAR 1-D indices into a flattened [e·(cap+1), d] buffer — 2-D
    #     (expert, pos) scatters make XLA materialise [t·k, d_shard] u32
    #     index matrices; 1-D row scatters keep indices at [t·k];
    # (b) operand/updates sharded on d ("dispatch_embed") so the scatter is
    #     fully local per shard; the buffer reshards for the expert einsum.
    flat_e = expert_idx.reshape(t * k)
    flat_pos = jnp.where(keep.reshape(-1), pos.reshape(-1), cap)  # cap = trash
    lin = flat_e * (cap + 1) + flat_pos
    buf = shard(jnp.zeros((e * (cap + 1), d), dtype),
                None, "dispatch_embed")
    tok_src = jnp.repeat(xt, k, axis=0) if k > 1 else xt
    tok_src = shard(tok_src.astype(dtype), "batch", "dispatch_embed")
    buf = buf.at[lin].set(tok_src, mode="drop")
    buf = buf.reshape(e, cap + 1, d)[:, :cap]
    if cfg.dispatch_reshard:
        buf = shard(buf, "experts", None, "act_embed")

    # batched per-expert SwiGLU
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dtype))
    h = jax.nn.silu(g) * h
    h = shard(h, "experts", None, "expert_mlp")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dtype))
    # reshard to d + flatten for a fully local 1-D row gather
    out_buf = shard(out_buf.reshape(e * cap, d), None, "dispatch_embed")

    # combine: gather each (token, choice) result, weight by gate
    lin_out = flat_e * cap + jnp.minimum(flat_pos, cap - 1)
    out_tok = out_buf[lin_out]                                 # [t*k, d]
    out_tok = shard(out_tok, "batch", "dispatch_embed")
    w = (gate.reshape(t * k) * keep.reshape(t * k)).astype(dtype)
    out = (out_tok * w[:, None]).reshape(t, k, d).sum(axis=1)

    if cfg.n_shared_experts:
        sp = p["shared"]
        sh = jnp.einsum("td,df->tf", xt, sp["wi"].astype(dtype))
        sg = jnp.einsum("td,df->tf", xt, sp["wg"].astype(dtype))
        out = out + jnp.einsum("tf,fd->td", jax.nn.silu(sg) * sh,
                               sp["wo"].astype(dtype))

    # aux losses (Switch-style load balance + router z-loss)
    counts = jnp.zeros((e,), jnp.float32).at[flat_e_all].add(1.0)
    density = counts / t                                            # [e]
    router_prob = probs.mean(axis=0)
    aux = {
        "load_balance": e * jnp.sum(density * router_prob),
        "router_z": jnp.mean(
            jnp.square(jax.nn.logsumexp(logits, axis=-1))),
        "dropped_frac": 1.0 - keep.mean(),
    }
    return out.reshape(b, s, d), aux


def load_stats(expert_idx: jax.Array, n_experts: int, backend: str = "xla"):
    """Expert load = `SELECT expert, COUNT(*) GROUP BY expert` over the
    (token→expert) assignment relation — computed with the paper engine's
    segmented-sum machinery (see DESIGN.md §4)."""
    from repro.kernels import ops as kops
    flat = expert_idx.reshape(-1).astype(jnp.int32)
    keys, sums, valid = kops.group_by_sum(
        flat, jnp.ones_like(flat), backend=backend)
    loads = jnp.zeros((n_experts,), jnp.int32)
    loads = loads.at[jnp.where(valid, keys, n_experts)].add(
        jnp.where(valid, sums, 0), mode="drop")
    return loads
