"""Mamba2 (SSD — state-space duality) mixer, chunked parallel form.

Follows the minimal SSD formulation of the Mamba2 paper: within chunks of
Q tokens the recurrence is evaluated as a (masked, decay-weighted)
attention-like einsum; across chunks a `lax.scan` carries the [h, p, n]
state.  ngroups=1 (B/C shared across heads), causal depthwise conv width 4,
gated RMSNorm output — the zamba2 configuration.

All decay exponents are differences of a cumulative sum taken *within* one
chunk, so every `exp` argument is ≤ 0 for the masked entries: numerically
safe in fp32 at any chunk length.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rms_norm


def mamba2_init(key, cfg: ModelConfig, stacked: int | None = None):
    ks = jax.random.split(key, 4)
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    conv_dim = di + 2 * n
    pre = (stacked,) if stacked is not None else ()
    lead = ("layers",) if stacked is not None else ()
    p = {
        "in_proj": dense_init(ks[0], pre + (d, 2 * di + 2 * n + h)),
        "conv_w": dense_init(ks[1], pre + (cfg.conv_width, conv_dim)),
        "A_log": jnp.zeros(pre + (h,)),
        "D": jnp.ones(pre + (h,)),
        "dt_bias": jnp.zeros(pre + (h,)),
        "norm_w": jnp.zeros(pre + (di,)),
        "out_proj": dense_init(ks[2], pre + (di, d)),
    }
    s = {
        "in_proj": lead + ("embed", "ssm_inner"),
        "conv_w": lead + ("conv", "ssm_inner"),
        "A_log": lead + (None,),
        "D": lead + (None,),
        "dt_bias": lead + (None,),
        "norm_w": lead + ("ssm_inner",),
        "out_proj": lead + ("ssm_inner", "embed"),
    }
    return p, s


def _split(cfg, zxbcdt):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc, w):
    """Depthwise causal conv over seq: xbc [b,s,c], w [k,c]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(k):
        out = out + pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
    return jax.nn.silu(out)


def _ssd_chunked(x, dA, B, C, chunk, state0=None, unroll=1):
    """x: [b,s,h,p] (dt-scaled), dA: [b,s,h] (≤0), B,C: [b,s,n].

    Sequential `lax.scan` over chunks: per-step memory is O(chunk²·h),
    independent of sequence length.  Returns y [b,s,h,p] and final state
    [b,h,p,n]."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    c = s // chunk
    xr = x.reshape(b, c, chunk, h, p).transpose(1, 0, 2, 3, 4)  # [c,b,l,h,p]
    Ar = dA.reshape(b, c, chunk, h).transpose(1, 0, 2, 3)       # [c,b,l,h]
    Br = B.reshape(b, c, chunk, n).transpose(1, 0, 2, 3)
    Cr = C.reshape(b, c, chunk, n).transpose(1, 0, 2, 3)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(S, inp):
        xc, Ac, Bc, Cc = inp
        cs = jnp.cumsum(Ac, axis=1)                             # [b,l,h]
        # intra-chunk decay matrix L_ij = exp(cs_i - cs_j), i ≥ j (≤ 0 exp)
        seg = cs[:, :, None, :] - cs[:, None, :, :]             # [b,i,j,h]
        L = jnp.where(mask[None, :, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("bin,bjn->bij", Cc, Bc)
        y = jnp.einsum("bij,bijh,bjhp->bihp", scores, L, xc)
        # inter-chunk contribution from the carried state
        y = y + jnp.einsum("bin,bhpn,bih->bihp", Cc, S, jnp.exp(cs))
        # state update
        decay_to_end = jnp.exp(cs[:, -1:, :] - cs)              # [b,l,h]
        S_new = S * jnp.exp(cs[:, -1])[:, :, None, None] \
            + jnp.einsum("bln,blh,blhp->bhpn", Bc, decay_to_end, xc)
        return S_new, y

    S0 = state0 if state0 is not None else jnp.zeros((b, h, p, n),
                                                     jnp.float32)
    final, ys = jax.lax.scan(body, S0, (xr, Ar, Br, Cr),
                             unroll=unroll)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, final


def mamba2_apply(p, cfg: ModelConfig, x, dtype, state=None, conv_state=None):
    """Full-sequence mixer. Returns (y, (ssm_state, conv_state))."""
    b, s, _ = x.shape
    di, n, h, hp = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dtype))
    z, xbc_pre, dt = _split(cfg, zxbcdt)
    xbc = _causal_conv(xbc_pre, p["conv_w"].astype(dtype))
    xr, B, C = xbc[..., :di], xbc[..., di:di + n], xbc[..., di + n:]
    xr = shard(xr, "batch", "seq", "ssm_inner")
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = dt * A                                               # [b,s,h] ≤ 0

    xh = xr.reshape(b, s, h, hp).astype(jnp.float32) * dt[..., None]
    chunk = min(cfg.ssm_chunk, s)
    pad = (-s) % chunk
    if pad:
        # state-preserving padding: zero input and zero decay (dA=0)
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y, final = _ssd_chunked(xh, dA, B.astype(jnp.float32),
                            C.astype(jnp.float32), chunk,
                            unroll=True if cfg.probe_unroll else 1)
    y = y[:, :s] + p["D"].astype(jnp.float32)[None, None, :, None] \
        * xr.reshape(b, s, h, hp).astype(jnp.float32)
    y = y.reshape(b, s, di).astype(dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dtype))
    new_conv = xbc_pre[:, -(cfg.conv_width - 1):, :] \
        if s >= cfg.conv_width - 1 else None
    return out, (final, new_conv)


def mamba2_decode(p, cfg: ModelConfig, x, ssm_state, conv_state, dtype):
    """One-token step. x: [b,1,d]; ssm_state: [b,h,p,n];
    conv_state: [b, conv_width-1, conv_dim]."""
    b = x.shape[0]
    di, n, h, hp = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dtype))
    z, xbc, dt = _split(cfg, zxbcdt)
    # causal conv via the rolling state
    window = jnp.concatenate([conv_state, xbc], axis=1)       # [b,k,c]
    w = p["conv_w"].astype(dtype)
    conv_out = jnp.einsum("bkc,kc->bc", window, w)[:, None, :]
    xbc1 = jax.nn.silu(conv_out)
    new_conv_state = window[:, 1:, :]
    xr, B, C = xbc1[..., :di], xbc1[..., di:di + n], xbc1[..., di + n:]
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [b,h]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt1 * A)                                  # [b,h]
    xh = xr[:, 0].reshape(b, h, hp).astype(jnp.float32) * dt1[..., None]
    outer = jnp.einsum("bhp,bn->bhpn", xh, B[:, 0].astype(jnp.float32))
    new_state = ssm_state * decay[..., None, None] + outer
    y = jnp.einsum("bhpn,bn->bhp", new_state, C[:, 0].astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] \
        * xr[:, 0].reshape(b, h, hp).astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dtype))
    return out, (new_state, new_conv_state)
