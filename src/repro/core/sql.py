"""A small SQL front-end for the guarded-aggregate engine.

Parses the fragment the paper targets — SELECT <aggs> FROM <tables>
WHERE <equi-joins ∧ local predicates> [GROUP BY <cols>] — into an
``AggQuery``, so the engine plugs into systems that speak SQL (the paper's
point: these optimisations belong in ordinary RDBMS planners).

Supported grammar (case-insensitive keywords):

    SELECT  agg(col) [AS name] [, ...] | agg(*) | DISTINCT inside agg
    FROM    rel [alias] [, ...]
    WHERE   a.col = b.col            -- equi-join (any number, AND-ed)
          | a.col <op> <literal>     -- local selection (=, <, >, <=, >=, !=)
          | a.col IN (v1, v2, ...)
    GROUP BY a.col [, ...]

Example (the paper's Fig. 1):

    SELECT MIN(s.s_acctbal), MAX(s.s_acctbal)
    FROM region r, nation n, supplier s, partsupp ps, part p
    WHERE r.r_regionkey = n.n_regionkey AND n.n_nationkey = s.s_nationkey
      AND s.s_suppkey = ps.ps_suppkey AND ps.ps_partkey = p.p_partkey
      AND r.r_name IN (2, 3) AND p.p_price > 1200.0
"""

from __future__ import annotations

import re

from repro.core.query import Agg, AggQuery, Atom, selection_from_spec
from repro.tables.table import Schema

_AGG_RE = re.compile(
    r"(count|sum|avg|min|max|median)\s*\(\s*(distinct\s+)?"
    r"(\*|[a-z_][\w.]*)\s*\)(?:\s+as\s+(\w+))?", re.I)
_JOIN_RE = re.compile(r"^(\w+)\.(\w+)\s*=\s*(\w+)\.(\w+)$")
_SEL_RE = re.compile(r"^(\w+)\.(\w+)\s*(=|!=|<=|>=|<|>)\s*([-\w.']+)$")
_IN_RE = re.compile(r"^(\w+)\.(\w+)\s+in\s*\(([^)]*)\)$", re.I)


class SqlError(ValueError):
    pass


def _split_top(s: str, sep: str) -> list[str]:
    """Split on `sep` at parenthesis depth 0."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if depth == 0 and ch == sep:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    out.append("".join(cur).strip())
    return [x for x in out if x]


def _literal(tok: str):
    tok = tok.strip().strip("'")
    try:
        return int(tok)
    except ValueError:
        try:
            return float(tok)
        except ValueError:
            return tok


def parse_sql(sql: str, schema: Schema) -> AggQuery:
    """Parse the supported fragment into an AggQuery (natural-join form:
    equi-joined columns are renamed to shared variables)."""
    s = re.sub(r"\s+", " ", sql.strip().rstrip(";"))
    m = re.match(r"select (.*?) from (.*?)(?: where (.*?))?"
                 r"(?: group by (.*?))?$", s, re.I)
    if not m:
        raise SqlError(f"unparsable query: {sql!r}")
    sel_s, from_s, where_s, group_s = m.groups()

    # FROM: aliases
    alias2rel: dict[str, str] = {}
    for part in _split_top(from_s, ","):
        toks = part.split()
        if len(toks) == 1:
            alias2rel[toks[0]] = toks[0]
        elif len(toks) == 2:
            alias2rel[toks[1]] = toks[0]
        else:
            raise SqlError(f"bad FROM item: {part!r}")
    for rel in alias2rel.values():
        if rel not in schema.relations:
            raise SqlError(f"unknown relation {rel!r}")

    # variable names: start as alias.col, merged by equi-joins (union-find)
    var: dict[tuple[str, str], str] = {}
    parent: dict[str, str] = {}

    def find(x: str) -> str:
        while parent.get(x, x) != x:
            x = parent[x]
        return x

    def union(a: str, b: str):
        parent[find(a)] = find(b)

    def var_of(alias: str, col: str) -> str:
        if alias not in alias2rel:
            raise SqlError(f"unknown alias {alias!r}")
        if col not in schema.relations[alias2rel[alias]].column_names():
            raise SqlError(f"unknown column {alias}.{col}")
        return var.setdefault((alias, col), f"{alias}.{col}")

    selections: dict[str, list] = {}
    if where_s:
        for cond in re.split(r"\s+and\s+", where_s, flags=re.I):
            cond = cond.strip()
            if (jm := _JOIN_RE.match(cond)):
                a, ca, b, cb = jm.groups()
                union(var_of(a, ca), var_of(b, cb))
            elif (im := _IN_RE.match(cond)):
                a, col, vals = im.groups()
                values = tuple(_literal(v) for v in vals.split(","))
                var_of(a, col)
                selections.setdefault(a, []).append(
                    ("in", col, values))
            elif (sm := _SEL_RE.match(cond)):
                a, col, op, lit = sm.groups()
                if (lm := re.match(r"^(\w+)\.(\w+)$", lit)) \
                        and lm.group(1) in alias2rel:
                    raise SqlError(
                        f"non-equi join term {cond!r}: only equi-joins "
                        "between relations are supported (θ-joins fall "
                        "outside the paper's fragment)")
                var_of(a, col)
                selections.setdefault(a, []).append(
                    (op, col, _literal(lit)))
            else:
                raise SqlError(f"unsupported WHERE term: {cond!r}")

    # atoms with canonical (union-find root) variable names
    atoms = []
    for alias, rel in alias2rel.items():
        vars_ = tuple(
            find(var.get((alias, c), f"{alias}.{c}"))
            for c in schema.relations[rel].column_names())
        atoms.append(Atom(rel, alias, vars_))

    # selections → predicate closures over schema column names, plus the
    # declarative specs the serving tier fingerprints (see query.py)
    sel_fns = {}
    sel_specs = {}
    for alias, conds in selections.items():
        sel_specs[alias] = tuple(conds)
        sel_fns[alias] = selection_from_spec(conds)

    # aggregates
    aggs = []
    for am in _AGG_RE.finditer(sel_s):
        func, distinct, arg, name = am.groups()
        if arg == "*":
            v = None
        else:
            if "." not in arg:
                raise SqlError(f"qualify the column: {arg!r}")
            a, c = arg.split(".", 1)
            v = find(var_of(a, c))
        aggs.append(Agg(func.lower(), v, distinct=bool(distinct),
                        name=(name or "").strip() or
                        f"{func.lower()}({'distinct ' if distinct else ''}"
                        f"{arg})"))
    if not aggs:
        raise SqlError("no aggregate in SELECT (the engine targets "
                       "aggregate queries)")

    group_by = ()
    if group_s:
        gs = []
        for g in group_s.split(","):
            a, c = g.strip().split(".", 1)
            gs.append(find(var_of(a, c)))
        group_by = tuple(gs)

    return AggQuery(atoms=tuple(atoms), aggregates=tuple(aggs),
                    group_by=group_by, selections=sel_fns,
                    selection_specs=sel_specs)
