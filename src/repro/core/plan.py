"""Physical plan representation: an op-graph IR.

A plan is a DAG of ``PlanNode``s, each wrapping one physical op and naming
its input nodes explicitly.  Four plan classes mirror the paper's
experimental conditions:

  ref       — materialising left-deep joins, aggregate at the end
              (baseline; what a standard engine does)
  opt       — §4.2 logical rewrite: materialise each parent⋈child pair but
              immediately re-group to the parent's attrs, SUM(c_p·c_c)
  opt_plus  — §5: the FreqJoin physical operator, zero join materialisation
  oma       — §4.1: semi-joins only (requires the 0MA conditions)

The FK/PK flag (§4.3) downgrades FreqJoins to semi-joins where sound and
skips useless pre-grouping on unique keys.

Every node has a content-addressed ``key()``: a structural hash of its
whole sub-DAG (relations, selection specs, join columns — never aliases or
variable names, which canonicalisation assigns role-sensitively).  Two
nodes with equal keys — possibly from *different* plans — compute identical
frequency vectors over the same database.  That is the unit of sharing the
multi-query executor exploits: any common sub-DAG (a shared filtered
dimension scan, a shared semi-join chain) is computed once even when the
enclosing join shapes differ, which is how partial fusion across different
join shapes works (cf. structure-guided evaluation over decompositions).

``PhysicalPlan.ops`` is a derived topological linearisation kept for the
linear alias-state interpreters (the distributed engine, reference
semantics in tests): each op payload names its aliases, and any topological
order of the DAG replays correctly through a ``state[alias]`` sweep.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable

from repro.core.hypergraph import JoinTree
from repro.core.query import Agg, Atom, selection_from_spec


# ---------------------------------------------------------------------------
# Op payloads (the per-node physical operator descriptions)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScanOp:
    """``spec`` carries the declarative form of ``selection`` (the query's
    ``selection_specs`` entry) when one exists; node keys use it so
    structurally-equal selections from *different* query objects unify.
    Opaque selections key on callable identity instead."""

    alias: str
    rel: str
    selection: Callable | None
    spec: tuple | None = None


@dataclasses.dataclass(frozen=True)
class SemiJoinOp:
    """parent.freq ← parent.freq · [∃ live child match]  (0MA / FK-PK)."""

    parent: str
    child: str
    on_vars: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class FreqJoinOp:
    """parent.freq ← parent.freq · Σ matching child.freq  (paper §5)."""

    parent: str
    child: str
    on_vars: tuple[str, ...]
    pregroup: bool  # §4.3: group child to distinct keys first


@dataclasses.dataclass(frozen=True)
class MaterializeJoinOp:
    """parent ← parent ⋈ child (row expansion).  In `opt` mode the executor
    groups straight back to the parent attrs (SUM of freq products); in
    `ref` mode the expanded rows are kept (standard engine behaviour)."""

    parent: str
    child: str
    on_vars: tuple[str, ...]
    regroup: bool  # True in `opt` mode


@dataclasses.dataclass(frozen=True)
class FinalAggOp:
    root: str
    group_by: tuple[str, ...]
    aggregates: tuple[Agg, ...]
    dedup: bool  # oma mode: aggregate over live rows (set semantics)


PlanOp = ScanOp | SemiJoinOp | FreqJoinOp | MaterializeJoinOp | FinalAggOp


# ---------------------------------------------------------------------------
# The op-graph IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class PlanNode:
    """One op in the plan DAG.

    ``inputs`` are the nodes whose produced states this op consumes — for
    join ops ``(parent_state, child_state)``, for scans ``()``, for the
    final aggregate ``(root_state,)``.  ``struct`` is the alias/var-blind
    structural descriptor of THIS op alone (``None`` marks ops whose result
    is never shareable, e.g. materialising joins with dynamic shapes);
    ``key()`` combines it with the input keys into the content address of
    the whole sub-DAG.
    """

    op: PlanOp
    inputs: tuple["PlanNode", ...]
    struct: tuple | None

    def key(self) -> tuple | None:
        """Content address of this node's sub-DAG: equal keys ⇒ identical
        frequency vectors over the same database.  ``None`` propagates
        upward from any unshareable (opaque / materialising) input."""
        cached = self.__dict__.get("_key", False)
        if cached is not False:
            return cached
        if self.struct is None:
            key = None
        else:
            in_keys = tuple(i.key() for i in self.inputs)
            key = None if any(k is None for k in in_keys) \
                else (self.struct, in_keys)
        self.__dict__["_key"] = key  # frozen dataclass: cache via __dict__
        return key

    def postorder(self) -> list["PlanNode"]:
        """Topological (inputs-first, left-to-right, deduplicated) order of
        this node's sub-DAG, this node last."""
        out: list[PlanNode] = []
        seen: set[int] = set()

        def rec(n: "PlanNode"):
            if id(n) in seen:
                return
            seen.add(id(n))
            for i in n.inputs:
                rec(i)
            out.append(n)

        rec(self)
        return out


def rewrite_dag(root: PlanNode,
                fn: Callable[[PlanNode, tuple[PlanNode, ...]], PlanNode],
                ) -> PlanNode:
    """Bottom-up structural rewrite: ``fn(node, rebuilt_inputs)`` returns
    the replacement node.  Shared sub-DAGs are rewritten once (memoised by
    object identity), so sharing is preserved."""
    memo: dict[int, PlanNode] = {}

    def rec(n: PlanNode) -> PlanNode:
        r = memo.get(id(n))
        if r is None:
            ins = tuple(rec(i) for i in n.inputs)
            memo[id(n)] = r = fn(n, ins)
        return r

    return rec(root)


def _digest(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()


def _short_key(node: PlanNode) -> str:
    k = node.key()
    return "-" if k is None else _digest(k)[:10]


@dataclasses.dataclass(frozen=True)
class Decision:
    """One gated planner decision: which pass, on what, applied or skipped,
    why, and the stat values the gate read.

    ``depends`` maps relation → data-version token (``Table.content_token``)
    for every table whose statistics the gate consulted: a consumer (the
    serving tier's plan cache) declares a persisted decision *stale* —
    and replans — exactly when one of those tokens no longer matches the
    live catalog.  Purely JSON-able so the trace survives the plan store."""

    pass_name: str
    target: str               # alias / edge / "" for whole-plan decisions
    applied: bool
    reason: str
    stats: tuple = ()         # sorted (name, value) pairs the gate read
    depends: tuple = ()       # sorted (relation, token) pairs

    def to_payload(self) -> dict:
        return {"pass": self.pass_name, "target": self.target,
                "applied": self.applied, "reason": self.reason,
                "stats": [list(kv) for kv in self.stats],
                "depends": [list(kv) for kv in self.depends]}

    @classmethod
    def from_payload(cls, p: dict) -> "Decision":
        return cls(pass_name=p["pass"], target=p["target"],
                   applied=bool(p["applied"]), reason=p["reason"],
                   stats=tuple(tuple(kv) for kv in p["stats"]),
                   depends=tuple(tuple(kv) for kv in p["depends"]))

    def describe(self) -> str:
        verdict = "applied" if self.applied else "skipped"
        vals = " ".join(f"{k}={v}" for k, v in self.stats)
        tgt = f" @{self.target}" if self.target else ""
        line = f"{self.pass_name}{tgt}: {verdict} — {self.reason}"
        return f"{line} [{vals}]" if vals else line


@dataclasses.dataclass(frozen=True, eq=False)
class PhysicalPlan:
    """A rooted op DAG.  ``root`` is the FinalAgg node; ``tree`` and
    ``var_cols`` carry the query context the executor needs to resolve
    variables to schema columns and key domains.

    ``decisions`` is the planner's machine-readable decision trace (one
    :class:`Decision` per gated transform considered).  It is deliberately
    EXCLUDED from ``cache_key``: a decision only matters to plan identity
    when it changed the emitted graph, and then the op DAG itself already
    differs — two structurally identical plans are interchangeable no
    matter what the planner pondered on the way."""

    mode: str
    root: PlanNode
    tree: JoinTree
    var_cols: dict[str, dict[str, str]]  # alias → {var → schema column}
    decisions: tuple = ()                # tuple[Decision, ...]

    @property
    def nodes(self) -> tuple[PlanNode, ...]:
        """Deterministic topological order of the whole DAG (root last)."""
        cached = self.__dict__.get("_nodes")
        if cached is None:
            cached = tuple(self.root.postorder())
            self.__dict__["_nodes"] = cached
        return cached

    @property
    def ops(self) -> tuple[PlanOp, ...]:
        """Linear op-payload view (a valid topological replay order for
        alias-state interpreters; see module docstring)."""
        return tuple(n.op for n in self.nodes)

    def cache_key(self) -> tuple:
        """Structural identity for plan caching.  Op payload tuples hash by
        field values; ``ScanOp.selection`` callables hash by object
        identity, which is exactly right — two plans sharing a selection
        object are interchangeable, two plans with distinct closures are
        only unified upstream by the query fingerprint (which compares
        declarative selection specs, not closures)."""
        return (self.mode, self.ops, self.tree.cache_key(),
                tuple(sorted((a, tuple(sorted(m.items())))
                             for a, m in self.var_cols.items())))

    def __eq__(self, other):
        return (isinstance(other, PhysicalPlan)
                and self.cache_key() == other.cache_key())

    def __hash__(self):
        return hash(self.cache_key())

    def scanned_rels(self) -> tuple[str, ...]:
        """Relations this plan reads, sorted — the serving tier passes only
        these to the jitted executable so unrelated tables can't force a
        retrace."""
        return tuple(sorted({n.op.rel for n in self.nodes
                             if isinstance(n.op, ScanOp)}))

    def graph_key(self) -> str | None:
        """Content address of the ENTIRE plan DAG (aggregates included) —
        what the serving tier hashes into a fused program's cache identity.
        ``None`` when any node is unshareable (opaque selections,
        materialising joins)."""
        k = self.root.key()
        return None if k is None else _digest((self.mode, k))

    def subplan_keys(self) -> frozenset:
        """Content keys of this plan's *non-trivial* shareable subplans:
        join nodes and selection-carrying scans.  (A bare scan is just a
        table read — sharing it saves nothing, so it does not make two
        plans worth fusing.)  Two plans whose key sets intersect can be
        compiled into one program that computes each shared sub-DAG once.
        Materialising plans are never jittable, hence never fusable:
        empty."""
        out = set()
        if any(isinstance(n.op, MaterializeJoinOp) for n in self.nodes):
            return frozenset()
        for n in self.nodes:
            k = n.key()
            if k is None:
                continue
            op = n.op
            if isinstance(op, (SemiJoinOp, FreqJoinOp)) or (
                    isinstance(op, ScanOp)
                    and (op.selection is not None or op.spec is not None)):
                out.add(k)
        return frozenset(out)

    def describe(self) -> str:
        """Render the DAG, one node per line, with input edges and short
        content keys — the inspection surface for fusion decisions: two
        plans fuse exactly when they print a common non-trivial key."""
        lines = [f"plan[{self.mode}] root={self.tree.root}"]
        ids = {id(n): i for i, n in enumerate(self.nodes)}
        for i, n in enumerate(self.nodes):
            ins = ", ".join(f"%{ids[id(x)]}" for x in n.inputs)
            ins = f"({ins}) " if ins else ""
            lines.append(f"  %{i} = {n.op!r} {ins}key={_short_key(n)}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Node builders (compute the structural descriptor for each op kind)
# ---------------------------------------------------------------------------


def make_scan_node(op: ScanOp, atom) -> PlanNode:
    # repeated variables inside one atom change which column a variable
    # resolves to downstream; capture the equality pattern positionally
    pattern = tuple(atom.vars.index(v) for v in atom.vars)
    if op.selection is not None and op.spec is None:
        sel: object = ("<opaque>", id(op.selection))
    else:
        sel = op.spec
    return PlanNode(op, (), ("scan", op.rel, pattern, sel))


def make_join_node(op: SemiJoinOp | FreqJoinOp, parent: PlanNode,
                   child: PlanNode,
                   var_cols: dict[str, dict[str, str]]) -> PlanNode:
    pcols = tuple(var_cols[op.parent][v] for v in op.on_vars)
    ccols = tuple(var_cols[op.child][v] for v in op.on_vars)
    tag = ("semi",) if isinstance(op, SemiJoinOp) else ("freq", op.pregroup)
    return PlanNode(op, (parent, child), (tag, pcols, ccols))


def make_materialize_node(op: MaterializeJoinOp, parent: PlanNode,
                          child: PlanNode) -> PlanNode:
    # dynamic output shapes: never shareable, poisons downstream keys
    return PlanNode(op, (parent, child), None)


def make_final_agg_node(op: FinalAggOp, root_state: PlanNode,
                        root_atom) -> PlanNode:
    """``root_atom`` is the join-tree atom of ``op.root`` (None when the
    root state is a materialised join result spanning several atoms).

    The struct must pin BOTH the variable names (the executed program's
    output dict is keyed by them — two plans may only share a compiled
    program if their outputs rename identically) AND the root-atom column
    *positions* each variable binds (names alone are role-coloured labels:
    SUM over s_suppkey and SUM over s_nationkey would otherwise collide).
    Any output variable we cannot position structurally makes the node
    unshareable rather than ambiguously keyed."""

    def pos(var: str | None):
        if var is None:
            return None
        if root_atom is None or var not in root_atom.vars:
            raise LookupError
        return root_atom.vars.index(var)

    try:
        aggs = tuple((a.func, a.var, pos(a.var), a.distinct, a.name)
                     for a in op.aggregates)
        groups = tuple((g, pos(g)) for g in op.group_by)
        struct = ("agg", groups, aggs, op.dedup)
    except LookupError:
        struct = None
    return PlanNode(op, (root_state,), struct)


# ---------------------------------------------------------------------------
# Plan segmentation (cross-fingerprint fusion support)
# ---------------------------------------------------------------------------
#
# A zero-materialisation plan is `prefix ; suffix`: the prefix (scans +
# semi-join/FreqJoin sweep) computes the root relation's frequency vector,
# the suffix (FinalAggOp) folds it into answers.  ``prefix_key`` is the
# WHOLE-prefix identity (PR 2's fusion condition, still reported so the
# serving tier can distinguish whole-prefix fusion from the strictly more
# general subplan-overlap fusion that ``subplan_keys`` drives).


@dataclasses.dataclass(frozen=True)
class PlanSegments:
    """A plan split at the aggregate boundary.

    ``prefix_key`` is the structural identity of the root frequency vector
    the prefix computes: two plans with equal keys (and equal shape
    buckets) share their *entire* prefix.  ``None`` marks plans with no
    shareable prefix (materialising ops, whose dataflow is dynamic and
    never jitted anyway).
    """

    prefix_ops: tuple[PlanOp, ...]
    suffix_ops: tuple[PlanOp, ...]
    prefix_key: str | None


def op_result_keys(plan: "PhysicalPlan") -> list[tuple | None]:
    """Per-node structural keys for the frequency vector each op produces,
    aligned with ``plan.ops`` (``None`` for ops that produce none / are
    never shared).  Two ops with equal keys — possibly from different
    plans — compute identical vectors over the same database, which is what
    lets ``Executor.compile_multi`` deduplicate shared work across member
    plans."""
    return [n.key() if isinstance(n.op, (ScanOp, SemiJoinOp, FreqJoinOp))
            else None for n in plan.nodes]


def segment_plan(plan: "PhysicalPlan") -> PlanSegments:
    """Split `plan` into (shareable prefix, per-query suffix)."""
    prefix = tuple(op for op in plan.ops if not isinstance(op, FinalAggOp))
    suffix = tuple(op for op in plan.ops if isinstance(op, FinalAggOp))
    prefix_key: str | None = None
    if not any(isinstance(op, MaterializeJoinOp) for op in plan.ops):
        root_key = plan.root.inputs[0].key()
        if root_key is not None:
            prefix_key = _digest(root_key)
    return PlanSegments(prefix, suffix, prefix_key)


# ---------------------------------------------------------------------------
# Stable plan serialisation (cross-process plan-cache persistence)
# ---------------------------------------------------------------------------
#
# A payload is plain JSON-able data: the DAG as a topologically ordered node
# list with integer input edges, plus the query context (join tree, alias →
# var → column maps).  Deserialisation re-runs the SAME node builders the
# planner uses (``make_scan_node`` & co.), so every structural descriptor —
# and therefore ``key()``, ``graph_key()`` and ``subplan_keys()`` — is
# recomputed rather than trusted from disk: a reloaded plan is
# content-identical to one freshly planned, which is what lets a warm
# process fuse it against live plans.
#
# The one thing a payload cannot carry is an opaque selection callable;
# plans whose scans attach a selection without a declarative ``spec`` raise
# ``PlanNotSerialisable`` (their fingerprints are process-salted singletons
# anyway, so persisting them would be meaningless).  Spec-carrying
# selections are rebuilt from the spec via ``selection_from_spec`` — the
# same builder the SQL front-end uses — so reloaded scans select
# bitwise-identically.


class PlanNotSerialisable(ValueError):
    """The plan carries state that cannot survive a process boundary
    (an opaque selection callable without a declarative spec)."""


def _spec_to_jsonable(spec: tuple | None):
    if spec is None:
        return None
    return [[op, col, list(val) if op == "in" else val]
            for op, col, val in spec]


def _spec_from_jsonable(spec) -> tuple | None:
    if spec is None:
        return None
    return tuple((op, col, tuple(val) if op == "in" else val)
                 for op, col, val in spec)


def plan_to_payload(plan: "PhysicalPlan") -> dict:
    """Serialise a plan into a JSON-able payload (see section comment).

    Raises ``PlanNotSerialisable`` for plans with opaque selections."""
    nodes = plan.nodes
    index = {id(n): i for i, n in enumerate(nodes)}
    entries = []
    for n in nodes:
        op = n.op
        e: dict = {"inputs": [index[id(i)] for i in n.inputs]}
        if isinstance(op, ScanOp):
            if op.selection is not None and op.spec is None:
                raise PlanNotSerialisable(
                    f"scan of {op.rel!r} (alias {op.alias!r}) attaches an "
                    "opaque selection callable with no declarative spec; "
                    "it cannot be rebuilt in another process")
            e.update(kind="scan", alias=op.alias, rel=op.rel,
                     spec=_spec_to_jsonable(op.spec))
        elif isinstance(op, SemiJoinOp):
            e.update(kind="semi", parent=op.parent, child=op.child,
                     on_vars=list(op.on_vars))
        elif isinstance(op, FreqJoinOp):
            e.update(kind="freq", parent=op.parent, child=op.child,
                     on_vars=list(op.on_vars), pregroup=op.pregroup)
        elif isinstance(op, MaterializeJoinOp):
            e.update(kind="mat", parent=op.parent, child=op.child,
                     on_vars=list(op.on_vars), regroup=op.regroup)
        elif isinstance(op, FinalAggOp):
            e.update(kind="agg", root=op.root, group_by=list(op.group_by),
                     dedup=op.dedup,
                     aggregates=[{"func": a.func, "var": a.var,
                                  "distinct": a.distinct, "name": a.name}
                                 for a in op.aggregates])
        else:  # pragma: no cover
            raise PlanNotSerialisable(f"unknown op {op!r}")
        entries.append(e)
    tree = plan.tree
    return {
        "mode": plan.mode,
        "root": index[id(plan.root)],
        "nodes": entries,
        "tree": {
            "root": tree.root,
            "parent": dict(tree.parent),
            "atoms": {alias: {"rel": a.rel, "vars": list(a.vars)}
                      for alias, a in tree.atoms.items()},
        },
        "var_cols": {alias: dict(m) for alias, m in plan.var_cols.items()},
        "decisions": [d.to_payload() for d in plan.decisions],
    }


def plan_from_payload(payload: dict) -> "PhysicalPlan":
    """Rebuild a ``PhysicalPlan`` from ``plan_to_payload`` output.

    Node structural descriptors (hence content keys) are recomputed by the
    planner's own builders, never read from the payload."""
    tdoc = payload["tree"]
    atoms = {alias: Atom(a["rel"], alias, tuple(a["vars"]))
             for alias, a in tdoc["atoms"].items()}
    tree = JoinTree(tdoc["root"],
                    {alias: p for alias, p in tdoc["parent"].items()},
                    atoms)
    var_cols = {alias: dict(m) for alias, m in payload["var_cols"].items()}

    nodes: list[PlanNode] = []
    for e in payload["nodes"]:
        ins = tuple(nodes[i] for i in e["inputs"])
        kind = e["kind"]
        if kind == "scan":
            spec = _spec_from_jsonable(e["spec"])
            sel = selection_from_spec(spec) if spec is not None else None
            op = ScanOp(e["alias"], e["rel"], sel, spec)
            nodes.append(make_scan_node(op, atoms[e["alias"]]))
        elif kind == "semi":
            op = SemiJoinOp(e["parent"], e["child"], tuple(e["on_vars"]))
            nodes.append(make_join_node(op, ins[0], ins[1], var_cols))
        elif kind == "freq":
            op = FreqJoinOp(e["parent"], e["child"], tuple(e["on_vars"]),
                            e["pregroup"])
            nodes.append(make_join_node(op, ins[0], ins[1], var_cols))
        elif kind == "mat":
            op = MaterializeJoinOp(e["parent"], e["child"],
                                   tuple(e["on_vars"]), e["regroup"])
            nodes.append(make_materialize_node(op, ins[0], ins[1]))
        elif kind == "agg":
            op = FinalAggOp(
                e["root"], tuple(e["group_by"]),
                tuple(Agg(a["func"], a["var"], distinct=a["distinct"],
                          name=a["name"]) for a in e["aggregates"]),
                e["dedup"])
            nodes.append(make_final_agg_node(op, ins[0],
                                             atoms.get(e["root"])))
        else:
            raise ValueError(f"unknown node kind {kind!r}")
    decisions = tuple(Decision.from_payload(d)
                      for d in payload.get("decisions", ()))
    return PhysicalPlan(payload["mode"], nodes[payload["root"]], tree,
                        var_cols, decisions=decisions)
