"""Physical plan representation.

A plan is a linear op sequence over named intermediate states (one state per
atom alias), derived from a bottom-up join-tree traversal.  Four plan
classes mirror the paper's experimental conditions:

  ref       — materialising left-deep joins, aggregate at the end
              (baseline; what a standard engine does)
  opt       — §4.2 logical rewrite: materialise each parent⋈child pair but
              immediately re-group to the parent's attrs, SUM(c_p·c_c)
  opt_plus  — §5: the FreqJoin physical operator, zero join materialisation
  oma       — §4.1: semi-joins only (requires the 0MA conditions)

The FK/PK flag (§4.3) downgrades FreqJoins to semi-joins where sound and
skips useless pre-grouping on unique keys.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.hypergraph import JoinTree
from repro.core.query import Agg


@dataclasses.dataclass(frozen=True)
class ScanOp:
    alias: str
    rel: str
    selection: Callable | None


@dataclasses.dataclass(frozen=True)
class SemiJoinOp:
    """parent.freq ← parent.freq · [∃ live child match]  (0MA / FK-PK)."""

    parent: str
    child: str
    on_vars: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class FreqJoinOp:
    """parent.freq ← parent.freq · Σ matching child.freq  (paper §5)."""

    parent: str
    child: str
    on_vars: tuple[str, ...]
    pregroup: bool  # §4.3: group child to distinct keys first


@dataclasses.dataclass(frozen=True)
class MaterializeJoinOp:
    """parent ← parent ⋈ child (row expansion).  In `opt` mode the executor
    groups straight back to the parent attrs (SUM of freq products); in
    `ref` mode the expanded rows are kept (standard engine behaviour)."""

    parent: str
    child: str
    on_vars: tuple[str, ...]
    regroup: bool  # True in `opt` mode


@dataclasses.dataclass(frozen=True)
class FinalAggOp:
    root: str
    group_by: tuple[str, ...]
    aggregates: tuple[Agg, ...]
    dedup: bool  # oma mode: aggregate over live rows (set semantics)


PlanOp = ScanOp | SemiJoinOp | FreqJoinOp | MaterializeJoinOp | FinalAggOp


@dataclasses.dataclass(frozen=True, eq=False)
class PhysicalPlan:
    mode: str
    ops: tuple[PlanOp, ...]
    tree: JoinTree
    var_cols: dict[str, dict[str, str]]  # alias → {var → schema column}

    def cache_key(self) -> tuple:
        """Structural identity for plan caching.  Op tuples hash by field
        values; ``ScanOp.selection`` callables hash by object identity,
        which is exactly right — two plans sharing a selection object are
        interchangeable, two plans with distinct closures are only unified
        upstream by the query fingerprint (which compares declarative
        selection specs, not closures)."""
        return (self.mode, self.ops, self.tree.cache_key(),
                tuple(sorted((a, tuple(sorted(m.items())))
                             for a, m in self.var_cols.items())))

    def __eq__(self, other):
        return (isinstance(other, PhysicalPlan)
                and self.cache_key() == other.cache_key())

    def __hash__(self):
        return hash(self.cache_key())

    def scanned_rels(self) -> tuple[str, ...]:
        """Relations this plan reads, sorted — the serving tier passes only
        these to the jitted executable so unrelated tables can't force a
        retrace."""
        return tuple(sorted({op.rel for op in self.ops
                             if isinstance(op, ScanOp)}))

    def describe(self) -> str:
        lines = [f"plan[{self.mode}] root={self.tree.root}"]
        for op in self.ops:
            lines.append(f"  {op}")
        return "\n".join(lines)
