"""Physical plan representation.

A plan is a linear op sequence over named intermediate states (one state per
atom alias), derived from a bottom-up join-tree traversal.  Four plan
classes mirror the paper's experimental conditions:

  ref       — materialising left-deep joins, aggregate at the end
              (baseline; what a standard engine does)
  opt       — §4.2 logical rewrite: materialise each parent⋈child pair but
              immediately re-group to the parent's attrs, SUM(c_p·c_c)
  opt_plus  — §5: the FreqJoin physical operator, zero join materialisation
  oma       — §4.1: semi-joins only (requires the 0MA conditions)

The FK/PK flag (§4.3) downgrades FreqJoins to semi-joins where sound and
skips useless pre-grouping on unique keys.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable

from repro.core.hypergraph import JoinTree
from repro.core.query import Agg


@dataclasses.dataclass(frozen=True)
class ScanOp:
    """``spec`` carries the declarative form of ``selection`` (the query's
    ``selection_specs`` entry) when one exists; the segmentation pass keys
    scans on it so structurally-equal selections from *different* query
    objects unify.  Opaque selections key on callable identity instead."""

    alias: str
    rel: str
    selection: Callable | None
    spec: tuple | None = None


@dataclasses.dataclass(frozen=True)
class SemiJoinOp:
    """parent.freq ← parent.freq · [∃ live child match]  (0MA / FK-PK)."""

    parent: str
    child: str
    on_vars: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class FreqJoinOp:
    """parent.freq ← parent.freq · Σ matching child.freq  (paper §5)."""

    parent: str
    child: str
    on_vars: tuple[str, ...]
    pregroup: bool  # §4.3: group child to distinct keys first


@dataclasses.dataclass(frozen=True)
class MaterializeJoinOp:
    """parent ← parent ⋈ child (row expansion).  In `opt` mode the executor
    groups straight back to the parent attrs (SUM of freq products); in
    `ref` mode the expanded rows are kept (standard engine behaviour)."""

    parent: str
    child: str
    on_vars: tuple[str, ...]
    regroup: bool  # True in `opt` mode


@dataclasses.dataclass(frozen=True)
class FinalAggOp:
    root: str
    group_by: tuple[str, ...]
    aggregates: tuple[Agg, ...]
    dedup: bool  # oma mode: aggregate over live rows (set semantics)


PlanOp = ScanOp | SemiJoinOp | FreqJoinOp | MaterializeJoinOp | FinalAggOp


@dataclasses.dataclass(frozen=True, eq=False)
class PhysicalPlan:
    mode: str
    ops: tuple[PlanOp, ...]
    tree: JoinTree
    var_cols: dict[str, dict[str, str]]  # alias → {var → schema column}

    def cache_key(self) -> tuple:
        """Structural identity for plan caching.  Op tuples hash by field
        values; ``ScanOp.selection`` callables hash by object identity,
        which is exactly right — two plans sharing a selection object are
        interchangeable, two plans with distinct closures are only unified
        upstream by the query fingerprint (which compares declarative
        selection specs, not closures)."""
        return (self.mode, self.ops, self.tree.cache_key(),
                tuple(sorted((a, tuple(sorted(m.items())))
                             for a, m in self.var_cols.items())))

    def __eq__(self, other):
        return (isinstance(other, PhysicalPlan)
                and self.cache_key() == other.cache_key())

    def __hash__(self):
        return hash(self.cache_key())

    def scanned_rels(self) -> tuple[str, ...]:
        """Relations this plan reads, sorted — the serving tier passes only
        these to the jitted executable so unrelated tables can't force a
        retrace."""
        return tuple(sorted({op.rel for op in self.ops
                             if isinstance(op, ScanOp)}))

    def describe(self) -> str:
        lines = [f"plan[{self.mode}] root={self.tree.root}"]
        for op in self.ops:
            lines.append(f"  {op}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Plan segmentation (cross-fingerprint fusion support)
# ---------------------------------------------------------------------------
#
# A zero-materialisation plan is `prefix ; suffix`: the prefix (scans +
# semi-join/FreqJoin sweep) computes the root relation's frequency vector,
# the suffix (FinalAggOp) folds it into answers.  The prefix depends only on
# the join structure and selections — NOT on which aggregates the query
# asks for — so two different fingerprints often share it verbatim.  The
# keys below name each op's produced frequency vector structurally
# (relations, selection specs, join columns — never aliases or variable
# names, which canonicalisation assigns role-sensitively), so isomorphic
# prefixes from different queries map to equal keys and a multi-query
# executor can compute each distinct vector once.


@dataclasses.dataclass(frozen=True)
class PlanSegments:
    """A plan split at the aggregate boundary.

    ``prefix_key`` is the structural identity of the root frequency vector
    the prefix computes: two plans with equal keys (and equal shape
    buckets) can be fused into one XLA program that runs the prefix once.
    ``None`` marks plans with no shareable prefix (materialising ops, whose
    dataflow is dynamic and never jitted anyway).
    """

    prefix_ops: tuple[PlanOp, ...]
    suffix_ops: tuple[PlanOp, ...]
    prefix_key: str | None


def _scan_key(plan: "PhysicalPlan", op: ScanOp) -> tuple:
    atom = plan.tree.atoms[op.alias]
    # repeated variables inside one atom change which column a variable
    # resolves to downstream; capture the equality pattern positionally
    pattern = tuple(atom.vars.index(v) for v in atom.vars)
    if op.selection is not None and op.spec is None:
        sel: object = ("<opaque>", id(op.selection))
    else:
        sel = op.spec
    return ("scan", op.rel, pattern, sel)


def _thread_keys(plan: "PhysicalPlan"):
    """Walk the op sequence once, threading each alias's current frequency
    key.  Returns (per-op produced key, final alias → key map) — the single
    source of the chain rule both ``op_result_keys`` and ``segment_plan``
    consume, so they cannot drift when a new PlanOp type is added."""
    cur: dict[str, tuple | None] = {}
    out: list[tuple | None] = []
    for op in plan.ops:
        key: tuple | None = None
        if isinstance(op, ScanOp):
            key = _scan_key(plan, op)
            cur[op.alias] = key
        elif isinstance(op, (SemiJoinOp, FreqJoinOp)):
            pk, ck = cur.get(op.parent), cur.get(op.child)
            if pk is not None and ck is not None:
                pcols = tuple(plan.var_cols[op.parent][v] for v in op.on_vars)
                ccols = tuple(plan.var_cols[op.child][v] for v in op.on_vars)
                tag = ("semi",) if isinstance(op, SemiJoinOp) \
                    else ("freq", op.pregroup)
                key = (tag, pk, ck, pcols, ccols)
            cur[op.parent] = key
        elif isinstance(op, MaterializeJoinOp):
            cur[op.parent] = None  # dynamic shapes: poison the chain
        out.append(key)
    return out, cur


def op_result_keys(plan: "PhysicalPlan") -> list[tuple | None]:
    """Per-op structural keys for the frequency vector each op produces
    (``None`` for ops that produce none / are never shared).  Two ops with
    equal keys — possibly from different plans — compute identical vectors
    over the same database, which is what lets ``Executor.compile_multi``
    deduplicate shared work across member plans."""
    return _thread_keys(plan)[0]


def segment_plan(plan: "PhysicalPlan") -> PlanSegments:
    """Split `plan` into (shareable prefix, per-query suffix)."""
    prefix = tuple(op for op in plan.ops if not isinstance(op, FinalAggOp))
    suffix = tuple(op for op in plan.ops if isinstance(op, FinalAggOp))
    prefix_key: str | None = None
    if not any(isinstance(op, MaterializeJoinOp) for op in plan.ops):
        root_key = _thread_keys(plan)[1].get(plan.tree.root)
        if root_key is not None:
            prefix_key = hashlib.sha256(repr(root_key).encode()).hexdigest()
    return PlanSegments(prefix, suffix, prefix_key)
