"""Acyclicity check + join-tree construction via GYO reduction (paper §3).

The GYO (Graham / Yu–Özsoyoğlu) reduction repeatedly removes *ears*: an atom
A is an ear if every variable of A that also occurs elsewhere is covered by
a single other atom W (the witness).  Removing ears until one atom remains
certifies α-acyclicity, and the removal order yields a join tree (A hangs
under its witness).  Linear-time in query size for our purposes (queries are
tiny next to data).

``JoinTree`` supports re-rooting (the 0MA/guarded rewrites root the tree at
the guard, paper §4.1) and pre/post-order traversals.
"""

from __future__ import annotations

import dataclasses

from repro.core.query import Atom


@dataclasses.dataclass(eq=False)
class JoinTree:
    """Rooted join tree over atom aliases.

    Hashable/comparable by structural content (root, edge set, atoms) so
    plans embedding a tree can serve as cache keys in the serving tier.
    """

    root: str
    parent: dict[str, str | None]
    atoms: dict[str, Atom]

    def cache_key(self) -> tuple:
        return (self.root,
                tuple(sorted((a, p or "") for a, p in self.parent.items())),
                tuple(sorted(self.atoms.items())))

    def __eq__(self, other):
        return (isinstance(other, JoinTree)
                and self.cache_key() == other.cache_key())

    def __hash__(self):
        return hash(self.cache_key())

    def children(self, alias: str) -> list[str]:
        return sorted(a for a, p in self.parent.items() if p == alias)

    def postorder(self) -> list[str]:
        out: list[str] = []

        def rec(u: str):
            for c in self.children(u):
                rec(c)
            out.append(u)

        rec(self.root)
        return out

    def edges_bottom_up(self) -> list[tuple[str, str]]:
        """(parent, child) pairs in the order semi-joins/FreqJoins run:
        children fully processed before their parent consumes them."""
        out: list[tuple[str, str]] = []
        for u in self.postorder():
            p = self.parent[u]
            if p is not None:
                out.append((p, u))
        return out

    def shared_vars(self, u: str, v: str) -> tuple[str, ...]:
        su = set(self.atoms[u].vars)
        return tuple(x for x in self.atoms[v].vars if x in su)

    def rerooted(self, new_root: str) -> "JoinTree":
        """Reorient edges so `new_root` is the root (paper: the guard may be
        chosen as root because join trees are freely re-rootable)."""
        if new_root not in self.atoms:
            raise KeyError(new_root)
        adj: dict[str, set[str]] = {a: set() for a in self.atoms}
        for a, p in self.parent.items():
            if p is not None:
                adj[a].add(p)
                adj[p].add(a)
        parent: dict[str, str | None] = {new_root: None}
        stack = [new_root]
        seen = {new_root}
        while stack:
            u = stack.pop()
            for v in adj[u]:
                if v not in seen:
                    seen.add(v)
                    parent[v] = u
                    stack.append(v)
        return JoinTree(new_root, parent, dict(self.atoms))


def build_join_tree(atoms: tuple[Atom, ...]) -> JoinTree | None:
    """GYO reduction. Returns a join tree, or None if the CQ is cyclic."""
    if not atoms:
        raise ValueError("empty query")
    remaining = {a.alias: set(a.vars) for a in atoms}
    atom_map = {a.alias: a for a in atoms}
    parent: dict[str, str | None] = {}

    def occurs_elsewhere(alias: str, var: str) -> bool:
        return any(var in vs for al, vs in remaining.items() if al != alias)

    progress = True
    while len(remaining) > 1 and progress:
        progress = False
        for alias in sorted(remaining):
            core = {v for v in remaining[alias] if occurs_elsewhere(alias, v)}
            witness = None
            for other in sorted(remaining):
                if other != alias and core <= remaining[other]:
                    witness = other
                    break
            if witness is not None:
                parent[alias] = witness
                del remaining[alias]
                progress = True
                break
    if len(remaining) > 1:
        return None  # cyclic
    root = next(iter(remaining))
    parent[root] = None
    return JoinTree(root, parent, atom_map)
