"""Planner statistics: per-table/per-column stats, cost model, feedback.

This module is the single home for every cardinality/selectivity policy
constant the planner and serving tier consult (``scripts/lint.py``
enforces that threshold constants live here and nowhere else).  The shape
mirrors the decision cards in SNIPPETS.md: each rewrite/fusion decision is
a structural gate followed by a *calibration* against numbers kept here.

Three layers:

  * ``TableStats`` / ``ColumnStats`` — cheap per-relation summaries (live
    row counts, distinct estimates, min/max, FK orphan counts) computed
    once per table load/update from the numpy columns.  Each carries the
    table's content ``token`` so a consumer can tell exactly which data
    version a decision was calibrated against.
  * ``StatsCatalog`` — the live registry the planner reads: selectivity
    estimation for declarative selection specs, a padded-shape cost model
    for fusion admission, and decision-dependency validation (a recorded
    decision is stale iff a table it consulted changed token).
  * serve-time feedback — EWMA solo vs. fused serve times per
    (fingerprint, fusion-group signature); a fusion that consistently
    regresses a member vs. its solo baseline is *demoted* and the grouper
    stops forming it.

Grounded in Memory-Efficient Group-by Aggregates over Multi-Way Joins
(PAPERS.md, 1906.05745): statistics sized by the *relations*, never the
join.
"""

from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro.core.plan import (
    FinalAggOp,
    FreqJoinOp,
    MaterializeJoinOp,
    PhysicalPlan,
    ScanOp,
    SemiJoinOp,
)
from repro.tables.table import Schema, Table

STATS_VERSION = 1

# ---------------------------------------------------------------------------
# Policy constants (the ONLY allowed home for these — see scripts/lint.py).
# ---------------------------------------------------------------------------

#: FK-join elimination only fires on a verified-clean FK edge: the child
#: must have zero orphan references or dropping the join changes answers.
FK_ELIM_MAX_ORPHANS = 0

#: Pre-filter pushdown wants a genuinely selective dimension…
PREFILTER_MAX_SELECTIVITY = 0.25
#: …feeding a parent big enough that shrinking the materialised
#: intermediate is worth an extra semi-join (tiny tables: overhead wins).
PREFILTER_MIN_PARENT_ROWS = 64

#: Fusion admission: a plan never joins a fusion group whose maximum
#: estimated (padded-shape) cost is ≥ this multiple of its own.
FUSION_COST_DISPARITY = 8.0

#: Feedback demotion: a fusion is demoted for a member once observed at
#: least this many times fused AND its fused EWMA serve time exceeds the
#: solo baseline by this factor.
DEMOTION_MIN_OBSERVATIONS = 2
DEMOTION_REGRESSION_FACTOR = 1.5

#: Smoothing for observed serve times (newest observation's weight).
SERVE_EWMA_ALPHA = 0.5


# ---------------------------------------------------------------------------
# Per-table statistics
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ColumnStats:
    """Summary of one column over the *live* (freq > 0) rows."""

    distinct: int
    lo: float | None = None
    hi: float | None = None

    def to_payload(self) -> dict:
        return {"distinct": self.distinct, "lo": self.lo, "hi": self.hi}

    @classmethod
    def from_payload(cls, p: dict) -> "ColumnStats":
        return cls(distinct=int(p["distinct"]),
                   lo=p.get("lo"), hi=p.get("hi"))


@dataclasses.dataclass(frozen=True)
class TableStats:
    """Summary of one relation at one data version (``token``)."""

    relation: str
    rows: int                  # live tuples (freq > 0)
    capacity: int              # padded physical capacity
    token: str                 # Table.content_token() of the data version
    columns: dict[str, ColumnStats]
    #: orphan reference counts per declared outgoing FK, keyed
    #: "src_col->dst.dst_col" — 0 means every live src value has a live
    #: unique partner in dst (the soundness condition for FK-join
    #: elimination; referential integrity is measured, never assumed).
    fk_orphans: dict[str, int]

    def to_payload(self) -> dict:
        return {
            "version": STATS_VERSION,
            "relation": self.relation,
            "rows": self.rows,
            "capacity": self.capacity,
            "token": self.token,
            "columns": {c: s.to_payload() for c, s in self.columns.items()},
            "fk_orphans": dict(self.fk_orphans),
        }

    @classmethod
    def from_payload(cls, p: dict) -> "TableStats":
        if p.get("version") != STATS_VERSION:
            raise ValueError(f"stats version {p.get('version')!r} != "
                             f"{STATS_VERSION}")
        return cls(
            relation=p["relation"], rows=int(p["rows"]),
            capacity=int(p["capacity"]), token=p["token"],
            columns={c: ColumnStats.from_payload(s)
                     for c, s in p["columns"].items()},
            fk_orphans={k: int(v) for k, v in p["fk_orphans"].items()},
        )


def _live_column(table: Table, name: str, live: np.ndarray) -> np.ndarray:
    return np.asarray(table.columns[name])[live]


def compute_table_stats(name: str, table: Table, schema: Schema,
                        db: dict[str, Table]) -> TableStats:
    """One full pass over a table's live rows: numpy-cheap, O(rows)."""
    freq = np.asarray(table.freq)
    live = freq > 0
    rows = int(live.sum())
    columns: dict[str, ColumnStats] = {}
    for col in table.column_names:
        vals = _live_column(table, col, live)
        if vals.size == 0:
            columns[col] = ColumnStats(distinct=0)
            continue
        distinct = int(np.unique(vals).size)
        lo = hi = None
        if np.issubdtype(vals.dtype, np.number):
            lo, hi = float(vals.min()), float(vals.max())
        columns[col] = ColumnStats(distinct=distinct, lo=lo, hi=hi)

    fk_orphans: dict[str, int] = {}
    for fk in schema.foreign_keys:
        if fk.src != name or fk.dst not in db:
            continue
        dst = db[fk.dst]
        src_vals = _live_column(table, fk.src_col, live)
        dst_live = np.asarray(dst.freq) > 0
        dst_vals = _live_column(dst, fk.dst_col, dst_live)
        orphans = int((~np.isin(src_vals, dst_vals)).sum())
        fk_orphans[f"{fk.src_col}->{fk.dst}.{fk.dst_col}"] = orphans

    return TableStats(relation=name, rows=rows, capacity=table.capacity,
                      token=table.content_token(), columns=columns,
                      fk_orphans=fk_orphans)


# ---------------------------------------------------------------------------
# Serve-time feedback
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FeedbackRecord:
    """EWMA serve times for one (fingerprint, fusion-group signature).

    ``signature == ""`` is the solo baseline for the fingerprint."""

    ewma_s: float = 0.0
    count: int = 0

    def observe(self, serve_s: float) -> None:
        if self.count == 0:
            self.ewma_s = serve_s
        else:
            a = SERVE_EWMA_ALPHA
            self.ewma_s = a * serve_s + (1.0 - a) * self.ewma_s
        self.count += 1

    def to_payload(self) -> dict:
        return {"ewma_s": self.ewma_s, "count": self.count}

    @classmethod
    def from_payload(cls, p: dict) -> "FeedbackRecord":
        return cls(ewma_s=float(p["ewma_s"]), count=int(p["count"]))


# ---------------------------------------------------------------------------
# The catalog
# ---------------------------------------------------------------------------

class StatsCatalog:
    """Live statistics registry: tables, cost model, serve-time feedback.

    Thread-safe; every method takes the internal lock.  Table entries are
    installed either by :meth:`refresh` (a full compute — the caller's
    ``stat_refreshes`` counter should track these) or :meth:`install`
    (e.g. loaded from a warm :class:`~repro.service.stats_store.StatsStore`
    after a token match — no compute, no refresh counted).
    """

    def __init__(self, schema: Schema):
        self.schema = schema
        self._tables: dict[str, TableStats] = {}
        self._feedback: dict[tuple[str, str], FeedbackRecord] = {}
        self._lock = threading.Lock()

    # -- table stats ------------------------------------------------------

    def refresh(self, name: str, table: Table,
                db: dict[str, Table]) -> TableStats:
        st = compute_table_stats(name, table, self.schema, db)
        with self._lock:
            self._tables[name] = st
        return st

    def install(self, stats: TableStats) -> None:
        with self._lock:
            self._tables[stats.relation] = stats

    def get(self, name: str) -> TableStats | None:
        with self._lock:
            return self._tables.get(name)

    def token(self, name: str) -> str | None:
        st = self.get(name)
        return st.token if st is not None else None

    def tables(self) -> dict[str, TableStats]:
        with self._lock:
            return dict(self._tables)

    # -- decision-dependency validation -----------------------------------

    def validate_depends(self, depends: dict[str, str]) -> bool:
        """True iff every (relation → token) a decision recorded still
        matches the catalog — i.e. the decision's inputs are current."""
        with self._lock:
            return all(
                (st := self._tables.get(rel)) is not None
                and st.token == tok
                for rel, tok in depends.items())

    # -- selectivity estimation -------------------------------------------

    def estimate_selectivity(self, rel: str, spec) -> float | None:
        """Estimated live-row fraction passing a declarative selection
        spec (AND-ed ``(op, col, literal)`` terms).  ``None`` when the
        relation has no stats — callers must treat that as "gate fails",
        never as "assume selective"."""
        st = self.get(rel)
        if st is None or spec is None:
            return None
        frac = 1.0
        for op, col, val in spec:
            cs = st.columns.get(col)
            if cs is None or cs.distinct <= 0:
                return None
            if op == "=":
                f = 1.0 / cs.distinct
            elif op == "in":
                f = min(len(tuple(val)) / cs.distinct, 1.0)
            elif op == "!=":
                f = 1.0 - 1.0 / cs.distinct
            elif op in ("<", ">", "<=", ">="):
                if cs.lo is None or cs.hi is None or cs.hi <= cs.lo:
                    f = 0.5
                else:
                    span = cs.hi - cs.lo
                    if op in ("<", "<="):
                        f = (float(val) - cs.lo) / span
                    else:
                        f = (cs.hi - float(val)) / span
            else:
                return None
            frac *= min(max(f, 0.0), 1.0)
        return frac

    # -- cost model --------------------------------------------------------

    def estimate_plan_cost(self, plan: PhysicalPlan,
                           rows: dict[str, int] | None = None) -> float:
        """Estimated work for one execution of ``plan``.

        The engine is static-shape: sweeps run over *padded* capacities
        regardless of live counts or selections, so the honest unit of
        work per node is the padded rows it touches.  Pass ``rows``
        mapping relation → padded bucket capacity for serve-time costs;
        falls back to catalog live row counts (planner-side estimates).
        """
        sizes: dict[int, float] = {}
        cost = 0.0
        for node in plan.root.postorder():
            op = node.op
            if isinstance(op, ScanOp):
                if rows is not None and op.rel in rows:
                    r = float(rows[op.rel])
                else:
                    st = self.get(op.rel)
                    r = float(st.rows) if st is not None else 1.0
                sizes[id(node)] = r
                cost += r
            elif isinstance(op, (SemiJoinOp, FreqJoinOp)):
                p = sizes[id(node.inputs[0])]
                c = sizes[id(node.inputs[1])]
                sizes[id(node)] = p       # sweeps keep the parent's shape
                cost += p + c
            elif isinstance(op, MaterializeJoinOp):
                p = sizes[id(node.inputs[0])]
                c = sizes[id(node.inputs[1])]
                sizes[id(node)] = p * max(c, 1.0) ** 0.5  # growth, damped
                cost += p + c + sizes[id(node)]
            elif isinstance(op, FinalAggOp):
                r = sizes[id(node.inputs[0])]
                sizes[id(node)] = r
                cost += r
        return cost

    # -- serve-time feedback ----------------------------------------------

    def observe_serve(self, fingerprint: str, signature: str,
                      serve_s: float) -> None:
        """Record an observed serve time.  ``signature`` is the fusion
        group signature the request ran under ("" = served solo)."""
        with self._lock:
            rec = self._feedback.setdefault((fingerprint, signature),
                                            FeedbackRecord())
            rec.observe(serve_s)

    def is_demoted(self, fingerprint: str, signature: str) -> bool:
        """True iff this fusion has been observed regressing this member
        vs. its solo baseline — the grouper must not re-form it."""
        with self._lock:
            fused = self._feedback.get((fingerprint, signature))
            solo = self._feedback.get((fingerprint, ""))
            if fused is None or solo is None or solo.count == 0:
                return False
            return (fused.count >= DEMOTION_MIN_OBSERVATIONS
                    and fused.ewma_s
                    > DEMOTION_REGRESSION_FACTOR * solo.ewma_s)

    def demotions(self) -> list[dict]:
        """Currently-demoted (fingerprint, signature) pairs with numbers."""
        with self._lock:
            keys = list(self._feedback)
        out = []
        for fp, sig in keys:
            if sig and self.is_demoted(fp, sig):
                with self._lock:
                    fused = self._feedback[(fp, sig)]
                    solo = self._feedback.get((fp, ""), FeedbackRecord())
                out.append({"fingerprint": fp, "signature": sig,
                            "fused_ewma_s": fused.ewma_s,
                            "solo_ewma_s": solo.ewma_s})
        return out

    def feedback_payload(self) -> dict:
        """JSON-able snapshot of the feedback table (for the store)."""
        with self._lock:
            return {
                "version": STATS_VERSION,
                "records": [
                    {"fingerprint": fp, "signature": sig,
                     **rec.to_payload()}
                    for (fp, sig), rec in sorted(self._feedback.items())
                ],
            }

    def load_feedback(self, payload: dict) -> int:
        """Install a persisted feedback snapshot; returns records loaded.
        Existing in-memory records win (they are newer)."""
        if payload.get("version") != STATS_VERSION:
            return 0
        n = 0
        with self._lock:
            for r in payload.get("records", ()):
                key = (r["fingerprint"], r["signature"])
                if key not in self._feedback:
                    self._feedback[key] = FeedbackRecord.from_payload(r)
                    n += 1
        return n

    def feedback_len(self) -> int:
        with self._lock:
            return len(self._feedback)


__all__ = [
    "ColumnStats",
    "TableStats",
    "FeedbackRecord",
    "StatsCatalog",
    "compute_table_stats",
    "FK_ELIM_MAX_ORPHANS",
    "PREFILTER_MAX_SELECTIVITY",
    "PREFILTER_MIN_PARENT_ROWS",
    "FUSION_COST_DISPARITY",
    "DEMOTION_MIN_OBSERVATIONS",
    "DEMOTION_REGRESSION_FACTOR",
    "SERVE_EWMA_ALPHA",
    "STATS_VERSION",
]
