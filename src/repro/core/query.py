"""Query IR: aggregate queries over acyclic conjunctive queries.

A query is (paper Eq. 1):

    Q = γ_{g1..gk, A1(a1)..Am(am)} ( π_U ( R1 ⋈ ... ⋈ Rn ) )

We represent the join part datalog-style: each ``Atom`` names a schema
relation and binds every column positionally to a query variable; atoms
sharing a variable are natural-joined on it (the paper's post-renaming
normal form).  Arbitrary single-relation selections attach to atoms as
callables over the column dict — matching the paper's "local selections may
be arbitrary" generalisation (§3).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

AGG_FUNCS = ("count", "sum", "avg", "min", "max", "median")
SET_SAFE_FUNCS = ("min", "max")


def selection_from_spec(spec) -> Callable:
    """Compile a declarative selection spec — a tuple of ``(op, column,
    literal)`` terms, AND-ed, with ``op="in"`` holding a tuple of literals —
    into the predicate closure the executor applies at scan time.

    This is the single builder shared by the SQL front-end (which derives
    specs from WHERE terms) and plan deserialisation (which must rebuild
    the *same* callable from a persisted spec so a reloaded plan selects
    bitwise-identically to the plan that was stored)."""
    terms = tuple((op, col, tuple(val) if op == "in" else val)
                  for op, col, val in spec)

    def pred(cols):
        import jax.numpy as jnp
        mask = None
        for op, col, val in terms:
            c = cols[col]
            if op == "in":
                m_ = jnp.zeros(c.shape, bool)
                for v in val:
                    m_ = m_ | (c == v)
            else:
                m_ = {"=": c == val, "!=": c != val,
                      "<": c < val, ">": c > val,
                      "<=": c <= val, ">=": c >= val}[op]
            mask = m_ if mask is None else (mask & m_)
        return mask

    return pred


@dataclasses.dataclass(frozen=True)
class Atom:
    """One occurrence of a relation in the join; ``vars`` binds columns
    positionally (len(vars) == len(schema columns))."""

    rel: str
    alias: str
    vars: tuple[str, ...]

    def var_of(self, col_idx: int) -> str:
        return self.vars[col_idx]


@dataclasses.dataclass(frozen=True)
class Agg:
    """One aggregate expression A(a). ``var=None`` means COUNT(*)."""

    func: str
    var: str | None = None
    distinct: bool = False
    name: str = ""

    def __post_init__(self):
        if self.func not in AGG_FUNCS:
            raise ValueError(f"unknown aggregate {self.func}")
        if self.func == "count" and self.var is None and self.distinct:
            raise ValueError("COUNT(DISTINCT *) is not a thing")
        if self.func != "count" and self.var is None:
            raise ValueError(f"{self.func} needs an argument variable")
        if not self.name:
            d = "distinct " if self.distinct else ""
            object.__setattr__(
                self, "name", f"{self.func}({d}{self.var or '*'})")


@dataclasses.dataclass(frozen=True, eq=False)
class AggQuery:
    """γ over an ACQ. ``selections[alias]`` is σ applied at scan time.

    ``selection_specs[alias]`` optionally carries the *declarative* form of
    the same predicates — a tuple of ``(op, column, literal)`` terms (with
    ``op="in"`` holding a tuple of literals) — so the serving tier can
    fingerprint queries structurally.  Queries whose selections exist only
    as opaque callables are still executable but never share a plan-cache
    entry (the fingerprinter cannot prove them equivalent).
    """

    atoms: tuple[Atom, ...]
    aggregates: tuple[Agg, ...]
    group_by: tuple[str, ...] = ()
    selections: Mapping[str, Callable] = dataclasses.field(default_factory=dict)
    selection_specs: Mapping[str, tuple] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        aliases = [a.alias for a in self.atoms]
        if len(set(aliases)) != len(aliases):
            raise ValueError("atom aliases must be unique")
        for alias in self.selections:
            if alias not in aliases:
                raise ValueError(f"selection on unknown alias {alias}")
        for alias in self.selection_specs:
            if alias not in self.selections:
                raise ValueError(
                    f"selection_specs for {alias!r} without a matching "
                    "selection callable")

    def atom(self, alias: str) -> Atom:
        for a in self.atoms:
            if a.alias == alias:
                return a
        raise KeyError(alias)

    def output_vars(self) -> tuple[str, ...]:
        """Grouping vars + every var referenced by an aggregate."""
        out = list(self.group_by)
        for ag in self.aggregates:
            if ag.var is not None and ag.var not in out:
                out.append(ag.var)
        return tuple(out)

    def all_vars(self) -> tuple[str, ...]:
        seen: list[str] = []
        for a in self.atoms:
            for v in a.vars:
                if v not in seen:
                    seen.append(v)
        return tuple(seen)
