"""Frequency-aware aggregate evaluation (paper §4.2 rewrites).

Once the bottom-up sweep finishes, the root relation carries frequencies
that encode the bag multiplicity of every answer tuple.  Standard aggregates
are rewritten to operate on (value, frequency) pairs:

    COUNT(*)  → SUM(c)                    COUNT(A)      → SUM(c·nonnull(A))
    SUM(A)    → SUM(A·c)                  AVG(A)        → SUM(A·c)/SUM(c)
    MEDIAN(A) → weighted-percentile(A,c)  MIN/MAX       → over live rows
    COUNT(DISTINCT A) / SUM(DISTINCT A)   → over distinct live values

`dedup=True` (0MA mode) aggregates with set semantics: weights become
live-row indicators.  GROUP BY is evaluated with one sort of the root
relation + segmented reductions — never by materialising groups.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.core.query import Agg
from repro.tables.table import pack_keys


def _acc_dtype(dt):
    if jnp.issubdtype(dt, jnp.floating):
        return dt
    return jnp.int64 if jax.config.jax_enable_x64 else jnp.int32


def _big(dt):
    return jnp.asarray(
        jnp.finfo(dt).max if jnp.issubdtype(dt, jnp.floating)
        else jnp.iinfo(dt).max, dt)


def _small(dt):
    return jnp.asarray(
        jnp.finfo(dt).min if jnp.issubdtype(dt, jnp.floating)
        else jnp.iinfo(dt).min, dt)


def _distinct_mask(values, live):
    """Boolean mask (in sorted order) marking the first live occurrence of
    each distinct live value; returns (sorted_values, mask)."""
    v = jnp.where(live, values, _big(values.dtype))
    order = jnp.argsort(v)
    vs = v[order]
    ls = live[order]
    first = jnp.concatenate([jnp.ones((1,), bool), vs[1:] != vs[:-1]])
    return vs, first & ls


def scalar_aggregate(ag: Agg, cols: dict[str, jax.Array], freq: jax.Array,
                     dedup: bool) -> jax.Array:
    w = (freq > 0).astype(freq.dtype) if dedup else freq
    live = freq > 0
    if ag.func == "count" and ag.var is None:
        return jnp.sum(w.astype(_acc_dtype(w.dtype)))
    a = cols[ag.var] if ag.var is not None else None
    if ag.distinct:
        vs, mask = _distinct_mask(a, live)
        if ag.func == "count":
            return jnp.sum(mask.astype(jnp.int32))
        if ag.func == "sum":
            return jnp.sum(jnp.where(mask, vs, 0).astype(_acc_dtype(a.dtype)))
        if ag.func == "avg":
            s = jnp.sum(jnp.where(mask, vs, 0).astype(jnp.float32))
            n = jnp.sum(mask.astype(jnp.float32))
            return s / jnp.maximum(n, 1)
        # min/max distinct == min/max
    if ag.func == "count":
        return jnp.sum(w.astype(_acc_dtype(w.dtype)))  # nulls unsupported
    if ag.func == "sum":
        acc = _acc_dtype(jnp.promote_types(a.dtype, w.dtype))
        return jnp.sum(a.astype(acc) * w.astype(acc))
    if ag.func == "avg":
        s = jnp.sum(a.astype(jnp.float64 if jax.config.jax_enable_x64
                             else jnp.float32) * w)
        n = jnp.sum(w).astype(s.dtype)
        return s / jnp.maximum(n, 1)
    if ag.func == "min":
        return jnp.min(jnp.where(live, a, _big(a.dtype)))
    if ag.func == "max":
        return jnp.max(jnp.where(live, a, _small(a.dtype)))
    if ag.func == "median":
        return ops.weighted_percentile(a, w, 0.5)
    raise NotImplementedError(ag.func)


def grouped_aggregate(group_by: tuple[str, ...], aggregates: tuple[Agg, ...],
                      cols: dict[str, jax.Array], freq: jax.Array,
                      domains: dict[str, int | None], dedup: bool):
    """GROUP BY via one sort + segmented reductions.

    Returns (out_cols, out_valid): fixed capacity == input capacity; rows
    with out_valid=False are dead.  Group rows sit at the last row of each
    sorted run (segment-sum emission convention).
    """
    w = (freq > 0).astype(freq.dtype) if dedup else freq
    key = pack_keys([cols[g] for g in group_by],
                    [domains.get(g) for g in group_by])
    # dead rows sort last and never mark a group as live
    key = jnp.where(freq > 0, key, _big(key.dtype))
    order = jnp.argsort(key)
    ks = key[order]
    n = ks.shape[0]
    is_last = jnp.concatenate([ks[1:] != ks[:-1], jnp.ones((1,), bool)])
    is_first = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    run_id = jnp.cumsum(is_first.astype(jnp.int32)) - 1
    live_s = (freq > 0)[order]
    w_s = w[order]

    def seg_sum(v):
        return jnp.take(jax.ops.segment_sum(v, run_id, num_segments=n), run_id)

    out_cols: dict[str, jax.Array] = {g: cols[g][order] for g in group_by}
    group_live = seg_sum(live_s.astype(jnp.int32)) > 0
    out_valid = is_last & group_live

    for ag in aggregates:
        a = cols[ag.var][order] if ag.var is not None else None
        if ag.distinct:
            raise NotImplementedError("DISTINCT inside GROUP BY")
        if ag.func == "count":
            out = seg_sum(w_s.astype(_acc_dtype(w_s.dtype)))
        elif ag.func == "sum":
            acc = _acc_dtype(jnp.promote_types(a.dtype, w_s.dtype))
            out = seg_sum(a.astype(acc) * w_s.astype(acc))
        elif ag.func == "avg":
            f = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
            s = seg_sum(a.astype(f) * w_s.astype(f))
            c = seg_sum(w_s.astype(f))
            out = s / jnp.maximum(c, 1)
        elif ag.func == "min":
            v = jnp.where(live_s, a, _big(a.dtype))
            out = jnp.take(jax.ops.segment_min(v, run_id, num_segments=n),
                           run_id)
        elif ag.func == "max":
            v = jnp.where(live_s, a, _small(a.dtype))
            out = jnp.take(jax.ops.segment_max(v, run_id, num_segments=n),
                           run_id)
        elif ag.func == "median":
            out = _grouped_weighted_median(ks, a, w_s, live_s)
        else:
            raise NotImplementedError(f"{ag.func} with GROUP BY")
        out_cols[ag.name] = out
    return out_cols, out_valid


def _grouped_weighted_median(sorted_keys, values, weights, live):
    """Weighted median per group: one lexicographic sort by (group, value),
    then a segment-relative weighted-cumsum threshold — no group ever
    materialises (paper §4.2's PERCENTILE(0.5, A, c) generalised to
    GROUP BY)."""
    n = sorted_keys.shape[0]
    big = _big(values.dtype)
    v = jnp.where(live, values, big)
    # stable sort by value within already-key-sorted runs: sort (key, value)
    order = jnp.lexsort((v, sorted_keys))
    ks = sorted_keys[order]
    vs = v[order]
    ws = jnp.where(live[order], weights[order], 0).astype(
        jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
    is_first = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    run_id = jnp.cumsum(is_first.astype(jnp.int32)) - 1
    cw = jnp.cumsum(ws)
    run_start_cw = jnp.take(
        jax.ops.segment_min(jnp.where(is_first, cw - ws, jnp.inf),
                            run_id, num_segments=n), run_id)
    rel_cw = cw - run_start_cw                        # within-group cumsum
    total = jnp.take(jax.ops.segment_max(rel_cw, run_id, num_segments=n),
                     run_id)
    # first row of each group whose cumulative weight reaches half
    reach = rel_cw >= 0.5 * total
    cand_v = jnp.where(reach, vs, big)
    med = jnp.take(jax.ops.segment_min(cand_v, run_id, num_segments=n),
                   run_id)
    # scatter medians back to the ORIGINAL (group-sorted) row order
    out = jnp.zeros(n, values.dtype).at[order].set(med.astype(values.dtype))
    return out
