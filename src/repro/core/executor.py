"""Graph interpreter over the fixed-shape columnar substrate.

Both execution surfaces interpret the plan's op DAG (``PhysicalPlan.root``
/ ``nodes``), with deliberately different option sets:

  * ``execute``  — eager, runs every plan class; materialising ops (ref/opt
    baselines) use dynamic shapes the way a row engine would, and the
    executor tracks the paper's headline metric (peak materialised/live
    tuples) per step → Fig. 6 reproduction.  ``oom_guard`` and ``ExecStats``
    belong to this surface only: both need concrete intermediate sizes,
    which exist eagerly but not under tracing.
  * ``compile``  — jits the zero-materialisation plan classes (oma /
    opt_plus), whose dataflow is entirely static; this is the TPU path,
    what the timing benchmarks measure, and what the serving tier caches.
    Stats-dependent options are rejected up front (a traced program cannot
    count live tuples per step), so an Executor configured with
    ``oom_guard`` refuses to compile rather than silently dropping the
    guard.  Padded tables (``Table.pad_to``) run through compiled plans
    unchanged: every operator masks by frequency, so dead rows are inert.

Under tracing, node results are memoised by their content keys
(``PlanNode.key``): a key hit reuses the already-traced frequency vector
instead of re-tracing the kernels.  ``compile_multi`` shares one memo
across *all* member plans, so any sub-DAG two members have in common — a
filtered dimension scan, a semi-join chain, even when the enclosing join
shapes differ — is computed exactly once in the fused XLA program.

An ``oom_guard`` bounds materialisation for the baselines: exceeding it
raises ``MaterialisationLimit`` (reported as the paper's X entries).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregates import grouped_aggregate, scalar_aggregate
from repro.core.plan import (
    FinalAggOp,
    FreqJoinOp,
    MaterializeJoinOp,
    PhysicalPlan,
    PlanNode,
    ScanOp,
    SemiJoinOp,
)
from repro.kernels import ops as kops
from repro.tables.table import Schema, Table, pack_keys


class MaterialisationLimit(RuntimeError):
    """Raised when a baseline plan exceeds the allowed intermediate size
    (the paper's 'X — out of memory' condition)."""


@dataclasses.dataclass
class ExecStats:
    peak_tuples: int = 0
    steps: list = dataclasses.field(default_factory=list)

    def record(self, opname: str, n: int):
        self.steps.append((opname, int(n)))
        self.peak_tuples = max(self.peak_tuples, int(n))


@dataclasses.dataclass
class _State:
    cols: dict[str, Any]     # var → column array
    freq: Any                # frequency column


class Executor:
    def __init__(self, db: dict[str, Table], schema: Schema,
                 freq_dtype=jnp.int32, backend: str = "xla",
                 interpret: bool = True, oom_guard: int | None = None,
                 dense_domain: bool = False,
                 span_hook: Callable[[str], Any] | None = None,
                 profile_annotations: bool = False,
                 tuning=None):
        self.db = db
        self.schema = schema
        self.freq_dtype = freq_dtype
        self.backend = backend
        self.interpret = interpret
        self.oom_guard = oom_guard
        # beyond-paper: sort-free scatter-add FreqJoin on dense key domains
        self.dense_domain = dense_domain
        # tuned kernel configs (repro.kernels.autotune.TuneTable, or None
        # for untuned defaults): looked up at trace time by the concrete
        # kernel input sizes — already bucket-padded on the serving path,
        # so the lookup lands on the bucket the entry was tuned at
        self.tuning = tuning
        # observability hooks: span_hook(name) -> context manager wraps the
        # trace/execute phases (the serving tier wires its own spans above
        # this layer; the hook is for standalone Executor users), and
        # profile_annotations=True additionally emits
        # jax.profiler.TraceAnnotation markers so the phases show up named
        # in a JAX/Perfetto profiler capture
        self.span_hook = span_hook
        self.profile_annotations = profile_annotations

    def jittable(self) -> "Executor":
        """Copy with eager-only options stripped — the configuration
        ``compile()`` accepts.  Use when one benchmark harness drives both
        guarded eager baselines and jitted plans."""
        return Executor(self.db, self.schema, self.freq_dtype, self.backend,
                        self.interpret, oom_guard=None,
                        dense_domain=self.dense_domain,
                        span_hook=self.span_hook,
                        profile_annotations=self.profile_annotations,
                        tuning=self.tuning)

    @contextlib.contextmanager
    def _span(self, name: str):
        """Enter the caller's span hook and (optionally) a jax.profiler
        trace annotation around one executor phase."""
        with contextlib.ExitStack() as stack:
            if self.profile_annotations:
                try:
                    stack.enter_context(jax.profiler.TraceAnnotation(name))
                except Exception:
                    pass  # profiler unavailable on this backend — skip
            if self.span_hook is not None:
                stack.enter_context(self.span_hook(name))
            yield

    # ------------------------------------------------------------------
    def _domains(self, plan: PhysicalPlan, alias: str) -> dict[str, int | None]:
        atom = plan.tree.atoms[alias]
        rel = self.schema.relations[atom.rel]
        return {v: rel.columns[i].domain for i, v in enumerate(atom.vars)}

    def _scan(self, plan: PhysicalPlan, op: ScanOp) -> _State:
        tab = self.db[op.rel]
        atom = plan.tree.atoms[op.alias]
        rel = self.schema.relations[atom.rel]
        if op.selection is not None:
            tab = tab.select(op.selection)
        cols = {}
        for i, cname in enumerate(rel.column_names()):
            cols[atom.vars[i]] = tab.columns[cname]
        return _State(cols, tab.freq.astype(self.freq_dtype))

    def _key(self, plan: PhysicalPlan, alias: str, st: _State,
             on_vars: tuple[str, ...]):
        """Packed join key + (optional) dense key-domain size."""
        if not on_vars:
            return jnp.zeros(st.freq.shape, jnp.int32), 1
        doms = self._domains(plan, alias)
        dlist = [doms.get(v) for v in on_vars]
        key = pack_keys([st.cols[v] for v in on_vars], dlist)
        domain = None
        if self.dense_domain and all(d is not None for d in dlist):
            domain = 1
            for d in dlist:
                domain *= d
        return key, domain

    def _tune_cfg(self, kernel: str, *sizes: int):
        """Tuned config for one kernel call (None → untuned defaults).
        Sizes are the concrete trace-time array lengths, which on the
        serving path are already padded to their shape bucket — so the
        table lookup hits exactly the bucket ``autotune()`` measured."""
        if self.tuning is None:
            return None
        return self.tuning.lookup(kernel, sizes, self.backend)

    def _semi_join(self, plan: PhysicalPlan, op: SemiJoinOp,
                   p: _State, c: _State) -> _State:
        pk, _pd = self._key(plan, op.parent, p, op.on_vars)
        ck, cdom = self._key(plan, op.child, c, op.on_vars)
        freq = kops.semi_join(pk, p.freq, ck, c.freq,
                              backend=self.backend,
                              interpret=self.interpret,
                              domain=cdom,
                              config=self._tune_cfg(
                                  "semi_join", pk.shape[0], ck.shape[0]))
        return _State(p.cols, freq)

    def _freq_join(self, plan: PhysicalPlan, op: FreqJoinOp,
                   p: _State, c: _State) -> _State:
        pk, _pd = self._key(plan, op.parent, p, op.on_vars)
        ck, cdom = self._key(plan, op.child, c, op.on_vars)
        cf = c.freq
        if op.pregroup and cdom is None:
            ck, cf, _valid = kops.group_by_sum(
                ck, cf, backend=self.backend, interpret=self.interpret,
                config=self._tune_cfg("segment_sum", ck.shape[0]))
        freq = kops.freq_join(pk, p.freq, ck, cf,
                              backend=self.backend,
                              interpret=self.interpret,
                              domain=cdom,
                              config=self._tune_cfg(
                                  "freq_join", pk.shape[0], ck.shape[0]))
        return _State(p.cols, freq)

    # ------------------------------------------------------------------
    def execute(self, plan: PhysicalPlan, stats: ExecStats | None = None):
        """Eager DAG interpretation (every plan class, per-step stats).

        Intermediate states are dropped after their last consumer, so peak
        host memory tracks the largest live intermediate — matching the
        linear interpreter this replaced, whose per-alias state slots were
        overwritten in place (a ref-mode chain of materialising joins must
        not retain every expanded intermediate until the end)."""
        stats = stats if stats is not None else ExecStats()
        if self.span_hook is not None or self.profile_annotations:
            with self._span("executor.execute"):
                return self._execute_inner(plan, stats)
        return self._execute_inner(plan, stats)

    def _execute_inner(self, plan: PhysicalPlan, stats: ExecStats):
        consumers: dict[int, int] = {}
        for node in plan.nodes:
            for i in node.inputs:
                consumers[id(i)] = consumers.get(id(i), 0) + 1
        vals: dict[int, Any] = {}
        results: dict[str, Any] = {}
        for node in plan.nodes:
            op = node.op
            ins = [vals[id(i)] for i in node.inputs]
            if isinstance(op, ScanOp):
                st = self._scan(plan, op)
                stats.record(f"scan({op.alias})", int(jnp.sum(st.freq > 0)))
            elif isinstance(op, SemiJoinOp):
                st = self._semi_join(plan, op, ins[0], ins[1])
                stats.record(f"semijoin({op.parent}⋉{op.child})",
                             int(jnp.sum(st.freq > 0)))
            elif isinstance(op, FreqJoinOp):
                st = self._freq_join(plan, op, ins[0], ins[1])
                stats.record(f"freqjoin({op.parent}⋉ᶠ{op.child})",
                             int(jnp.sum(st.freq > 0)))
            elif isinstance(op, MaterializeJoinOp):
                st = self._materialize_join(plan, op, ins[0], ins[1], stats)
            elif isinstance(op, FinalAggOp):
                st = results = self._final_agg(plan, op, ins[0])
            else:  # pragma: no cover
                raise TypeError(op)
            vals[id(node)] = st
            for i in node.inputs:
                consumers[id(i)] -= 1
                if consumers[id(i)] == 0:
                    del vals[id(i)]
        results = dict(results)
        results["__stats__"] = stats
        return results

    # ------------------------------------------------------------------
    def _materialize_join(self, plan, op: MaterializeJoinOp,
                          p: _State, c: _State, stats) -> _State:
        """Eager row-expanding join (the ref/opt baselines)."""
        pk = np.asarray(self._key(plan, op.parent, p, op.on_vars)[0])
        ck = np.asarray(self._key(plan, op.child, c, op.on_vars)[0])
        pf = np.asarray(p.freq)
        cf = np.asarray(c.freq)
        plive = np.flatnonzero(pf > 0)
        clive = np.flatnonzero(cf > 0)
        pk, pf = pk[plive], pf[plive]
        ck, cf = ck[clive], cf[clive]
        order = np.argsort(ck, kind="stable")
        cks, cfs = ck[order], cf[order]
        lo = np.searchsorted(cks, pk, side="left")
        hi = np.searchsorted(cks, pk, side="right")
        counts = hi - lo
        total = int(counts.sum())
        if self.oom_guard is not None and total > self.oom_guard:
            raise MaterialisationLimit(
                f"join {op.parent}⋈{op.child} would materialise {total} "
                f"tuples (> {self.oom_guard})")
        stats.record(f"join({op.parent}⋈{op.child})", total)
        pidx = np.repeat(np.arange(len(pk)), counts)
        offs = np.concatenate([[0], np.cumsum(counts)[:-1]])
        within = np.arange(total) - np.repeat(offs, counts)
        cidx = order[np.repeat(lo, counts) + within]

        out_cols: dict[str, np.ndarray] = {}
        for v, col in p.cols.items():
            out_cols[v] = np.asarray(col)[plive][pidx]
        for v, col in c.cols.items():
            if v not in out_cols:
                out_cols[v] = np.asarray(col)[clive][cidx]
        out_freq = pf[pidx] * cf[cidx]

        if op.regroup:
            # §4.2 Opt: group straight back to the parent's attributes
            parent_vars = list(p.cols.keys())
            sort_keys = tuple(out_cols[v] for v in reversed(parent_vars))
            if sort_keys:
                gorder = np.lexsort(sort_keys)
            else:
                gorder = np.arange(total)
            freq_sorted = out_freq[gorder]
            cols_sorted = {v: out_cols[v][gorder] for v in parent_vars}
            if total == 0:
                boundary = np.zeros(0, bool)
            else:
                boundary = np.zeros(total, bool)
                boundary[0] = True
                for v in parent_vars:
                    col = cols_sorted[v]
                    boundary[1:] |= col[1:] != col[:-1]
            starts = np.flatnonzero(boundary)
            sums = np.add.reduceat(freq_sorted, starts) if total else \
                np.zeros(0, freq_sorted.dtype)
            new_cols = {v: jnp.asarray(cols_sorted[v][starts])
                        for v in parent_vars}
            stats.record(f"regroup({op.parent})", len(starts))
            return _State(new_cols, jnp.asarray(sums))

        return _State({v: jnp.asarray(a) for v, a in out_cols.items()},
                      jnp.asarray(out_freq))

    # ------------------------------------------------------------------
    def _final_agg(self, plan, op: FinalAggOp, st: _State):
        out: dict[str, Any] = {}
        if not op.group_by:
            for ag in op.aggregates:
                out[ag.name] = scalar_aggregate(ag, st.cols, st.freq,
                                                op.dedup)
            return out
        doms = self._domains(plan, op.root) \
            if op.root in plan.tree.atoms else {}
        cols, valid = grouped_aggregate(op.group_by, op.aggregates,
                                        st.cols, st.freq, doms, op.dedup)
        out["groups"] = cols
        out["valid"] = valid
        return out

    # ------------------------------------------------------------------
    def _check_jittable(self, plans) -> None:
        for plan in plans:
            if any(isinstance(op, MaterializeJoinOp) for op in plan.ops):
                raise ValueError(f"plan mode {plan.mode} materialises joins; "
                                 "only oma/opt_plus plans are jittable")
        if self.oom_guard is not None:
            raise ValueError(
                "oom_guard is an eager-only option: it needs concrete "
                "per-step tuple counts, which do not exist under jit "
                "tracing (and compiled oma/opt_plus plans never "
                "materialise beyond the base relations anyway). Use "
                "execute() for guarded baselines, or build the Executor "
                "without oom_guard to compile.")

    def _inner_executor(self, db: dict[str, Table]) -> "Executor":
        """The node evaluator ``_trace_plan`` traces with — a fresh
        executor bound to the traced-through database.  Subclasses swap in
        alternative evaluators here (``DistributedExecutor`` returns one
        whose semi/freq joins are ring sweeps over the mesh); the traversal
        itself — content-key memoisation, sub-DAG dedup, multi-plan fusion
        — is shared and lives only in ``_trace_plan``."""
        return Executor(db, self.schema, self.freq_dtype,
                        self.backend, self.interpret,
                        dense_domain=self.dense_domain,
                        tuning=self.tuning)

    def _trace_plan(self, db: dict[str, Table], plan: PhysicalPlan,
                    memo: dict | None = None,
                    root: PlanNode | None = None) -> Any:
        """One plan's DAG evaluation, for use under tracing.

        ``memo`` maps node content keys (``PlanNode.key``) to the frequency
        vectors already computed this trace: a key hit reuses the cached
        vector (only the column views of the node's parent chain are
        rebuilt — free) and skips tracing the node's kernels AND its entire
        child sub-DAG.  Shared across plans by ``compile_multi``, this is
        how a fused multi-query program runs each common sub-DAG exactly
        once even when the member plans' overall join shapes differ.

        ``root`` selects where evaluation stops (default: the whole plan,
        ``plan.root``).  The mesh path evaluates to ``plan.root.inputs[0]``
        — the pre-aggregate root state — inside its shard_map program and
        aggregates outside, so the same traversal serves both lowerings."""
        inner = self._inner_executor(db)
        vals: dict[int, _State] = {}

        def ev(node: PlanNode) -> Any:
            st = vals.get(id(node))
            if st is not None:
                return st
            op = node.op
            key = node.key() if memo is not None else None
            if isinstance(op, ScanOp):
                st = inner._scan(plan, op)
                if key is not None:
                    if key in memo:
                        st = _State(st.cols, memo[key])
                    else:
                        memo[key] = st.freq
            elif isinstance(op, (SemiJoinOp, FreqJoinOp)):
                p = ev(node.inputs[0])
                if key is not None and key in memo:
                    st = _State(p.cols, memo[key])
                else:
                    c = ev(node.inputs[1])
                    st = inner._semi_join(plan, op, p, c) \
                        if isinstance(op, SemiJoinOp) \
                        else inner._freq_join(plan, op, p, c)
                    if key is not None:
                        memo[key] = st.freq
            elif isinstance(op, FinalAggOp):
                st = inner._final_agg(plan, op, ev(node.inputs[0]))
            else:  # pragma: no cover — _check_jittable rejects these
                raise TypeError(op)
            vals[id(node)] = st
            return st

        return ev(plan.root if root is None else root)

    def compile(self, plan: PhysicalPlan):
        """Jit the static plan classes (oma / opt_plus): db → aggregates."""
        self._check_jittable([plan])

        def run(db: dict[str, Table]):
            # a fresh memo still dedups repeated sub-DAGs *within* the plan
            # (self-joins scanning one relation twice, say)
            return self._trace_plan(db, plan, memo={})

        return self._wrap_jitted(jax.jit(run), "executor.run")

    def compile_multi(self, plans: list[PhysicalPlan]):
        """Jit several static plans into ONE program: db → [aggregates].

        The member plans' DAG evaluations share a trace-level memo keyed by
        node content keys, so every sub-DAG that is structurally identical
        across members — a whole prefix, or just a shared scan/semi-join
        chain under different join shapes — is computed once and its
        frequency vector fanned out to every consumer.  One XLA compilation
        serves every member query; results are returned in plan order."""
        if not plans:
            raise ValueError("compile_multi needs at least one plan")
        self._check_jittable(plans)

        def run(db: dict[str, Table]):
            memo: dict = {}
            return [self._trace_plan(db, plan, memo) for plan in plans]

        return self._wrap_jitted(jax.jit(run), "executor.run_multi")

    def _wrap_jitted(self, jitted, name: str):
        """With hooks active, run the jitted callable under a span (its
        first call also covers the XLA trace + compile); otherwise return
        it untouched so the serving hot path pays nothing."""
        if self.span_hook is None and not self.profile_annotations:
            return jitted

        def wrapped(db: dict[str, Table]):
            with self._span(name):
                return jitted(db)

        return wrapped


def shared_subplan_savings(plans: list[PhysicalPlan]) -> int:
    """How many non-trivial subplan evaluations ``compile_multi`` saves by
    fusing `plans`, versus compiling each alone: the multiset of the
    members' shareable subplan keys minus its distinct support."""
    sets = [plan.subplan_keys() for plan in plans]
    union: set = set()
    total = 0
    for s in sets:
        total += len(s)
        union |= s
    return total - len(union)
