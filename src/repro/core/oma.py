"""Query classification: guardedness, set-safety, 0MA (paper §3, §4.1).

A query of the paper's Eq.-1 form is

  * guarded       — all grouping + aggregate vars occur in ONE atom (the
                    guard).  COUNT(*) is trivially guarded (empty var set).
  * set-safe      — duplicate elimination on π_U does not change the result:
                    MIN/MAX always; any aggregate with DISTINCT; and
                    schema-derived safety (below).
  * 0MA           — acyclic + guarded + set-safe: evaluable with semi-joins
                    only (the first bottom-up Yannakakis pass).

Schema-derived set-safety: we implement the sound criterion that every join
tree edge below the guard runs along a declared FK(parent) → PK/unique(child)
edge, in which case every guard tuple has at most one extension through the
whole join, so π_U carries no duplicates and *any* aggregate is set-safe.
(This is the same schema knowledge that powers the §4.3 optimisations.)
"""

from __future__ import annotations

import dataclasses

from repro.core.hypergraph import JoinTree, build_join_tree
from repro.core.query import SET_SAFE_FUNCS, AggQuery
from repro.tables.table import Schema


@dataclasses.dataclass(frozen=True)
class Classification:
    acyclic: bool
    guarded: bool
    guard: str | None          # alias of a guard atom (if guarded)
    set_safe: bool
    tree: JoinTree | None      # rooted at guard when guarded

    @property
    def is_oma(self) -> bool:
        return self.acyclic and self.guarded and self.set_safe


def find_guards(query: AggQuery) -> list[str]:
    """All atoms containing every output var (candidates for the root)."""
    out = set(query.output_vars())
    return [a.alias for a in query.atoms if out <= set(a.vars)]


def edge_is_fk_pk(tree: JoinTree, schema: Schema, parent: str,
                  child: str) -> bool:
    """True if the (parent, child) join runs along a single declared
    FK(parent column) → unique(child column) edge — then each parent tuple
    has at most one child partner (paper §4.3)."""
    shared = tree.shared_vars(parent, child)
    if len(shared) != 1:
        return False
    var = shared[0]
    pa, ca = tree.atoms[parent], tree.atoms[child]
    p_cols = [schema.relations[pa.rel].columns[i].name
              for i, v in enumerate(pa.vars) if v == var]
    c_cols = [schema.relations[ca.rel].columns[i].name
              for i, v in enumerate(ca.vars) if v == var]
    for pc in p_cols:
        for cc in c_cols:
            if schema.fk_edge(pa.rel, pc, ca.rel, cc):
                if schema.relations[ca.rel].meta(cc).unique:
                    return True
    return False


def subtree_all_fk_pk(tree: JoinTree, schema: Schema, node: str) -> bool:
    """Every edge in the subtree rooted at `node` is FK→PK: frequencies in
    the whole subtree stay identically 1 (paper §4.3, Example 4.2)."""
    for c in tree.children(node):
        if not edge_is_fk_pk(tree, schema, node, c):
            return False
        if not subtree_all_fk_pk(tree, schema, c):
            return False
    return True


def _schema_set_safe(tree: JoinTree, schema: Schema, guard: str) -> bool:
    return subtree_all_fk_pk(tree, schema, guard)


def classify(query: AggQuery, schema: Schema) -> Classification:
    tree = build_join_tree(query.atoms)
    if tree is None:
        return Classification(False, False, None, False, None)
    guards = find_guards(query)
    if not guards:
        return Classification(True, False, None, False, tree)
    # prefer a guard that makes the whole tree FK/PK-safe, else the first
    guard = guards[0]
    for g in guards:
        if _schema_set_safe(tree.rerooted(g), schema, g):
            guard = g
            break
    tree = tree.rerooted(guard)

    def agg_set_safe(ag) -> bool:
        return ag.func in SET_SAFE_FUNCS or ag.distinct

    set_safe = (all(agg_set_safe(ag) for ag in query.aggregates)
                or _schema_set_safe(tree, schema, guard))
    return Classification(True, True, guard, set_safe, tree)
