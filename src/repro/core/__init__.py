"""The paper's contribution: guarded aggregate queries without
materialisation — query IR, GYO join trees, 0MA classification, rule-based
rewrites (§4), frequency-propagating executor with the FreqJoin physical
operator (§5), and the shard_map distributed engine.
"""

from repro.core.executor import ExecStats, Executor, MaterialisationLimit
from repro.core.hypergraph import JoinTree, build_join_tree
from repro.core.oma import Classification, classify
from repro.core.plan import PhysicalPlan, PlanSegments, segment_plan
from repro.core.query import Agg, AggQuery, Atom
from repro.core.rewrite import plan_query
from repro.core.sql import parse_sql, SqlError

__all__ = [
    "Agg",
    "AggQuery",
    "Atom",
    "Classification",
    "classify",
    "build_join_tree",
    "JoinTree",
    "PhysicalPlan",
    "PlanSegments",
    "plan_query",
    "segment_plan",
    "parse_sql",
    "SqlError",
    "Executor",
    "ExecStats",
    "MaterialisationLimit",
]
