"""The paper's contribution: guarded aggregate queries without
materialisation — query IR, GYO join trees, 0MA classification, rule-based
rewrites (§4), frequency-propagating executor with the FreqJoin physical
operator (§5), and the shard_map distributed engine.
"""

from repro.core.executor import (
    ExecStats,
    Executor,
    MaterialisationLimit,
    shared_subplan_savings,
)
from repro.core.hypergraph import JoinTree, build_join_tree
from repro.core.oma import Classification, classify
from repro.core.plan import (
    Decision,
    PhysicalPlan,
    PlanNode,
    PlanNotSerialisable,
    PlanSegments,
    op_result_keys,
    plan_from_payload,
    plan_to_payload,
    rewrite_dag,
    segment_plan,
)
from repro.core.query import Agg, AggQuery, Atom, selection_from_spec
from repro.core.rewrite import PlanningError, plan_query
from repro.core.sql import parse_sql, SqlError
from repro.core.stats import StatsCatalog, TableStats, compute_table_stats

__all__ = [
    "Agg",
    "AggQuery",
    "Atom",
    "Classification",
    "Decision",
    "PlanningError",
    "StatsCatalog",
    "TableStats",
    "compute_table_stats",
    "classify",
    "build_join_tree",
    "JoinTree",
    "PhysicalPlan",
    "PlanNode",
    "PlanNotSerialisable",
    "PlanSegments",
    "op_result_keys",
    "plan_from_payload",
    "plan_query",
    "plan_to_payload",
    "rewrite_dag",
    "segment_plan",
    "selection_from_spec",
    "shared_subplan_savings",
    "parse_sql",
    "SqlError",
    "Executor",
    "ExecStats",
    "MaterialisationLimit",
]
