"""The planner: rule-based rewrites of logical plans (paper §4).

``plan_query`` turns an AggQuery into a PhysicalPlan:

  1. GYO → join tree; classify (acyclic / guarded / set-safe / 0MA).
  2. Re-root the tree at the guard (§4.1).
  3. mode="auto": 0MA → semi-join sweep; guarded → FreqJoin sweep (Opt⁺);
     unguarded/cyclic → materialising baseline (the paper's fallback: "when
     our optimisations are not applicable, execution is not affected").
  4. FK/PK knowledge (§4.3): an edge whose whole child subtree is FK→PK
     carries frequency ≡ 1, so the FreqJoin degrades to a semi-join; the
     child pre-grouping is skipped when the join key is unique in the child.

Modes can be forced (benchmarks compare ref / opt / opt_plus / oma on the
same query, mirroring the paper's experimental conditions).
"""

from __future__ import annotations

from repro.core.hypergraph import build_join_tree
from repro.core.oma import classify, edge_is_fk_pk, subtree_all_fk_pk
from repro.core.plan import (
    FinalAggOp,
    FreqJoinOp,
    MaterializeJoinOp,
    PhysicalPlan,
    ScanOp,
    SemiJoinOp,
)
from repro.core.query import AggQuery
from repro.tables.table import Schema


def _var_cols(query: AggQuery, schema: Schema) -> dict[str, dict[str, str]]:
    out: dict[str, dict[str, str]] = {}
    for a in query.atoms:
        cols = schema.relations[a.rel].column_names()
        m: dict[str, str] = {}
        for i, v in enumerate(a.vars):
            m.setdefault(v, cols[i])
        out[a.alias] = m
    return out


def _key_unique_in(schema: Schema, atom, on_vars, var_cols) -> bool:
    cols = [var_cols[atom.alias][v] for v in on_vars]
    return schema.relations[atom.rel].is_unique(cols)


def plan_query(query: AggQuery, schema: Schema, mode: str = "auto",
               use_fkpk: bool = False) -> PhysicalPlan:
    cls = classify(query, schema)
    if cls.tree is None:
        raise ValueError(
            "cyclic query: out of the paper's guarded-acyclic fragment "
            "(would need hypertree decomposition, see paper §7)")
    tree = cls.tree
    var_cols = _var_cols(query, schema)

    if mode == "auto":
        if cls.is_oma:
            mode = "oma"
        elif cls.guarded:
            mode = "opt_plus"
        else:
            mode = "ref"
    if mode == "oma" and not cls.is_oma:
        raise ValueError("query is not 0MA; cannot force oma mode")
    if mode in ("opt", "opt_plus") and not cls.guarded:
        raise ValueError("query is not guarded; frequency propagation "
                         "would lose the aggregate attributes")

    ops: list = [ScanOp(a.alias, a.rel, query.selections.get(a.alias),
                        spec=query.selection_specs.get(a.alias))
                 for a in query.atoms]

    if mode == "ref":
        # left-deep materialising joins in join-tree connectivity order so
        # every join has a shared key (no cross products).
        order = [u for u in reversed(tree.postorder())]  # root first
        base = order[0]
        for nxt in order[1:]:
            par = tree.parent[nxt]
            on = tree.shared_vars(par, nxt) if par is not None else ()
            ops.append(MaterializeJoinOp(base, nxt, on, regroup=False))
        ops.append(FinalAggOp(base, query.group_by, query.aggregates,
                              dedup=False))
        return PhysicalPlan("ref", tuple(ops), tree, var_cols)

    # bottom-up sweep over join-tree edges (children before parents)
    for parent, child in tree.edges_bottom_up():
        on = tree.shared_vars(parent, child)
        if mode == "oma":
            ops.append(SemiJoinOp(parent, child, on))
            continue
        fkpk = use_fkpk and edge_is_fk_pk(tree, schema, parent, child) \
            and subtree_all_fk_pk(tree, schema, child)
        if fkpk:
            # child freq ≡ 1 and ≤1 partner: FreqJoin degenerates to a
            # semi-join (§4.3) — skip the grouping machinery entirely.
            ops.append(SemiJoinOp(parent, child, on))
        elif mode == "opt":
            ops.append(MaterializeJoinOp(parent, child, on, regroup=True))
        else:  # opt_plus
            pregroup = not (use_fkpk and _key_unique_in(
                schema, tree.atoms[child], on, var_cols))
            ops.append(FreqJoinOp(parent, child, on, pregroup=pregroup))

    ops.append(FinalAggOp(tree.root, query.group_by, query.aggregates,
                          dedup=(mode == "oma")))
    return PhysicalPlan(mode, tuple(ops), tree, var_cols)


__all__ = ["plan_query", "classify", "build_join_tree"]
