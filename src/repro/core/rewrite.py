"""The planner: a pass pipeline lowering logical queries to the op-graph IR
(paper §4), with every transform *gated* and every decision *recorded*.

``plan_query`` turns an AggQuery into a ``PhysicalPlan`` by running a small
sequence of passes over a shared build state:

  1. ``_pass_classify``   — GYO → join tree; classify (acyclic / guarded /
                            set-safe / 0MA); resolve ``mode="auto"``
                            (0MA → semi-join sweep; guarded → FreqJoin
                            sweep (Opt⁺); unguarded/cyclic → materialising
                            baseline, the paper's fallback).
  2. ``_pass_reroot_guard``— re-root the join tree at the guard (§4.1);
                            join trees are freely re-rootable.
  3. ``_pass_lower``      — emit the op graph: one scan node per atom
                            (selections not yet attached), a join node per
                            tree edge (mode-generic sweep), the final
                            aggregate node.
  4. ``_pass_fkpk_degrade``— §4.3 IR rewrite: an edge whose whole child
                            subtree is FK→PK carries frequency ≡ 1, so the
                            FreqJoin/materialising join degrades to a
                            semi-join; child pre-grouping is dropped when
                            the join key is unique in the child.
  5. ``_pass_fk_join_eliminate`` — drop a semi-join against an unfiltered
                            FK→PK leaf entirely when measured statistics
                            prove it filters nothing (zero orphan
                            references); cf. Calcite's
                            FkJoinEliminationRule, made sound here by
                            *measuring* referential integrity instead of
                            trusting the declaration.
  6. ``_pass_prefilter_pushdown`` — in the materialising baseline, push a
                            selective dimension in front of the join chain
                            as a semi-join pre-filter so intermediates
                            shrink before they are expanded (the decision
                            cards' ``date_cte_isolate`` family).
  7. ``_pass_attach_selections`` — rewrite scan nodes to carry the query's
                            per-alias selections (callable + declarative
                            spec), which flows into the nodes' content keys.

Every pass follows the same discipline (the decision-card shape): a
*structural gate* (is the rewrite shape-applicable at all?), then a
*stats calibration* against the :class:`~repro.core.stats.StatsCatalog`
(is it worth it / provably sound on THIS data?), then apply-or-skip — and
each considered candidate leaves a machine-readable
:class:`~repro.core.plan.Decision` on the plan, which ``explain()``
renders and the serving tier uses to detect stale plans (a decision's
``depends`` tokens no longer matching the live catalog ⇒ replan).

With ``stats=None`` (the default — library callers, tests) the two
stats-calibrated passes (5 and 6) record a skip and change nothing: the
planner's output is byte-for-byte what it was before the stats layer
existed.  Modes can be forced (benchmarks compare ref / opt / opt_plus /
oma on the same query, mirroring the paper's experimental conditions).
"""

from __future__ import annotations

import dataclasses

from repro.core.hypergraph import build_join_tree
from repro.core.oma import classify, edge_is_fk_pk, subtree_all_fk_pk
from repro.core.plan import (
    Decision,
    FinalAggOp,
    FreqJoinOp,
    MaterializeJoinOp,
    PhysicalPlan,
    PlanNode,
    ScanOp,
    SemiJoinOp,
    make_final_agg_node,
    make_join_node,
    make_materialize_node,
    make_scan_node,
    rewrite_dag,
)
from repro.core.query import AggQuery
from repro.core.stats import (
    FK_ELIM_MAX_ORPHANS,
    PREFILTER_MAX_SELECTIVITY,
    PREFILTER_MIN_PARENT_ROWS,
)
from repro.tables.table import Schema


class PlanningError(ValueError):
    """A query the planner cannot lower (cyclic, or a forced mode whose
    preconditions the query fails).  Subclasses ``ValueError`` so existing
    callers' handlers keep working; the serving tier catches it per
    request so one unplannable query never aborts its batch-mates."""


def _var_cols(query: AggQuery, schema: Schema) -> dict[str, dict[str, str]]:
    out: dict[str, dict[str, str]] = {}
    for a in query.atoms:
        cols = schema.relations[a.rel].column_names()
        m: dict[str, str] = {}
        for i, v in enumerate(a.vars):
            m.setdefault(v, cols[i])
        out[a.alias] = m
    return out


def _key_unique_in(schema: Schema, atom, on_vars, var_cols) -> bool:
    cols = [var_cols[atom.alias][v] for v in on_vars]
    return schema.relations[atom.rel].is_unique(cols)


@dataclasses.dataclass
class PlanBuild:
    """Mutable state threaded through the pass pipeline."""

    query: AggQuery
    schema: Schema
    mode: str                 # resolved after _pass_classify
    use_fkpk: bool
    stats: object = None      # StatsCatalog | None — calibration source
    tree: object = None       # JoinTree after _pass_classify
    guard: str | None = None
    var_cols: dict = dataclasses.field(default_factory=dict)
    root: PlanNode | None = None  # FinalAgg node after _pass_lower
    decisions: list = dataclasses.field(default_factory=list)

    def decide(self, pass_name: str, target: str, applied: bool,
               reason: str, stats: dict | None = None,
               rels: tuple = ()) -> bool:
        """Record one gated decision; returns ``applied`` so call sites
        read ``if st.decide(...):``.  ``rels`` names the relations whose
        catalog tokens the gate consulted (→ ``Decision.depends``)."""
        depends = []
        if self.stats is not None:
            for r in sorted(set(rels)):
                tok = self.stats.token(r)
                if tok is not None:
                    depends.append((r, tok))
        self.decisions.append(Decision(
            pass_name=pass_name, target=target, applied=applied,
            reason=reason,
            stats=tuple(sorted((stats or {}).items())),
            depends=tuple(depends)))
        return applied


def _pass_classify(st: PlanBuild) -> PlanBuild:
    cls = classify(st.query, st.schema)
    if cls.tree is None:
        raise PlanningError(
            "cyclic query: out of the paper's guarded-acyclic fragment "
            "(would need hypertree decomposition, see paper §7)")
    st.tree = cls.tree
    st.guard = cls.guard
    st.var_cols = _var_cols(st.query, st.schema)
    if st.mode == "auto":
        if cls.is_oma:
            st.mode = "oma"
        elif cls.guarded:
            st.mode = "opt_plus"
        else:
            st.mode = "ref"
    if st.mode == "oma" and not cls.is_oma:
        raise PlanningError("query is not 0MA; cannot force oma mode")
    if st.mode in ("opt", "opt_plus") and not cls.guarded:
        raise PlanningError("query is not guarded; frequency propagation "
                            "would lose the aggregate attributes")
    st.decide("classify", "", True,
              f"mode={st.mode}",
              {"acyclic": cls.acyclic, "guarded": cls.guarded,
               "oma": cls.is_oma, "set_safe": cls.set_safe,
               "guard": cls.guard or ""})
    return st


def _pass_reroot_guard(st: PlanBuild) -> PlanBuild:
    # classify() already roots the tree at its preferred guard (it tries
    # each guard candidate for whole-tree FK/PK safety); this pass is the
    # explicit seam where an alternative rooting policy would plug in.
    if st.guard is None:
        st.decide("reroot_guard", "", False, "no guard: unguarded query")
    elif st.tree.root != st.guard:
        st.tree = st.tree.rerooted(st.guard)
        st.decide("reroot_guard", st.guard, True,
                  f"re-rooted join tree at guard {st.guard!r} (§4.1)")
    else:
        st.decide("reroot_guard", st.guard, False,
                  f"tree already rooted at guard {st.guard!r}")
    return st


def _pass_lower(st: PlanBuild) -> PlanBuild:
    """Emit the op graph: scans, the mode-generic join sweep, final agg."""
    query, tree, mode = st.query, st.tree, st.mode
    cur: dict[str, PlanNode] = {}
    for a in query.atoms:
        op = ScanOp(a.alias, a.rel, None, spec=None)
        cur[a.alias] = make_scan_node(op, a)

    if mode == "ref":
        # left-deep materialising joins in join-tree connectivity order so
        # every join has a shared key (no cross products).
        order = [u for u in reversed(tree.postorder())]  # root first
        base = order[0]
        for nxt in order[1:]:
            par = tree.parent[nxt]
            on = tree.shared_vars(par, nxt) if par is not None else ()
            op = MaterializeJoinOp(base, nxt, on, regroup=False)
            cur[base] = make_materialize_node(op, cur[base], cur[nxt])
        agg = FinalAggOp(base, query.group_by, query.aggregates,
                         dedup=False)
        st.root = make_final_agg_node(agg, cur[base], tree.atoms.get(base))
        st.decide("lower", "", True,
                  "materialising left-deep join chain (ref baseline)",
                  {"mode": mode, "atoms": len(query.atoms)})
        return st

    # bottom-up sweep over join-tree edges (children before parents)
    for parent, child in tree.edges_bottom_up():
        on = tree.shared_vars(parent, child)
        if mode == "oma":
            op = SemiJoinOp(parent, child, on)
            cur[parent] = make_join_node(op, cur[parent], cur[child],
                                         st.var_cols)
        elif mode == "opt":
            op = MaterializeJoinOp(parent, child, on, regroup=True)
            cur[parent] = make_materialize_node(op, cur[parent], cur[child])
        else:  # opt_plus
            op = FreqJoinOp(parent, child, on, pregroup=True)
            cur[parent] = make_join_node(op, cur[parent], cur[child],
                                         st.var_cols)

    agg = FinalAggOp(tree.root, query.group_by, query.aggregates,
                     dedup=(mode == "oma"))
    st.root = make_final_agg_node(agg, cur[tree.root],
                                  tree.atoms.get(tree.root))
    st.decide("lower", "", True,
              f"bottom-up {mode} sweep over join-tree edges",
              {"mode": mode, "atoms": len(query.atoms)})
    return st


def _pass_fkpk_degrade(st: PlanBuild) -> PlanBuild:
    """§4.3 as an IR rewrite over the lowered graph."""
    if not st.use_fkpk or st.mode not in ("opt", "opt_plus"):
        st.decide("fkpk_degrade", "", False,
                  "gate: use_fkpk off" if not st.use_fkpk
                  else f"gate: mode {st.mode!r} has no freq joins to "
                       "degrade")
        return st
    tree, schema, var_cols = st.tree, st.schema, st.var_cols

    def rw(node: PlanNode, ins: tuple[PlanNode, ...]) -> PlanNode:
        op = node.op
        if isinstance(op, (FreqJoinOp, MaterializeJoinOp)) \
                and tree.parent.get(op.child) == op.parent:
            edge = f"{op.parent}⋈{op.child}"
            fkpk = edge_is_fk_pk(tree, schema, op.parent, op.child) \
                and subtree_all_fk_pk(tree, schema, op.child)
            if fkpk:
                # child freq ≡ 1 and ≤1 partner: the join degenerates to a
                # semi-join (§4.3) — skip the grouping machinery entirely.
                st.decide("fkpk_degrade", edge, True,
                          "whole child subtree is FK→PK: freq ≡ 1, join "
                          "degrades to semi-join (§4.3)")
                semi = SemiJoinOp(op.parent, op.child, op.on_vars)
                return make_join_node(semi, ins[0], ins[1], var_cols)
            st.decide("fkpk_degrade", edge, False,
                      "child subtree not FK→PK throughout")
            if isinstance(op, FreqJoinOp):
                pregroup = not _key_unique_in(
                    schema, tree.atoms[op.child], op.on_vars, var_cols)
                if pregroup != op.pregroup:
                    rep = dataclasses.replace(op, pregroup=pregroup)
                    return make_join_node(rep, ins[0], ins[1], var_cols)
        return _rebuild(node, ins, st)

    st.root = rewrite_dag(st.root, rw)
    return st


def _fk_edge_cols(st: PlanBuild, parent: str, child: str,
                  on_vars) -> tuple[str, str, str, str] | None:
    """(src_rel, src_col, dst_rel, dst_col) of the declared FK behind an
    FK→PK tree edge, or None."""
    if len(on_vars) != 1:
        return None
    v = on_vars[0]
    src_rel = st.tree.atoms[parent].rel
    dst_rel = st.tree.atoms[child].rel
    src_col = st.var_cols[parent].get(v)
    dst_col = st.var_cols[child].get(v)
    if src_col is None or dst_col is None:
        return None
    return src_rel, src_col, dst_rel, dst_col


def _pass_fk_join_eliminate(st: PlanBuild) -> PlanBuild:
    """Drop semi-joins that provably filter nothing.

    Structural gate: a ``SemiJoinOp`` on a tree edge whose child input is
    a bare leaf scan, the edge is a declared FK→PK, the child carries no
    selection, and no child-exclusive variable feeds the output.  Under
    those conditions the semi-join can only remove parent rows whose FK
    value has no live partner — *orphans*.

    Stats calibration: measured orphan count for that FK must be
    ``<= FK_ELIM_MAX_ORPHANS`` (i.e. zero).  Referential integrity is
    never assumed from the declaration alone: the catalog counted it on
    this exact data version, and the decision's ``depends`` tokens pin
    both tables so any later change invalidates the plan."""
    if st.mode not in ("oma", "opt", "opt_plus"):
        st.decide("fk_join_eliminate", "", False,
                  "gate: materialising baseline emits no semi-joins")
        return st
    query, needed = st.query, set(st.query.output_vars())

    def rw(node: PlanNode, ins: tuple[PlanNode, ...]) -> PlanNode:
        op = node.op
        if not (isinstance(op, SemiJoinOp)
                and isinstance(ins[1].op, ScanOp)
                and st.tree.parent.get(op.child) == op.parent):
            return _rebuild(node, ins, st)
        edge = f"{op.parent}⋉{op.child}"
        if op.child in query.selections or op.child in query.selection_specs:
            st.decide("fk_join_eliminate", edge, False,
                      "child carries a selection: the semi-join filters")
            return _rebuild(node, ins, st)
        extra = set(st.tree.atoms[op.child].vars) - set(op.on_vars)
        if extra & needed:
            st.decide("fk_join_eliminate", edge, False,
                      f"child vars {sorted(extra & needed)} feed the "
                      "output")
            return _rebuild(node, ins, st)
        fk = _fk_edge_cols(st, op.parent, op.child, op.on_vars)
        if fk is None or not st.schema.fk_edge(*fk) \
                or not edge_is_fk_pk(st.tree, st.schema, op.parent,
                                     op.child):
            st.decide("fk_join_eliminate", edge, False,
                      "edge is not a declared FK→PK")
            return _rebuild(node, ins, st)
        if st.stats is None:
            st.decide("fk_join_eliminate", edge, False,
                      "no stats catalog: orphan count unverifiable")
            return _rebuild(node, ins, st)
        src_rel, src_col, dst_rel, dst_col = fk
        tstats = st.stats.get(src_rel)
        orphans = None if tstats is None else \
            tstats.fk_orphans.get(f"{src_col}->{dst_rel}.{dst_col}")
        if orphans is None:
            st.decide("fk_join_eliminate", edge, False,
                      f"no orphan statistics for {src_rel}.{src_col}",
                      rels=(src_rel, dst_rel))
            return _rebuild(node, ins, st)
        if orphans > FK_ELIM_MAX_ORPHANS:
            st.decide("fk_join_eliminate", edge, False,
                      f"{orphans} orphaned {src_rel}.{src_col} refs: "
                      "the semi-join filters them",
                      {"orphans": orphans,
                       "max_orphans": FK_ELIM_MAX_ORPHANS},
                      rels=(src_rel, dst_rel))
            return _rebuild(node, ins, st)
        st.decide("fk_join_eliminate", edge, True,
                  "FK→PK with zero measured orphans: the semi-join is an "
                  "identity on live rows — eliminated",
                  {"orphans": orphans,
                   "max_orphans": FK_ELIM_MAX_ORPHANS},
                  rels=(src_rel, dst_rel))
        return ins[0]

    st.root = rewrite_dag(st.root, rw)
    return st


def _pass_prefilter_pushdown(st: PlanBuild) -> PlanBuild:
    """Selective-dimension pre-filter pushdown for the materialising
    baseline.

    Structural gate: ``mode == "ref"`` (sweep modes already filter every
    edge bottom-up — a pre-filter would duplicate work the static-shape
    sweep does anyway), and a join-tree edge (parent, child) where the
    child carries a *declarative* selection spec.

    Stats calibration: the child's estimated selectivity must be
    ``<= PREFILTER_MAX_SELECTIVITY`` and the parent big enough
    (``>= PREFILTER_MIN_PARENT_ROWS``) that shrinking the materialised
    intermediates pays for an extra semi-join.

    Apply: the parent's scan is wrapped in a semi-join against the
    (soon-to-be-filtered) child scan, so parent rows that would join to
    nothing are dead *before* the row-expanding joins run.  Answer-
    preserving: a parent row with no surviving child partner contributes
    no tuple to the join result either way."""
    if st.mode != "ref":
        st.decide("prefilter_pushdown", "", False,
                  f"gate: {st.mode} sweeps already semi-filter every edge")
        return st
    if st.stats is None:
        st.decide("prefilter_pushdown", "", False,
                  "no stats catalog: selectivity unverifiable")
        return st

    query = st.query
    # candidate pre-filters, grouped by the parent alias whose scan they
    # wrap (a parent with several selective children gets nested filters)
    wraps: dict[str, list] = {}
    for parent, child in st.tree.edges_bottom_up():
        spec = query.selection_specs.get(child)
        if spec is None:
            continue
        edge = f"{parent}⋉{child}"
        child_rel = st.tree.atoms[child].rel
        parent_rel = st.tree.atoms[parent].rel
        sel = st.stats.estimate_selectivity(child_rel, spec)
        pstats = st.stats.get(parent_rel)
        prows = pstats.rows if pstats is not None else None
        if sel is None or prows is None:
            st.decide("prefilter_pushdown", edge, False,
                      f"no statistics for {child_rel}/{parent_rel}",
                      rels=(child_rel, parent_rel))
            continue
        gate = {"selectivity": round(sel, 4),
                "max_selectivity": PREFILTER_MAX_SELECTIVITY,
                "parent_rows": prows,
                "min_parent_rows": PREFILTER_MIN_PARENT_ROWS}
        if sel > PREFILTER_MAX_SELECTIVITY:
            st.decide("prefilter_pushdown", edge, False,
                      f"child {child_rel} not selective enough",
                      gate, rels=(child_rel, parent_rel))
            continue
        if prows < PREFILTER_MIN_PARENT_ROWS:
            st.decide("prefilter_pushdown", edge, False,
                      f"parent {parent_rel} too small: semi-join overhead "
                      "exceeds the materialisation saved",
                      gate, rels=(child_rel, parent_rel))
            continue
        st.decide("prefilter_pushdown", edge, True,
                  f"selective {child_rel} pre-filters {parent_rel} before "
                  "the materialising chain",
                  gate, rels=(child_rel, parent_rel))
        on = st.tree.shared_vars(parent, child)
        wraps.setdefault(parent, []).append((child, on))
    if not wraps:
        return st

    # locate the shared child scan nodes so the inserted semi-joins reuse
    # the very nodes the join chain reads (selections attach once, later)
    scans = {n.op.alias: n for n in st.root.postorder()
             if isinstance(n.op, ScanOp)}

    def rw(node: PlanNode, ins: tuple[PlanNode, ...]) -> PlanNode:
        op = node.op
        if isinstance(op, ScanOp) and op.alias in wraps:
            out = node
            for child, on in wraps[op.alias]:
                semi = SemiJoinOp(op.alias, child, on)
                out = make_join_node(semi, out, scans[child], st.var_cols)
            return out
        return _rebuild(node, ins, st)

    st.root = rewrite_dag(st.root, rw)
    return st


def _pass_attach_selections(st: PlanBuild) -> PlanBuild:
    """Attach the query's per-alias selections to the scan nodes."""
    query = st.query
    if not query.selections:
        return st

    def rw(node: PlanNode, ins: tuple[PlanNode, ...]) -> PlanNode:
        op = node.op
        if isinstance(op, ScanOp) and op.alias in query.selections:
            rep = dataclasses.replace(
                op, selection=query.selections[op.alias],
                spec=query.selection_specs.get(op.alias))
            return make_scan_node(rep, query.atom(op.alias))
        return _rebuild(node, ins, st)

    st.root = rewrite_dag(st.root, rw)
    return st


def _rebuild(node: PlanNode, ins: tuple[PlanNode, ...],
             st: PlanBuild) -> PlanNode:
    """Re-create `node` over rewritten inputs (identity when unchanged)."""
    if ins == node.inputs:
        return node
    op = node.op
    if isinstance(op, (SemiJoinOp, FreqJoinOp)):
        return make_join_node(op, ins[0], ins[1], st.var_cols)
    if isinstance(op, MaterializeJoinOp):
        return make_materialize_node(op, ins[0], ins[1])
    if isinstance(op, FinalAggOp):
        return make_final_agg_node(op, ins[0],
                                   st.tree.atoms.get(op.root))
    return PlanNode(op, ins, node.struct)  # pragma: no cover


PASSES = (
    _pass_classify,
    _pass_reroot_guard,
    _pass_lower,
    _pass_fkpk_degrade,
    _pass_fk_join_eliminate,
    _pass_prefilter_pushdown,
    _pass_attach_selections,
)


def plan_query(query: AggQuery, schema: Schema, mode: str = "auto",
               use_fkpk: bool = False, stats=None) -> PhysicalPlan:
    """Plan ``query``.  ``stats`` is an optional
    :class:`~repro.core.stats.StatsCatalog`: with it, the stats-calibrated
    passes (FK-join elimination, pre-filter pushdown) may fire; without
    it they record a skip and the output matches the stats-free planner
    exactly.  Raises :class:`PlanningError` for unplannable queries."""
    st = PlanBuild(query, schema, mode, use_fkpk, stats=stats)
    for p in PASSES:
        st = p(st)
    return PhysicalPlan(st.mode, st.root, st.tree, st.var_cols,
                        decisions=tuple(st.decisions))


__all__ = ["plan_query", "classify", "build_join_tree", "PASSES",
           "PlanBuild", "PlanningError"]
