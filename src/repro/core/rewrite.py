"""The planner: a pass pipeline lowering logical queries to the op-graph IR
(paper §4).

``plan_query`` turns an AggQuery into a ``PhysicalPlan`` by running a small
sequence of passes over a shared build state:

  1. ``_pass_classify``   — GYO → join tree; classify (acyclic / guarded /
                            set-safe / 0MA); resolve ``mode="auto"``
                            (0MA → semi-join sweep; guarded → FreqJoin
                            sweep (Opt⁺); unguarded/cyclic → materialising
                            baseline, the paper's fallback).
  2. ``_pass_reroot_guard``— re-root the join tree at the guard (§4.1);
                            join trees are freely re-rootable.
  3. ``_pass_lower``      — emit the op graph: one scan node per atom
                            (selections not yet attached), a join node per
                            tree edge (mode-generic sweep), the final
                            aggregate node.
  4. ``_pass_fkpk_degrade``— §4.3 IR rewrite: an edge whose whole child
                            subtree is FK→PK carries frequency ≡ 1, so the
                            FreqJoin/materialising join degrades to a
                            semi-join; child pre-grouping is dropped when
                            the join key is unique in the child.
  5. ``_pass_attach_selections`` — rewrite scan nodes to carry the query's
                            per-alias selections (callable + declarative
                            spec), which flows into the nodes' content keys.

Each pass is ``PlanBuild → PlanBuild`` and the pipeline is the module-level
``PASSES`` tuple, so new rewrites (e.g. admission-driven batch formation)
slot in without touching the others.  Modes can be forced (benchmarks
compare ref / opt / opt_plus / oma on the same query, mirroring the
paper's experimental conditions).
"""

from __future__ import annotations

import dataclasses

from repro.core.hypergraph import build_join_tree
from repro.core.oma import classify, edge_is_fk_pk, subtree_all_fk_pk
from repro.core.plan import (
    FinalAggOp,
    FreqJoinOp,
    MaterializeJoinOp,
    PhysicalPlan,
    PlanNode,
    ScanOp,
    SemiJoinOp,
    make_final_agg_node,
    make_join_node,
    make_materialize_node,
    make_scan_node,
    rewrite_dag,
)
from repro.core.query import AggQuery
from repro.tables.table import Schema


def _var_cols(query: AggQuery, schema: Schema) -> dict[str, dict[str, str]]:
    out: dict[str, dict[str, str]] = {}
    for a in query.atoms:
        cols = schema.relations[a.rel].column_names()
        m: dict[str, str] = {}
        for i, v in enumerate(a.vars):
            m.setdefault(v, cols[i])
        out[a.alias] = m
    return out


def _key_unique_in(schema: Schema, atom, on_vars, var_cols) -> bool:
    cols = [var_cols[atom.alias][v] for v in on_vars]
    return schema.relations[atom.rel].is_unique(cols)


@dataclasses.dataclass
class PlanBuild:
    """Mutable state threaded through the pass pipeline."""

    query: AggQuery
    schema: Schema
    mode: str                 # resolved after _pass_classify
    use_fkpk: bool
    tree: object = None       # JoinTree after _pass_classify
    guard: str | None = None
    var_cols: dict = dataclasses.field(default_factory=dict)
    root: PlanNode | None = None  # FinalAgg node after _pass_lower


def _pass_classify(st: PlanBuild) -> PlanBuild:
    cls = classify(st.query, st.schema)
    if cls.tree is None:
        raise ValueError(
            "cyclic query: out of the paper's guarded-acyclic fragment "
            "(would need hypertree decomposition, see paper §7)")
    st.tree = cls.tree
    st.guard = cls.guard
    st.var_cols = _var_cols(st.query, st.schema)
    if st.mode == "auto":
        if cls.is_oma:
            st.mode = "oma"
        elif cls.guarded:
            st.mode = "opt_plus"
        else:
            st.mode = "ref"
    if st.mode == "oma" and not cls.is_oma:
        raise ValueError("query is not 0MA; cannot force oma mode")
    if st.mode in ("opt", "opt_plus") and not cls.guarded:
        raise ValueError("query is not guarded; frequency propagation "
                         "would lose the aggregate attributes")
    return st


def _pass_reroot_guard(st: PlanBuild) -> PlanBuild:
    # classify() already roots the tree at its preferred guard (it tries
    # each guard candidate for whole-tree FK/PK safety); this pass is the
    # explicit seam where an alternative rooting policy would plug in.
    if st.guard is not None and st.tree.root != st.guard:
        st.tree = st.tree.rerooted(st.guard)
    return st


def _pass_lower(st: PlanBuild) -> PlanBuild:
    """Emit the op graph: scans, the mode-generic join sweep, final agg."""
    query, tree, mode = st.query, st.tree, st.mode
    cur: dict[str, PlanNode] = {}
    for a in query.atoms:
        op = ScanOp(a.alias, a.rel, None, spec=None)
        cur[a.alias] = make_scan_node(op, a)

    if mode == "ref":
        # left-deep materialising joins in join-tree connectivity order so
        # every join has a shared key (no cross products).
        order = [u for u in reversed(tree.postorder())]  # root first
        base = order[0]
        for nxt in order[1:]:
            par = tree.parent[nxt]
            on = tree.shared_vars(par, nxt) if par is not None else ()
            op = MaterializeJoinOp(base, nxt, on, regroup=False)
            cur[base] = make_materialize_node(op, cur[base], cur[nxt])
        agg = FinalAggOp(base, query.group_by, query.aggregates,
                         dedup=False)
        st.root = make_final_agg_node(agg, cur[base], tree.atoms.get(base))
        return st

    # bottom-up sweep over join-tree edges (children before parents)
    for parent, child in tree.edges_bottom_up():
        on = tree.shared_vars(parent, child)
        if mode == "oma":
            op = SemiJoinOp(parent, child, on)
            cur[parent] = make_join_node(op, cur[parent], cur[child],
                                         st.var_cols)
        elif mode == "opt":
            op = MaterializeJoinOp(parent, child, on, regroup=True)
            cur[parent] = make_materialize_node(op, cur[parent], cur[child])
        else:  # opt_plus
            op = FreqJoinOp(parent, child, on, pregroup=True)
            cur[parent] = make_join_node(op, cur[parent], cur[child],
                                         st.var_cols)

    agg = FinalAggOp(tree.root, query.group_by, query.aggregates,
                     dedup=(mode == "oma"))
    st.root = make_final_agg_node(agg, cur[tree.root],
                                  tree.atoms.get(tree.root))
    return st


def _pass_fkpk_degrade(st: PlanBuild) -> PlanBuild:
    """§4.3 as an IR rewrite over the lowered graph."""
    if not st.use_fkpk or st.mode not in ("opt", "opt_plus"):
        return st
    tree, schema, var_cols = st.tree, st.schema, st.var_cols

    def rw(node: PlanNode, ins: tuple[PlanNode, ...]) -> PlanNode:
        op = node.op
        if isinstance(op, (FreqJoinOp, MaterializeJoinOp)) \
                and tree.parent.get(op.child) == op.parent:
            fkpk = edge_is_fk_pk(tree, schema, op.parent, op.child) \
                and subtree_all_fk_pk(tree, schema, op.child)
            if fkpk:
                # child freq ≡ 1 and ≤1 partner: the join degenerates to a
                # semi-join (§4.3) — skip the grouping machinery entirely.
                semi = SemiJoinOp(op.parent, op.child, op.on_vars)
                return make_join_node(semi, ins[0], ins[1], var_cols)
            if isinstance(op, FreqJoinOp):
                pregroup = not _key_unique_in(
                    schema, tree.atoms[op.child], op.on_vars, var_cols)
                if pregroup != op.pregroup:
                    rep = dataclasses.replace(op, pregroup=pregroup)
                    return make_join_node(rep, ins[0], ins[1], var_cols)
        return _rebuild(node, ins, st)

    st.root = rewrite_dag(st.root, rw)
    return st


def _pass_attach_selections(st: PlanBuild) -> PlanBuild:
    """Attach the query's per-alias selections to the scan nodes."""
    query = st.query
    if not query.selections:
        return st

    def rw(node: PlanNode, ins: tuple[PlanNode, ...]) -> PlanNode:
        op = node.op
        if isinstance(op, ScanOp) and op.alias in query.selections:
            rep = dataclasses.replace(
                op, selection=query.selections[op.alias],
                spec=query.selection_specs.get(op.alias))
            return make_scan_node(rep, query.atom(op.alias))
        return _rebuild(node, ins, st)

    st.root = rewrite_dag(st.root, rw)
    return st


def _rebuild(node: PlanNode, ins: tuple[PlanNode, ...],
             st: PlanBuild) -> PlanNode:
    """Re-create `node` over rewritten inputs (identity when unchanged)."""
    if ins == node.inputs:
        return node
    op = node.op
    if isinstance(op, (SemiJoinOp, FreqJoinOp)):
        return make_join_node(op, ins[0], ins[1], st.var_cols)
    if isinstance(op, MaterializeJoinOp):
        return make_materialize_node(op, ins[0], ins[1])
    if isinstance(op, FinalAggOp):
        return make_final_agg_node(op, ins[0],
                                   st.tree.atoms.get(op.root))
    return PlanNode(op, ins, node.struct)  # pragma: no cover


PASSES = (
    _pass_classify,
    _pass_reroot_guard,
    _pass_lower,
    _pass_fkpk_degrade,
    _pass_attach_selections,
)


def plan_query(query: AggQuery, schema: Schema, mode: str = "auto",
               use_fkpk: bool = False) -> PhysicalPlan:
    st = PlanBuild(query, schema, mode, use_fkpk)
    for p in PASSES:
        st = p(st)
    return PhysicalPlan(st.mode, st.root, st.tree, st.var_cols)


__all__ = ["plan_query", "classify", "build_join_tree", "PASSES",
           "PlanBuild"]
