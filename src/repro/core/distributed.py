"""Distributed Yannakakis sweep: Ring-FreqJoin over the device mesh.

The paper runs on Spark, whose physical layer hash-shuffles both join sides.
A TPU mesh has no shuffle service, and all-to-all hash partitioning needs
worst-case per-destination capacities (dynamic shapes).  We instead exploit
the additive-semiring law the FreqJoin computes with (property-tested in
tests/test_kernels.py):

    mult(R, S₁ ⊎ S₂) = mult(R, S₁) + mult(R, S₂)

so with the child relation row-sharded over the mesh, each parent shard can
accumulate exact multipliers by visiting every child shard once around a
ring (`lax.ppermute`), exactly like ring attention:

    for step in range(axis_size):
        mult += local_multiplier(parent_keys, child_shard)
        child_shard = ppermute(child_shard, +1)

Parent rows never move; no shuffle capacities; static shapes throughout; and
the per-step compute (sort once, then searchsorted) overlaps with the
ppermute of the next shard (XLA latency hiding).  The semi-join sweep is the
same ring in the Boolean semiring (max instead of +).

Multi-pod: the ring nests — a full `data`-ring per `pod` step — so
inter-pod (DCI) hops happen once per pod, not once per shard.

There is ONE plan interpreter: ``DistributedExecutor`` subclasses
``core.executor.Executor`` and reuses its node-keyed graph traversal
(``_trace_plan``) verbatim — the mesh lowering only swaps the node
evaluator (``_RingExecutor``: semi/freq joins become ring sweeps) and
runs the traversal inside one ``shard_map`` program per compile, stopping
at the pre-aggregate root state.  Content-key memoisation, sub-DAG dedup
and ``compile_multi`` fusion therefore work unchanged on the mesh: a
fused multi-query mesh program runs every shared sub-DAG's ring sweep
exactly once.

Final aggregates run *outside* the shard_map, on the root columns
constrained to a REPLICATED layout: the sweep's exact integer frequencies
are identical to the local engine's, and aggregating replicated arrays
executes the same single-device reduction program on every device — which
is what makes mesh answers bitwise-equal to a single-device reference over
identically-padded tables (see ``tables.table.sharded_bucket_capacity``).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

# jax.shard_map graduated from jax.experimental in 0.5.x; support both
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

# lax.pvary arrived with the 0.5.x varying-axes checker; under the older
# shard_map every value is already device-varying, so it's the identity
_pvary = getattr(lax, "pvary", lambda x, axes: x)

from repro.core.executor import Executor, _State
from repro.core.plan import (
    FreqJoinOp,
    PhysicalPlan,
    PlanNode,
    SemiJoinOp,
)
from repro.tables.table import (
    Schema,
    Table,
    sharded_bucket_capacity,
)


def _local_multiplier(pk, ck, cf, mode: str):
    """Exact multiplier of parent keys against ONE child shard
    (sort + prefix-sum + searchsorted; same algorithm as kernels.ops)."""
    order = jnp.argsort(ck)
    cks = ck[order]
    cfs = cf[order]
    if mode == "any":
        cfs = (cfs > 0).astype(cfs.dtype)
    prefix = jnp.concatenate([jnp.zeros((1,), cfs.dtype), jnp.cumsum(cfs)])
    lo = jnp.searchsorted(cks, pk, side="left")
    hi = jnp.searchsorted(cks, pk, side="right")
    return prefix[hi] - prefix[lo]


def ring_freq_join(pk, pf, ck, cf, *, ring_axes: Sequence[str],
                   mode: str = "sum", presort: bool = False):
    """Inside shard_map: exact FreqJoin with the child sharded over
    `ring_axes` (innermost axis rotates fastest).  Returns new parent freq.

    presort=False — baseline: each ring step sorts the visiting shard
        (what a naive port of the paper's sort-merge join does: Spark
        re-sorts per shuffle partition).
    presort=True  — beyond-paper: each shard sorts its child block ONCE
        and the ring rotates (sorted keys, prefix sums); every step is
        then two searchsorteds + a gather.  Saves (P−1) sorts per join —
        see EXPERIMENTS.md §Perf (engine cell).
    """
    mult = _pvary(jnp.zeros(pk.shape, pf.dtype), tuple(ring_axes))

    def rotate(x, axis):
        size = lax.psum(1, axis)
        perm = [(i, (i + 1) % size) for i in range(size)]
        return lax.ppermute(x, axis, perm)

    if presort:
        order = jnp.argsort(ck)
        cks = ck[order]
        cfs = cf[order]
        if mode == "any":
            cfs = (cfs > 0).astype(pf.dtype)
        prefix = jnp.concatenate(
            [jnp.zeros((1,), cfs.dtype), jnp.cumsum(cfs)])
        payload = (cks, prefix)

        def local(payload_):
            cks_, prefix_ = payload_
            lo = jnp.searchsorted(cks_, pk, side="left")
            hi = jnp.searchsorted(cks_, pk, side="right")
            return (prefix_[hi] - prefix_[lo]).astype(pf.dtype)
    else:
        payload = (ck, cf)

        def local(payload_):
            ck_, cf_ = payload_
            return _local_multiplier(pk, ck_, cf_, mode).astype(pf.dtype)

    # nested rings: data-ring innermost (ICI), pod-ring outermost (DCI)
    axes = list(ring_axes)
    sizes = [lax.psum(1, a) for a in axes]

    def body(carry, _):
        payload_, mult_ = carry
        m = local(payload_)
        mult_ = jnp.maximum(mult_, m) if mode == "any" else mult_ + m
        payload_ = jax.tree.map(lambda x: rotate(x, axes[-1]), payload_)
        return (payload_, mult_), None

    total_inner = sizes[-1]
    carry = (payload, mult)
    if len(axes) == 1:
        carry, _ = lax.scan(body, carry, None, length=total_inner)
    else:
        outer_axis, outer_size = axes[0], sizes[0]

        def outer_body(carry, _):
            carry, _ = lax.scan(body, carry, None, length=total_inner)
            payload_, mult_ = carry
            payload_ = jax.tree.map(lambda x: rotate(x, outer_axis),
                                    payload_)
            return (payload_, mult_), None

        carry, _ = lax.scan(outer_body, carry, None, length=outer_size)
    _, mult = carry
    if mode == "any":
        mult = (mult > 0).astype(pf.dtype)
    return pf * mult


def allreduce_freq_join(pk, pf, ck, cf, *, ring_axes: Sequence[str],
                        mode: str = "sum", domain: int):
    """Beyond-paper distributed FreqJoin for dense key domains: each shard
    scatter-adds its child block into a domain-sized accumulator, ONE psum
    over the ring axes produces the global multiplier table, and parents
    gather locally.  Replaces P ring steps (P ppermutes + P searchsorted
    passes) with one all-reduce of `domain` elements — the distributed
    twin of the local dense-domain FreqJoin (EXPERIMENTS §Perf)."""
    cfx = (cf > 0).astype(pf.dtype) if mode == "any" else cf.astype(pf.dtype)
    acc = jnp.zeros((domain,), pf.dtype)
    acc = acc.at[jnp.clip(ck, 0, domain - 1)].add(
        jnp.where((ck >= 0) & (ck < domain), cfx, 0))
    for a in ring_axes:
        acc = lax.psum(acc, a)
    mult = acc[jnp.clip(pk, 0, domain - 1)]
    mult = jnp.where((pk >= 0) & (pk < domain), mult, 0)
    if mode == "any":
        mult = (mult > 0).astype(pf.dtype)
    return pf * mult


def shard_table(table: Table, sharding) -> Table:
    """Place every column (and freq) of `table` under `sharding`."""
    cols = {c: jax.device_put(a, sharding) for c, a in table.columns.items()}
    return Table(cols, jax.device_put(table.freq, sharding))


class _RingExecutor(Executor):
    """Per-shard node evaluator: the ``Executor`` semantics with semi/freq
    joins replaced by ring (or dense-domain all-reduce) sweeps over the
    mesh axes.  Instantiated by ``DistributedExecutor._inner_executor``
    inside its shard_map program — every other node type (scans, the
    content-key memo, selection masking) is inherited unchanged, which is
    the whole point: one interpreter, two lowerings."""

    def __init__(self, db: dict[str, Table], schema: Schema, freq_dtype,
                 ring_axes: Sequence[str], presort: bool,
                 dense_domain: bool):
        super().__init__(db, schema, freq_dtype,
                         dense_domain=dense_domain)
        self.ring_axes = tuple(ring_axes)
        self.presort = presort

    def _key(self, plan, alias, st, on_vars):
        key, dom = super()._key(plan, alias, st, on_vars)
        if dom is not None and dom >= (1 << 31):
            # the all-reduce variant scatter-adds into a domain-sized
            # accumulator per shard — cap it at int32 indexing range and
            # fall back to the ring
            dom = None
        return key, dom

    def _ring(self, pk, pf, ck, cf, cdom, mode: str):
        if cdom is not None:
            return allreduce_freq_join(pk, pf, ck, cf,
                                       ring_axes=self.ring_axes,
                                       mode=mode, domain=cdom)
        return ring_freq_join(pk, pf, ck, cf, ring_axes=self.ring_axes,
                              mode=mode, presort=self.presort)

    def _semi_join(self, plan, op: SemiJoinOp, p: _State,
                   c: _State) -> _State:
        pk, _pd = self._key(plan, op.parent, p, op.on_vars)
        ck, cdom = self._key(plan, op.child, c, op.on_vars)
        return _State(p.cols, self._ring(pk, p.freq, ck, c.freq, cdom,
                                         "any"))

    def _freq_join(self, plan, op: FreqJoinOp, p: _State,
                   c: _State) -> _State:
        # op.pregroup (pre-summing duplicate child keys) is a local-engine
        # micro-optimisation; the ring accumulates exact per-shard sums
        # anyway, so it is ignored — identical integers by the semiring law
        pk, _pd = self._key(plan, op.parent, p, op.on_vars)
        ck, cdom = self._key(plan, op.child, c, op.on_vars)
        return _State(p.cols, self._ring(pk, p.freq, ck, c.freq, cdom,
                                         "sum"))

    def _final_agg(self, plan, op, st):  # pragma: no cover — guarded
        raise TypeError("final aggregation must not run per-shard; "
                        "DistributedExecutor aggregates outside shard_map")


class DistributedExecutor(Executor):
    """The graph interpreter lowered onto a device mesh.

    Tables are row-sharded over `data_axes` (e.g. ("pod", "data") on the
    production mesh).  ``compile``/``compile_multi`` emit ONE jitted
    program per call: the inherited ``_trace_plan`` traversal runs inside
    a single ``shard_map`` with ``_RingExecutor`` as the node evaluator —
    every semi/freq join a ring sweep, every memo hit shared across member
    plans — evaluated up to each plan's pre-aggregate root state; final
    aggregation then runs outside the shard_map on replicated root
    columns, so answers are bitwise-equal to a single-device run over the
    same padded capacities.
    """

    def __init__(self, schema: Schema, mesh: jax.sharding.Mesh,
                 data_axes: Sequence[str] = ("data",),
                 freq_dtype=jnp.int32, presort: bool = False,
                 dense_domain: bool = False,
                 span_hook=None, profile_annotations: bool = False):
        super().__init__({}, schema, freq_dtype,
                         dense_domain=dense_domain, span_hook=span_hook,
                         profile_annotations=profile_annotations)
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.presort = presort

    def jittable(self) -> "DistributedExecutor":
        return self          # never carries eager-only options

    # -- sharding helpers --------------------------------------------------
    @property
    def n_shards(self) -> int:
        n = 1
        for a in self.data_axes:
            n *= self.mesh.shape[a]
        return n

    def topology(self) -> tuple[tuple[str, ...], tuple[int, ...]]:
        """(axis names, shard counts) — the shape-relevant mesh identity
        the serving tier folds into its executable-cache keys."""
        return (self.data_axes,
                tuple(self.mesh.shape[a] for a in self.data_axes))

    def row_sharding(self):
        return jax.sharding.NamedSharding(
            self.mesh, jax.sharding.PartitionSpec(self.data_axes))

    def replicated_sharding(self):
        return jax.sharding.NamedSharding(
            self.mesh, jax.sharding.PartitionSpec())

    def shard_capacity(self, n_rows: int, min_bucket: int = 8) -> int:
        """Global padded capacity for an n-row table on this mesh: each
        shard gets a power-of-two block, so within-bucket per-shard growth
        never changes the compiled program's shapes."""
        return sharded_bucket_capacity(n_rows, self.n_shards, min_bucket)

    def shard_db(self, db: dict[str, Table],
                 min_bucket: int = 8) -> dict[str, Table]:
        """Pad each table to its per-shard power-of-two bucket
        (``sharded_bucket_capacity``) and shard rows over the mesh."""
        sh = self.row_sharding()
        return {name: shard_table(t.pad_to(self.shard_capacity(t.capacity,
                                                               min_bucket)),
                                  sh)
                for name, t in db.items()}

    # -- plan execution ----------------------------------------------------
    def _inner_executor(self, db: dict[str, Table]) -> Executor:
        return _RingExecutor(db, self.schema, self.freq_dtype,
                             self.data_axes, self.presort,
                             self.dense_domain)

    @staticmethod
    def _agg_state_node(plan: PhysicalPlan) -> PlanNode:
        """The pre-aggregate root state — where the shard_map stops."""
        return plan.root.inputs[0]

    @staticmethod
    def _agg_cols(plan: PhysicalPlan) -> set[str]:
        """Root-state columns the final aggregate actually reads; only
        these leave the shard_map (smaller out-specs, nothing else is
        gathered)."""
        op = plan.root.op
        need = set(op.group_by)
        for ag in op.aggregates:
            if ag.var is not None:
                need.add(ag.var)
        return need

    def _ring_program(self, plans: list[PhysicalPlan]):
        """db → [result dict per plan]: one shard_map sweep evaluating
        every member to its root state (shared trace memo, exactly like
        the local ``compile_multi``), then replicated final aggregation."""
        spec = jax.sharding.PartitionSpec(self.data_axes)
        rep = self.replicated_sharding()

        def sweep(db: dict[str, Table]):
            memo: dict = {}
            outs = []
            for plan in plans:
                st = self._trace_plan(db, plan, memo,
                                      root=self._agg_state_node(plan))
                need = self._agg_cols(plan)
                outs.append(({v: c for v, c in st.cols.items()
                              if v in need}, st.freq))
            return outs

        def run(db: dict[str, Table]):
            specs = jax.tree.map(lambda _: spec, db)
            outs = _shard_map(sweep, mesh=self.mesh, in_specs=(specs,),
                              out_specs=spec)(db)
            results = []
            for plan, (cols, freq) in zip(plans, outs):
                # replicate the (exact, order-independent) sweep output so
                # the aggregate program is the single-device one on every
                # device — bitwise parity with the local executor
                cols = {v: jax.lax.with_sharding_constraint(c, rep)
                        for v, c in cols.items()}
                freq = jax.lax.with_sharding_constraint(freq, rep)
                results.append(self._final_agg(plan, plan.root.op,
                                               _State(cols, freq)))
            return results

        return run

    def compile(self, plan: PhysicalPlan):
        """Jit one plan's ring program: sharded db → aggregates."""
        self._check_jittable([plan])
        run = self._ring_program([plan])
        return self._wrap_jitted(jax.jit(lambda db: run(db)[0]),
                                 "executor.run")

    def compile_multi(self, plans: list[PhysicalPlan]):
        """Jit several plans into ONE mesh program (shared ring sweeps):
        sharded db → [aggregates], results in plan order."""
        if not plans:
            raise ValueError("compile_multi needs at least one plan")
        self._check_jittable(plans)
        return self._wrap_jitted(jax.jit(self._ring_program(list(plans))),
                                 "executor.run_multi")
