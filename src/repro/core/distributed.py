"""Distributed Yannakakis sweep: Ring-FreqJoin over the device mesh.

The paper runs on Spark, whose physical layer hash-shuffles both join sides.
A TPU mesh has no shuffle service, and all-to-all hash partitioning needs
worst-case per-destination capacities (dynamic shapes).  We instead exploit
the additive-semiring law the FreqJoin computes with (property-tested in
tests/test_kernels.py):

    mult(R, S₁ ⊎ S₂) = mult(R, S₁) + mult(R, S₂)

so with the child relation row-sharded over the mesh, each parent shard can
accumulate exact multipliers by visiting every child shard once around a
ring (`lax.ppermute`), exactly like ring attention:

    for step in range(axis_size):
        mult += local_multiplier(parent_keys, child_shard)
        child_shard = ppermute(child_shard, +1)

Parent rows never move; no shuffle capacities; static shapes throughout; and
the per-step compute (sort once, then searchsorted) overlaps with the
ppermute of the next shard (XLA latency hiding).  The semi-join sweep is the
same ring in the Boolean semiring (max instead of +).

Multi-pod: the ring nests — a full `data`-ring per `pod` step — so
inter-pod (DCI) hops happen once per pod, not once per shard.

Final aggregates run *outside* the shard_map on row-sharded root columns;
jnp reductions over sharded arrays let XLA insert the psum/all-gather, and
grouping reuses the same segmented machinery.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

# jax.shard_map graduated from jax.experimental in 0.5.x; support both
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

# lax.pvary arrived with the 0.5.x varying-axes checker; under the older
# shard_map every value is already device-varying, so it's the identity
_pvary = getattr(lax, "pvary", lambda x, axes: x)

from repro.core.aggregates import scalar_aggregate
from repro.core.plan import (
    FinalAggOp,
    FreqJoinOp,
    MaterializeJoinOp,
    PhysicalPlan,
    ScanOp,
    SemiJoinOp,
)
from repro.tables.table import Schema, Table, pack_keys


def _local_multiplier(pk, ck, cf, mode: str):
    """Exact multiplier of parent keys against ONE child shard
    (sort + prefix-sum + searchsorted; same algorithm as kernels.ops)."""
    order = jnp.argsort(ck)
    cks = ck[order]
    cfs = cf[order]
    if mode == "any":
        cfs = (cfs > 0).astype(cfs.dtype)
    prefix = jnp.concatenate([jnp.zeros((1,), cfs.dtype), jnp.cumsum(cfs)])
    lo = jnp.searchsorted(cks, pk, side="left")
    hi = jnp.searchsorted(cks, pk, side="right")
    return prefix[hi] - prefix[lo]


def ring_freq_join(pk, pf, ck, cf, *, ring_axes: Sequence[str],
                   mode: str = "sum", presort: bool = False):
    """Inside shard_map: exact FreqJoin with the child sharded over
    `ring_axes` (innermost axis rotates fastest).  Returns new parent freq.

    presort=False — baseline: each ring step sorts the visiting shard
        (what a naive port of the paper's sort-merge join does: Spark
        re-sorts per shuffle partition).
    presort=True  — beyond-paper: each shard sorts its child block ONCE
        and the ring rotates (sorted keys, prefix sums); every step is
        then two searchsorteds + a gather.  Saves (P−1) sorts per join —
        see EXPERIMENTS.md §Perf (engine cell).
    """
    mult = _pvary(jnp.zeros(pk.shape, pf.dtype), tuple(ring_axes))

    def rotate(x, axis):
        size = lax.psum(1, axis)
        perm = [(i, (i + 1) % size) for i in range(size)]
        return lax.ppermute(x, axis, perm)

    if presort:
        order = jnp.argsort(ck)
        cks = ck[order]
        cfs = cf[order]
        if mode == "any":
            cfs = (cfs > 0).astype(pf.dtype)
        prefix = jnp.concatenate(
            [jnp.zeros((1,), cfs.dtype), jnp.cumsum(cfs)])
        payload = (cks, prefix)

        def local(payload_):
            cks_, prefix_ = payload_
            lo = jnp.searchsorted(cks_, pk, side="left")
            hi = jnp.searchsorted(cks_, pk, side="right")
            return (prefix_[hi] - prefix_[lo]).astype(pf.dtype)
    else:
        payload = (ck, cf)

        def local(payload_):
            ck_, cf_ = payload_
            return _local_multiplier(pk, ck_, cf_, mode).astype(pf.dtype)

    # nested rings: data-ring innermost (ICI), pod-ring outermost (DCI)
    axes = list(ring_axes)
    sizes = [lax.psum(1, a) for a in axes]

    def body(carry, _):
        payload_, mult_ = carry
        m = local(payload_)
        mult_ = jnp.maximum(mult_, m) if mode == "any" else mult_ + m
        payload_ = jax.tree.map(lambda x: rotate(x, axes[-1]), payload_)
        return (payload_, mult_), None

    total_inner = sizes[-1]
    carry = (payload, mult)
    if len(axes) == 1:
        carry, _ = lax.scan(body, carry, None, length=total_inner)
    else:
        outer_axis, outer_size = axes[0], sizes[0]

        def outer_body(carry, _):
            carry, _ = lax.scan(body, carry, None, length=total_inner)
            payload_, mult_ = carry
            payload_ = jax.tree.map(lambda x: rotate(x, outer_axis),
                                    payload_)
            return (payload_, mult_), None

        carry, _ = lax.scan(outer_body, carry, None, length=outer_size)
    _, mult = carry
    if mode == "any":
        mult = (mult > 0).astype(pf.dtype)
    return pf * mult


def allreduce_freq_join(pk, pf, ck, cf, *, ring_axes: Sequence[str],
                        mode: str = "sum", domain: int):
    """Beyond-paper distributed FreqJoin for dense key domains: each shard
    scatter-adds its child block into a domain-sized accumulator, ONE psum
    over the ring axes produces the global multiplier table, and parents
    gather locally.  Replaces P ring steps (P ppermutes + P searchsorted
    passes) with one all-reduce of `domain` elements — the distributed
    twin of the local dense-domain FreqJoin (EXPERIMENTS §Perf)."""
    cfx = (cf > 0).astype(pf.dtype) if mode == "any" else cf.astype(pf.dtype)
    acc = jnp.zeros((domain,), pf.dtype)
    acc = acc.at[jnp.clip(ck, 0, domain - 1)].add(
        jnp.where((ck >= 0) & (ck < domain), cfx, 0))
    for a in ring_axes:
        acc = lax.psum(acc, a)
    mult = acc[jnp.clip(pk, 0, domain - 1)]
    mult = jnp.where((pk >= 0) & (pk < domain), mult, 0)
    if mode == "any":
        mult = (mult > 0).astype(pf.dtype)
    return pf * mult


class DistributedExecutor:
    """Executes oma/opt_plus plans with row-sharded tables.

    Tables are sharded on rows over `data_axes` (e.g. ("pod", "data") on the
    production mesh); the bottom-up sweep runs in one shard_map program with
    Ring-FreqJoins; final aggregation runs on the sharded root columns under
    jit (XLA inserts the cross-shard reductions).
    """

    def __init__(self, schema: Schema, mesh: jax.sharding.Mesh,
                 data_axes: Sequence[str] = ("data",),
                 freq_dtype=jnp.int32, presort: bool = False,
                 dense_domain: bool = False):
        self.schema = schema
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.freq_dtype = freq_dtype
        self.presort = presort
        self.dense_domain = dense_domain

    # -- sharding helpers --------------------------------------------------
    def row_sharding(self):
        return jax.sharding.NamedSharding(
            self.mesh, jax.sharding.PartitionSpec(self.data_axes))

    def shard_db(self, db: dict[str, Table]) -> dict[str, Table]:
        """Pad each table to a multiple of the ring size and shard rows."""
        n_shards = 1
        for a in self.data_axes:
            n_shards *= self.mesh.shape[a]
        out = {}
        sh = self.row_sharding()
        for name, t in db.items():
            cap = ((t.capacity + n_shards - 1) // n_shards) * n_shards
            cols = {}
            for c, arr in t.columns.items():
                pad = jnp.zeros((cap - t.capacity,) + arr.shape[1:], arr.dtype)
                cols[c] = jax.device_put(jnp.concatenate([arr, pad]), sh)
            freq = jax.device_put(
                jnp.concatenate([t.freq,
                                 jnp.zeros((cap - t.capacity,), t.freq.dtype)]),
                sh)
            out[name] = Table(cols, freq)
        return out

    # -- plan execution -----------------------------------------------------
    def compile(self, plan: PhysicalPlan):
        if any(isinstance(op, MaterializeJoinOp) for op in plan.ops):
            raise ValueError("distributed execution supports the "
                             "zero-materialisation plan classes (oma/opt_plus)")
        schema = self.schema
        freq_dtype = self.freq_dtype
        data_axes = self.data_axes

        def domains(alias):
            atom = plan.tree.atoms[alias]
            rel = schema.relations[atom.rel]
            return {v: rel.columns[i].domain
                    for i, v in enumerate(atom.vars)}

        def key_of(alias, cols, freq, on_vars):
            if not on_vars:
                return jnp.zeros(freq.shape, jnp.int32), 1
            doms = domains(alias)
            dlist = [doms.get(v) for v in on_vars]
            key = pack_keys([cols[v] for v in on_vars], dlist)
            dom = None
            if self.dense_domain and all(d is not None for d in dlist):
                dom = 1
                for d in dlist:
                    dom *= d
                if dom >= (1 << 31):
                    dom = None
            return key, dom

        final: FinalAggOp = next(op for op in plan.ops
                                 if isinstance(op, FinalAggOp))

        def sweep(db: dict[str, Table]):
            """Runs per-shard under shard_map; returns root cols + freq."""
            state: dict[str, tuple[dict, jax.Array]] = {}
            for op in plan.ops:
                if isinstance(op, ScanOp):
                    t = db[op.rel]
                    if op.selection is not None:
                        t = t.select(op.selection)
                    atom = plan.tree.atoms[op.alias]
                    rel = schema.relations[atom.rel]
                    cols = {atom.vars[i]: t.columns[c]
                            for i, c in enumerate(rel.column_names())}
                    state[op.alias] = (cols, t.freq.astype(freq_dtype))
                elif isinstance(op, (SemiJoinOp, FreqJoinOp)):
                    pcols, pf = state[op.parent]
                    ccols, cf = state[op.child]
                    pk, _pd = key_of(op.parent, pcols, pf, op.on_vars)
                    ck, cdom = key_of(op.child, ccols, cf, op.on_vars)
                    mode = "any" if isinstance(op, SemiJoinOp) else "sum"
                    if cdom is not None:
                        pf = allreduce_freq_join(pk, pf, ck, cf,
                                                 ring_axes=data_axes,
                                                 mode=mode, domain=cdom)
                    else:
                        pf = ring_freq_join(pk, pf, ck, cf,
                                            ring_axes=data_axes, mode=mode,
                                            presort=self.presort)
                    state[op.parent] = (pcols, pf)
                elif isinstance(op, FinalAggOp):
                    pass
            return state[plan.tree.root]

        in_specs = jax.sharding.PartitionSpec(data_axes)

        def run(db: dict[str, Table]):
            specs = jax.tree.map(lambda _: in_specs, db)
            cols, freq = _shard_map(
                sweep, mesh=self.mesh, in_specs=(specs,),
                out_specs=in_specs)(db)
            out = {}
            for ag in final.aggregates:
                out[ag.name] = scalar_aggregate(ag, cols, freq, final.dedup)
            return out

        return jax.jit(run)
