"""AdamW with decoupled weight decay, global-norm clipping, and cosine LR.

Params live in f32 (the "master" copy); model code casts to bf16 at use
sites, so no separate cast copy is materialised.  Optimizer state shards
exactly like the parameters (the spec tree is reused), which is what makes
FSDP + elastic re-meshing work for the whole train state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    m: Any
    v: Any


jax.tree_util.register_pytree_node(
    AdamWState,
    lambda s: ((s.step, s.m, s.v), None),
    lambda _, c: AdamWState(*c),
)


def adamw_init(params, state_dtype=jnp.float32) -> AdamWState:
    """state_dtype=bfloat16 halves optimizer HBM (m/v stored bf16, math in
    f32) — the memory-term lever for the biggest models (EXPERIMENTS §Perf)."""
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, state_dtype), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, *,
                 lr: jax.Array | float,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    def upd_m(m, g):
        return (b1 * m.astype(jnp.float32) + (1 - b1) * g).astype(m.dtype)

    def upd_v(v, g):
        return (b2 * v.astype(jnp.float32)
                + (1 - b2) * g * g).astype(v.dtype)

    new_m = jax.tree.map(upd_m, state.m, grads)
    new_v = jax.tree.map(upd_v, state.v, grads)

    def upd(p, m, v):
        mhat = m.astype(jnp.float32) / b1c
        vhat = v.astype(jnp.float32) / b2c
        return (p - lr * (mhat / (jnp.sqrt(vhat) + eps)
                          + weight_decay * p)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr
