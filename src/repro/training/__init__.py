from repro.training.optimizer import AdamWState, adamw_init, adamw_update
from repro.training.losses import cross_entropy_loss
from repro.training.step import TrainState, build_train_step, init_train_state

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cross_entropy_loss",
    "TrainState",
    "build_train_step",
    "init_train_state",
]
