"""Losses: masked cross-entropy with z-loss (logit-norm regulariser)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

IGNORE = -1  # label value excluded from the loss (e.g. image positions)


def cross_entropy_loss(logits, labels, z_weight: float = 1e-4):
    """logits [B,S,V] (any float dtype), labels [B,S] int (IGNORE masked).

    Returns (loss, metrics)."""
    logits = logits.astype(jnp.float32)
    mask = (labels != IGNORE).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = nll.sum() / denom
    zloss = (jnp.square(lse) * mask).sum() / denom
    loss = ce + z_weight * zloss
    return loss, {"ce": ce, "zloss": zloss,
                  "tokens": mask.sum().astype(jnp.int32)}
