"""Train step: microbatched gradient accumulation + AdamW.

The global batch is split into M microbatches and processed by a
`lax.scan`; gradients accumulate in f32.  Two consequences matter at scale:

  * peak activation memory is that of ONE microbatch (the logits tensor of
    a full 1M-token batch over a 262k vocab would be ~0.5 PB — microbatching
    is not an optimisation here, it is the feasibility condition);
  * under FSDP the per-microbatch reduce-scatters overlap with the next
    microbatch's compute (XLA latency hiding across scan iterations).

Optional int8 error-feedback gradient compression (distributed/compression)
applies to the accumulated gradient before the optimizer — the knob for
cross-pod (DCI) bandwidth relief.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.models.config import ModelConfig
from repro.training.losses import cross_entropy_loss
from repro.training.optimizer import (
    AdamWState,
    adamw_init,
    adamw_update,
    cosine_schedule,
)


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    step: jax.Array


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.step), None),
    lambda _, c: TrainState(*c),
)


def init_train_state(params, opt_state_dtype=jnp.float32) -> TrainState:
    return TrainState(params=params,
                      opt=adamw_init(params, opt_state_dtype),
                      step=jnp.zeros((), jnp.int32))


def build_train_step(cfg: ModelConfig, *, microbatches: int = 1,
                     base_lr: float = 3e-4, warmup: int = 100,
                     total_steps: int = 10_000, remat: str = "full",
                     compress_grads: bool = False,
                     weight_decay: float = 0.1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    batch: {"tokens" [B,S], "labels" [B,S], optional "image_embeds"}.
    B must divide by `microbatches`.
    """
    lr_fn = cosine_schedule(base_lr, warmup, total_steps)

    def loss_fn(params, mb):
        logits, aux = forward(params, cfg, mb, remat=remat)
        labels = mb["labels"]
        if cfg.frontend == "vision_stub":
            # image positions carry no next-token loss
            pad = jnp.full(labels.shape[:1] + (cfg.num_patches,), -1,
                           labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        loss, metrics = cross_entropy_loss(logits, labels)
        if cfg.family == "moe" and aux is not None:
            loss = loss + cfg.router_aux_weight * aux["load_balance"] \
                + cfg.router_z_weight * aux["router_z"]
            metrics = dict(metrics, load_balance=aux["load_balance"],
                           dropped_frac=aux["dropped_frac"])
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        m = microbatches

        def to_mb(x):
            return x.reshape((m, x.shape[0] // m) + x.shape[1:])

        mbs = jax.tree.map(to_mb, batch)
        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)

        def mb_body(carry, mb):
            g_acc, loss_acc = carry
            (loss, metrics), g = grad_fn(state.params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 g_acc, g)
            return (g_acc, loss_acc + loss), metrics

        (g_sum, loss_sum), metrics = jax.lax.scan(
            mb_body, (zero_g, jnp.zeros((), jnp.float32)), mbs)
        grads = jax.tree.map(lambda g: g / m, g_sum)

        if compress_grads:
            from repro.distributed.compression import ef_int8_roundtrip
            grads = jax.tree.map(ef_int8_roundtrip, grads)

        lr = lr_fn(state.step)
        params, opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, lr=lr,
            weight_decay=weight_decay)
        new_state = TrainState(params, opt, state.step + 1)
        out_metrics = {
            "loss": loss_sum / m,
            **{k: v[-1] for k, v in metrics.items()},
            **opt_metrics,
        }
        return new_state, out_metrics

    return train_step
